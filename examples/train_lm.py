"""LM training driver on the public API (CPU-runnable reduced config).

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3_0_6b] [--steps 30]

Uses the full trainer (checkpointing + LEA-coded DP + compression available
via flags on repro.launch.train); asserts the loss actually decreases.
For the production-scale run, drop --smoke and launch on a pod:
    python -m repro.launch.train --arch qwen3_0_6b --steps 1000 ...
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    out = train_mod.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
    ])
    losses = [h["loss"] for h in out["history"] if "loss" in h]
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
          f"({out['wall_s']:.1f}s)")
    assert losses[-1] < losses[0], "training must reduce the loss"
    print("OK")


if __name__ == "__main__":
    main()
