"""End-to-end driver of the paper's kind: deadline-constrained coded linear
regression over a simulated credit-based cluster (the Sec. 2.1 example,
deg f = 2), LEA vs static.

    PYTHONPATH=src python examples/coded_regression.py

Each round evaluates the gradient f(X_j, y_j) = X_j^T (X_j w - y_j) on
Lagrange-encoded data; rounds that miss the deadline are lost (no update).
LEA learns the workers' Markov dynamics and sustains a much higher timely
throughput, so it converges while the static allocation starves.

The whole simulation side runs on the PR-1 batched engine: ONE
``throughput.rollout`` call samples the trajectory and allocates every
round for both strategies (a single batched allocator DP), per-chunk
on-time masks come from one vectorised ``chunk_on_time`` call, and round
success is one vectorised comparison — the seed-era per-round
estimator/update/allocate Python loop is gone.  Only the gradient-descent
recursion itself (w_{m+1} depends on w_m) runs round-by-round, decoding
through a memoised ``DecodeCache``.

Exact-path variant: the float descent above is the ML adaptation (decode
conditioning caps k); the paper's protocol is EXACT over a finite field.
The final section replays the same LEA straggler patterns through the
exact DEGREE-2 gradient ``coded_linear_gradient_modp`` — the very
polynomial this example's workers evaluate, with encode, worker-shard
gradient GEMMs and erasure-aware decode all on device over GF(2^31 - 1) —
and checks every decoded gradient against the numpy ``matmul_modp`` /
``decode_matrix_modp`` oracle to the last bit.  The regression example is
GF(p) end to end.

Smoke knob: REPRO_EXAMPLE_ROUNDS overrides the round count (CI gate).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FIELD_P, CodeSpec, DecodeCache, LoadParams,
                        chunk_on_time, coded_linear_gradient,
                        coded_linear_gradient_modp, decode_matrix_modp,
                        encode_dataset, encode_dataset_modp, matmul_modp)
from repro.core import throughput

# NOTE on k: the decode interpolates a degree-(k-1)*2 polynomial; over the
# reals in float32 that is well-conditioned up to k ~ 10 (the paper works in
# a finite field F where conditioning does not exist — DESIGN §9; the exact
# GF(p) path in repro.core.lagrange covers large k bit-exactly).
N, R, K = 10, 6, 8
MU_G, MU_B, D = 6.0, 1.0, 1.0
P_GG, P_BB = 0.85, 0.7
ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "120"))
ROWS, COLS = 20, 12
STRATEGIES = ("lea", "static_equal")   # paper's iid prob-1/2 static benchmark

spec = CodeSpec(N, R, K, deg_f=2)
lp = LoadParams(n=N, kstar=spec.recovery_threshold,
                ell_g=int(min(MU_G * D, R)), ell_b=int(MU_B * D))
print(f"K* = {lp.kstar} (mode={spec.mode}), ell_g={lp.ell_g}, ell_b={lp.ell_b}")

rng = np.random.default_rng(0)
w_true = rng.normal(size=(COLS,))
x_chunks = rng.normal(size=(K, ROWS, COLS))
y_chunks = x_chunks @ w_true + 0.01 * rng.normal(size=(K, ROWS))
coded = encode_dataset(spec, jnp.asarray(x_chunks, jnp.float32),
                       jnp.asarray(y_chunks, jnp.float32))

# -- one engine rollout: trajectory + every round's loads for BOTH strategies
states, loads, feasible = throughput.rollout(
    jax.random.PRNGKey(0), lp, jnp.full((N,), P_GG), jnp.full((N,), P_BB),
    ROUNDS, strategies=STRATEGIES,
)
success = throughput.score_rollout(states, loads, feasible, lp,
                                   MU_G, MU_B, D)                  # (M, S)
# every round's erasure pattern in one vectorised call: which encoded
# evaluations arrived (the first loads[i] chunks of each on-time worker)
on_time_all = chunk_on_time(states, loads, MU_G, MU_B, D, R)       # (S, M, nr)
success_h, on_time_h = np.asarray(success), np.asarray(on_time_all)


def descend(strategy: str):
    """Gradient descent over the successful rounds of one strategy."""
    j = STRATEGIES.index(strategy)
    cache = DecodeCache(spec)
    w = jnp.zeros((COLS,), jnp.float32)
    lr = 2e-2 / (K * ROWS)
    hits, losses = 0, []
    for m in range(ROUNDS):
        if success_h[m, j]:
            hits += 1
            grad = coded_linear_gradient(coded, w, on_time_h[j, m], cache=cache)
            # float-decode sanity guard: an ill-conditioned received set (rare
            # under the strided alphas, possible under static's all-or-nothing
            # patterns) is treated as a failed round, like a checksum miss.
            gnorm = float(jnp.linalg.norm(grad))
            if not np.isfinite(gnorm) or gnorm > 1e4 * K * ROWS:
                hits -= 1
            else:
                w = w - lr * grad
        losses.append(float(jnp.mean((jnp.asarray(x_chunks) @ w
                                      - jnp.asarray(y_chunks)) ** 2)))
    return hits / ROUNDS, w, losses


tput_lea, w_lea, loss_lea = descend("lea")
tput_static, w_static, loss_static = descend("static_equal")
err_lea = float(np.linalg.norm(np.asarray(w_lea) - w_true) / np.linalg.norm(w_true))
err_static = float(np.linalg.norm(np.asarray(w_static) - w_true) / np.linalg.norm(w_true))
print(f"LEA    : timely throughput {tput_lea:.3f}, final loss {loss_lea[-1]:.4f}, "
      f"|w-w*|/|w*| = {err_lea:.3f}")
print(f"static : timely throughput {tput_static:.3f}, final loss {loss_static[-1]:.4f}, "
      f"|w-w*|/|w*| = {err_static:.3f}")
assert tput_lea > tput_static, "LEA should beat the static allocation"
assert err_lea < err_static, "more on-time rounds => closer to w*"

# -- exact-path variant: the SAME straggler patterns, over the paper's field -
# The SAME deg-2 code (spec, K* = 15) evaluated exactly: integer twins of the
# regression data, encoded over GF(p), each round's worker-side gradient
# X~^T(X~ w - y~) computed with the Mersenne-31 GEMMs and decoded through the
# round's erasure pattern — the full degree-2 protocol, GF(p) end to end.
# Every decoded gradient must agree with the numpy modp oracle bit for bit.
rng_x = np.random.default_rng(1)
x_int = rng_x.integers(0, FIELD_P, size=(K, ROWS, COLS), dtype=np.int64)
y_int = rng_x.integers(0, FIELD_P, size=(K, ROWS), dtype=np.int64)
w_int = rng_x.integers(0, FIELD_P, size=(COLS,), dtype=np.int64)
coded_x = encode_dataset_modp(spec, jnp.asarray(x_int, jnp.int32),
                              jnp.asarray(y_int, jnp.int32))
xt_np = np.asarray(coded_x.x_tilde, np.int64)
yt_np = np.asarray(coded_x.y_tilde, np.int64)

j_lea = STRATEGIES.index("lea")
exact_jit = jax.jit(lambda m: coded_linear_gradient_modp(
    coded_x, jnp.asarray(w_int, jnp.int32), m))
# round-invariant worker-side chunk gradients, by the numpy oracle
grads_np = np.stack([
    matmul_modp(
        xt_np[v].T,
        ((matmul_modp(xt_np[v], w_int.reshape(-1, 1))[:, 0] - yt_np[v])
         % FIELD_P).reshape(-1, 1),
    )[:, 0]
    for v in range(spec.nr)
])                                           # (nr, cols)
checked = 0
for m in range(ROUNDS):
    on = on_time_h[j_lea, m]
    if on.sum() < spec.recovery_threshold:
        continue
    out, ok = exact_jit(jnp.asarray(on))
    rec = np.nonzero(on)[0][: spec.recovery_threshold]
    per_chunk = matmul_modp(decode_matrix_modp(spec, rec), grads_np[rec])
    want = per_chunk.sum(axis=0) % FIELD_P
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)
    checked += 1
    if checked >= 6:
        break
print(f"exact  : GF(p) deg-2 gradient round == numpy modp oracle on {checked} "
      f"LEA straggler patterns (K*={spec.recovery_threshold}, bit-exact)")
print("OK")
