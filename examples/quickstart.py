"""Quickstart: one round of Lagrange-coded computation with LEA allocation.

    PYTHONPATH=src python examples/quickstart.py

Encodes a dataset across 5 simulated workers, lets LEA pick the per-worker
loads from its state estimates, drops the stragglers, and decodes the matmul
from the K* fastest results.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CodeSpec, LoadParams, allocate, encode_dataset,
                        coded_matmul, init_estimator, predicted_good_prob,
                        update_estimator)

# -- a 5-worker cluster storing r=2 coded chunks each, k=6 data chunks -------
spec = CodeSpec(n=5, r=2, k=6, deg_f=1)
print(f"code: mode={spec.mode}, recovery threshold K*={spec.recovery_threshold}")

rng = np.random.default_rng(0)
x_chunks = jnp.asarray(rng.normal(size=(spec.k, 16, 8)), jnp.float32)
w = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

coded = encode_dataset(spec, x_chunks)       # "stored at the workers"

# -- LEA: estimate worker states, allocate two-level loads -------------------
lp = LoadParams(n=spec.n, kstar=spec.recovery_threshold, ell_g=2, ell_b=1)
est = init_estimator(spec.n)
est = update_estimator(est, jnp.asarray([1, 1, 0, 1, 0]))   # observed round 1
est = update_estimator(est, jnp.asarray([1, 0, 0, 1, 1]))   # observed round 2
p_good = predicted_good_prob(est)
loads, i_star = allocate(p_good, lp)
print("estimated P[good]:", np.round(np.asarray(p_good), 3))
print("LEA allocation   :", np.asarray(loads), f"(i*={int(i_star)})")

# -- the network decides who is on time; master decodes from any K* ----------
true_states = np.array([1, 0, 0, 1, 1])      # worker 1,2 slow this round
on_time = np.zeros(spec.nr, bool)
for i in range(spec.n):
    done = int(loads[i]) if (true_states[i] or loads[i] <= lp.ell_b) else 0
    on_time[i * spec.r: i * spec.r + done] = True
print(f"on-time encoded chunks: {int(on_time.sum())}/{spec.nr}")

result = coded_matmul(coded, w, on_time)
expected = jnp.einsum("krc,c->kr", x_chunks, w)
err = float(jnp.max(jnp.abs(result - expected)))
print(f"decoded f(X_j) = X_j @ w for all {spec.k} chunks, max err {err:.2e}")
assert err < 1e-3
print("OK")
