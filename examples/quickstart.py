"""Quickstart: one round of Lagrange-coded computation with LEA allocation,
then a whole paper-scale scenario grid in one line.

    PYTHONPATH=src python examples/quickstart.py

Encodes a dataset across 5 simulated workers, lets LEA pick the per-worker
loads from its state estimates — using the batched allocate API: the
estimator's predictions after round 1 AND after round 2 are stacked on a
leading axis and solved by ONE allocator DP — drops the stragglers, and
decodes the matmul from the K* fastest results.
Finishes with the `repro.sweeps` one-liner that replays a slice of the
paper's Fig. 3 Monte-Carlo grid, then a `repro.policies` comparison on a
drifting (non-stationary) chain where windowed LEA beats vanilla LEA.

Smoke knob: REPRO_QUICKSTART_ROUNDS overrides the sweep length (CI gate).
"""

import os

import jax.numpy as jnp
import numpy as np

from repro.core import (FIELD_P, CodeSpec, LoadParams, allocate,
                        coded_matmul, coded_matmul_exact, encode_dataset,
                        encode_dataset_modp, init_estimator, matmul_modp,
                        predicted_good_prob, update_estimator)

# -- a 5-worker cluster storing r=2 coded chunks each, k=6 data chunks -------
spec = CodeSpec(n=5, r=2, k=6, deg_f=1)
print(f"code: mode={spec.mode}, recovery threshold K*={spec.recovery_threshold}")

rng = np.random.default_rng(0)
x_chunks = jnp.asarray(rng.normal(size=(spec.k, 16, 8)), jnp.float32)
w = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

coded = encode_dataset(spec, x_chunks)       # "stored at the workers"

# -- LEA: estimate worker states, allocate two-level loads -------------------
# The PR-1 allocate API is batched over leading axes (the LoadParams are
# static): the predictions after round 1 and after round 2 go through ONE
# (2, n) allocator DP, showing how the engine allocates every round of a
# Monte-Carlo sweep in a single batched call.
lp = LoadParams(n=spec.n, kstar=spec.recovery_threshold, ell_g=2, ell_b=1)
est = init_estimator(spec.n)
est = update_estimator(est, jnp.asarray([1, 1, 0, 1, 0]))   # observed round 1
p_good_r1 = predicted_good_prob(est)
est = update_estimator(est, jnp.asarray([1, 0, 0, 1, 1]))   # observed round 2
p_good = predicted_good_prob(est)
loads_b, i_star_b = allocate(jnp.stack([p_good_r1, p_good]), lp)  # one DP
for rnd, (p, ld, i) in enumerate(zip((p_good_r1, p_good), loads_b, i_star_b), 1):
    print(f"after round {rnd}: P[good]~{np.round(np.asarray(p), 3)}"
          f" -> loads {np.asarray(ld)} (i*={int(i)})")
loads = loads_b[-1]                          # act on the freshest estimate

# -- the network decides who is on time; master decodes from any K* ----------
true_states = np.array([1, 0, 0, 1, 1])      # worker 1,2 slow this round
on_time = np.zeros(spec.nr, bool)
for i in range(spec.n):
    done = int(loads[i]) if (true_states[i] or loads[i] <= lp.ell_b) else 0
    on_time[i * spec.r: i * spec.r + done] = True
print(f"on-time encoded chunks: {int(on_time.sum())}/{spec.nr}")

result = coded_matmul(coded, w, on_time)
expected = jnp.einsum("krc,c->kr", x_chunks, w)
err = float(jnp.max(jnp.abs(result - expected)))
print(f"decoded f(X_j) = X_j @ w for all {spec.k} chunks, max err {err:.2e}")
assert err < 1e-3

# -- the same round, EXACT over the paper's finite field GF(2^31 - 1) --------
# No float conditioning, no tolerance: encode, worker matmul and the
# erasure-aware decode all run on device in Mersenne-31 arithmetic
# (repro.kernels.gf) and agree with the numpy modp oracle to the last bit.
rng_x = np.random.default_rng(1)
x_int = rng_x.integers(0, FIELD_P, size=(spec.k, 16, 8), dtype=np.int64)
w_int = rng_x.integers(0, FIELD_P, size=(8,), dtype=np.int64)
coded_x = encode_dataset_modp(spec, jnp.asarray(x_int, jnp.int32))
out, ok = coded_matmul_exact(coded_x, jnp.asarray(w_int, jnp.int32),
                             jnp.asarray(on_time))
exact_want = matmul_modp(x_int.reshape(-1, 8), w_int.reshape(-1, 1)).reshape(spec.k, 16)
assert bool(ok)
np.testing.assert_array_equal(np.asarray(out, np.int64), exact_want)
print(f"exact GF(p) decode: bit-identical to the numpy oracle (p = {FIELD_P})")

# -- the paper's Fig. 3 grid, through the sweep subsystem, in one line -------
from repro import sweeps

rounds = int(os.environ.get("REPRO_QUICKSTART_ROUNDS", "500"))
for r in sweeps.run("fig3", rounds=rounds):
    print(f"{r.name}: " + " ".join(f"R_{s}={v:.3f}" for s, v in r.throughput.items())
          + f"  lea/static={r.ratio['lea']:.2f}x")
    assert r.throughput["lea"] >= r.throughput["static"]

# -- pluggable policies: on a drifting chain, windowed LEA tracks the regime -
# while vanilla LEA's all-history counts lag (repro.policies; regret columns
# measure the gap to the genie oracle on the shared trajectory)
for r in sweeps.run("drifting_chains", periods=(150,), rounds=max(rounds, 300), step=25):
    print(f"{r.name}: R_lea={r.throughput['lea']:.3f} "
          f"R_lea_window64={r.throughput['lea_window64']:.3f} "
          f"regret: lea={r.regret['lea']:.0f} lea_window64={r.regret['lea_window64']:.0f}")
print("OK")
