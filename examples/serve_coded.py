"""Online coded-computation service (the paper's EC2 workload, Sec. 6.2):
linear requests f_m(X_j) = X_j^T b_m arrive with shift-exponential gaps and a
hard per-round deadline; the service uses LEA to allocate worker loads and
decodes each round from the K* fastest results.

    PYTHONPATH=src python examples/serve_coded.py

Two stages, both on the batched engine (the seed-era per-round host loop —
eager estimator updates, a hand-built on-time chunk mask — is gone):

  1. OFFLINE: one ``throughput.rollout`` samples the trajectory and every
     round's LEA loads, ``chunk_on_time`` derives every round's erasure
     pattern in one vectorised call, and a few served rounds are decoded
     EXACTLY over GF(2^31 - 1) with ``coded_matmul_exact`` and checked
     against the numpy mod-p oracle bit for bit (k = 50 is far beyond
     float-decode conditioning — the paper's protocol is finite-field).
  2. STREAMING: ``repro.serving.simulate_serving`` runs the same pool as an
     online service — shift-exponential arrivals feed a device-resident
     request queue, EDF water-filling splits the workers across in-flight
     requests, and admission control sheds requests the pool would miss —
     one compiled ``lax.scan``, full per-request accounting.

Smoke knob: REPRO_EXAMPLE_ROUNDS overrides the round count (CI gate).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import (FIELD_P, CodeSpec, LoadParams, chunk_on_time,
                        coded_matmul_exact, encode_dataset_modp, matmul_modp)
from repro.core import throughput

N, R, K = 15, 10, 50              # paper Sec. 6.2, scenario 5/6 scale (k=50)
MU_G, MU_B, D = 10.0, 1.0, 6.0    # 10x credit gap (Fig. 1), d=6s
P_GG, P_BB = 0.85, 0.6
ROUNDS = int(os.environ.get("REPRO_EXAMPLE_ROUNDS", "200"))
T_C, MEAN = 0.2, 0.8              # shift-exp arrival gaps, in round units

spec = CodeSpec(N, R, K, deg_f=1)
lp = LoadParams(n=N, kstar=spec.recovery_threshold,
                ell_g=int(min(MU_G * D, R)), ell_b=int(MU_B * D))
print(f"service: n={N} workers, K*={lp.kstar}, loads ({lp.ell_g}/{lp.ell_b})")

rng = np.random.default_rng(0)
x_int = rng.integers(0, FIELD_P, size=(K, 6, 32), dtype=np.int64)
coded = encode_dataset_modp(spec, jnp.asarray(x_int, jnp.int32))

# -- 1. offline: one rollout, every round's loads + erasure patterns --------
p_gg, p_bb = jnp.full((N,), P_GG), jnp.full((N,), P_BB)
states, loads, feasible = throughput.rollout(
    jax.random.PRNGKey(0), lp, p_gg, p_bb, ROUNDS, strategies=("lea",),
)
success = throughput.score_rollout(states, loads, feasible, lp,
                                   MU_G, MU_B, D)               # (M, 1)
on_time = np.asarray(chunk_on_time(states, loads, MU_G, MU_B, D, R))
served = int(np.asarray(success)[:, 0].sum())

# decode a few served rounds exactly and check the numpy mod-p oracle
exact_jit = jax.jit(lambda b, m: coded_matmul_exact(coded, b, m))
checked = 0
for m in range(ROUNDS):
    if not bool(success[m, 0]):
        continue
    b_int = rng.integers(0, FIELD_P, size=(32,), dtype=np.int64)
    out, ok = exact_jit(jnp.asarray(b_int, jnp.int32),
                        jnp.asarray(on_time[0, m]))
    want = np.stack([matmul_modp(x_int[j], b_int.reshape(-1, 1))[:, 0]
                     for j in range(K)])
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)
    checked += 1
    if checked >= 4:
        break
print(f"decode : {checked} served rounds decoded from K*={lp.kstar} "
      f"fastest results over GF(p), bit-exact vs the numpy oracle")
print(f"timely computation throughput: {served/ROUNDS:.3f} "
      f"({served}/{ROUNDS} rounds)")
assert served / ROUNDS > 0.5

# -- 2. streaming: the same pool as an online service -----------------------
process = serving.make_process("shift_exp", t_const=T_C, mean=MEAN)
req = serving.RequestSpec(
    kstar=lp.kstar, ell_g=lp.ell_g, ell_b=lp.ell_b,
    deadline_rel=1,            # finish by the round after arrival
    admit_threshold=0.5, reserve_cap=1.0,
)
out = serving.simulate_serving(
    jax.random.PRNGKey(0), jnp.ones((N,), bool), p_gg, p_bb,
    MU_G, MU_B, D, req, process,
    rounds=ROUNDS, strategies=("lea",), capacity=4,
)
arr = int(out.arrivals[0])
adm = int(out.admitted[0])
on_t = int(out.served_on_time[0])
lat = np.asarray(out.sojourn)[0][np.asarray(out.events)[0] != 0]
print(f"stream : {arr} arrivals (shift-exp gaps {T_C}+Exp({MEAN}) rounds), "
      f"{adm} admitted, {on_t} served on time, "
      f"{int(out.rejected[0])} shed by admission")
print(f"stream : service throughput {on_t/max(arr, 1):.3f}, "
      f"median sojourn {np.median(lat) if lat.size else 0:.0f} round(s)")
# every request ends in exactly one disposition
assert arr == adm + int(out.rejected[0])
assert adm == (on_t + int(out.served_late[0]) + int(out.expired[0])
               + int(out.in_flight[0]))
assert on_t > 0
print("OK")
