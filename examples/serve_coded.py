"""Online coded-computation service (the paper's EC2 workload, Sec. 6.2):
linear requests f_m(X_j) = X_j^T B_m arrive with shift-exponential gaps and a
hard per-round deadline; the service uses LEA to allocate worker loads and
decodes each round from the K* fastest results.

    PYTHONPATH=src python examples/serve_coded.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CodeSpec, LoadParams, allocate, coded_matmul,
                        encode_dataset, init_estimator, predicted_good_prob,
                        round_success, update_estimator)
from repro.core.markov import initial_states, step_states

N, R, K = 15, 10, 50              # paper Sec. 6.2, scenario 5/6 scale (k=50)
MU_G, MU_B, D = 10.0, 1.0, 6.0    # 10x credit gap (Fig. 1), d=6s
P_GG, P_BB = 0.85, 0.6
ROUNDS = 40
T_C, LAM = 0.0, 0.02              # arrival gap (scaled down for the demo)

spec = CodeSpec(N, R, K, deg_f=1)
lp = LoadParams(n=N, kstar=spec.recovery_threshold,
                ell_g=int(min(MU_G * D, R)), ell_b=int(MU_B * D))
print(f"service: n={N} workers, K*={lp.kstar}, loads ({lp.ell_g}/{lp.ell_b})")

rng = np.random.default_rng(0)
x_chunks = jnp.asarray(rng.normal(size=(K, 6, 32)), jnp.float32)
coded = encode_dataset(spec, x_chunks)

key = jax.random.PRNGKey(0)
key, k0 = jax.random.split(key)
states = initial_states(k0, jnp.full((N,), P_GG), jnp.full((N,), P_BB))
est = init_estimator(N)
served = 0
t_start = time.time()
for m in range(ROUNDS):
    time.sleep(min(T_C + rng.exponential(LAM), 0.1))      # request arrival
    b_m = jnp.asarray(rng.normal(size=(32,)), jnp.float32)  # round input
    key, k1 = jax.random.split(key)
    states = step_states(k1, states, jnp.full((N,), P_GG), jnp.full((N,), P_BB))
    p_good = jnp.where(est.seen_prev, predicted_good_prob(est), jnp.full((N,), 0.5))
    loads, _ = allocate(p_good, lp)
    if bool(round_success(loads, states, lp, MU_G, MU_B, D)):
        ln, st = np.asarray(loads), np.asarray(states)
        on_time = np.zeros(spec.nr, bool)
        for i in range(N):
            done = ln[i] if (st[i] == 1 or ln[i] <= lp.ell_b) else 0
            on_time[i * R: i * R + done] = True
        out = coded_matmul(coded, b_m, on_time)
        served += 1
        status = "served"
    else:
        status = "MISSED DEADLINE"
    est = update_estimator(est, states)
    if m < 5 or m % 10 == 0:
        print(f"round {m:3d}: {status}")
print(f"timely computation throughput: {served/ROUNDS:.3f} "
      f"({served}/{ROUNDS} rounds, {time.time()-t_start:.1f}s wall)")
assert served / ROUNDS > 0.5
print("OK")
