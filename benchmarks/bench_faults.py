"""Fault-injection gate: the ``repro.faults`` runtime end to end.

Expands the ``packet_erasure`` scenario grid (preemption ramp x iid packet
loss on the Fig. 3 worker pool), turns each cell's meta into TRACED channel
parameters, and scores every cell's rounds under the three decode modes —
all-or-nothing, partial-work conserving, hierarchical layer-1 — on the SAME
trajectories and the SAME fault realisations, fused into ONE compiled
computation (:func:`repro.faults.engine.sweep_faults`; asserted in-run and
soft-checked against the committed baseline like every compile count).

Hard in-run gates (the acceptance criteria, not wall-clock-dependent):

  * containment — no (cell, round, strategy) is AON-recoverable but not
    conserve-recoverable;
  * strict dominance — summed over the faulted cells, the conserving decode
    recovers STRICTLY more rounds than all-or-nothing on the same PRNG keys;
  * executor accounting — a retry/degrade executor run under the same
    channel ends every round in exactly one of {on_time, late, partial,
    dropped} with the counts summing to the round total (never a silent
    drop).

Writes ``BENCH_faults.json`` at the repo root: per-cell recovery rates for
the three modes, the conserve-vs-AON gain, the compile count and the
executor's outcome histogram; rows/sec follows the ``benchmarks._softgate``
soft-regression convention (WARNING + manifest flag, never a failure).
"""

from __future__ import annotations

import os
import time

from benchmarks._softgate import (collect, committed_baseline, warn_compiles,
                                  warn_slowdown)

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_MANIFEST_PATH = os.path.join(_ROOT, "BENCH_faults.json")

FAMILY = "packet_erasure"
ROUNDS = 512
STRATEGIES = ("lea", "static")
SEED_BASE = 1000

# the executor accounting demo (small: it is a host loop)
EXEC_ROUNDS = 30
EXEC_P_PREEMPT = 0.35


def _unique_meta(scenarios, key):
    vals = {dict(sc.meta)[key] for sc in scenarios}
    assert len(vals) == 1, (key, vals)
    return vals.pop()


def run() -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import faults, sweeps
    from repro.core.lea import PoolLoad
    from repro.runtime.fault_tolerance import (CodedDataParallelExecutor,
                                               CodedDPConfig, OUTCOMES)

    scenarios = sweeps.expand(FAMILY, rounds=ROUNDS)
    b = len(scenarios)
    lp = scenarios[0].lp
    assert all(sc.lp == lp for sc in scenarios)
    n = lp.n
    packets = int(_unique_meta(scenarios, "packets"))
    p1 = int(_unique_meta(scenarios, "p1"))
    r = int(_unique_meta(scenarios, "r"))
    k1star = int(_unique_meta(scenarios, "k1star"))

    keys = jax.vmap(lambda i: jax.random.PRNGKey(SEED_BASE + i))(jnp.arange(b))
    pool = PoolLoad(
        kstar=jnp.full((b,), lp.kstar, jnp.int32),
        ell_g=jnp.full((b,), lp.ell_g, jnp.int32),
        ell_b=jnp.full((b,), lp.ell_b, jnp.int32),
        mask=jnp.ones((b, n), bool),
    )
    p_gg = jnp.asarray([sc.p_gg for sc in scenarios], jnp.float32)
    p_bb = jnp.asarray([sc.p_bb for sc in scenarios], jnp.float32)
    p_pre = jnp.asarray([dict(sc.meta)["p_preempt"] for sc in scenarios],
                        jnp.float32)
    p_drop = jnp.asarray([dict(sc.meta)["p_drop"] for sc in scenarios],
                         jnp.float32)
    channel = faults.make_channel([
        ("preempt", {"p_preempt": p_pre}),
        ("packet_bernoulli", {"p_drop": p_drop}),
    ])

    c0 = faults.fault_compile_cache_size()
    t0 = time.perf_counter()
    out = faults.sweep_faults(
        keys, pool, p_gg, p_bb,
        scenarios[0].mu_g, scenarios[0].mu_b, scenarios[0].deadline,
        channel, k1star,
        rounds=ROUNDS, strategies=STRATEGIES, r=r, packets=packets, p1=p1,
    )
    jax.block_until_ready(out)
    cold_s = time.perf_counter() - t0
    compiles = faults.fault_compile_cache_size() - c0
    # the whole fault grid — every (p_preempt, p_drop) cell — is ONE compile
    assert compiles == 1, compiles
    family_compiles = {FAMILY: compiles}

    t0 = time.perf_counter()
    jax.block_until_ready(faults.sweep_faults(
        keys, pool, p_gg, p_bb,
        scenarios[0].mu_g, scenarios[0].mu_b, scenarios[0].deadline,
        channel, k1star,
        rounds=ROUNDS, strategies=STRATEGIES, r=r, packets=packets, p1=p1,
    ))
    warm_s = time.perf_counter() - t0
    rows_per_sec = b * ROUNDS / warm_s

    aon = np.asarray(out.full_aon)            # (b, rounds, S) bool
    con = np.asarray(out.full_conserve)
    part = np.asarray(out.partial)
    # containment: a conserving decode can never lose a round AON recovers
    assert not (aon & ~con).any(), "AON-recoverable round lost by conserve"
    assert not (part & con).any(), "partial overlaps full_conserve"
    faulted = np.asarray(p_pre > 0) | np.asarray(p_drop > 0)
    gain_rounds = int(con[faulted].sum()) - int(aon[faulted].sum())
    # strict dominance under faults, on the same keys and the same traces
    assert gain_rounds > 0, "conserve did not strictly beat all-or-nothing"

    # retry/degrade executor under the same channel family: every round ends
    # in exactly one disposition and nothing is silently dropped
    cfg = CodedDPConfig(packets=packets, max_retries=2, allow_partial=True,
                        p1=p1)
    ex = CodedDataParallelExecutor(
        cfg, lambda params, sb: jax.tree.map(jnp.zeros_like, params),
        seed=0,
        channel=faults.make_channel(
            [("preempt", {"p_preempt": EXEC_P_PREEMPT})]
        ),
    )
    params = {"w": jnp.zeros(2)}
    batch = {"x": jnp.zeros((cfg.k, 2))}
    for _ in range(EXEC_ROUNDS):
        grads, info = ex.round(params, batch)
        assert (grads is None) == (info["outcome"] == "dropped")
    assert sum(ex.outcomes.values()) == ex.rounds == EXEC_ROUNDS

    baseline = committed_baseline(_MANIFEST_PATH)
    warnings = collect(
        warn_slowdown("bench_faults", rows_per_sec, baseline.get("rows_per_sec")),
        warn_compiles(
            "bench_faults", family_compiles, baseline.get("family_compiles", {})
        ),
    )
    slowdown_warned = any(w["kind"] == "slowdown" for w in warnings)
    compile_warned = any(w["kind"] == "compiles" for w in warnings)

    li = STRATEGIES.index("lea")
    cells = []
    for i, sc in enumerate(scenarios):
        meta = dict(sc.meta)
        cells.append({
            "name": sc.name,
            "p_preempt": float(meta["p_preempt"]),
            "p_drop": float(meta["p_drop"]),
            "recovered_aon": float(aon[i, :, li].mean()),
            "recovered_conserve": float(con[i, :, li].mean()),
            "recovered_partial_only": float(part[i, :, li].mean()),
            "served_any": float((con[i, :, li] | part[i, :, li]).mean()),
        })

    doc = {
        "bench": "bench_faults",
        "family": FAMILY,
        "cells": b,
        "rounds": ROUNDS,
        "strategies": list(STRATEGIES),
        "packets": packets,
        "p1": p1,
        "kstar": lp.kstar,
        "k1star": k1star,
        "conserve_contains_aon": True,
        "conserve_gain_rounds": gain_rounds,
        "family_compiles": family_compiles,
        "compile_warned": compile_warned,
        "rows_per_sec": rows_per_sec,
        "baseline_rows_per_sec": baseline.get("rows_per_sec"),
        "slowdown_warned": slowdown_warned,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "executor_rounds": ex.rounds,
        "executor_outcomes": {k: ex.outcomes[k] for k in OUTCOMES},
        "executor_outcomes_sum_ok": True,
        "warnings": warnings,
        "results": cells,
    }
    sweeps.write_manifest(_MANIFEST_PATH, doc)

    rows = [{
        "name": "bench_faults",
        "us_per_call": warm_s * 1e6 / (b * ROUNDS),
        "derived": (
            f"cells={b};rounds={ROUNDS};packets={packets};compiles={compiles};"
            f"gain_rounds={gain_rounds};rows_per_sec={rows_per_sec:.0f};"
            f"slowdown_warned={int(slowdown_warned)};"
            f"compile_warned={int(compile_warned)};"
            + ";".join(f"exec_{k}={ex.outcomes[k]}" for k in OUTCOMES)
        ),
    }]
    for c in cells:
        rows.append({
            "name": f"faults_{c['name']}",
            "us_per_call": warm_s * 1e6 / (b * ROUNDS),
            "derived": (
                f"aon={c['recovered_aon']:.4f};"
                f"conserve={c['recovered_conserve']:.4f};"
                f"partial={c['recovered_partial_only']:.4f};"
                f"served={c['served_any']:.4f}"
            ),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
