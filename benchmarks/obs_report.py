"""Cross-bench regression report: the ``repro.obs`` layer end to end.

Aggregates every committed ``BENCH_*.json`` at the repo root into ONE
regression summary (writes ``BENCH_obs.json``):

  * metric deltas — every shared numeric top-level metric of each manifest
    is diffed against the COMMITTED baseline (``git show HEAD:`` via
    ``benchmarks._softgate.committed_baseline``, the repo's soft-gate
    reference), absolute and relative;
  * softgate warnings — the structured warning records each bench appended
    to its manifest's ``warnings`` list are collected in one place;
  * provenance audit — which manifests carry the ``repro.obs.provenance``
    stamp (all of them must; ``tests/test_benchmarks_cli.py`` hard-gates
    the contract);
  * static cost rows — FLOP/byte/intensity estimates for the engine's
    pool-path entry points from the ``repro.launch.hlo_cost`` walker
    (lower + compile at reference small shapes, trip-count-aware HLO walk);
  * a trend section — the ``BENCH_history.jsonl`` trajectory behind every
    manifest (appended by ``repro.sweeps.results.write_manifest``) folded
    through ``repro.obs.history.trend_report``: per-metric time series and
    robust median-vs-envelope regression records — the softgate's
    "vs HEAD" diff widened to "vs trajectory" (``run.py --check`` gates
    on the hard records);
  * a telemetry + tap demo — a small ``telemetry=True, tap=True`` serving
    run, asserted to compile exactly ONCE via the unified ``repro.obs``
    compile counter and to stream block-aggregate tap events while the
    scan runs, exported as a valid Chrome trace-event document
    (``benchmarks/artifacts/obs_trace.json``, viewable in Perfetto /
    chrome://tracing) whose request dispositions are asserted to
    reconcile with the engine's own counters.

Hard in-run gates: the one-compile assertion, trace validity
(``repro.obs.validate_trace``) and disposition conservation.  Everything
wall-clock-ish stays soft, per the ``benchmarks._softgate`` convention —
including a missing git baseline: ``git show HEAD:`` being unavailable
(shallow export, untracked manifest) downgrades that manifest's delta
section to a structured ``baseline`` warning record, never an exception.
"""

from __future__ import annotations

import glob
import json
import os
import time

from benchmarks._softgate import committed_baseline_with_source

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_MANIFEST_PATH = os.path.join(_ROOT, "BENCH_obs.json")
_TRACE_PATH = os.path.join(_HERE, "artifacts", "obs_trace.json")

# the telemetry demo: Sec. 6.2-scale pool, tiny horizon (it is a demo of
# the export path, not a benchmark — bench_serving owns the perf numbers)
N = 15
KSTAR, ELL_G, ELL_B = 50, 10, 3
MU_G, MU_B, DEADLINE = 10.0, 3.0, 1.0
P_GG, P_BB = 0.8, 0.7
ROUNDS = 64
CELLS = 2
RATE = 0.6
DEADLINE_REL = 3
CAPACITY = 2
STRATEGIES = ("lea",)


def _numeric_deltas(current: dict, baseline: dict) -> dict:
    """Per-key {current, baseline, delta, rel} for shared numeric metrics."""
    deltas = {}
    for k, v in current.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        bv = baseline.get(k)
        if isinstance(bv, bool) or not isinstance(bv, (int, float)):
            continue
        deltas[k] = {
            "current": v,
            "baseline": bv,
            "delta": v - bv,
            "rel": (v - bv) / bv if bv else None,
        }
    return deltas


def run() -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import obs, serving, sweeps
    from repro.launch import hlo_cost

    # -- 1. aggregate every committed BENCH manifest -----------------------
    bench_paths = sorted(glob.glob(os.path.join(_ROOT, "BENCH_*.json")))
    bench_paths = [
        p for p in bench_paths
        if os.path.basename(p) != os.path.basename(_MANIFEST_PATH)
    ]
    benches: dict[str, dict] = {}
    warnings_collected: list[dict] = []
    missing_provenance: list[str] = []
    for path in bench_paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                current = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        baseline, baseline_source = committed_baseline_with_source(path)
        if baseline_source != "git":
            # no committed reference (shallow export, untracked manifest):
            # skip the delta section with a structured record — diffing a
            # fresh run against ITSELF (the worktree fallback) would report
            # zero drift and mask a real regression
            warnings_collected.append({
                "kind": "baseline",
                "bench": current.get("bench") or name,
                "metric": "baseline_source",
                "value": baseline_source,
                "baseline": "git",
                "manifest": name,
                "message": (
                    f"{name}: no committed baseline via git show HEAD: "
                    f"(source={baseline_source}); metric deltas skipped"
                ),
            })
            baseline = {}
        for w in current.get("warnings") or []:
            warnings_collected.append({**w, "manifest": name})
        prov = current.get("provenance") or {}
        if not prov:
            missing_provenance.append(name)
        benches[name] = {
            "bench": current.get("bench"),
            "has_provenance": bool(prov),
            "git_sha": prov.get("git_sha"),
            "baseline_source": baseline_source,
            "deltas": (_numeric_deltas(current, baseline)
                       if baseline_source == "git" else {}),
        }

    # -- 2. static per-target cost rows (hlo_cost entry-point walk) --------
    cost_rows = [
        hlo_cost.estimate_entry(t) for t in hlo_cost.entry_point_names()
    ]

    # -- 3. telemetry-on serving run -> Chrome trace -----------------------
    b = CELLS
    keys = jax.vmap(lambda i: jax.random.PRNGKey(3000 + i))(jnp.arange(b))
    spec = serving.RequestSpec(
        kstar=jnp.full((b,), KSTAR, jnp.int32),
        ell_g=jnp.full((b,), ELL_G, jnp.int32),
        ell_b=jnp.full((b,), ELL_B, jnp.int32),
        deadline_rel=jnp.full((b,), DEADLINE_REL, jnp.int32),
        admit_threshold=jnp.zeros((b,), jnp.float32),
        reserve_cap=jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32),
    )
    process = serving.make_process(
        "poisson", rate=jnp.full((b,), RATE, jnp.float32)
    )
    c0 = obs.compile_events("serving.sweep")
    t0 = time.perf_counter()
    with obs.capture_taps() as tap_events:
        out, tel = serving.sweep_serving(
            keys, jnp.ones((b, N), bool),
            jnp.full((b, N), P_GG, jnp.float32),
            jnp.full((b, N), P_BB, jnp.float32),
            MU_G, MU_B, DEADLINE, spec, process,
            rounds=ROUNDS, strategies=STRATEGIES, capacity=CAPACITY,
            telemetry=True, tap=True, tap_stride=ROUNDS // 4,
        )
        jax.block_until_ready(out)
    run_s = time.perf_counter() - t0
    telemetry_compiles = obs.compile_events("serving.sweep") - c0
    # telemetry+tap on adds ZERO compiles beyond the family's one computation
    assert telemetry_compiles == 1, telemetry_compiles
    # the taps actually streamed DURING the run: every cell announced every
    # stride block, and each event's host timestamp precedes run completion
    run_done_t = time.perf_counter()
    for e in tap_events:
        obs.validate_event(e)
    assert len(tap_events) == b * len(STRATEGIES) * 4, len(tap_events)
    assert all(e["host_time"] < run_done_t for e in tap_events)

    trace = obs.serving_trace(
        np.asarray(out.events)[0], np.asarray(out.sojourn)[0],
        strategies=STRATEGIES,
        telemetry=jax.tree.map(lambda x: np.asarray(x)[0], tel),
    )
    os.makedirs(os.path.dirname(_TRACE_PATH), exist_ok=True)
    obs.write_trace(_TRACE_PATH, trace)
    stats = obs.validate_trace(trace)
    # the trace's dispositions must reconcile with the engine's counters
    li = STRATEGIES.index("lea")
    disp = stats["dispositions"]
    want = {
        "on_time": int(np.asarray(out.served_on_time)[0, li]),
        "late": int(np.asarray(out.served_late)[0, li]),
        "expired": int(np.asarray(out.expired)[0, li]),
    }
    got = {k: disp.get(k, 0) for k in want}
    assert got == want, (got, want)
    assert stats["complete"] > 0, "trace has no request events"

    # -- 4. trend section: the history trajectory behind every manifest ----
    history_file = obs.history_path(_MANIFEST_PATH)
    trend = obs.trend_report(obs.read_history(history_file))
    for reg in trend["regressions"]:
        if reg.get("severity") == "hard":
            warnings_collected.append({**reg, "manifest": "BENCH_history.jsonl"})

    doc = {
        "bench": "obs_report",
        "manifests": sorted(benches),
        "benches": benches,
        "warnings_collected": warnings_collected,
        "missing_provenance": missing_provenance,
        "cost_model": cost_rows,
        "telemetry_compiles": telemetry_compiles,
        "tap_events": len(tap_events),
        "trace_path": os.path.relpath(_TRACE_PATH, _ROOT),
        "trace_events": stats["events"],
        "trace_complete": stats["complete"],
        "trace_dispositions": disp,
        "trace_dispositions_ok": True,
        "counter_names": list(obs.counter_names()),
        "compile_events_total": obs.compile_events(),
        "trend": trend,
        "serving_demo": {
            "cells": b, "rounds": ROUNDS, "rate": RATE,
            "capacity": CAPACITY, "run_s": run_s,
        },
    }
    sweeps.write_manifest(_MANIFEST_PATH, doc)

    rows = [{
        "name": "obs_report",
        "us_per_call": run_s * 1e6 / (b * ROUNDS),
        "derived": (
            f"manifests={len(benches)};warnings={len(warnings_collected)};"
            f"missing_provenance={len(missing_provenance)};"
            f"trace_events={stats['events']};complete={stats['complete']};"
            f"telemetry_compiles={telemetry_compiles};"
            f"tap_events={len(tap_events)};"
            f"history_entries={trend['entries']};"
            f"trend_regressions={len(trend['regressions'])}"
        ),
    }]
    for c in cost_rows:
        rows.append({
            "name": f"obs_cost_{c['target']}",
            "us_per_call": 0.0,
            "derived": (
                f"flops_per_round={c['flops_per_round']:.0f};"
                f"hbm_bytes_per_round={c['hbm_bytes_per_round']:.0f};"
                f"intensity={c['arithmetic_intensity']:.2f}"
            ),
        })
    for name, info in sorted(benches.items()):
        moved = sum(
            1 for d in info["deltas"].values()
            if d["rel"] is not None and abs(d["rel"]) > 1e-12
        )
        rows.append({
            "name": f"obs_delta_{name}",
            "us_per_call": 0.0,
            "derived": (
                f"metrics={len(info['deltas'])};moved={moved};"
                f"provenance={int(info['has_provenance'])}"
            ),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
