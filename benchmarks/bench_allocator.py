"""Old-vs-new engine benchmark: sequential seed path vs batched vmap engine.

The *old path* below is a faithful re-implementation of the v0 seed engine —
one ``lax.scan`` per (scenario, strategy, seed) whose body runs a fresh
double-argsort + O(n^2) ``lax.scan`` Poisson-binomial DP every round, plus a
scalar rejection-resampling while_loop for the static benchmark — kept here
verbatim so future perf work always measures against the true baseline on the
same host.

The *new path* is ``core.throughput.sweep``: per scenario, all seeds x
strategies share one compiled computation; every round of every seed goes
through a single batched allocate (``kernels.poisson_binomial``) and the
static draw chains are resampled in a vectorised while_loop.  Both paths use
identical PRNG key chains, so their Monte-Carlo results agree bit-for-bit —
the benchmark asserts it.

Reported rows (CSV via benchmarks.run):
  allocator_old / allocator_new — allocator microbenchmark, us per allocate
      call (old: one (n,) row per call; new: per-row cost inside one batched
      (4096, n) call)
  engine_old / engine_new — the Fig. 3 sweep (4 scenarios x 3 strategies x
      SEEDS seeds x ROUNDS rounds), warm steady-state seconds + rounds/sec
  engine_speedup — old/new wall-clock ratio (acceptance: >= 5x)
"""

from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_lea import SIM
from repro.core import markov, throughput
from repro.core import lea as lea_mod
from repro.core.lea import EstimatorState, LoadParams

SEEDS = 8
ROUNDS = 10_000
STRATEGIES = ("lea", "static", "oracle")


# ---------------------------------------------------------------------------
# Old path: the v0 seed engine, verbatim
# ---------------------------------------------------------------------------

def _seed_success_prob_all_prefixes(p_good_sorted: jnp.ndarray, lp: LoadParams) -> jnp.ndarray:
    n = lp.n
    i_tilde = jnp.arange(1, n + 1)
    w = jnp.ceil((lp.kstar - (n - i_tilde) * lp.ell_b) / lp.ell_g).astype(jnp.int32)

    def body(pmf, p):
        shifted = jnp.concatenate([jnp.zeros((1,), pmf.dtype), pmf[:-1]])
        new = pmf * (1.0 - p) + shifted * p
        return new, new

    pmf0 = jnp.zeros((n + 1,), jnp.float32).at[0].set(1.0)
    _, pmfs = jax.lax.scan(body, pmf0, p_good_sorted.astype(jnp.float32))
    counts = jnp.arange(n + 1)[None, :]
    tail_mask = counts >= jnp.maximum(w, 0)[:, None]
    tails = jnp.sum(pmfs * tail_mask, axis=-1)
    return jnp.where(w > i_tilde, 0.0, tails)


def _seed_allocate(p_good: jnp.ndarray, lp: LoadParams):
    order = jnp.argsort(-p_good)
    probs = _seed_success_prob_all_prefixes(p_good[order], lp)
    i_star = jnp.argmax(probs) + 1
    ranks = jnp.argsort(order)
    loads = jnp.where(ranks < i_star, lp.ell_g, lp.ell_b).astype(jnp.int32)
    return loads, i_star


def _seed_static_loads(key: jax.Array, pi_g: jnp.ndarray, lp: LoadParams) -> jnp.ndarray:
    def cond(carry):
        i, _, loads = carry
        return (jnp.sum(loads) < lp.kstar) & (i < 128)

    def body(carry):
        i, k, _ = carry
        k, sub = jax.random.split(k)
        draw = jax.random.uniform(sub, pi_g.shape) < pi_g
        return (i + 1, k, jnp.where(draw, lp.ell_g, lp.ell_b).astype(jnp.int32))

    init = (jnp.int32(0), key, jnp.zeros(pi_g.shape, jnp.int32))
    _, _, loads = jax.lax.while_loop(cond, body, init)
    return loads


class _OraclePrev(NamedTuple):
    state: jnp.ndarray
    seen: jnp.ndarray


@partial(jax.jit, static_argnames=("strategy", "lp", "rounds"))
def seed_simulate(key, strategy, lp: LoadParams, p_gg, p_bb, mu_g, mu_b, deadline, rounds):
    """The v0 sequential simulator: one per-round scan, one strategy."""
    k_traj, k_rounds = jax.random.split(key)
    states = markov.sample_trajectory(k_traj, p_gg, p_bb, rounds)
    pi_g = markov.stationary_good_prob(p_gg, p_bb)
    round_keys = jax.random.split(k_rounds, rounds)

    def lea_round(est: EstimatorState, xs):
        _, s_m = xs
        p_good = jnp.where(
            est.seen_prev, lea_mod.predicted_good_prob(est), jnp.full_like(pi_g, 0.5)
        )
        loads, _ = _seed_allocate(p_good, lp)
        ok = lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)
        return lea_mod.update_estimator(est, s_m), ok

    def static_round(carry, xs):
        k, s_m = xs
        loads = _seed_static_loads(k, pi_g, lp)
        return carry, lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)

    def oracle_round(prev, xs):
        _, s_m = xs
        p_good = jnp.where(prev.seen, jnp.where(prev.state == 1, p_gg, 1.0 - p_bb), pi_g)
        loads, _ = _seed_allocate(p_good, lp)
        ok = lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)
        return _OraclePrev(state=s_m, seen=jnp.asarray(True)), ok

    xs = (round_keys, states)
    if strategy == "lea":
        _, succ = jax.lax.scan(lea_round, lea_mod.init_estimator(lp.n), xs)
    elif strategy == "static":
        _, succ = jax.lax.scan(static_round, jnp.int32(0), xs)
    else:
        init = _OraclePrev(state=jnp.zeros_like(p_gg, dtype=jnp.int32), seen=jnp.asarray(False))
        _, succ = jax.lax.scan(oracle_round, init, xs)
    return succ


# ---------------------------------------------------------------------------
# The benchmark
# ---------------------------------------------------------------------------

def _paper_lp() -> LoadParams:
    return LoadParams(
        n=SIM.n, kstar=99,
        ell_g=int(min(SIM.mu_g * SIM.deadline, SIM.r)),
        ell_b=int(SIM.mu_b * SIM.deadline),
    )


def _old_path(lp: LoadParams, rounds: int, seeds: int) -> np.ndarray:
    """Sequential seed structure: scenario x strategy x seed simulate calls."""
    out = np.zeros((len(SIM.scenarios), len(STRATEGIES), seeds))
    for i, (p_gg, p_bb) in enumerate(SIM.scenarios):
        pg, pb = jnp.full((SIM.n,), p_gg), jnp.full((SIM.n,), p_bb)
        for j, s in enumerate(STRATEGIES):
            for seed in range(seeds):
                succ = seed_simulate(
                    jax.random.PRNGKey((i + 1) * 1000 + seed), s, lp, pg, pb,
                    SIM.mu_g, SIM.mu_b, SIM.deadline, rounds,
                )
                out[i, j, seed] = float(jnp.mean(succ.astype(jnp.float32)))
    return out


def _new_path(lp: LoadParams, rounds: int, seeds: int) -> np.ndarray:
    """Batched engine: one sweep per scenario (seeds batched, strategies fused)."""
    outs = []
    for i, (p_gg, p_bb) in enumerate(SIM.scenarios):
        keys = jnp.stack([jax.random.PRNGKey((i + 1) * 1000 + s) for s in range(seeds)])
        pg = jnp.broadcast_to(jnp.float32(p_gg), (seeds, SIM.n))
        pb = jnp.broadcast_to(jnp.float32(p_bb), (seeds, SIM.n))
        succ = throughput.sweep(
            keys, lp, pg, pb, SIM.mu_g, SIM.mu_b, SIM.deadline, rounds, STRATEGIES
        )  # (seeds, rounds, S)
        outs.append(jnp.mean(succ.astype(jnp.float32), axis=1).T)  # (S, seeds)
    return np.stack([np.asarray(o) for o in outs])                 # (scen, S, seeds)


def allocator_microbench(lp: LoadParams, batch: int = 4096, iters: int = 50):
    """us per allocate call: seed single-row vs batched per-row."""
    rng = np.random.default_rng(0)
    p1 = jnp.asarray(rng.uniform(0.05, 0.95, size=(lp.n,)), jnp.float32)
    pb = jnp.asarray(rng.uniform(0.05, 0.95, size=(batch, lp.n)), jnp.float32)
    old = jax.jit(lambda p: _seed_allocate(p, lp)[0])
    new = jax.jit(lambda p: lea_mod.allocate(p, lp)[0])
    old(p1).block_until_ready(); new(pb).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        old(p1).block_until_ready()
    t_old = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        new(pb).block_until_ready()
    t_new_call = (time.perf_counter() - t0) / iters * 1e6
    return t_old, t_new_call, t_new_call / batch


def run(rounds: int | None = None, seeds: int = SEEDS) -> list[dict]:
    rounds = rounds or ROUNDS
    lp = _paper_lp()

    us_old, us_new_call, us_new_row = allocator_microbench(lp)

    # warm both paths (compile excluded from the steady-state measurement),
    # and use the warm-up results to cross-check old == new bit-for-bit.
    r_old = _old_path(lp, rounds, seeds)    # (scen, S, seeds)
    r_new = _new_path(lp, rounds, seeds)    # (scen, S, seeds)
    max_dev = float(np.abs(r_old - r_new).max())

    # best-of-2 timed reps: a single rep is noisy under host contention
    def _best_of(fn, reps: int = 2) -> float:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(lp, rounds, seeds)
            best = min(best, time.perf_counter() - t0)
        return best

    t_old = _best_of(_old_path)
    t_new = _best_of(_new_path)

    total_rounds = len(SIM.scenarios) * len(STRATEGIES) * seeds * rounds
    speedup = t_old / t_new
    return [
        {"name": "allocator_old", "us_per_call": us_old,
         "derived": f"seed single-row allocate;n={lp.n}"},
        {"name": "allocator_new", "us_per_call": us_new_row,
         "derived": f"batched allocate per row;batch=4096;us_per_batch_call={us_new_call:.1f}"},
        {"name": "engine_old", "us_per_call": t_old * 1e6 / total_rounds,
         "derived": f"seconds={t_old:.2f};rounds_per_sec={total_rounds / t_old:.0f};"
                    f"scenarios=4;strategies=3;seeds={seeds};rounds={rounds}"},
        {"name": "engine_new", "us_per_call": t_new * 1e6 / total_rounds,
         "derived": f"seconds={t_new:.2f};rounds_per_sec={total_rounds / t_new:.0f};"
                    f"max_dev_vs_old={max_dev:.2e}"},
        {"name": "engine_speedup", "us_per_call": 0.0,
         "derived": f"speedup={speedup:.2f}x;old_s={t_old:.2f};new_s={t_new:.2f};"
                    f"results_match={max_dev == 0.0}"},
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
