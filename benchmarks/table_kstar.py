"""Recovery-threshold table (paper eqs. 15/16 + Sec. 3.1 worked examples).

A thin registry invocation: the worked examples live in the ``kstar_table``
scenario family (catalogue-only, never simulated); each row re-derives K*
through ``CodeSpec`` and checks it against the paper's expected value stored
in the scenario metadata.
"""

from __future__ import annotations

import time

from repro import sweeps
from repro.core.lagrange import CodeSpec


def run() -> list[dict]:
    scenarios = sweeps.expand("kstar_table")
    rows = []
    t0 = time.time()
    for sc in scenarios:
        m = sc.meta_dict()
        spec = CodeSpec(m["n"], m["r"], m["k"], m["deg_f"])
        got = spec.recovery_threshold
        assert got == m["expect_kstar"] == sc.lp.kstar, (m["where"], got, m)
        assert spec.mode == m["mode"], (m["where"], spec.mode, m["mode"])
        rows.append({
            "name": sc.name,
            "us_per_call": (time.time() - t0) * 1e6 / len(scenarios),
            "derived": (
                f"n={m['n']};r={m['r']};k={m['k']};deg={m['deg_f']};"
                f"Kstar={got};mode={spec.mode}"
            ),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
