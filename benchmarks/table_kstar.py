"""Recovery-threshold table (paper eqs. 15/16 + Sec. 3.1 worked examples)."""

from __future__ import annotations

import time

from repro.core.lagrange import CodeSpec


CASES = [
    # (n, r, k, deg_f, expected K*, where in the paper)
    (15, 10, 50, 2, 99, "Sec6.1 sim"),
    (15, 10, 50, 1, 50, "Sec6.2 EC2 k=50"),
    (15, 10, 100, 1, 100, "Sec6.2 EC2 k=100"),
    (15, 10, 120, 1, 120, "Sec6.2 EC2 k=120"),
    (3, 2, 2, 2, 3, "Sec3.1 example 1"),
    (3, 2, 4, 2, 6, "Sec3.1 example 2 (repetition)"),
]


def run() -> list[dict]:
    rows = []
    t0 = time.time()
    for n, r, k, deg, want, where in CASES:
        spec = CodeSpec(n, r, k, deg)
        got = spec.recovery_threshold
        assert got == want, (where, got, want)
        rows.append({
            "name": f"kstar_{where.replace(' ', '_')}",
            "us_per_call": (time.time() - t0) * 1e6 / len(CASES),
            "derived": f"n={n};r={r};k={k};deg={deg};Kstar={got};mode={spec.mode}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
