"""Paper Fig. 4 — EC2 experiments, simulated: 6 scenarios of the linear
workload f(X_j) = X_j^T B with K* in {120, 100, 50}, shift-exponential
arrivals T_c + Exp(lam).

A thin ``repro.sweeps`` registry invocation of the ``fig4`` family (see its
docstring for the hardware substitution: t2.micro credit dynamics replayed by
the measured two-state Markov chain, arrival gaps folded into the chain via
``markov.t_step_transitions``, the paper's EC2 static benchmark as engine
strategy ``static_single``).  K* is a traced batch quantity in the
shape-polymorphic engine, so all six scenarios (three K*s) run as ONE
compiled computation — on the same per-scenario PRNG keys as the PR-1
``throughput.compare`` path, so the emitted values are bit-identical.
"""

from __future__ import annotations

import time

from repro import sweeps


def run(rounds: int | None = None) -> list[dict]:
    rounds = rounds or 400
    scenarios = sweeps.expand("fig4", rounds=rounds)

    t0 = time.time()
    res = sweeps.run(scenarios)
    us_per_call = (time.time() - t0) * 1e6 / (len(scenarios) * 2 * rounds)

    rows = []
    for r in res:
        m = r.scenario.meta_dict()
        r_lea, r_static = r.throughput["lea"], r.throughput["static_single"]
        if r_static > 0:
            ratio = f"{r_lea / r_static:.2f}x"
        else:
            # binary-speed model boundary: at K*=k=nr*0.8 the equal-prob static
            # essentially never reaches K* (paper's EC2 speeds are continuous,
            # so its static floor is higher) — report the floor explicitly.
            ratio = "inf(static~0)"
        rows.append({
            "name": r.name,
            "us_per_call": us_per_call,
            "derived": (
                f"rows={m['rows']};k={m['k']};lam={m['lam']};d={m['d']};"
                f"Kstar={r.scenario.lp.kstar};"
                f"R_lea={r_lea:.4f};R_static={r_static:.4f};ratio={ratio}"
            ),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
