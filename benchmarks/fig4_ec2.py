"""Paper Fig. 4 — EC2 experiments, simulated: 6 scenarios of the linear
workload f(X_j) = X_j^T B with K*=50, shift-exponential arrivals T_c + Exp(lam).

Hardware substitution (DESIGN §9): the t2.micro credit dynamics are replayed
by the same two-state Markov speed model measured in the paper's Fig. 1
(burst ~= 10x baseline).  Arrival gaps matter because the worker chain keeps
mixing between requests: the seed applied round(gap/d) extra Markov
transitions between consecutive rounds; the batched engine instead folds the
gap into the chain itself — ``markov.t_step_transitions`` gives the exact
t-step transition probabilities, so one engine round IS one request and the
whole scenario runs as a single compiled computation
(``core.throughput.compare``).  LEA's estimator observes exactly the t-step
chain either way, so larger lambda degrades its one-step predictions exactly
as slower request rates did on EC2.  The static benchmark is the paper's EC2
one: a single ell_g/ell_b draw with probability 1/2 each (engine strategy
``static_single``).  Speeds are normalized so a good worker clears its full
store within the deadline and a bad one r/10 of it, i.e. mu = (ell_g, ell_b)
with d = 1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_lea import EC2
from repro.core.lagrange import CodeSpec
from repro.core import markov, throughput
from repro.core.lea import LoadParams

# credit-based chain estimated from Fig. 1-style traces
P_GG, P_BB = 0.85, 0.6


def run(rounds: int | None = None) -> list[dict]:
    rows = []
    rounds = rounds or 400
    strategies = ("lea", "static_single")
    for i, (xrows, k, lam, d) in enumerate(EC2.scenarios, 1):
        spec = CodeSpec(EC2.n, EC2.r, k, EC2.deg_f)
        # normalize speeds so a good worker clears its full store in time d
        # and a bad worker manages r/10 of it (Fig. 1's 10x gap).
        ell_g = EC2.r
        ell_b = max(1, EC2.r // 10)
        lp = LoadParams(n=EC2.n, kstar=spec.recovery_threshold,
                        ell_g=ell_g, ell_b=ell_b)
        gap = max(1, int(round((30.0 + lam) / (10 * d))))
        p_gg_t, p_bb_t = markov.t_step_transitions(P_GG, P_BB, gap)
        t0 = time.time()
        res = throughput.compare(
            jax.random.PRNGKey(i), lp,
            jnp.full((EC2.n,), p_gg_t), jnp.full((EC2.n,), p_bb_t),
            float(ell_g), float(ell_b), 1.0, rounds,
            strategies=strategies,
        )
        r_lea, r_static = res["lea"], res["static_single"]
        if r_static > 0:
            ratio = f"{r_lea / r_static:.2f}x"
        else:
            # binary-speed model boundary: at K*=k=nr*0.8 the equal-prob static
            # essentially never reaches K* (paper's EC2 speeds are continuous,
            # so its static floor is higher) — report the floor explicitly.
            ratio = "inf(static~0)"
        rows.append({
            "name": f"fig4_scenario{i}",
            "us_per_call": (time.time() - t0) * 1e6 / (2 * rounds),
            "derived": (
                f"rows={xrows};k={k};lam={lam};d={d};Kstar={lp.kstar};"
                f"R_lea={r_lea:.4f};R_static={r_static:.4f};ratio={ratio}"
            ),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
