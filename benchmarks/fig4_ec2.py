"""Paper Fig. 4 — EC2 experiments, simulated: 6 scenarios of the linear
workload f(X_j) = X_j^T B with K*=50, shift-exponential arrivals T_c + Exp(lam).

Hardware substitution (DESIGN §9): the t2.micro credit dynamics are replayed
by the same two-state Markov speed model measured in the paper's Fig. 1
(burst ~= 10x baseline).  Arrival gaps matter because the worker chain keeps
mixing between requests: we apply round(gap/d) extra Markov transitions
between consecutive rounds, so larger lambda degrades LEA's one-step
predictions exactly as slower request rates did on EC2.  The static
benchmark is the paper's EC2 one: ell_g/ell_b with probability 1/2 each.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_lea import EC2
from repro.core.lagrange import CodeSpec
from repro.core import lea as lea_mod
from repro.core import markov
from repro.core.lea import LoadParams

# credit-based chain estimated from Fig. 1-style traces
P_GG, P_BB = 0.85, 0.6


def _simulate(strategy: str, lp: LoadParams, gap_transitions: int,
              rounds: int, seed: int) -> float:
    """Round-driven sim with `gap_transitions` chain steps between requests."""
    n = lp.n
    p_gg = jnp.full((n,), P_GG)
    p_bb = jnp.full((n,), P_BB)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    states = markov.initial_states(k0, p_gg, p_bb)
    est = lea_mod.init_estimator(n)
    pi = markov.stationary_good_prob(p_gg, p_bb)
    succ = 0
    for m in range(rounds):
        for _ in range(gap_transitions):
            key, k = jax.random.split(key)
            states = markov.step_states(k, states, p_gg, p_bb)
        if strategy == "lea":
            p_good = jnp.where(est.seen_prev, lea_mod.predicted_good_prob(est),
                               jnp.full((n,), 0.5))
            loads, _ = lea_mod.allocate(p_good, lp)
        else:  # static_equal (paper's EC2 benchmark)
            key, k = jax.random.split(key)
            draw = jax.random.uniform(k, (n,)) < 0.5
            loads = jnp.where(draw, lp.ell_g, lp.ell_b).astype(jnp.int32)
        # speeds normalized so ell_g/ell_b encode the deadline directly:
        # a good worker clears <= ell_g evaluations in time d, a bad one ell_b.
        capacity = jnp.where(states == 1, lp.ell_g, lp.ell_b)
        received = jnp.sum(jnp.where(loads <= capacity, loads, 0))
        succ += int(received >= lp.kstar)
        est = lea_mod.update_estimator(est, states)
    return succ / rounds


def run(rounds: int | None = None) -> list[dict]:
    rows = []
    rounds = rounds or 400
    for i, (xrows, k, lam, d) in enumerate(EC2.scenarios, 1):
        spec = CodeSpec(EC2.n, EC2.r, k, EC2.deg_f)
        # normalize speeds so a good worker clears its full store in time d
        # and a bad worker manages r/10 of it (Fig. 1's 10x gap).
        ell_g = EC2.r
        ell_b = max(1, EC2.r // 10)
        lp = LoadParams(n=EC2.n, kstar=spec.recovery_threshold,
                        ell_g=ell_g, ell_b=ell_b)
        gap = max(1, int(round((30.0 + lam) / (10 * d))))
        t0 = time.time()
        r_lea = _simulate("lea", lp, gap, rounds, seed=i)
        r_static = _simulate("static_equal", lp, gap, rounds, seed=i)
        if r_static > 0:
            ratio = f"{r_lea / r_static:.2f}x"
        else:
            # binary-speed model boundary: at K*=k=nr*0.8 the equal-prob static
            # essentially never reaches K* (paper's EC2 speeds are continuous,
            # so its static floor is higher) — report the floor explicitly.
            ratio = "inf(static~0)"
        rows.append({
            "name": f"fig4_scenario{i}",
            "us_per_call": (time.time() - t0) * 1e6 / (2 * rounds),
            "derived": (
                f"rows={xrows};k={k};lam={lam};d={d};Kstar={lp.kstar};"
                f"R_lea={r_lea:.4f};R_static={r_static:.4f};ratio={ratio}"
            ),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
