"""Serving gate: the ``repro.serving`` streaming layer end to end.

Expands the ``arrival_grid`` scenario grid (Poisson arrival rate x request
deadline on the Sec. 6.2 worker pool), turns each cell's meta into TRACED
request-spec / arrival-process parameters, and runs the compiled serving
loop TWICE on the same keys — once admit-all (both admission gates
disabled) and once admission-controlled (the committed
``admit_threshold``/``reserve_cap`` settings).  Both runs share one
compiled computation: admission parameters are traced, so the whole grid
x {admit-all, controlled} fuses into ONE compile (asserted in-run and
soft-checked against the committed baseline like every compile count).

Hard in-run gates (the acceptance criteria, not wall-clock-dependent):

  * one compile — the full serving loop for the family compiles exactly
    once per (rounds, strategies, capacity, grace) signature;
  * conservation — every cell of both runs accounts every request:
    arrivals == admitted + rejected and admitted == served_on_time +
    served_late + expired + in_flight (never a silent drop);
  * admission beats admit-all at overload — summed over the cells whose
    arrival rate exceeds the pool's sustainable service rate
    (pi_g * n / m_min jobs per round), the controlled run serves STRICTLY
    more requests on time than admit-all, on the same keys and the same
    arrival streams.

Writes ``BENCH_serving.json`` at the repo root: per-cell timely
throughput for both admission modes, sojourn-time latency percentiles
(p50/p95/p99) and sustained served-requests/sec at every arrival rate;
rows/sec follows the ``benchmarks._softgate`` soft-regression convention
(WARNING + manifest flag, never a failure).
"""

from __future__ import annotations

import os
import time

from benchmarks._softgate import (collect, committed_baseline, warn_compiles,
                                  warn_slowdown)

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_MANIFEST_PATH = os.path.join(_ROOT, "BENCH_serving.json")

FAMILY = "arrival_grid"
ROUNDS = 512
STRATEGIES = ("lea",)
SEED_BASE = 2000


def _percentiles(sojourn, events, served_codes):
    """p50/p95/p99 sojourn (rounds) of the served requests of one cell."""
    import numpy as np

    lat = sojourn[np.isin(events, served_codes)]
    if lat.size == 0:
        return None, None, None
    p50, p95, p99 = np.percentile(lat, [50.0, 95.0, 99.0])
    return float(p50), float(p95), float(p99)


def run() -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import serving, sweeps
    from repro.core import markov

    scenarios = sweeps.expand(FAMILY, rounds=ROUNDS)
    b = len(scenarios)
    lp = scenarios[0].lp
    assert all(sc.lp == lp for sc in scenarios)
    n = lp.n
    meta0 = dict(scenarios[0].meta)
    capacity = int(meta0["capacity"])
    grace = int(meta0["grace"])
    assert all(dict(sc.meta)["process"] == "poisson" for sc in scenarios)

    keys = jax.vmap(lambda i: jax.random.PRNGKey(SEED_BASE + i))(jnp.arange(b))
    pool_mask = jnp.ones((b, n), bool)
    p_gg = jnp.asarray([sc.p_gg for sc in scenarios], jnp.float32)
    p_bb = jnp.asarray([sc.p_bb for sc in scenarios], jnp.float32)
    rates = jnp.asarray([dict(sc.meta)["rate"] for sc in scenarios],
                        jnp.float32)
    dl_rel = jnp.asarray([dict(sc.meta)["deadline_rel"] for sc in scenarios],
                         jnp.int32)
    thr = jnp.asarray([dict(sc.meta)["admit_threshold"] for sc in scenarios],
                      jnp.float32)
    cap = jnp.asarray([dict(sc.meta)["reserve_cap"] for sc in scenarios],
                      jnp.float32)
    process = serving.make_process("poisson", rate=rates)

    def spec(admit_threshold, reserve_cap):
        return serving.RequestSpec(
            kstar=jnp.full((b,), lp.kstar, jnp.int32),
            ell_g=jnp.full((b,), lp.ell_g, jnp.int32),
            ell_b=jnp.full((b,), lp.ell_b, jnp.int32),
            deadline_rel=dl_rel,
            admit_threshold=admit_threshold,
            reserve_cap=reserve_cap,
        )

    common = dict(rounds=ROUNDS, strategies=STRATEGIES, capacity=capacity,
                  grace=grace)
    zeros = jnp.zeros((b,), jnp.float32)

    c0 = serving.serving_compile_cache_size()
    t0 = time.perf_counter()
    out_all = serving.sweep_serving(
        keys, pool_mask, p_gg, p_bb,
        scenarios[0].mu_g, scenarios[0].mu_b, scenarios[0].deadline,
        spec(zeros, jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32)),
        process, **common,
    )
    jax.block_until_ready(out_all)
    cold_s = time.perf_counter() - t0
    # the controlled run: same shapes, traced admission knobs -> same compile
    out_ctl = serving.sweep_serving(
        keys, pool_mask, p_gg, p_bb,
        scenarios[0].mu_g, scenarios[0].mu_b, scenarios[0].deadline,
        spec(thr, cap), process, **common,
    )
    jax.block_until_ready(out_ctl)
    compiles = serving.serving_compile_cache_size() - c0
    # the whole grid, admit-all AND admission-controlled, is ONE compile
    assert compiles == 1, compiles
    family_compiles = {FAMILY: compiles}

    t0 = time.perf_counter()
    jax.block_until_ready(serving.sweep_serving(
        keys, pool_mask, p_gg, p_bb,
        scenarios[0].mu_g, scenarios[0].mu_b, scenarios[0].deadline,
        spec(zeros, jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32)),
        process, **common,
    ))
    warm_s = time.perf_counter() - t0
    rows_per_sec = b * ROUNDS / warm_s

    # conservation: every request of every cell in exactly one disposition
    def check_conservation(out):
        arr = np.asarray(out.arrivals)
        admitted = np.asarray(out.admitted)
        leave = (np.asarray(out.served_on_time) + np.asarray(out.served_late)
                 + np.asarray(out.expired) + np.asarray(out.in_flight))
        assert (arr == admitted + np.asarray(out.rejected)).all()
        assert (admitted == leave).all()

    check_conservation(out_all)
    check_conservation(out_ctl)

    # overload cells: arrival rate above the sustainable service rate
    pi_g = float(markov.stationary_good_prob(
        jnp.asarray(scenarios[0].p_gg[0]), jnp.asarray(scenarios[0].p_bb[0])))
    m_min = -(-lp.kstar // lp.ell_g)
    sustainable = pi_g * n / m_min          # expected good workers / job size
    overloaded = np.asarray(rates) > sustainable
    assert overloaded.any(), "grid has no overload cell"
    li = STRATEGIES.index("lea")
    served_all = np.asarray(out_all.served_on_time)[:, li]
    served_ctl = np.asarray(out_ctl.served_on_time)[:, li]
    admission_gain = int(served_ctl[overloaded].sum()
                         - served_all[overloaded].sum())
    # admission control must measurably beat admit-all at overload
    assert admission_gain > 0, (
        f"admission control served {admission_gain} fewer requests than "
        f"admit-all on the overloaded cells"
    )

    baseline = committed_baseline(_MANIFEST_PATH)
    warnings = collect(
        warn_slowdown("bench_serving", rows_per_sec, baseline.get("rows_per_sec")),
        warn_compiles(
            "bench_serving", family_compiles, baseline.get("family_compiles", {})
        ),
    )
    slowdown_warned = any(w["kind"] == "slowdown" for w in warnings)
    compile_warned = any(w["kind"] == "compiles" for w in warnings)

    served_codes = (serving.EVENT_ON_TIME, serving.EVENT_LATE)
    deadline_s = float(scenarios[0].deadline)   # one round = d seconds
    cells = []
    for i, sc in enumerate(scenarios):
        meta = dict(sc.meta)
        ev = np.asarray(out_ctl.events)[i, li]
        sj = np.asarray(out_ctl.sojourn)[i, li]
        p50, p95, p99 = _percentiles(sj, ev, served_codes)
        cells.append({
            "name": sc.name,
            "rate": float(meta["rate"]),
            "deadline_rel": int(meta["deadline_rel"]),
            "overloaded": bool(overloaded[i]),
            "arrivals": int(np.asarray(out_ctl.arrivals)[i, li]),
            "served_on_time_admit_all": int(served_all[i]),
            "served_on_time_controlled": int(served_ctl[i]),
            "rejected_controlled": int(np.asarray(out_ctl.rejected)[i, li]),
            "expired_admit_all": int(np.asarray(out_all.expired)[i, li]),
            "expired_controlled": int(np.asarray(out_ctl.expired)[i, li]),
            "served_per_round": float(served_ctl[i] / ROUNDS),
            "served_req_per_sec": float(served_ctl[i] / (ROUNDS * deadline_s)),
            "latency_p50_rounds": p50,
            "latency_p95_rounds": p95,
            "latency_p99_rounds": p99,
        })
        assert served_ctl[i] > 0, sc.name   # percentiles must be real

    doc = {
        "bench": "bench_serving",
        "family": FAMILY,
        "cells": b,
        "rounds": ROUNDS,
        "strategies": list(STRATEGIES),
        "capacity": capacity,
        "grace": grace,
        "kstar": lp.kstar,
        "admit_threshold": float(np.asarray(thr)[0]),
        "reserve_cap": float(np.asarray(cap)[0]),
        "sustainable_rate": sustainable,
        "conservation_ok": True,
        "admission_beats_admit_all": True,
        "admission_gain_requests": admission_gain,
        "family_compiles": family_compiles,
        "compile_warned": compile_warned,
        "rows_per_sec": rows_per_sec,
        "baseline_rows_per_sec": baseline.get("rows_per_sec"),
        "slowdown_warned": slowdown_warned,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "warnings": warnings,
        "results": cells,
    }
    sweeps.write_manifest(_MANIFEST_PATH, doc)

    rows = [{
        "name": "bench_serving",
        "us_per_call": warm_s * 1e6 / (b * ROUNDS),
        "derived": (
            f"cells={b};rounds={ROUNDS};compiles={compiles};"
            f"admission_gain={admission_gain};"
            f"rows_per_sec={rows_per_sec:.0f};"
            f"slowdown_warned={int(slowdown_warned)};"
            f"compile_warned={int(compile_warned)}"
        ),
    }]
    for c in cells:
        rows.append({
            "name": f"serving_{c['name']}",
            "us_per_call": warm_s * 1e6 / (b * ROUNDS),
            "derived": (
                f"served_all={c['served_on_time_admit_all']};"
                f"served_ctl={c['served_on_time_controlled']};"
                f"req_per_sec={c['served_req_per_sec']:.3f};"
                f"p50={c['latency_p50_rounds']};p95={c['latency_p95_rounds']};"
                f"p99={c['latency_p99_rounds']}"
            ),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
