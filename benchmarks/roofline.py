"""§Roofline reporter: aggregates experiments/dryrun/*.json into the
per-(arch x cell) three-term table used by EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "experiments", "dryrun")


def load(dir_: str = DEFAULT_DIR, pod_tag: str = "pod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{pod_tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(dir_: str = DEFAULT_DIR) -> list[dict]:
    rows = []
    for rec in load(dir_):
        name = f"roofline_{rec['arch']}_{rec['cell']}"
        if "skipped" in rec:
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"SKIP:{rec['skipped']}"})
            continue
        if "error" in rec:
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"ERROR:{rec['error'][:80]}"})
            continue
        r = rec["roofline"]
        rows.append({
            "name": name,
            "us_per_call": r["bound_s"] * 1e6,
            "derived": (
                f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
                f"collective_s={r['collective_s']:.4g};dom={r['dominant']};"
                f"useful={r['useful_flops_ratio']:.3f};"
                f"mem_dev_GiB={rec['memory'].get('per_device_total', 0)/2**30:.2f}"
            ),
        })
    return rows


def markdown_table(dir_: str = DEFAULT_DIR, pod_tag: str = "pod") -> str:
    lines = [
        "| arch | cell | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO flops | mem/dev (GiB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(dir_, pod_tag):
        if "skipped" in rec:
            lines.append(f"| {rec['arch']} | {rec['cell']} | — | — | — | N/A | — | — |")
            continue
        if "error" in rec:
            lines.append(f"| {rec['arch']} | {rec['cell']} | ERROR |  |  |  |  |  |")
            continue
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['cell']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{rec['memory'].get('per_device_total', 0)/2**30:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
