"""Paper Fig. 3 — numerical analysis: LEA vs static over the 4 scenarios.

Setting (Sec. 6.1): n=15 workers, k=50 chunks, r=10, deg f=2 -> K*=99;
mu=(10,3), d=1s.  Paper reports LEA/static improvements of 1.38x–17.5x.

Runs on the batched engine: all three strategies share one trajectory in a
single compiled computation per scenario (``core.throughput.compare``), with
the same PRNG keys as the seed so throughput values are unchanged.  Also
emits ``BENCH_fig3.json`` at the repo root — a perf baseline (rounds/sec,
allocator us/call) for future PRs to compare against.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_lea import SIM
from repro.core.lagrange import CodeSpec
from repro.core.lea import LoadParams
from repro.core import throughput

_BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                              "BENCH_fig3.json")


def _scenario_args(lp: LoadParams, rounds: int):
    for i, (p_gg, p_bb) in enumerate(SIM.scenarios, 1):
        yield i, (
            jax.random.PRNGKey(i), lp,
            jnp.full((SIM.n,), p_gg), jnp.full((SIM.n,), p_bb),
            SIM.mu_g, SIM.mu_b, SIM.deadline, rounds,
        )


def run(rounds: int | None = None, write_baseline: bool | None = None) -> list[dict]:
    # only full-length (default-rounds) runs may refresh the committed
    # baseline — a smoke run with tiny `rounds` must not clobber it
    if write_baseline is None:
        write_baseline = rounds is None
    spec = CodeSpec(SIM.n, SIM.r, SIM.k, SIM.deg_f)
    lp = LoadParams(
        n=SIM.n, kstar=spec.recovery_threshold,
        ell_g=int(min(SIM.mu_g * SIM.deadline, SIM.r)),
        ell_b=int(SIM.mu_b * SIM.deadline),
    )
    assert lp.kstar == 99
    rounds = rounds or SIM.rounds
    strategies = ("lea", "static", "oracle")
    rows, results = [], []
    for i, args in _scenario_args(lp, rounds):
        t0 = time.time()
        res = throughput.compare(*args, strategies=strategies)
        ratio = res["lea"] / max(res["static"], 1e-9)
        rows.append({
            "name": f"fig3_scenario{i}",
            "us_per_call": (time.time() - t0) * 1e6 / rounds,
            "derived": (
                f"R_lea={res['lea']:.4f};R_static={res['static']:.4f};"
                f"R_oracle={res['oracle']:.4f};ratio={ratio:.2f}x"
            ),
        })
        results.append({"scenario": i, **{f"R_{s}": res[s] for s in strategies},
                        "ratio_lea_static": ratio})

    if write_baseline:
        # warm steady-state pass (first pass above paid compilation)
        t0 = time.perf_counter()
        for _, args in _scenario_args(lp, rounds):
            throughput.compare(*args, strategies=strategies)
        warm_s = time.perf_counter() - t0
        try:
            from benchmarks.bench_allocator import allocator_microbench
        except ImportError:  # script mode: `python benchmarks/fig3_sim.py`
            from bench_allocator import allocator_microbench

        us_old, _, us_new_row = allocator_microbench(lp)
        baseline = {
            "bench": "fig3_sim",
            "rounds": rounds,
            "scenarios": len(SIM.scenarios),
            "strategies": list(strategies),
            "rounds_per_sec": len(SIM.scenarios) * rounds / warm_s,
            "allocator_us_per_call_seed": us_old,
            "allocator_us_per_call_batched_row": us_new_row,
            "results": results,
        }
        with open(_BASELINE_PATH, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
