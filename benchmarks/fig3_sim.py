"""Paper Fig. 3 — numerical analysis: LEA vs static over the 4 scenarios.

Setting (Sec. 6.1): n=15 workers, k=50 chunks, r=10, deg f=2 -> K*=99;
mu=(10,3), d=1s.  Paper reports LEA/static improvements of 1.38x–17.5x.

A thin ``repro.sweeps`` registry invocation: the ``fig3`` scenario family
expands the grid and the sweep executor runs all 4 scenarios as ONE compiled
computation (the scenarios share one LoadParams group), on the same per-
scenario PRNG keys as the PR-1 ``throughput.compare`` path — the emitted
throughput values are bit-identical.  Also emits ``BENCH_fig3.json`` at the
repo root — a perf baseline (rounds/sec, allocator us/call) for future PRs
to compare against.
"""

from __future__ import annotations

import os
import time

from repro import sweeps
from repro.configs.paper_lea import SIM

_BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                              "BENCH_fig3.json")

STRATEGIES = ("lea", "static", "oracle")


def run(rounds: int | None = None, write_baseline: bool | None = None) -> list[dict]:
    # only full-length (default-rounds) runs may refresh the committed
    # baseline — a smoke run with tiny `rounds` must not clobber it
    if write_baseline is None:
        write_baseline = rounds is None
    rounds = rounds or SIM.rounds
    scenarios = sweeps.expand("fig3", rounds=rounds)
    lp = scenarios[0].lp
    assert lp.kstar == 99

    t0 = time.time()
    res = sweeps.run(scenarios)
    us_per_call = (time.time() - t0) * 1e6 / (len(scenarios) * rounds)

    rows, results = [], []
    for i, r in enumerate(res, 1):
        tp = r.throughput
        ratio = tp["lea"] / max(tp["static"], 1e-9)
        rows.append({
            "name": r.name,
            "us_per_call": us_per_call,
            "derived": (
                f"R_lea={tp['lea']:.4f};R_static={tp['static']:.4f};"
                f"R_oracle={tp['oracle']:.4f};ratio={ratio:.2f}x"
            ),
        })
        results.append({"scenario": i, **{f"R_{s}": tp[s] for s in STRATEGIES},
                        "ratio_lea_static": ratio})

    if write_baseline:
        # warm steady-state pass (first pass above paid compilation)
        t0 = time.perf_counter()
        sweeps.run(scenarios)
        warm_s = time.perf_counter() - t0
        try:
            from benchmarks.bench_allocator import allocator_microbench
        except ImportError:  # script mode: `python benchmarks/fig3_sim.py`
            from bench_allocator import allocator_microbench

        us_old, _, us_new_row = allocator_microbench(lp)
        baseline = {
            "bench": "fig3_sim",
            "rounds": rounds,
            "scenarios": len(scenarios),
            "strategies": list(STRATEGIES),
            "rounds_per_sec": len(scenarios) * rounds / warm_s,
            "allocator_us_per_call_seed": us_old,
            "allocator_us_per_call_batched_row": us_new_row,
            "results": results,
        }
        sweeps.write_manifest(_BASELINE_PATH, baseline)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
