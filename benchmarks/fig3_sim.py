"""Paper Fig. 3 — numerical analysis: LEA vs static over the 4 scenarios.

Setting (Sec. 6.1): n=15 workers, k=50 chunks, r=10, deg f=2 -> K*=99;
mu=(10,3), d=1s.  Paper reports LEA/static improvements of 1.38x–17.5x.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_lea import SIM
from repro.core.lagrange import CodeSpec
from repro.core.lea import LoadParams
from repro.core import throughput


def run(rounds: int | None = None) -> list[dict]:
    spec = CodeSpec(SIM.n, SIM.r, SIM.k, SIM.deg_f)
    lp = LoadParams(
        n=SIM.n, kstar=spec.recovery_threshold,
        ell_g=int(min(SIM.mu_g * SIM.deadline, SIM.r)),
        ell_b=int(SIM.mu_b * SIM.deadline),
    )
    assert lp.kstar == 99
    rounds = rounds or SIM.rounds
    rows = []
    for i, (p_gg, p_bb) in enumerate(SIM.scenarios, 1):
        t0 = time.time()
        res = throughput.compare(
            jax.random.PRNGKey(i), lp,
            jnp.full((SIM.n,), p_gg), jnp.full((SIM.n,), p_bb),
            SIM.mu_g, SIM.mu_b, SIM.deadline, rounds,
            strategies=("lea", "static", "oracle"),
        )
        ratio = res["lea"] / max(res["static"], 1e-9)
        rows.append({
            "name": f"fig3_scenario{i}",
            "us_per_call": (time.time() - t0) * 1e6 / rounds,
            "derived": (
                f"R_lea={res['lea']:.4f};R_static={res['static']:.4f};"
                f"R_oracle={res['oracle']:.4f};ratio={ratio:.2f}x"
            ),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
