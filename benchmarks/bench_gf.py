"""Exact GF(p) path benchmark: numpy ``*_modp`` host oracle vs the device
path (``repro.kernels.gf``) + ``BENCH_gf.json``.

Three measurements at paper-scale shapes (Sec. 6.1/6.2: n=15, r=10, k=50 —
a (150, 50) generator over GF(2^31 - 1)):

  * ``gf_encode_gemm``   — the encode GEMM G @ X: ``lagrange.matmul_modp``
    (int64 broadcast-multiply / mod / sum) vs ``gf.matmul_gf`` (16 exact
    float32 limb GEMMs + Mersenne rotations on CPU/GPU, the Pallas kernel
    on TPU), GB/s both ways;
  * ``gf_decode_matrix`` — erasure-pattern decode-matrix construction:
    ``lagrange.decode_matrix_modp`` (python-loop basis + Fermat per node)
    per pattern vs ONE batched ``decode_matrix_modp_device`` call over all
    patterns;
  * ``gf_exact_round``   — the headline: a full exact coded round
    (worker-shard matmul -> gather survivors -> build decode matrix ->
    decode) per erasure pattern, numpy pipeline vs jit-vmapped
    ``coded_matmul_exact``.

Erasure patterns come from an engine ``rollout()`` on the paper's two-state
chains (via ``coded_ops.chunk_on_time``), not synthetic masks — the
stragglers ARE the paper's Markov workers.  Device results are asserted
bit-identical to the numpy pipeline before anything is timed.

``BENCH_gf.json`` at the repo root records shapes, times, GB/s and the
speedups; the acceptance bar is >= 5x on the exact-round path.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._softgate import collect, warn_speedup_bar
from repro.core import throughput
from repro.core.coded_ops import chunk_on_time, coded_matmul_exact, encode_dataset_modp
from repro.core.lagrange import (FIELD_P, CodeSpec, decode_matrix_modp,
                                 decode_matrix_modp_device,
                                 generator_matrix_modp, matmul_modp)
from repro.core.lea import LoadParams
from repro.kernels.gf import matmul_gf
from repro.sweeps import write_manifest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_MANIFEST = os.path.join(_ROOT, "BENCH_gf.json")

# paper-scale code: Sec. 6.2 EC2 k=50, deg f = 1 (exact matmul), K* = 50
N, R, K = 15, 10, 50
ROWS, COLS, DOUT = 25, 400, 8
PATTERNS = 24           # erasure patterns per timed pass (distinct rounds)
P_GG, P_BB = 0.85, 0.6  # the Fig. 4 credit-based chain
SPEEDUP_BAR = 5.0       # exact-round acceptance bar (soft: warn, never fail)


def _time(fn, iters: int = 3) -> float:
    fn()  # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    elif isinstance(out, (tuple, list)) and hasattr(out[0], "block_until_ready"):
        out[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def _rollout_patterns(spec: CodeSpec, lp: LoadParams, want: int) -> np.ndarray:
    """(want, nr) bool on-time masks with >= K* survivors, from the engine."""
    mu_g, mu_b, deadline = float(lp.ell_g), float(lp.ell_b), 1.0
    states, loads, _ = throughput.rollout(
        jax.random.PRNGKey(0), lp,
        jnp.full((lp.n,), P_GG), jnp.full((lp.n,), P_BB),
        rounds=8 * want, strategies=("lea",),
    )
    masks = np.asarray(chunk_on_time(states, loads[0], mu_g, mu_b, deadline, spec.r))
    good = masks[masks.sum(axis=1) >= spec.recovery_threshold]
    if good.shape[0] < want:  # pragma: no cover - generous rounds above
        raise RuntimeError(f"only {good.shape[0]} feasible rounds for {want} patterns")
    return good[:want]


def run() -> list[dict]:
    spec = CodeSpec(N, R, K, deg_f=1)
    kstar = spec.recovery_threshold
    lp = LoadParams(n=N, kstar=kstar, ell_g=R, ell_b=max(1, R // 10))
    rng = np.random.default_rng(0)

    x = rng.integers(0, FIELD_P, size=(K, ROWS, COLS), dtype=np.int64)
    # one model per round: every round genuinely re-evaluates its shards on
    # both paths (a shared w would let vmap hoist the device matmul out)
    w = rng.integers(0, FIELD_P, size=(PATTERNS, COLS, DOUT), dtype=np.int64)
    g_np = generator_matrix_modp(spec)
    masks = _rollout_patterns(spec, lp, PATTERNS)
    received = np.stack(
        [np.nonzero(m)[0][:kstar] for m in masks]
    )                                                     # (PATTERNS, K*)

    # -- encode GEMM: G (nr, k) @ X (k, rows*cols) ---------------------------
    x_flat = x.reshape(K, -1)
    x_dev = jnp.asarray(x_flat, jnp.int32)
    g_dev = jnp.asarray(g_np, jnp.int32)
    want_xt = matmul_modp(g_np, x_flat)
    got_xt = np.asarray(matmul_gf(g_dev, x_dev), np.int64)
    np.testing.assert_array_equal(got_xt, want_xt)        # bit-exact, always

    t_np = _time(lambda: matmul_modp(g_np, x_flat))
    enc = jax.jit(lambda a, b: matmul_gf(a, b))
    t_dev = _time(lambda: enc(g_dev, x_dev), iters=10)
    gemm_bytes = 4 * (spec.nr * K + K * x_flat.shape[1] + spec.nr * x_flat.shape[1])
    rows = [{
        "name": "gf_encode_gemm",
        "us_per_call": t_dev * 1e6,
        "derived": (
            f"shape={spec.nr}x{K}@{K}x{x_flat.shape[1]};"
            f"numpy_ms={t_np*1e3:.1f};device_ms={t_dev*1e3:.2f};"
            f"gbps={gemm_bytes/t_dev/1e9:.2f};speedup={t_np/t_dev:.1f}x"
        ),
    }]
    speedup_gemm = t_np / t_dev

    # -- decode-matrix construction over all erasure patterns ----------------
    def np_decode_mats():
        return [decode_matrix_modp(spec, r) for r in received]

    rec_dev = jnp.asarray(received, jnp.int32)
    dec = jax.jit(lambda r: decode_matrix_modp_device(spec, r))
    want_mats = np_decode_mats()
    got_mats = np.asarray(dec(rec_dev), np.int64)
    np.testing.assert_array_equal(got_mats, np.stack(want_mats))

    t_np = _time(np_decode_mats) / PATTERNS
    t_dev = _time(lambda: dec(rec_dev), iters=10) / PATTERNS
    rows.append({
        "name": "gf_decode_matrix",
        "us_per_call": t_dev * 1e6,
        "derived": (
            f"patterns={PATTERNS};kstar={kstar};"
            f"numpy_ms={t_np*1e3:.1f};device_ms={t_dev*1e3:.3f};"
            f"speedup={t_np/t_dev:.0f}x"
        ),
    })
    speedup_decode = t_np / t_dev

    # -- headline: full exact coded round, engine-driven erasure patterns ----
    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32))
    xt_np = np.asarray(coded.x_tilde, np.int64)
    w_dev = jnp.asarray(w, jnp.int32)
    masks_dev = jnp.asarray(masks)

    def np_round(on_time: np.ndarray, w_m: np.ndarray):
        res = matmul_modp(xt_np.reshape(spec.nr * ROWS, COLS), w_m)
        res = res.reshape(spec.nr, ROWS, DOUT)
        rec = np.nonzero(on_time)[0][:kstar]
        d = decode_matrix_modp(spec, rec)
        return matmul_modp(d, res[rec].reshape(kstar, -1))

    exact_batch = jax.jit(
        jax.vmap(lambda m, w_m: coded_matmul_exact(coded, w_m, m)[0])
    )
    got = np.asarray(exact_batch(masks_dev, w_dev), np.int64)
    for i in range(PATTERNS):
        want = np_round(masks[i], w[i]).reshape(K, ROWS, DOUT)
        np.testing.assert_array_equal(got[i], want)       # every pattern exact

    t_np = _time(
        lambda: [np_round(m, wm) for m, wm in zip(masks, w)], iters=1
    ) / PATTERNS
    t_dev = _time(lambda: exact_batch(masks_dev, w_dev), iters=5) / PATTERNS
    rows.append({
        "name": "gf_exact_round",
        "us_per_call": t_dev * 1e6,
        "derived": (
            f"patterns={PATTERNS};shards={spec.nr}x{ROWS}x{COLS};dout={DOUT};"
            f"numpy_ms={t_np*1e3:.1f};device_ms={t_dev*1e3:.2f};"
            f"speedup={t_np/t_dev:.0f}x;bitexact=1"
        ),
    })
    speedup_round = t_np / t_dev

    # soft perf gate, same convention as sweep_smoke: a refresh on a slow /
    # contended machine WARNS and flags the manifest, it never fails CI —
    # bit-exactness above is the hard gate, wall clock is not
    warnings = collect(warn_speedup_bar(
        "bench_gf", speedup_round, SPEEDUP_BAR, metric="exact-round speedup"
    ))
    below_bar = bool(warnings)

    doc = {
        "bench": "bench_gf",
        "speedup_bar": SPEEDUP_BAR,
        "speedup_below_bar": below_bar,
        "field_p": FIELD_P,
        "spec": {"n": N, "r": R, "k": K, "deg_f": 1, "kstar": kstar},
        "shapes": {
            "encode_gemm": [spec.nr, K, x_flat.shape[1]],
            "shard_rows": ROWS, "shard_cols": COLS, "dout": DOUT,
            "patterns": PATTERNS,
        },
        "backend": jax.default_backend(),
        "bit_exact_vs_numpy": True,
        "encode_gemm_gbps": gemm_bytes / (rows[0]["us_per_call"] / 1e6) / 1e9,
        "speedup_encode_gemm": speedup_gemm,
        "speedup_decode_matrix": speedup_decode,
        "speedup_exact_round": speedup_round,
        "warnings": warnings,
        "results": rows,
    }
    # write_manifest stamps provenance + enforces RFC-8259-strict JSON
    write_manifest(_MANIFEST, doc)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
