"""Kernel micro-benchmarks.

On this CPU container the XLA (ref) path is the executable-speed number; the
Pallas kernels run in interpret mode (correctness only — their timing is NOT
TPU-indicative and is reported separately as *_interpret).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lagrange import CodeSpec, generator_matrix
from repro.kernels.lagrange_encode.kernel import encode_matrix_pallas
from repro.kernels.lagrange_encode.ref import encode_matrix_ref
from repro.kernels.coded_gradient.kernel import coded_gradient_pallas
from repro.kernels.coded_gradient.ref import coded_gradient_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # Lagrange encode at the paper's sim scale: G (150,50) x X (50, 40000)
    spec = CodeSpec(15, 10, 50, 2)
    g = generator_matrix(spec)
    x = jnp.asarray(rng.normal(size=(50, 40_000)), jnp.float32)
    us_ref = _time(jax.jit(encode_matrix_ref), g, x)
    us_int = _time(lambda a, b: encode_matrix_pallas(a, b, interpret=True), g, x, iters=2)
    rows.append({"name": "lagrange_encode_xla", "us_per_call": us_ref,
                 "derived": "shape=150x50@50x40000"})
    rows.append({"name": "lagrange_encode_pallas_interpret", "us_per_call": us_int,
                 "derived": "interpret=True;correctness-path"})

    # fused coded gradient at EC2 scale-ish: (150 chunks, 25 rows, 3000 cols)
    xt = jnp.asarray(rng.normal(size=(150, 25, 1000)), jnp.float32)
    yt = jnp.asarray(rng.normal(size=(150, 25, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1000, 1)), jnp.float32)
    us_ref = _time(jax.jit(coded_gradient_ref), xt, yt, w)
    us_int = _time(lambda a, b, c: coded_gradient_pallas(a, b, c, interpret=True),
                   xt, yt, w, iters=2)
    rows.append({"name": "coded_gradient_xla", "us_per_call": us_ref,
                 "derived": "shape=150x25x1000"})
    rows.append({"name": "coded_gradient_pallas_interpret", "us_per_call": us_int,
                 "derived": "interpret=True;correctness-path"})

    # flash attention (small): B1 H4 S256 D64
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True)), q, k, v)
    us_int = _time(lambda a, b, c: flash_attention_pallas(
        a, b, c, causal=True, block_q=64, block_k=64, interpret=True), q, k, v, iters=2)
    rows.append({"name": "flash_attention_xla", "us_per_call": us_ref,
                 "derived": "B1H4S256D64,GQA2"})
    rows.append({"name": "flash_attention_pallas_interpret", "us_per_call": us_int,
                 "derived": "interpret=True;correctness-path"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
