"""Beyond-paper table: LEA-coded microbatch DP (the repetition branch inside
the trainer) vs static allocation, across network-dynamics regimes.

Two measurements per regime:
  * ``coded_dp_*``      — the eager :class:`CodedDataParallelExecutor` round
    loop (gradient decode included); its allocation hot path now runs through
    the jitted batched allocator (``runtime.fault_tolerance._plan_round``).
  * ``coded_dp_engine`` — the same three (p_gg, p_bb) regimes pushed through
    ``core.throughput.sweep`` in ONE batched computation (B=3 scenario rows,
    lea vs static columns, K*-criterion scoring), giving the pure scheduling
    throughput at engine speed.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import throughput
from repro.runtime.fault_tolerance import CodedDPConfig, CodedDataParallelExecutor

REGIMES = [(0.8, 0.8), (0.8, 0.7), (0.9, 0.6)]


def _grad_fn(params, batch):
    def loss(w):
        return jnp.mean((batch["x"] @ w - batch["y"]) ** 2)
    return {"w": jax.grad(lambda p: loss(p["w"]))(params)["w"]}


def run(rounds: int = 120, engine_rounds: int = 2000) -> list[dict]:
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
    }
    params = {"w": jnp.zeros((4,), jnp.float32)}
    rows = []
    cfg0 = CodedDPConfig(n_workers=8, r=4, k=16)
    for p_gg, p_bb in REGIMES:
        cfg = CodedDPConfig(n_workers=8, r=4, k=16, p_gg=p_gg, p_bb=p_bb)
        ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=1)
        t0 = time.time()
        for _ in range(rounds):
            ex.round(params, batch)
        rows.append({
            "name": f"coded_dp_pgg{p_gg}_pbb{p_bb}",
            "us_per_call": (time.time() - t0) * 1e6 / rounds,
            "derived": f"timely_throughput={ex.timely_throughput:.3f};Kstar={cfg.load_params.kstar}",
        })

    # same regimes, batched engine (shared LoadParams across regimes)
    lp = cfg0.load_params
    n = cfg0.n_workers
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(len(REGIMES))])
    pg = jnp.stack([jnp.full((n,), p) for p, _ in REGIMES])
    pb = jnp.stack([jnp.full((n,), p) for _, p in REGIMES])
    t0 = time.time()
    succ = throughput.sweep(
        keys, lp, pg, pb, cfg0.mu_g, cfg0.mu_b, cfg0.deadline,
        engine_rounds, ("lea", "static"),
    )
    dt = time.time() - t0
    r = np.asarray(succ, np.float32).mean(axis=1)   # (3, 2)
    derived = ";".join(
        f"pgg{p_gg}_pbb{p_bb}:R_lea={r[i, 0]:.3f},R_static={r[i, 1]:.3f}"
        for i, (p_gg, p_bb) in enumerate(REGIMES)
    )
    rows.append({
        "name": "coded_dp_engine",
        "us_per_call": dt * 1e6 / (len(REGIMES) * engine_rounds),
        "derived": f"{derived};Kstar={lp.kstar};rounds={engine_rounds}",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
