"""Beyond-paper table: LEA-coded microbatch DP (the repetition branch inside
the trainer) vs static allocation, across network-dynamics regimes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault_tolerance import CodedDPConfig, CodedDataParallelExecutor


def _grad_fn(params, batch):
    def loss(w):
        return jnp.mean((batch["x"] @ w - batch["y"]) ** 2)
    return {"w": jax.grad(lambda p: loss(p["w"]))(params)["w"]}


def run(rounds: int = 120) -> list[dict]:
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
    }
    params = {"w": jnp.zeros((4,), jnp.float32)}
    rows = []
    for p_gg, p_bb in [(0.8, 0.8), (0.8, 0.7), (0.9, 0.6)]:
        cfg = CodedDPConfig(n_workers=8, r=4, k=16, p_gg=p_gg, p_bb=p_bb)
        ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=1)
        t0 = time.time()
        for _ in range(rounds):
            ex.round(params, batch)
        rows.append({
            "name": f"coded_dp_pgg{p_gg}_pbb{p_bb}",
            "us_per_call": (time.time() - t0) * 1e6 / rounds,
            "derived": f"timely_throughput={ex.timely_throughput:.3f};Kstar={cfg.load_params.kstar}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
