"""CI smoke gate for the ``repro.sweeps`` subsystem + BENCH_sweep.json.

Runs a tiny heterogeneous-K* registry grid through the full production path
— 8 forced host devices, a 1-D ``jax.sharding`` batch mesh, ``round_chunk``
blocking, multi-seed rows — in a subprocess (XLA device-count flags must be
set before jax initialises, and the parent harness has already imported
jax), asserts the sharded/chunked output matches per-row static-``LoadParams``
engine runs bit-for-bit (the shape-polymorphic engine's full-width
invariant), and emits ``BENCH_sweep.json`` at the repo root with rows/sec,
per-row allocator time AND the compile count per scenario family so the
perf trajectory covers the sweep subsystem alongside ``BENCH_fig3.json``.

Since the traced-K*/ell engine, the WHOLE hetero-K* grid is ONE compiled
computation (``family_compiles`` asserts it); the compile count per family
is also soft-checked against the committed ``BENCH_sweep.json`` — a family
that starts compiling MORE computations than the baseline prints a WARNING
to stderr and flags the manifest, same convention as the rows/sec check
below (never a hard failure: the hard gate is the in-run assertion).

The warm rows/sec is also soft-checked against the previously committed
``BENCH_sweep.json``: a drop beyond ``SLOWDOWN_WARN_FRACTION`` prints a
WARNING to stderr (and flags the manifest/derived row) but never fails —
shared-CI wall clocks are too noisy for a hard gate.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks._softgate import (SLOWDOWN_WARN_FRACTION, collect,
                                  committed_baseline, warn_compiles,
                                  warn_slowdown)

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_BASELINE_PATH = os.path.join(_ROOT, "BENCH_sweep.json")

DEVICES = 8
ROUNDS = 192
ROUND_CHUNK = 48
SEEDS = 2
KS = (50, 80, 99)
LAMS = (0.2, 0.7)
FAMILY = "hetero_kstar"

_MARKER = "SWEEP_SMOKE_ROWS "


def run() -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT] + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        capture_output=True, text=True, timeout=900, env=env, cwd=_ROOT,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"sweep_smoke child failed:\n{proc.stdout}\n{proc.stderr}")
    if proc.stderr:
        print(proc.stderr, file=sys.stderr, end="")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARKER):
            return json.loads(line[len(_MARKER):])
    raise RuntimeError(f"sweep_smoke child produced no rows:\n{proc.stdout}")


def _child_main() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import sweeps
    from repro.core import lea as lea_mod
    from repro.core import throughput
    from repro.launch.mesh import make_sweep_mesh

    assert len(jax.devices()) == DEVICES, jax.devices()
    mesh = make_sweep_mesh()

    scenarios = sweeps.expand(FAMILY, ks=KS, lams=LAMS, rounds=ROUNDS)
    groups = sweeps.build_groups(scenarios, seeds=SEEDS)
    # traced K* fuses the whole heterogeneous grid into ONE group
    assert len(groups) == 1, [g.rounds for g in groups]

    c0 = sweeps.compile_cache_size()
    t0 = time.perf_counter()
    succs = sweeps.run_groups(groups, mesh=mesh, round_chunk=ROUND_CHUNK)
    cold_s = time.perf_counter() - t0
    compiles = sweeps.compile_cache_size() - c0
    assert compiles == len(groups) == 1, (compiles, len(groups))
    family_compiles = {FAMILY: compiles}

    # the smoke *gate*: production path == per-row static-LoadParams engine,
    # bit-for-bit (the shape-polymorphic engine's full-width invariant — the
    # strongest reference available now that one group spans many K*s)
    (group,), (succ,) = groups, succs
    for ri, rm in enumerate(group.rows):
        sc = group.scenarios[rm.scenario_index]
        ref = throughput.simulate_strategies(
            group.batch.keys[ri], sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, group.rounds,
            strategies=group.strategies,
        )
        np.testing.assert_array_equal(succ[ri], np.asarray(ref))

    # warm steady-state rows/sec (simulated rounds per wall second)
    t0 = time.perf_counter()
    sweeps.run_groups(groups, mesh=mesh, round_chunk=ROUND_CHUNK)
    warm_s = time.perf_counter() - t0
    total_rows = sum(g.batch.rows for g in groups)
    rows_per_sec = total_rows * ROUNDS / warm_s

    # soft regression checks vs the COMMITTED baseline (benchmarks._softgate:
    # git HEAD reference, stderr WARNING + manifest flag, never a hard
    # failure — the hard in-run assertion above is the real gate)
    baseline = committed_baseline(_BASELINE_PATH)
    baseline_rps = baseline.get("rows_per_sec")
    warnings = collect(
        warn_slowdown("sweep_smoke", rows_per_sec, baseline_rps),
        warn_compiles(
            "sweep_smoke", family_compiles, baseline.get("family_compiles", {})
        ),
    )
    slowdown_warned = any(w["kind"] == "slowdown" for w in warnings)
    compile_warned = any(w["kind"] == "compiles" for w in warnings)

    # per-row allocator time inside one batched allocate (the sweep hot path)
    lp = scenarios[0].lp
    p = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (4096, lp.n)), jnp.float32)
    alloc = jax.jit(lambda q: lea_mod.allocate(q, lp)[0])
    alloc(p).block_until_ready()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        alloc(p).block_until_ready()
    allocator_us_per_row = (time.perf_counter() - t0) / reps / p.shape[0] * 1e6

    results = sweeps.summarize(groups, succs, scenario_order=scenarios)
    doc = sweeps.manifest(
        results,
        bench="sweep_smoke",
        extra={
            "devices": DEVICES,
            "mesh_axes": list(mesh.axis_names),
            "seeds": SEEDS,
            "rounds": ROUNDS,
            "round_chunk": ROUND_CHUNK,
            "groups": len(groups),
            "group_compiles": compiles,
            "family_compiles": family_compiles,
            "compile_warned": compile_warned,
            "batch_rows": total_rows,
            "rows_per_sec": rows_per_sec,
            "baseline_rows_per_sec": baseline_rps,
            "slowdown_warned": slowdown_warned,
            "cold_s": cold_s,
            "warm_s": warm_s,
            "allocator_us_per_row": allocator_us_per_row,
            "warnings": warnings,
        },
    )
    sweeps.write_manifest(_BASELINE_PATH, doc)

    rows = [{
        "name": "sweep_smoke",
        "us_per_call": warm_s * 1e6 / (total_rows * ROUNDS),
        "derived": (
            f"devices={DEVICES};groups={len(groups)};rows={total_rows};"
            f"rounds={ROUNDS};chunk={ROUND_CHUNK};"
            f"rows_per_sec={rows_per_sec:.0f};compiles={compiles};bitexact=1;"
            f"baseline_rps={baseline_rps or 0:.0f};"
            f"slowdown_warned={int(slowdown_warned)};"
            f"compile_warned={int(compile_warned)}"
        ),
    }]
    for r in results:
        rows.append({
            "name": f"sweep_{r.name}",
            "us_per_call": warm_s * 1e6 / (total_rows * ROUNDS),
            "derived": (
                f"Kstar={r.scenario.lp.kstar};"
                + ";".join(f"R_{s}={v:.4f}" for s, v in r.throughput.items())
                + f";ratio={r.baseline_ratio:.2f}x"
            ),
        })
    print(_MARKER + json.dumps(rows))


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child_main()
    else:
        for row in run():
            print(row)
