"""Policy shoot-out benchmark + ``BENCH_policies.json``.

Runs the registered scheduling policies (vanilla LEA, windowed LEA,
discounted LEA, Thompson sampling, UCB) against the static floor and the
genie oracle on three chain regimes — a stationary paper chain, the
``drifting_chains`` sinusoidal drift and the ``regime_switch`` degradation
waves — through the full ``repro.sweeps`` registry path, and emits
``BENCH_policies.json`` at the repo root with per-policy timely
throughput, the ratio against each scenario's baseline, and the final
cumulative regret vs the oracle (the ``regret_*`` manifest columns).

Sized for the CI smoke gate (a few seconds of simulation); the knobs are
module constants so a paper-scale run is one edit away.
"""

from __future__ import annotations

import os
import time

from repro import sweeps
from repro.configs.paper_lea import SIM
from repro.sweeps.scenarios import _sim_lp

_BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_policies.json",
)

ROUNDS = 1_200
SEEDS = 4
# the full policy axis: vanilla LEA and its adaptive variants, the
# randomised/optimistic learners, the static floor, the genie oracle
# (spelled out by name — "oracle" must stay present for the regret columns)
STRATEGIES = ("lea", "lea_window64", "lea_discount97", "thompson", "ucb",
              "static", "oracle")


def _stationary_scenario(rounds: int) -> sweeps.Scenario:
    """The paper's Sec. 6.1 scenario-2 chain with the policy axis attached."""
    lp = _sim_lp()
    p_gg, p_bb = SIM.scenarios[1]
    return sweeps.Scenario(
        name="stationary_sim2", family="bench_policies", lp=lp,
        p_gg=(p_gg,) * SIM.n, p_bb=(p_bb,) * SIM.n,
        mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline, rounds=rounds,
        strategies=STRATEGIES, baseline="lea", seed=2,
        meta=(("chain", "sim_scenario2"),),
    )


def run(rounds: int = ROUNDS, seeds: int = SEEDS,
        write_baseline: bool = True) -> list[dict]:
    scenarios = (
        (_stationary_scenario(rounds),)
        + sweeps.expand("drifting_chains", periods=(400,), rounds=rounds,
                        strategies=STRATEGIES)
        + sweeps.expand("regime_switch", dwells=(250,), rounds=rounds,
                        strategies=STRATEGIES)
    )
    t0 = time.perf_counter()
    results = sweeps.run(scenarios, seeds=seeds)
    wall_s = time.perf_counter() - t0
    total_rounds = len(scenarios) * seeds * rounds

    if write_baseline:
        doc = sweeps.manifest(
            results,
            bench="bench_policies",
            extra={
                "strategies": list(STRATEGIES),
                "seeds": seeds,
                "rounds": rounds,
                "wall_s": wall_s,
                "sim_rounds_per_sec": total_rounds / max(wall_s, 1e-9),
            },
        )
        sweeps.write_manifest(_BASELINE_PATH, doc)

    rows = []
    for r in results:
        for s in STRATEGIES:
            derived = f"R={r.throughput[s]:.4f}"
            if s != r.scenario.baseline:
                derived += f";ratio={r.ratio[s]:.2f}x"
            if s in r.regret:
                derived += f";final_regret={r.regret[s]:.1f}"
            rows.append({
                "name": f"policy_{r.name}_{s}",
                "us_per_call": wall_s * 1e6 / total_rounds,
                "derived": derived,
            })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
