"""Raw-speed gate for the sweep hot path + BENCH_speed.json.

Measures the pipelined executor (``repro.sweeps.run_group(pipeline=True)``
— shard-once batch cache, donated round-chunk carries, async double-
buffered block dispatch) against the sync path IN THE SAME PROCESS on the
committed ``sweep_smoke`` grid, so the before/after comparison is honest on
whatever machine runs it: both numbers are fresh, the committed
``rows_per_sec`` of an older container never inflates the speedup.

Three subprocess children (XLA device flags and persistent-cache config
must precede jax import):

  * the MAIN child: sync vs async warm rows/sec, phase-seconds
    attribution, the bit-identity hard gate (async == sync, full-width
    rows), donation proof (runtime buffer deletion AND
    ``input_output_alias`` in the compiled block-step HLO) and the tap
    overlap accounting (``tap.engine_pool.block_seconds`` from a
    tapped pipelined run);
  * a COLD cache child + a WARM cache child sharing one
    ``REPRO_COMPILE_CACHE`` dir: the warm process must re-run the same
    family with ZERO backend compile events through the unified counter
    (``repro.obs.counters.backend_compile_events``) — the cold-vs-warm
    process compile-time row.

Acceptance is the soft-gate convention (``benchmarks._softgate``): the
async path must reach ``SPEEDUP_BAR`` (1.3x) over sync — a miss WARNS and
flags the manifest, the hard gates are the in-child assertions
(bit-identity, donation, warm-restart 0 compiles).  ``BENCH_speed.json``
lands at the repo root and feeds ``BENCH_history.jsonl`` + the trend gate
like every other manifest.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks._softgate import (collect, committed_baseline, warn_slowdown,
                                  warn_speedup_bar)

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_MANIFEST_PATH = os.path.join(_ROOT, "BENCH_speed.json")

# the committed sweep_smoke grid (benchmarks/sweep_smoke.py) — the speedup
# is measured on exactly the workload the sweep gate tracks
DEVICES = 8
ROUNDS = 192
# both paths run the SAME chunking (sync: lax.map block size; async: the
# dispatched block size).  192/96 = 2 blocks keeps the async loop genuinely
# double-buffered while paying the per-block dispatch tax only twice.
ROUND_CHUNK = 96
SEEDS = 2
KS = (50, 80, 99)
LAMS = (0.2, 0.7)
FAMILY = "hetero_kstar"

SPEEDUP_BAR = 1.3
WARM_REPS = 5

_MARKER = "BENCH_SPEED "


def _child_env(extra: dict | None = None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT]
        + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    if extra:
        env.update(extra)
    return env


def _spawn(flag: str, env: dict) -> dict:
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), flag],
        capture_output=True, text=True, timeout=900, env=env, cwd=_ROOT,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_speed child {flag} failed:\n{proc.stdout}\n{proc.stderr}")
    if proc.stderr:
        print(proc.stderr, file=sys.stderr, end="")
    for line in proc.stdout.splitlines():
        if line.startswith(_MARKER):
            return json.loads(line[len(_MARKER):])
    raise RuntimeError(f"bench_speed child {flag} printed no payload:\n{proc.stdout}")


def run() -> list[dict]:
    main = _spawn("--child-main", _child_env())
    with tempfile.TemporaryDirectory() as cache_dir:
        env = _child_env({"REPRO_COMPILE_CACHE": cache_dir})
        cold = _spawn("--child-cache", env)
        warm = _spawn("--child-cache", env)
    # warm restart of an already-cached family: the unified counter must
    # attribute ZERO backend compiles (the persistent-cache acceptance gate)
    assert cold["backend_compiles"] >= 1, cold
    assert warm["backend_compiles"] == 0, warm
    assert warm["persistent_hits"] >= warm["trace_entries"], warm

    speedup = main["async_rows_per_sec"] / main["sync_rows_per_sec"]
    baseline = committed_baseline(_MANIFEST_PATH)
    warnings = collect(
        warn_speedup_bar("bench_speed", speedup, SPEEDUP_BAR,
                         metric="async_vs_sync_rows_per_sec"),
        warn_slowdown("bench_speed", main["async_rows_per_sec"],
                      baseline.get("async_rows_per_sec")),
        None if main["tap_overlap_s"] > 0 else {
            "kind": "overlap",
            "bench": "bench_speed",
            "metric": "tap_overlap_s",
            "value": float(main["tap_overlap_s"]),
            "baseline": 0.0,
            "message": (
                "bench_speed measured no host/device overlap in the tapped "
                "pipelined run (expected on a 1-core box under contention); "
                "soft check only"
            ),
        },
    )

    from repro.sweeps.results import write_manifest

    doc = {
        "bench": "bench_speed",
        "family": FAMILY,
        "devices": DEVICES,
        "rounds": ROUNDS,
        "round_chunk": ROUND_CHUNK,
        "seeds": SEEDS,
        "batch_rows": main["batch_rows"],
        # before/after, measured in one process on this machine
        "sync_rows_per_sec": main["sync_rows_per_sec"],
        "async_rows_per_sec": main["async_rows_per_sec"],
        "speedup_async_vs_sync": speedup,
        "speedup_bar": SPEEDUP_BAR,
        "speedup_below_bar": bool(speedup < SPEEDUP_BAR),
        "sync_cold_s": main["sync_cold_s"],
        "sync_warm_s": main["sync_warm_s"],
        "async_cold_s": main["async_cold_s"],
        "async_warm_s": main["async_warm_s"],
        "bitexact_async_vs_sync": True,          # hard-asserted in the child
        # donation proof, both layers
        "donated_runtime": main["donated_runtime"],
        "donation_hlo_alias": main["donation_hlo_alias"],
        "pipeline_stats": main["pipeline_stats"],
        # tap overlap accounting (block walls observed DURING the async run)
        "tap_block_seconds_count": main["tap_block_seconds_count"],
        "tap_block_seconds_sum": main["tap_block_seconds_sum"],
        "tap_overlap_s": main["tap_overlap_s"],
        # persistent compile cache: cold vs warm PROCESS on one cache dir
        "cache_cold_compile_s": cold["compile_s"],
        "cache_warm_compile_s": warm["compile_s"],
        "cache_cold_backend_compiles": cold["backend_compiles"],
        "cache_warm_backend_compiles": warm["backend_compiles"],
        "cache_warm_persistent_hits": warm["persistent_hits"],
        "baseline_async_rows_per_sec": baseline.get("async_rows_per_sec"),
        "warnings": warnings,
    }
    write_manifest(_MANIFEST_PATH, doc)

    return [{
        "name": "bench_speed",
        "us_per_call": main["async_warm_s"] * 1e6 / (main["batch_rows"] * ROUNDS),
        "derived": (
            f"sync_rps={main['sync_rows_per_sec']:.0f};"
            f"async_rps={main['async_rows_per_sec']:.0f};"
            f"speedup={speedup:.2f}x;bar={SPEEDUP_BAR}x;"
            f"below_bar={int(speedup < SPEEDUP_BAR)};bitexact=1;"
            f"donated={int(main['donated_runtime'])};"
            f"hlo_alias={int(main['donation_hlo_alias'])};"
            f"warm_restart_compiles={warm['backend_compiles']};"
            f"cache_cold_s={cold['compile_s']:.2f};"
            f"cache_warm_s={warm['compile_s']:.2f}"
        ),
    }]


def _child_main() -> None:
    import numpy as np

    import jax

    from repro import sweeps
    from repro.launch.mesh import make_sweep_mesh
    from repro.obs.metrics import MetricsRegistry, tap_to_registry
    from repro.obs import taps as _taps
    from repro.sweeps import executor

    assert len(jax.devices()) == DEVICES, jax.devices()
    mesh = make_sweep_mesh()
    scenarios = sweeps.expand(FAMILY, ks=KS, lams=LAMS, rounds=ROUNDS)
    (group,) = sweeps.build_groups(scenarios, seeds=SEEDS)
    rows = group.batch.rows

    def _measure(pipeline: bool) -> tuple[float, float, np.ndarray]:
        t0 = time.perf_counter()
        out = executor.run_group(group, mesh=mesh, round_chunk=ROUND_CHUNK,
                                 pipeline=pipeline)
        cold_s = time.perf_counter() - t0
        warm_s = float("inf")
        for _ in range(WARM_REPS):                 # best-of: least contended
            t0 = time.perf_counter()
            out = executor.run_group(group, mesh=mesh, round_chunk=ROUND_CHUNK,
                                     pipeline=pipeline)
            warm_s = min(warm_s, time.perf_counter() - t0)
        return cold_s, warm_s, out

    sync_cold_s, sync_warm_s, sync_out = _measure(pipeline=False)
    async_cold_s, async_warm_s, async_out = _measure(pipeline=True)
    stats = executor.last_pipeline_stats()

    # HARD gate: the async path must be bit-identical to the sync engine
    np.testing.assert_array_equal(async_out, sync_out)
    # HARD gate: the carries were really donated
    assert stats["donated"] is True, stats
    hlo_alias = "input_output_alias" in executor.pipeline_block_hlo(
        group, mesh=mesh, round_chunk=ROUND_CHUNK)
    assert hlo_alias, "block step compiled without input_output_alias"

    # tapped pipelined run: block walls observed at actual completion;
    # overlap = host fold time that hid under the in-flight block dispatch
    reg = MetricsRegistry()
    _taps.add_tap("bench_speed", tap_to_registry(reg))
    try:
        tapped = executor.run_group(group, mesh=mesh, round_chunk=ROUND_CHUNK,
                                    pipeline=True, tap=True)
    finally:
        _taps.remove_tap("bench_speed")
    np.testing.assert_array_equal(tapped, sync_out)
    tap_stats = executor.last_pipeline_stats()
    blk = reg.get("tap.engine_pool.block_seconds") or {"count": 0, "sum": 0.0}
    overlap_s = float(tap_stats["fold_s"])         # folds ran while a block flew

    print(_MARKER + json.dumps({
        "batch_rows": rows,
        "sync_rows_per_sec": rows * ROUNDS / sync_warm_s,
        "async_rows_per_sec": rows * ROUNDS / async_warm_s,
        "sync_cold_s": sync_cold_s,
        "sync_warm_s": sync_warm_s,
        "async_cold_s": async_cold_s,
        "async_warm_s": async_warm_s,
        "donated_runtime": bool(stats["donated"]),
        "donation_hlo_alias": bool(hlo_alias),
        "pipeline_stats": {k: (bool(v) if isinstance(v, bool) else v)
                           for k, v in stats.items()},
        "tap_block_seconds_count": int(blk["count"]),
        "tap_block_seconds_sum": float(blk["sum"]),
        "tap_overlap_s": overlap_s,
    }))


def _child_cache() -> None:
    # persistent-cache wiring BEFORE jax touches a backend
    from repro.launch.cache import enable_compile_cache

    assert enable_compile_cache() is not None, "REPRO_COMPILE_CACHE unset"

    from repro import sweeps
    from repro.launch.mesh import make_sweep_mesh
    from repro.obs import counters
    from repro.sweeps import executor

    mesh = make_sweep_mesh()
    scenarios = sweeps.expand(FAMILY, ks=KS, lams=LAMS, rounds=ROUNDS)
    (group,) = sweeps.build_groups(scenarios, seeds=SEEDS)
    t0 = time.perf_counter()
    executor.run_group(group, mesh=mesh, round_chunk=ROUND_CHUNK)
    compile_s = time.perf_counter() - t0           # first call: compile + run
    print(_MARKER + json.dumps({
        "trace_entries": counters.compile_events("sweeps.run_group"),
        "persistent_hits": counters.persistent_cache_hits(),
        "backend_compiles": counters.backend_compile_events("sweeps.run_group"),
        "compile_s": compile_s,
    }))


if __name__ == "__main__":
    if "--child-main" in sys.argv:
        _child_main()
    elif "--child-cache" in sys.argv:
        _child_cache()
    else:
        for row in run():
            print(row)
