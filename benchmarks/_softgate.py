"""Shared soft-perf-gate helpers for the BENCH_*.json-writing targets.

The repo's regression convention (established by ``sweep_smoke``, shared by
``bench_faults``): every perf-ish metric is checked against the COMMITTED
manifest — ``git show HEAD:BENCH_*.json``, so local refreshes can never
ratchet the reference down; the working-tree file is only the fallback when
git is unavailable — and a regression beyond tolerance prints a WARNING to
stderr and flags the manifest, but never fails the run.  Shared-CI wall
clocks are too noisy for hard gates; the hard gates are the in-run
correctness assertions.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

# warn (never fail) when a throughput-style metric drops more than this
# fraction below the committed baseline
SLOWDOWN_WARN_FRACTION = 0.30


def committed_baseline(path: str) -> dict:
    """The committed manifest at ``path`` (git HEAD), falling back to the
    on-disk file outside a usable git checkout."""
    root = os.path.dirname(os.path.abspath(path))
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{os.path.basename(path)}"],
            capture_output=True, text=True, timeout=30, cwd=root,
        )
        if blob.returncode == 0:
            return json.loads(blob.stdout)
    except (OSError, subprocess.SubprocessError, json.JSONDecodeError):
        pass
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def warn_slowdown(
    bench: str,
    value: float,
    baseline_value: float | None,
    *,
    metric: str = "rows/sec",
    fraction: float = SLOWDOWN_WARN_FRACTION,
) -> bool:
    """Soft throughput check: True (and a stderr WARNING) iff ``value`` fell
    more than ``fraction`` below the committed ``baseline_value``."""
    if not baseline_value or value >= (1.0 - fraction) * baseline_value:
        return False
    print(
        f"WARNING: {bench} {metric} regressed "
        f"{1.0 - value / baseline_value:.0%} vs committed baseline "
        f"({value:.0f} vs {baseline_value:.0f}); soft check only",
        file=sys.stderr,
    )
    return True


def warn_compiles(
    bench: str,
    family_compiles: dict[str, int],
    baseline_compiles: dict[str, int],
) -> bool:
    """Soft compile-count check: True (and one stderr WARNING per family)
    iff any family compiled MORE computations than the committed baseline.
    Counts are deterministic, but the convention stays soft — the hard gate
    is each bench's in-run one-compile assertion."""
    warned = False
    for fam, count in family_compiles.items():
        committed = baseline_compiles.get(fam)
        if committed is not None and count > committed:
            warned = True
            print(
                f"WARNING: {bench} family {fam!r} compiled {count} "
                f"computations vs {committed} in the committed baseline; "
                "soft check only",
                file=sys.stderr,
            )
    return warned
