"""Shared soft-perf-gate helpers for the BENCH_*.json-writing targets.

The repo's regression convention (established by ``sweep_smoke``, shared by
``bench_faults``): every perf-ish metric is checked against the COMMITTED
manifest — ``git show HEAD:BENCH_*.json``, so local refreshes can never
ratchet the reference down; the working-tree file is only the fallback when
git is unavailable — and a regression beyond tolerance prints a WARNING to
stderr and flags the manifest, but never fails the run.  Shared-CI wall
clocks are too noisy for hard gates; the hard gates are the in-run
correctness assertions.

Each check returns a STRUCTURED warning record (or ``None`` / an empty
list when the check passes) so benches can append it to their manifest's
``warnings`` list and ``benchmarks/run.py obs_report`` can surface every
soft regression across all committed manifests in one place.  A record is
a flat JSON-able dict: ``{"kind", "bench", "metric", "value", "baseline",
"message", ...}``; truthiness is preserved (record dict / non-empty list
iff the old booleans were True), so ``bool(...)`` recovers the legacy
manifest flags.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

# warn (never fail) when a throughput-style metric drops more than this
# fraction below the committed baseline
SLOWDOWN_WARN_FRACTION = 0.30


def committed_baseline_with_source(path: str) -> tuple[dict, str]:
    """The committed manifest at ``path`` plus WHERE it came from.

    Returns ``(doc, source)`` with ``source`` one of ``"git"`` (``git show
    HEAD:`` succeeded), ``"worktree"`` (no usable git checkout / the file
    is untracked at HEAD — the on-disk file stands in), or ``"missing"``
    (neither; ``doc`` is ``{}``).  Consumers that must degrade gracefully
    (``obs_report``) use the source to emit a structured ``baseline``
    warning record instead of silently diffing against the wrong
    reference."""
    git_root = _repo_root(os.path.dirname(os.path.abspath(path)))
    if git_root is not None:
        rel = os.path.relpath(os.path.abspath(path), git_root)
        try:
            blob = subprocess.run(
                ["git", "show", f"HEAD:{rel.replace(os.sep, '/')}"],
                capture_output=True, text=True, timeout=30, cwd=git_root,
            )
            if blob.returncode == 0:
                return json.loads(blob.stdout), "git"
        except (OSError, subprocess.SubprocessError, json.JSONDecodeError):
            pass
    try:
        with open(path) as f:
            return json.load(f), "worktree"
    except (OSError, json.JSONDecodeError):
        return {}, "missing"


def _repo_root(start: str) -> str | None:
    """The git worktree root containing ``start``, or None without git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--show-toplevel"],
            capture_output=True, text=True, timeout=30, cwd=start,
        )
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return None


def committed_baseline(path: str) -> dict:
    """The committed manifest at ``path`` (git HEAD), falling back to the
    on-disk file outside a usable git checkout."""
    return committed_baseline_with_source(path)[0]


def _emit(record: dict) -> dict:
    print(f"WARNING: {record['message']}", file=sys.stderr)
    return record


def warn_slowdown(
    bench: str,
    value: float,
    baseline_value: float | None,
    *,
    metric: str = "rows/sec",
    fraction: float = SLOWDOWN_WARN_FRACTION,
) -> dict | None:
    """Soft throughput check: a warning record (and a stderr WARNING) iff
    ``value`` fell more than ``fraction`` below the committed
    ``baseline_value``; ``None`` when the check passes."""
    if not baseline_value or value >= (1.0 - fraction) * baseline_value:
        return None
    return _emit({
        "kind": "slowdown",
        "bench": bench,
        "metric": metric,
        "value": float(value),
        "baseline": float(baseline_value),
        "drop_fraction": 1.0 - value / baseline_value,
        "message": (
            f"{bench} {metric} regressed "
            f"{1.0 - value / baseline_value:.0%} vs committed baseline "
            f"({value:.0f} vs {baseline_value:.0f}); soft check only"
        ),
    })


def warn_compiles(
    bench: str,
    family_compiles: dict[str, int],
    baseline_compiles: dict[str, int],
) -> list[dict]:
    """Soft compile-count check: one warning record (and one stderr WARNING)
    per family that compiled MORE computations than the committed baseline;
    an empty list when every family holds.  Counts are deterministic, but
    the convention stays soft — the hard gate is each bench's in-run
    one-compile assertion."""
    records = []
    for fam, count in family_compiles.items():
        committed = baseline_compiles.get(fam)
        if committed is not None and count > committed:
            records.append(_emit({
                "kind": "compiles",
                "bench": bench,
                "metric": f"family_compiles[{fam}]",
                "value": int(count),
                "baseline": int(committed),
                "message": (
                    f"{bench} family {fam!r} compiled {count} "
                    f"computations vs {committed} in the committed "
                    "baseline; soft check only"
                ),
            }))
    return records


def warn_speedup_bar(
    bench: str,
    speedup: float,
    bar: float,
    *,
    metric: str = "speedup",
) -> dict | None:
    """Soft absolute-bar check: a warning record (and a stderr WARNING) iff
    ``speedup`` is below the acceptance ``bar``; ``None`` otherwise.  Wall
    clock is never a hard gate (machine contention)."""
    if speedup >= bar:
        return None
    return _emit({
        "kind": "speedup_bar",
        "bench": bench,
        "metric": metric,
        "value": float(speedup),
        "baseline": float(bar),
        "message": (
            f"{bench} {metric} {speedup:.1f}x is below the {bar:.0f}x bar; "
            "soft check only (machine contention?)"
        ),
    })


def collect(*checks) -> list[dict]:
    """Flatten check results (records, ``None``s, lists of records) into the
    manifest ``warnings`` list."""
    out: list[dict] = []
    for c in checks:
        if not c:
            continue
        out.extend(c if isinstance(c, list) else [c])
    return out
