# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

Usage:
  python -m benchmarks.run                 # run every suite
  python -m benchmarks.run bench_policies  # run the named suite(s) only
  python -m benchmarks.run --list          # print registered targets + blurbs
  python -m benchmarks.run --check ...     # additionally trend-gate: exit 2
                                           # on any HARD trend regression in
                                           # the BENCH_history.jsonl
                                           # trajectory after the suites run
  python -m benchmarks.run --quiet ...     # suppress the stderr progress
                                           # line (CI logs)

Exit code 0 is the CI smoke gate: every requested suite must produce its
rows without raising (exit 1 otherwise); ``--check`` adds exit 2 when the
robust trend detector (``repro.obs.history.trend_report``) flags a hard
regression — the median of the newest history entries leaving the
committed median ± max(tol·|median|, z·MAD) envelope on the worse side
for a perf metric.  Eight targets additionally refresh a manifest at the
repo root (each blurb in ``SUITES`` names its file): ``fig3_sim`` ->
``BENCH_fig3.json`` (rounds/sec, allocator us/call), ``sweep_smoke`` ->
``BENCH_sweep.json`` (with a soft rows/sec regression check against the
committed baseline), ``bench_speed`` -> ``BENCH_speed.json`` (sync vs
async-pipelined executor rows/sec measured in one process, donated-carry
proof, tap overlap accounting and the persistent-compile-cache
cold-vs-warm process row), ``bench_policies`` -> ``BENCH_policies.json``
(per-policy throughput, baseline ratio, final regret + CI vs the oracle),
``bench_gf`` -> ``BENCH_gf.json`` (exact GF(p) device-vs-numpy speedups,
>= 5x acceptance on the exact coded round), ``bench_faults`` ->
``BENCH_faults.json`` (packet-erasure grid: partial-work-conserving decode
vs all-or-nothing under shared fault traces, retry/degrade outcome
accounting), ``bench_serving`` -> ``BENCH_serving.json`` (streaming
serving grid: latency percentiles, served-requests/sec and the
admission-control-vs-admit-all gain at overload) and ``obs_report`` ->
``BENCH_obs.json`` (cross-bench regression summary: metric deltas vs the
committed baselines, collected softgate warnings, provenance audit,
static hlo_cost rows, the trend section over ``BENCH_history.jsonl``,
plus a telemetry+tap serving run exported as the Chrome trace
``benchmarks/artifacts/obs_trace.json``).  Every manifest write appends
its history record (``repro.obs.history``; ``REPRO_BENCH_HISTORY``
redirects the file).

A stderr progress line (suites done, elapsed — ``repro.obs.metrics.
ProgressLine``) tracks the selection unless ``--quiet``; the process-
default metrics registry collects per-suite wall-clock
(``bench.<target>.seconds``) and the executors' compile/phase attribution
either way.

Profiling: set ``REPRO_PROFILE=<dir>`` to wrap the selected suites in a
``jax.profiler`` trace (``repro.obs.profile_trace``); engine phases are
annotated via ``jax.named_scope`` either way.
"""

import os
import sys
import traceback

# (target name, module under benchmarks/, one-line description) — kept as a
# static table so ``--list`` never has to import jax or the suites.
# Convention: a blurb names the BENCH_*.json it refreshes at the repo root
# IF AND ONLY IF the target writes one (audited by tests/test_benchmarks_cli).
SUITES = [
    ("fig3_sim", "fig3_sim",
     "paper Fig. 3 (4 sim scenarios, LEA vs static vs oracle; writes BENCH_fig3.json)"),
    ("fig4_ec2", "fig4_ec2",
     "paper Fig. 4 (6 EC2 scenarios, simulated credit dynamics)"),
    ("table_kstar", "table_kstar",
     "recovery-threshold table (eqs. 15/16)"),
    ("sweep_smoke", "sweep_smoke",
     "repro.sweeps gate: sharded+chunked grid, bit-exact vs engine; writes BENCH_sweep.json"),
    ("bench_speed", "bench_speed",
     "raw-speed gate: sync vs async-pipelined executor, donated carries, "
     "persistent-cache warm restart; writes BENCH_speed.json"),
    ("bench_policies", "bench_policies",
     "scheduling-policy shoot-out with regret columns; writes BENCH_policies.json"),
    ("bench_gf", "bench_gf",
     "exact GF(p) device path vs numpy modp oracle; writes BENCH_gf.json"),
    ("bench_faults", "bench_faults",
     "fault-injection gate: packet erasure grid, conserve vs all-or-nothing, "
     "retry/degrade accounting; writes BENCH_faults.json"),
    ("bench_serving", "bench_serving",
     "streaming serving gate: arrival grid, latency percentiles, admission "
     "control vs admit-all at overload; writes BENCH_serving.json"),
    ("bench_kernels", "bench_kernels",
     "Pallas-kernel + XLA-path microbenchmarks"),
    ("bench_allocator", "bench_allocator",
     "old (sequential seed) vs new (batched) engine + allocator"),
    ("coded_dp", "coded_dp_bench",
     "beyond-paper: LEA-coded microbatch DP in the trainer"),
    ("roofline", "roofline",
     "33-cell dry-run roofline terms (from experiments/dryrun)"),
    ("obs_report", "obs_report",
     "cross-bench regression summary: metric deltas vs committed baselines, "
     "softgate warnings, provenance audit, hlo_cost rows + Chrome trace; "
     "writes BENCH_obs.json"),
]


def list_targets() -> str:
    width = max(len(name) for name, _, _ in SUITES)
    return "\n".join(f"{name:<{width}}  {desc}" for name, _, desc in SUITES)


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--list" in argv:
        print(list_targets())
        return
    check = "--check" in argv
    quiet = "--quiet" in argv
    argv = [a for a in argv if a not in ("--check", "--quiet")]

    known = {name for name, _, _ in SUITES}
    unknown = [a for a in argv if a not in known]
    if unknown:
        raise SystemExit(
            f"unknown benchmark target(s): {', '.join(unknown)}\n"
            f"registered targets:\n{list_targets()}"
        )
    selected = [row for row in SUITES if not argv or row[0] in argv]

    import importlib

    # REPRO_COMPILE_CACHE=<dir>: persistent XLA compile cache — one-compile-
    # per-family survives process restarts (repro.launch.cache; the hit
    # listener keeps the unified compile counters honest on warm restarts)
    from repro.launch.cache import enable_compile_cache

    enable_compile_cache()

    # REPRO_PROFILE=<dir> wraps the whole selection in a jax.profiler trace;
    # each suite gets a host-side TraceAnnotation span (repro.obs.profiling)
    from repro.obs import annotate, profile_trace
    from repro.obs.metrics import DEFAULT as _metrics
    from repro.obs.metrics import ProgressLine, timed

    progress = ProgressLine(total=len(selected), enabled=not quiet,
                            label="benchmarks")
    print("name,us_per_call,derived")
    failed = False
    with profile_trace("benchmarks.run"):
        for i, (name, module, _) in enumerate(selected):
            try:
                fn = importlib.import_module(f"benchmarks.{module}").run
                with annotate(f"suite:{name}"), timed(f"bench.{name}"):
                    rows = fn()
                for row in rows:
                    print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
            except Exception as e:  # pragma: no cover
                failed = True
                print(f"{name},0,\"SUITE ERROR: {e}\"", file=sys.stdout)
                traceback.print_exc(file=sys.stderr)
            progress.update(i + 1)
    progress.close()
    if failed:
        raise SystemExit(1)
    if check:
        regressions = _trend_check()
        if regressions:
            for r in regressions:
                print(f"TREND REGRESSION: {r['message']}", file=sys.stderr)
            raise SystemExit(2)


def _trend_check() -> list[dict]:
    """Hard trend-regression records over the benchmark history trajectory.

    The history file is ``BENCH_history.jsonl`` next to the repo-root
    manifests (``REPRO_BENCH_HISTORY`` overrides — the hook the tests use
    to doctor a synthetic slowdown)."""
    from repro.obs import history as _history

    anchor = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_obs.json")
    records = _history.read_history(_history.history_path(anchor))
    return _history.hard_regressions(_history.trend_report(records))


if __name__ == "__main__":
    main()
