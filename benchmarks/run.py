# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

Exit code 0 is the CI smoke gate: every suite must produce its rows without
raising.  ``fig3_sim`` additionally refreshes the ``BENCH_fig3.json`` perf
baseline (rounds/sec, allocator us/call) at the repo root.

Tables:
  fig3_sim         paper Fig. 3 (4 sim scenarios, LEA vs static vs oracle)
  fig4_ec2         paper Fig. 4 (6 EC2 scenarios, simulated credit dynamics)
  table_kstar      recovery-threshold table (eqs. 15/16)
  sweep_smoke      repro.sweeps gate: tiny hetero-K* registry grid, sharded
                   over 8 forced host devices + round-chunked, checked
                   bit-exact vs the plain engine; refreshes BENCH_sweep.json
  bench_kernels    Pallas-kernel + XLA-path microbenchmarks
  bench_allocator  old (sequential seed) vs new (batched) engine + allocator
  coded_dp         beyond-paper: LEA-coded microbatch DP in the trainer
  roofline         33-cell dry-run roofline terms (from experiments/dryrun)
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_allocator, bench_kernels, coded_dp_bench,
                            fig3_sim, fig4_ec2, roofline, sweep_smoke,
                            table_kstar)

    suites = [
        ("fig3_sim", fig3_sim.run),
        ("fig4_ec2", fig4_ec2.run),
        ("table_kstar", table_kstar.run),
        ("sweep_smoke", sweep_smoke.run),
        ("bench_kernels", bench_kernels.run),
        ("bench_allocator", bench_allocator.run),
        ("coded_dp", coded_dp_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites:
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
        except Exception as e:  # pragma: no cover
            failed = True
            print(f"{name},0,\"SUITE ERROR: {e}\"", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
