"""Scenario registry — named, composable Monte-Carlo scenario families.

A *scenario* is one fully-specified simulation cell: a two-state Markov
worker model (per-worker ``p_gg``/``p_bb``), speeds, a deadline, a static
:class:`~repro.core.lea.LoadParams`, the strategies to run and the baseline
strategy that ratios are reported against.  A *family* is a registered
function expanding keyword parameters into a tuple of scenarios — the
paper's Fig. 3 / Fig. 4 grids are families, and so are the beyond-paper
grids in :mod:`repro.sweeps.scenarios` (deadline sweeps, bursty chains,
heterogeneous-K*, elastic worker-pool ramps, straggler-slack grids).

:func:`build_groups` flattens (scenarios x seeds) into :class:`SweepGroup`s:
one flat :class:`ScenarioBatch` pytree per static ``(rounds, strategies)``
signature.  Load parameters are NOT part of the signature: ``kstar``/
``ell_g``/``ell_b`` ride the batch as traced (B,) leaves and pools of
different sizes are padded to the group's widest scenario with a (B, n_max)
``worker_mask`` (padded workers carry a frozen always-good chain, receive
no load and never count toward K*) — so the executor compiles ONE
computation per group no matter how many K*s, load levels or pool sizes the
scenarios span (fig4's three K* groups, the whole ``hetero_kstar`` grid,
every ``deadline_sweep`` load level and the ``elastic_pool`` ramp each fuse
into a single compile).

Padding convention: rows whose scenario is NARROWER than the group's n_max
are simulated at width n_max with the extra workers masked.  The mask makes
the padding inert (full-width rows are bit-identical to the static-
``LoadParams`` engine), but the PRNG stream geometry is the padded width's
— pool width has always been part of the stream (a width-10 scenario alone
and the same scenario padded to width 30 draw different, equally valid
Monte-Carlo streams).  Corollary: a PADDED row's exact bits depend on the
group's n_max and hence on which other scenarios share its (rounds,
strategies) signature — adding a wider scenario to a sweep stream-shifts
the narrower rows' Monte-Carlo draws (never their distribution).
Full-width rows are composition-independent.  Group composition itself is
deterministic (signature + first-seen order), so any fixed scenario list
reproduces bit-for-bit run to run.

PRNG discipline: a scenario with an explicit ``seed`` uses ``PRNGKey(seed)``
for its first Monte-Carlo repeat — exactly the key the paper benchmarks
always used — and ``fold_in(PRNGKey(seed), s)`` for extra repeats, so
``seeds=1`` replications are bit-identical to the pre-registry paths while
``seeds>1`` adds independent streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lea import LoadParams
from repro.core.throughput import strategy_known

# a schedule segment: (start_round, p_gg row, p_bb row) — the chain in force
# from start_round until the next segment's start (piecewise-constant)
ScheduleSegment = tuple[int, tuple[float, ...], tuple[float, ...]]

# a dense chain spec: per-round rows, shape (rounds, n) as nested tuples
DenseRows = tuple[tuple[float, ...], ...]


def as_dense_schedule(p_gg, p_bb) -> tuple[DenseRows, DenseRows]:
    """Precomputed (rounds, n) chain arrays -> a hashable ``dense_schedule``.

    The dense counterpart of the piecewise-constant ``schedule`` segments:
    row t is the chain governing the transition into round t (row 0 doubles
    as the initial distribution, exactly the engine's time-varying-chain
    convention).  Use for computed drift curves that change every round.
    """
    p_gg = np.asarray(p_gg, np.float32)
    p_bb = np.asarray(p_bb, np.float32)
    if p_gg.ndim != 2 or p_gg.shape != p_bb.shape:
        raise ValueError(f"dense schedule needs matching (rounds, n) arrays, "
                         f"got {p_gg.shape} vs {p_bb.shape}")
    to_rows = lambda a: tuple(tuple(float(v) for v in row) for row in a)
    return (to_rows(p_gg), to_rows(p_bb))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named simulation cell (hashable: probabilities are tuples).

    ``strategies`` may name any registered policy
    (:mod:`repro.policies`) alongside the engine-native static draws.
    A non-empty ``schedule`` makes the chain non-stationary: piecewise-
    constant segments materialised into (rounds, n) transition arrays at
    batch-build time (``p_gg``/``p_bb`` then hold the round-0 rows, kept
    for display and validation).  ``dense_schedule`` is the second
    materialisation path: a precomputed per-round (rounds, n) chain spec
    (:func:`as_dense_schedule`) for drift curves that move every round —
    mutually exclusive with ``schedule``.
    """

    name: str
    family: str
    lp: LoadParams
    p_gg: tuple[float, ...]          # per-worker, length lp.n (round-0 chain)
    p_bb: tuple[float, ...]
    mu_g: float
    mu_b: float
    deadline: float
    rounds: int
    strategies: tuple[str, ...] = ("lea", "static", "oracle")
    baseline: str = "static"
    seed: int | None = None          # explicit PRNGKey seed (paper replication)
    meta: tuple[tuple[str, Any], ...] = ()
    schedule: tuple[ScheduleSegment, ...] = ()
    dense_schedule: tuple[DenseRows, DenseRows] | None = None

    def __post_init__(self):
        if len(self.p_gg) != self.lp.n or len(self.p_bb) != self.lp.n:
            raise ValueError(f"{self.name}: p_gg/p_bb must have length n={self.lp.n}")
        for s in self.strategies:
            if not strategy_known(s):
                raise ValueError(f"{self.name}: unknown strategy {s!r}")
        if self.baseline not in self.strategies:
            raise ValueError(f"{self.name}: baseline {self.baseline!r} not in strategies")
        if self.schedule:
            starts = [seg[0] for seg in self.schedule]
            if starts[0] != 0:
                raise ValueError(f"{self.name}: schedule must start at round 0")
            if any(b <= a for a, b in zip(starts, starts[1:])):
                raise ValueError(f"{self.name}: schedule starts must increase")
            if starts[-1] >= self.rounds:
                raise ValueError(f"{self.name}: schedule start beyond rounds")
            for start, g, b in self.schedule:
                if len(g) != self.lp.n or len(b) != self.lp.n:
                    raise ValueError(
                        f"{self.name}: schedule rows at {start} must have length n"
                    )
            if (tuple(self.schedule[0][1]) != tuple(self.p_gg)
                    or tuple(self.schedule[0][2]) != tuple(self.p_bb)):
                raise ValueError(
                    f"{self.name}: p_gg/p_bb must equal the schedule's round-0 rows"
                )
        if self.dense_schedule is not None:
            if self.schedule:
                raise ValueError(
                    f"{self.name}: schedule and dense_schedule are mutually exclusive"
                )
            gg, bb = self.dense_schedule
            if len(gg) != self.rounds or len(bb) != self.rounds:
                raise ValueError(
                    f"{self.name}: dense_schedule must have one row per round "
                    f"(got {len(gg)}/{len(bb)} for rounds={self.rounds})"
                )
            for rows in (gg, bb):
                if any(len(row) != self.lp.n for row in rows):
                    raise ValueError(
                        f"{self.name}: dense_schedule rows must have length n={self.lp.n}"
                    )
            if (tuple(gg[0]) != tuple(self.p_gg)
                    or tuple(bb[0]) != tuple(self.p_bb)):
                raise ValueError(
                    f"{self.name}: p_gg/p_bb must equal the dense schedule's round-0 rows"
                )

    @property
    def scheduled(self) -> bool:
        """Does this scenario batch as (rounds, n) chain arrays?"""
        return bool(self.schedule) or self.dense_schedule is not None

    @property
    def group_signature(self) -> tuple:
        """The static-arg signature the executor compiles per.

        Load parameters are traced batch leaves, so they do NOT appear here
        — only ``(rounds, strategies)`` plus the chain-array rank flag.
        Scheduled scenarios (piecewise OR dense) batch as (rounds, n) chain
        arrays — a different input shape — so they group separately from
        stationary ones.
        """
        return (self.rounds, self.strategies, self.scheduled)

    def chain_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Materialise the chain: (n,) float32 rows, or (rounds, n) when
        scheduled (row t = the chain governing the transition into round t)."""
        if self.dense_schedule is not None:
            return (np.asarray(self.dense_schedule[0], np.float32),
                    np.asarray(self.dense_schedule[1], np.float32))
        if not self.schedule:
            return (np.asarray(self.p_gg, np.float32),
                    np.asarray(self.p_bb, np.float32))
        p_gg = np.empty((self.rounds, self.lp.n), np.float32)
        p_bb = np.empty((self.rounds, self.lp.n), np.float32)
        bounds = [seg[0] for seg in self.schedule] + [self.rounds]
        for (start, g, b), end in zip(self.schedule, bounds[1:]):
            p_gg[start:end] = np.asarray(g, np.float32)
            p_bb[start:end] = np.asarray(b, np.float32)
        return p_gg, p_bb

    def meta_dict(self) -> dict[str, Any]:
        return dict(self.meta)


class ScenarioBatch(NamedTuple):
    """Flat (B, ...) pytree of simulation inputs — one row per (scenario, seed).

    Chain arrays and the worker mask are padded to the group's widest
    scenario (``n_max``); ``kstar``/``ell_g``/``ell_b`` are the TRACED
    per-row load parameters the shape-polymorphic engine consumes.
    """

    keys: jnp.ndarray         # (B, 2) uint32 PRNG keys
    p_gg: jnp.ndarray         # (B, n_max) float32 — or (B, rounds, n_max)
    p_bb: jnp.ndarray         # (B, n_max) float32 — or (B, rounds, n_max)
    mu_g: jnp.ndarray         # (B,)   float32
    mu_b: jnp.ndarray         # (B,)   float32
    deadline: jnp.ndarray     # (B,)   float32
    kstar: jnp.ndarray        # (B,)   int32
    ell_g: jnp.ndarray        # (B,)   int32
    ell_b: jnp.ndarray        # (B,)   int32
    worker_mask: jnp.ndarray  # (B, n_max) bool — True = real worker

    @property
    def rows(self) -> int:
        return self.p_gg.shape[0]

    @property
    def n_max(self) -> int:
        """The group's padded pool width."""
        return self.worker_mask.shape[-1]

    @property
    def pool(self):
        """The batch's load parameters as a batched ``lea.PoolLoad``."""
        from repro.core.lea import PoolLoad

        return PoolLoad(kstar=self.kstar, ell_g=self.ell_g, ell_b=self.ell_b,
                        mask=self.worker_mask)


class RowMeta(NamedTuple):
    """Provenance of one batch row: which scenario, which Monte-Carlo repeat."""

    scenario_index: int     # into SweepGroup.scenarios
    seed_index: int


@dataclasses.dataclass(frozen=True)
class SweepGroup:
    """All rows sharing one static (rounds, strategies) signature.

    Load parameters live in ``batch`` as traced leaves (``batch.pool``);
    the per-scenario static :class:`~repro.core.lea.LoadParams` remain on
    the :class:`Scenario` objects for display/manifests.
    """

    rounds: int
    strategies: tuple[str, ...]
    batch: ScenarioBatch
    scenarios: tuple[Scenario, ...]
    rows: tuple[RowMeta, ...]        # aligned with batch rows

    @property
    def n_max(self) -> int:
        return self.batch.n_max


# ---------------------------------------------------------------------------
# family registration
# ---------------------------------------------------------------------------

_FAMILIES: dict[str, Callable[..., tuple[Scenario, ...]]] = {}


def register(name: str):
    """Decorator: register ``fn(**params) -> tuple[Scenario, ...]`` as a family."""

    def deco(fn):
        if name in _FAMILIES:
            raise ValueError(f"scenario family {name!r} already registered")
        _FAMILIES[name] = fn
        return fn

    return deco


def _ensure_builtins() -> None:
    # built-in families live in scenarios.py; importing it registers them
    from . import scenarios  # noqa: F401


def family_names() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_FAMILIES))


def describe(name: str) -> str:
    _ensure_builtins()
    doc = _FAMILIES[name].__doc__ or ""
    return doc.strip().splitlines()[0] if doc.strip() else ""


def catalogue() -> str:
    """Human-readable one-line-per-family catalogue (ROADMAP / --help text)."""
    _ensure_builtins()
    width = max((len(n) for n in _FAMILIES), default=0)
    return "\n".join(f"{n:<{width}}  {describe(n)}" for n in sorted(_FAMILIES))


def expand(family: str, **params) -> tuple[Scenario, ...]:
    """Expand a named family into its scenarios."""
    _ensure_builtins()
    if family not in _FAMILIES:
        raise KeyError(
            f"unknown scenario family {family!r}; available: {', '.join(sorted(_FAMILIES))}"
        )
    scenarios = tuple(_FAMILIES[family](**params))
    names = [sc.name for sc in scenarios]
    if len(set(names)) != len(names):
        raise ValueError(f"family {family!r} produced duplicate scenario names")
    return scenarios


# ---------------------------------------------------------------------------
# batch building
# ---------------------------------------------------------------------------

def scenario_base_key(
    sc: Scenario, fallback_seed_base: int, position: int
) -> jax.Array:
    """The scenario's PRNG stream root.

    Explicit seeds map to ``PRNGKey(seed)`` (paper replication).  Seedless
    scenarios get ``fold_in(PRNGKey(fallback_seed_base), position)`` — a
    stream disjoint from every raw ``PRNGKey(i)``, so mixing seedless
    families with explicit-seed families (fig3's PRNGKey(1..4)) can never
    silently share draws.
    """
    if sc.seed is not None:
        return jax.random.PRNGKey(sc.seed)
    return jax.random.fold_in(jax.random.PRNGKey(fallback_seed_base), position)


def row_key(base: jax.Array, seed_index: int) -> jax.Array:
    """Repeat 0 keeps the scenario's own key (paper bit-identity); later
    repeats fold the repeat index in for independent streams."""
    return base if seed_index == 0 else jax.random.fold_in(base, seed_index)


# chain values padding a narrower scenario's extra workers: a frozen
# always-good chain (stationary prob exactly 1, stay-good prob exactly 1) —
# deterministic, inert extras the engine additionally pins via the mask
_FROZEN_P_GG = 1.0
_FROZEN_P_BB = 0.0


def _pad_chain(arr: np.ndarray, n_max: int, value: float) -> np.ndarray:
    """Pad the worker (last) axis of an (n,) / (rounds, n) chain array."""
    pad = n_max - arr.shape[-1]
    if pad == 0:
        return arr
    widths = [(0, 0)] * (arr.ndim - 1) + [(0, pad)]
    return np.pad(arr, widths, constant_values=np.float32(value))


def build_groups(
    scenarios: Sequence[Scenario] | Iterable[Scenario],
    *,
    seeds: int = 1,
    fallback_seed_base: int = 0,
) -> tuple[SweepGroup, ...]:
    """Flatten (scenarios x seeds) into one SweepGroup per static signature.

    Groups preserve first-seen scenario order; within a group rows are laid
    out scenario-major ((sc0, seed0), (sc0, seed1), ..., (sc1, seed0), ...).
    Scenarios narrower than the group's widest pool are mask-padded (see the
    module docstring for the convention).
    """
    if seeds < 1:
        raise ValueError("seeds must be >= 1")
    scenarios = tuple(scenarios)
    by_sig: dict[tuple, list[tuple[int, Scenario]]] = {}
    for pos, sc in enumerate(scenarios):
        by_sig.setdefault(sc.group_signature, []).append((pos, sc))

    groups = []
    for (rounds, strategies, _scheduled), entries in by_sig.items():
        scs = [sc for _, sc in entries]
        n_max = max(sc.lp.n for sc in scs)
        keys, p_gg, p_bb, mu_g, mu_b, deadline, rows = [], [], [], [], [], [], []
        kstar, ell_g, ell_b, wmask = [], [], [], []
        for si, (pos, sc) in enumerate(entries):
            base = scenario_base_key(sc, fallback_seed_base, pos)
            chain_gg, chain_bb = sc.chain_arrays()
            chain_gg = _pad_chain(chain_gg, n_max, _FROZEN_P_GG)
            chain_bb = _pad_chain(chain_bb, n_max, _FROZEN_P_BB)
            mask_row = np.arange(n_max) < sc.lp.n
            for s in range(seeds):
                keys.append(row_key(base, s))
                p_gg.append(chain_gg)
                p_bb.append(chain_bb)
                mu_g.append(sc.mu_g)
                mu_b.append(sc.mu_b)
                deadline.append(sc.deadline)
                kstar.append(sc.lp.kstar)
                ell_g.append(sc.lp.ell_g)
                ell_b.append(sc.lp.ell_b)
                wmask.append(mask_row)
                rows.append(RowMeta(scenario_index=si, seed_index=s))
        batch = ScenarioBatch(
            keys=jnp.stack(keys),
            p_gg=jnp.asarray(np.stack(p_gg)),
            p_bb=jnp.asarray(np.stack(p_bb)),
            mu_g=jnp.asarray(mu_g, jnp.float32),
            mu_b=jnp.asarray(mu_b, jnp.float32),
            deadline=jnp.asarray(deadline, jnp.float32),
            kstar=jnp.asarray(kstar, jnp.int32),
            ell_g=jnp.asarray(ell_g, jnp.int32),
            ell_b=jnp.asarray(ell_b, jnp.int32),
            worker_mask=jnp.asarray(np.stack(wmask)),
        )
        groups.append(
            SweepGroup(rounds=rounds, strategies=strategies, batch=batch,
                       scenarios=tuple(scs), rows=tuple(rows))
        )
    return tuple(groups)
