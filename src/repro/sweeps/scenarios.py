"""Built-in scenario families: the paper's grids + beyond-paper sweeps.

Paper replications (bit-identical to the pre-registry benchmark paths on the
same PRNG keys):

  * ``fig3``           — Sec. 6.1 numerical grid (4 chains, K*=99)
  * ``fig4``           — Sec. 6.2 EC2 replay (6 scenarios, K* in {120,100,50})
  * ``kstar_table``    — the recovery-threshold worked examples (not simulated)

Beyond-paper families (the scenario diversity the ROADMAP asks for; the
straggler-slack and elastic-pool grids follow the regimes studied by *Slack
Squeeze Coded Computing* (arXiv:1904.07098) and *Hierarchical Coded Elastic
Computing* (arXiv:2206.09399)):

  * ``deadline_sweep``  — deadline d grid; loads ell(d) move with d, so K*
                          feasibility and LEA's edge shift along the grid
                          (traced ell -> the whole grid is ONE compile)
  * ``bursty_chains``   — fixed stationary availability, swept mixing
                          eigenvalue lam = p_gg + p_bb - 1 (iid -> long bursts)
  * ``hetero_kstar``    — data-size grid k -> heterogeneous K* (traced K* ->
                          the whole grid is ONE compile, the
                          shape-polymorphic engine's showcase)
  * ``elastic_pool``    — worker-pool ramp n (elastic scale-up/down at fixed
                          work), preempted-pool regimes; pools mask-padded
                          to the widest ramp point, again ONE compile
  * ``straggler_slack`` — speed-ratio x deadline grid: how much straggler
                          slack LEA can squeeze vs static

Non-stationary families (the ``repro.policies`` proving grounds — chains
whose parameters move, where windowed/discounted estimators beat vanilla
LEA's all-history counts; cf. the changing-worker regimes of Slack Squeeze
Coded Computing):

  * ``drifting_chains`` — per-worker availability drifts sinusoidally with
                          phase offsets, so the identity of the reliable
                          workers rotates continuously
  * ``regime_switch``   — abrupt regime changes every ``dwell`` rounds: a
                          rotating third of the pool degrades (preemption /
                          credit-exhaustion waves)
  * ``computed_drift``  — SMOOTH per-round drift through the dense
                          (rounds, n) ``dense_schedule`` spec (no step-block
                          quantisation; the second materialisation path)
"""

from __future__ import annotations

import math

from repro.configs.paper_lea import EC2, SIM
from repro.core import markov
from repro.core.lagrange import CodeSpec
from repro.core.lea import LoadParams

from .registry import Scenario, as_dense_schedule, register

# default strategy tuple for the non-stationary families: vanilla LEA vs its
# adaptive variants, the static floor and the genie ceiling (regret columns)
POLICY_STRATEGIES = ("lea", "lea_window64", "lea_discount97", "static", "oracle")


def _const(n: int, v: float) -> tuple[float, ...]:
    return (float(v),) * n


def _chain_rows(pis, lam: float) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """Per-worker (p_gg, p_bb) rows with stationary dists ``pis`` and shared
    mixing eigenvalue ``lam`` (the bursty_chains parametrization)."""
    p_gg = tuple(float(pi + (1.0 - pi) * lam) for pi in pis)
    p_bb = tuple(float((1.0 - pi) + pi * lam) for pi in pis)
    return p_gg, p_bb


def _sim_lp(k: int = SIM.k, deg_f: int = SIM.deg_f) -> LoadParams:
    """The paper Sec. 6.1 LoadParams: K* from ``CodeSpec(n, r, k, deg_f)``,
    two-level loads from the mu * d budget — shared by every family that
    runs on the SIM worker pool."""
    spec = CodeSpec(SIM.n, SIM.r, k, deg_f)
    return LoadParams(
        n=SIM.n, kstar=spec.recovery_threshold,
        ell_g=int(min(SIM.mu_g * SIM.deadline, SIM.r)),
        ell_b=int(SIM.mu_b * SIM.deadline),
    )


# ---------------------------------------------------------------------------
# paper replications
# ---------------------------------------------------------------------------

@register("fig3")
def fig3(rounds: int | None = None) -> tuple[Scenario, ...]:
    """Paper Fig. 3: 4 Markov chains, n=15, K*=99, LEA vs static vs oracle."""
    lp = _sim_lp()
    rounds = rounds or SIM.rounds
    return tuple(
        Scenario(
            name=f"fig3_scenario{i}", family="fig3", lp=lp,
            p_gg=_const(SIM.n, p_gg), p_bb=_const(SIM.n, p_bb),
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline, rounds=rounds,
            strategies=("lea", "static", "oracle"), baseline="static",
            seed=i, meta=(("scenario", i),),
        )
        for i, (p_gg, p_bb) in enumerate(SIM.scenarios, 1)
    )


# credit-based chain estimated from Fig. 1-style traces (see fig4_ec2.py)
FIG4_P_GG, FIG4_P_BB = 0.85, 0.6


@register("fig4")
def fig4(rounds: int = 400) -> tuple[Scenario, ...]:
    """Paper Fig. 4 EC2 replay: 6 scenarios, heterogeneous K* in {120,100,50}
    (one fused compile — K* is a traced batch quantity).

    The arrival gap is folded into the chain via the exact t-step transition
    probabilities (``markov.t_step_transitions``) so one engine round is one
    request; speeds are normalized so a good worker clears its full store
    within the deadline and a bad one r/10 of it.
    """
    scenarios = []
    for i, (xrows, k, lam, d) in enumerate(EC2.scenarios, 1):
        spec = CodeSpec(EC2.n, EC2.r, k, EC2.deg_f)
        ell_g = EC2.r
        ell_b = max(1, EC2.r // 10)
        lp = LoadParams(n=EC2.n, kstar=spec.recovery_threshold,
                        ell_g=ell_g, ell_b=ell_b)
        gap = max(1, int(round((30.0 + lam) / (10 * d))))
        p_gg_t, p_bb_t = markov.t_step_transitions(FIG4_P_GG, FIG4_P_BB, gap)
        scenarios.append(Scenario(
            name=f"fig4_scenario{i}", family="fig4", lp=lp,
            p_gg=_const(EC2.n, float(p_gg_t)), p_bb=_const(EC2.n, float(p_bb_t)),
            mu_g=float(ell_g), mu_b=float(ell_b), deadline=1.0, rounds=rounds,
            strategies=("lea", "static_single"), baseline="static_single",
            seed=i,
            meta=(("rows", xrows), ("k", k), ("lam", lam), ("d", d),
                  ("gap", gap)),
        ))
    return tuple(scenarios)


@register("kstar_table")
def kstar_table(rounds: int = 0) -> tuple[Scenario, ...]:
    """Recovery-threshold worked examples (eqs. 15/16) — catalogue by default.

    With the default ``rounds=0`` these scenarios are never simulated; the
    table benchmark reads the expected K* / coding mode off ``meta`` and
    checks ``CodeSpec`` (``sweeps.run`` raises its catalogue-only error).
    Passing ``rounds > 0`` makes the family genuinely expandable into
    simulatable scenarios — each worked example runs on a placeholder
    fifty-fifty chain, useful for smoke-testing the K* grid end to end.
    """
    cases = [
        # (n, r, k, deg_f, expected K*, expected mode, where in the paper);
        # K* and mode are the PAPER's values, hard-coded — never re-derived
        # from CodeSpec here, so the table benchmark is a real check
        (15, 10, 50, 2, 99, "lagrange", "Sec6.1 sim"),
        (15, 10, 50, 1, 50, "lagrange", "Sec6.2 EC2 k=50"),
        (15, 10, 100, 1, 100, "lagrange", "Sec6.2 EC2 k=100"),
        (15, 10, 120, 1, 120, "lagrange", "Sec6.2 EC2 k=120"),
        (3, 2, 2, 2, 3, "lagrange", "Sec3.1 example 1"),
        (3, 2, 4, 2, 6, "repetition", "Sec3.1 example 2 (repetition)"),
    ]
    scenarios = []
    for n, r, k, deg, want, want_mode, where in cases:
        spec = CodeSpec(n, r, k, deg)
        lp = LoadParams(n=n, kstar=spec.recovery_threshold, ell_g=2, ell_b=1)
        scenarios.append(Scenario(
            name=f"kstar_{where.replace(' ', '_')}", family="kstar_table",
            lp=lp, p_gg=_const(n, 0.5), p_bb=_const(n, 0.5),
            mu_g=2.0, mu_b=1.0, deadline=1.0, rounds=rounds,
            strategies=("lea",), baseline="lea",
            meta=(("n", n), ("r", r), ("k", k), ("deg_f", deg),
                  ("expect_kstar", want), ("mode", want_mode), ("where", where)),
        ))
    return tuple(scenarios)


# ---------------------------------------------------------------------------
# beyond-paper families
# ---------------------------------------------------------------------------

@register("deadline_sweep")
def deadline_sweep(
    deadlines: tuple[float, ...] = (0.5, 0.7, 1.0, 1.5, 2.0),
    p_gg: float = 0.8,
    p_bb: float = 0.7,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Deadline grid on the Fig. 3 chain: loads ell(d) shift with d, so each
    deadline is its own LoadParams group (K* feasibility changes)."""
    spec = CodeSpec(SIM.n, SIM.r, SIM.k, SIM.deg_f)
    scenarios = []
    for d in deadlines:
        ell_g = int(min(SIM.mu_g * d, SIM.r))
        ell_b = max(1, int(SIM.mu_b * d))
        if ell_g <= ell_b:  # deadline too tight for a two-level allocation
            continue
        lp = LoadParams(n=SIM.n, kstar=spec.recovery_threshold,
                        ell_g=ell_g, ell_b=ell_b)
        scenarios.append(Scenario(
            name=f"deadline_d{d:g}", family="deadline_sweep", lp=lp,
            p_gg=_const(SIM.n, p_gg), p_bb=_const(SIM.n, p_bb),
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=float(d), rounds=rounds,
            meta=(("deadline", d),),
        ))
    return tuple(scenarios)


@register("bursty_chains")
def bursty_chains(
    lams: tuple[float, ...] = (0.0, 0.3, 0.6, 0.8, 0.95),
    pi_g: float = 0.6,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Correlation sweep at fixed availability: pi_g held constant while the
    chain's mixing eigenvalue lam = p_gg + p_bb - 1 ramps from iid (lam=0) to
    long bursts (lam -> 1) — the regime where LEA's one-step prediction gains
    the most over the stationary static draw."""
    lp = _sim_lp()
    scenarios = []
    for lam in lams:
        # _chain_rows keeps the stationary distribution at pi_g for every
        # lam in [0, 1) while the mixing eigenvalue ramps.
        p_gg, p_bb = _chain_rows((pi_g,) * SIM.n, lam)
        scenarios.append(Scenario(
            name=f"bursty_lam{lam:g}", family="bursty_chains", lp=lp,
            p_gg=p_gg, p_bb=p_bb,
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline, rounds=rounds,
            meta=(("lam", lam), ("pi_g", pi_g)),
        ))
    return tuple(scenarios)


@register("hetero_kstar")
def hetero_kstar(
    ks: tuple[int, ...] = (50, 80, 100, 120),
    deg_f: int = 1,
    lams: tuple[float, ...] = (0.2, 0.6),
    pi_g: float = 0.6,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Data-size grid k -> heterogeneous K*: a (k x burstiness) product grid.
    K* is a traced batch quantity, so the whole grid is ONE compiled
    computation regardless of how many K*s it spans."""
    scenarios = []
    for k in ks:
        lp = _sim_lp(k=k, deg_f=deg_f)
        for lam in lams:
            p_gg, p_bb = _chain_rows((pi_g,) * SIM.n, lam)
            scenarios.append(Scenario(
                name=f"kstar{lp.kstar}_lam{lam:g}",
                family="hetero_kstar", lp=lp,
                p_gg=p_gg, p_bb=p_bb,
                mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
                rounds=rounds,
                meta=(("k", k), ("kstar", lp.kstar), ("lam", lam)),
            ))
    return tuple(scenarios)


@register("elastic_pool")
def elastic_pool(
    ns: tuple[int, ...] = (10, 15, 20, 30),
    k: int = 50,
    deg_f: int = 2,
    p_gg: float = 0.8,
    p_bb: float = 0.7,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Elastic worker-pool ramp: the pool grows/shrinks at fixed work (k, r),
    as when preemptible machines join and leave (cf. Hierarchical Coded
    Elastic Computing, arXiv:2206.09399).  The ramp is mask-padded to its
    widest point and fused into ONE compile; K* stays put while the
    allocator's headroom n*ell_g - K* ramps."""
    scenarios = []
    for n in ns:
        spec = CodeSpec(n, SIM.r, k, deg_f)
        ell_g = int(min(SIM.mu_g * SIM.deadline, SIM.r))
        ell_b = int(SIM.mu_b * SIM.deadline)
        if n * ell_g < spec.recovery_threshold:
            continue   # pool too small to ever meet K* by the deadline
        lp = LoadParams(n=n, kstar=spec.recovery_threshold,
                        ell_g=ell_g, ell_b=ell_b)
        scenarios.append(Scenario(
            name=f"elastic_n{n}", family="elastic_pool", lp=lp,
            p_gg=_const(n, p_gg), p_bb=_const(n, p_bb),
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline, rounds=rounds,
            meta=(("n", n), ("kstar", spec.recovery_threshold)),
        ))
    return tuple(scenarios)


# ---------------------------------------------------------------------------
# non-stationary families (repro.policies proving grounds)
# ---------------------------------------------------------------------------

@register("drifting_chains")
def drifting_chains(
    periods: tuple[int, ...] = (400, 1000),
    rounds: int = 2_000,
    step: int = 50,
    lam: float = 0.5,
    base_pi: float = 0.55,
    amp: float = 0.35,
    strategies: tuple[str, ...] = POLICY_STRATEGIES,
    baseline: str = "lea",
) -> tuple[Scenario, ...]:
    """Sinusoidal availability drift with per-worker phase offsets.

    Worker i's stationary availability follows
    ``pi_i(t) = base_pi + amp * sin(2*pi*(t/period + i/n))`` (piecewise-
    constant in blocks of ``step`` rounds; mixing eigenvalue ``lam`` fixed),
    so WHICH workers are reliable rotates continuously — vanilla LEA's
    all-history counts converge to every worker's time-average and stop
    ranking, while windowed/discounted estimators track the current phase.
    One scenario per drift period."""
    n = SIM.n
    lp = _sim_lp()
    scenarios = []
    for period in periods:
        schedule = []
        for start in range(0, rounds, step):
            t_mid = start + step / 2.0
            pis = [
                min(max(base_pi + amp * math.sin(
                    2.0 * math.pi * (t_mid / period + i / n)), 0.02), 0.98)
                for i in range(n)
            ]
            p_gg, p_bb = _chain_rows(pis, lam)
            schedule.append((start, p_gg, p_bb))
        scenarios.append(Scenario(
            name=f"drift_T{period}", family="drifting_chains", lp=lp,
            p_gg=schedule[0][1], p_bb=schedule[0][2],
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
            rounds=rounds, strategies=tuple(strategies), baseline=baseline,
            schedule=tuple(schedule),
            meta=(("period", period), ("step", step), ("lam", lam),
                  ("base_pi", base_pi), ("amp", amp)),
        ))
    return tuple(scenarios)


@register("regime_switch")
def regime_switch(
    dwells: tuple[int, ...] = (250, 500),
    rounds: int = 2_000,
    lam: float = 0.5,
    pi_good: float = 0.9,
    pi_degraded: float = 0.1,
    n_rotate: int = 3,
    strategies: tuple[str, ...] = POLICY_STRATEGIES,
    baseline: str = "lea",
) -> tuple[Scenario, ...]:
    """Abrupt degradation waves: every ``dwell`` rounds a different third of
    the pool degrades (preemption / credit-exhaustion, cf. the Fig. 1 EC2
    traces), rotating through ``n_rotate`` worker groups.

    Long-run, every worker is degraded 1/n_rotate of the time, so vanilla
    LEA's cumulative counts blur the groups together; a windowed/discounted
    estimator re-identifies the currently-degraded group within its memory
    length after each switch.  One scenario per dwell time."""
    n = SIM.n
    lp = _sim_lp()
    scenarios = []
    for dwell in dwells:
        schedule = []
        for regime, start in enumerate(range(0, rounds, dwell)):
            degraded = {i for i in range(n) if i % n_rotate == regime % n_rotate}
            pis = [pi_degraded if i in degraded else pi_good for i in range(n)]
            p_gg, p_bb = _chain_rows(pis, lam)
            schedule.append((start, p_gg, p_bb))
        scenarios.append(Scenario(
            name=f"regime_dwell{dwell}", family="regime_switch", lp=lp,
            p_gg=schedule[0][1], p_bb=schedule[0][2],
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
            rounds=rounds, strategies=tuple(strategies), baseline=baseline,
            schedule=tuple(schedule),
            meta=(("dwell", dwell), ("lam", lam), ("pi_good", pi_good),
                  ("pi_degraded", pi_degraded), ("n_rotate", n_rotate)),
        ))
    return tuple(scenarios)


@register("computed_drift")
def computed_drift(
    periods: tuple[int, ...] = (400, 1000),
    rounds: int = 2_000,
    lam: float = 0.5,
    base_pi: float = 0.55,
    amp: float = 0.35,
    strategies: tuple[str, ...] = POLICY_STRATEGIES,
    baseline: str = "lea",
) -> tuple[Scenario, ...]:
    """Smooth per-round drift via a precomputed dense (rounds, n) chain spec.

    The ``dense_schedule`` showcase: the same rotating sinusoidal
    availability as ``drifting_chains`` but computed at EVERY round (no
    ``step``-block quantisation) — ``pi_i(t) = base_pi + amp *
    sin(2*pi*(t/period + i/n))`` materialised directly as (rounds, n)
    arrays through :func:`repro.sweeps.registry.as_dense_schedule`.  One
    scenario per drift period; windowed/discounted LEA variants track the
    continuously-moving regime that vanilla LEA's all-history counts blur.
    """
    n = SIM.n
    lp = _sim_lp()
    scenarios = []
    for period in periods:
        t = [tm + 0.5 for tm in range(rounds)]      # mid-round sample points
        p_gg = []
        p_bb = []
        for tm in t:
            pis = [
                min(max(base_pi + amp * math.sin(
                    2.0 * math.pi * (tm / period + i / n)), 0.02), 0.98)
                for i in range(n)
            ]
            g, b = _chain_rows(pis, lam)
            p_gg.append(g)
            p_bb.append(b)
        dense = as_dense_schedule(p_gg, p_bb)
        scenarios.append(Scenario(
            name=f"cdrift_T{period}", family="computed_drift", lp=lp,
            p_gg=dense[0][0], p_bb=dense[1][0],
            mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
            rounds=rounds, strategies=tuple(strategies), baseline=baseline,
            dense_schedule=dense,
            meta=(("period", period), ("lam", lam),
                  ("base_pi", base_pi), ("amp", amp)),
        ))
    return tuple(scenarios)


@register("packet_erasure")
def packet_erasure(
    p_preempts: tuple[float, ...] = (0.0, 0.2, 0.4),
    p_drops: tuple[float, ...] = (0.0, 0.05, 0.15),
    packets: int = 4,
    p1: int = 1,
    k1: int = 25,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Fault grid for the ``repro.faults`` runtime: preemption x packet loss.

    A (p_preempt x p_drop) product grid on the Fig. 3 worker pool; each
    cell's fault channel — a ``preempt`` ramp composed with iid
    ``packet_bernoulli`` erasure — and its packet geometry ride in ``meta``
    (the registry stays fault-agnostic).  ``benchmarks/bench_faults.py``
    turns the meta columns into TRACED channel parameters and scores every
    cell's rounds under three decode modes (all-or-nothing / partial-work
    conserving / hierarchical layer-1, threshold ``K1 = (k1-1) deg_f + 1``)
    on the same trajectories and the same fault realisations, fused into
    ONE compile via :func:`repro.faults.engine.sweep_faults`.
    """
    lp = _sim_lp()
    k1star = CodeSpec(SIM.n, SIM.r, k1, SIM.deg_f).recovery_threshold
    scenarios = []
    for p_pre in p_preempts:
        for p_drop in p_drops:
            scenarios.append(Scenario(
                name=f"erasure_pre{p_pre:g}_drop{p_drop:g}",
                family="packet_erasure", lp=lp,
                p_gg=_const(SIM.n, 0.8), p_bb=_const(SIM.n, 0.7),
                mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
                rounds=rounds,
                meta=(("p_preempt", p_pre), ("p_drop", p_drop),
                      ("packets", packets), ("p1", p1), ("k1", k1),
                      ("k1star", k1star), ("r", SIM.r)),
            ))
    return tuple(scenarios)


@register("arrival_grid")
def arrival_grid(
    rates: tuple[float, ...] = (0.6, 1.2, 2.4),
    deadline_rels: tuple[int, ...] = (1, 3),
    k: int = 50,
    deg_f: int = 1,
    capacity: int = 6,
    admit_threshold: float = 0.5,
    reserve_cap: float = 0.7,
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Serving grid for ``repro.serving``: arrival rate x request deadline.

    Poisson requests (``rate`` per round) on the Sec. 6.2 worker pool
    (K*=50 at deg f=1, so each request's minimal segment is 5 workers —
    2-3 concurrent jobs saturate the 15-worker pool, and the top rate is a
    genuine overload).  Each cell's arrival process, request lifetime
    ``deadline_rel``, queue ``capacity`` and admission-control settings
    (``admit_threshold``/``reserve_cap`` — the settings the controlled run
    uses; admit-all is the same compile with the gates disabled) ride in
    ``meta``: ``benchmarks/bench_serving.py`` turns the meta columns into
    TRACED :class:`~repro.serving.queue.RequestSpec` / arrival-process
    parameters and the whole grid — admit-all and controlled variants
    included — fuses into ONE compile via
    :func:`repro.serving.sweep_serving`.  Run offline (``sweeps.run``)
    the scenarios measure the pool's single-job ceiling on the same chain.
    """
    lp = _sim_lp(k=k, deg_f=deg_f)
    scenarios = []
    for rate in rates:
        for dl in deadline_rels:
            scenarios.append(Scenario(
                name=f"arrive_r{rate:g}_dl{dl}", family="arrival_grid",
                lp=lp, p_gg=_const(SIM.n, 0.8), p_bb=_const(SIM.n, 0.7),
                mu_g=SIM.mu_g, mu_b=SIM.mu_b, deadline=SIM.deadline,
                rounds=rounds, strategies=("lea",), baseline="lea",
                meta=(("process", "poisson"), ("rate", rate),
                      ("deadline_rel", dl), ("capacity", capacity),
                      ("grace", 0), ("admit_threshold", admit_threshold),
                      ("reserve_cap", reserve_cap), ("kstar", lp.kstar)),
            ))
    return tuple(scenarios)


@register("straggler_slack")
def straggler_slack(
    speed_ratios: tuple[float, ...] = (2.0, 3.3, 5.0, 10.0),
    deadlines: tuple[float, ...] = (1.0, 1.5),
    rounds: int = 2_000,
) -> tuple[Scenario, ...]:
    """Straggler-slack grid: how slow is a bad worker (mu_g / mu_b) x how much
    deadline slack exists — the adaptive-straggler regime of Slack Squeeze
    Coded Computing (arXiv:1904.07098).  Each cell reshapes (ell_g, ell_b),
    (ell is traced, so the whole grid still compiles once)."""
    spec = CodeSpec(SIM.n, SIM.r, SIM.k, SIM.deg_f)
    scenarios = []
    for ratio in speed_ratios:
        mu_b = SIM.mu_g / ratio
        for d in deadlines:
            ell_g = int(min(SIM.mu_g * d, SIM.r))
            ell_b = max(1, int(mu_b * d))
            if ell_g <= ell_b:
                continue
            lp = LoadParams(n=SIM.n, kstar=spec.recovery_threshold,
                            ell_g=ell_g, ell_b=ell_b)
            scenarios.append(Scenario(
                name=f"slack_r{ratio:g}_d{d:g}", family="straggler_slack",
                lp=lp, p_gg=_const(SIM.n, 0.8), p_bb=_const(SIM.n, 0.7),
                mu_g=SIM.mu_g, mu_b=float(mu_b), deadline=float(d),
                rounds=rounds,
                meta=(("speed_ratio", ratio), ("deadline", d)),
            ))
    return tuple(scenarios)
