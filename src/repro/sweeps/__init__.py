"""repro.sweeps — sharded, chunked, registry-driven Monte-Carlo sweeps.

The production sweep runner over the batched engine
(:mod:`repro.core.throughput`):

  * :mod:`~repro.sweeps.registry`  — named scenario families -> flat
    :class:`ScenarioBatch` pytrees, grouped by static compile signature;
  * :mod:`~repro.sweeps.scenarios` — the paper's Fig. 3 / Fig. 4 grids plus
    deadline, bursty-chain, heterogeneous-K*, elastic-pool and
    straggler-slack families;
  * :mod:`~repro.sweeps.executor`  — one compiled computation per group,
    sharded over a 1-D ``jax.sharding`` mesh, ``round_chunk``-bounded memory;
  * :mod:`~repro.sweeps.results`   — throughputs, baseline ratios, CIs,
    ``BENCH_*.json``-style manifests.

The one-liner::

    from repro import sweeps
    from repro.launch.mesh import make_sweep_mesh

    results = sweeps.run("hetero_kstar", seeds=4,
                         mesh=make_sweep_mesh(), round_chunk=4096)
    for r in results:
        print(r.name, r.throughput, f"{r.baseline_ratio:.2f}x")
"""

from repro.obs.telemetry import TelemetryFrame

from .executor import (compile_cache_size, last_pipeline_stats,
                       pipeline_block_hlo, run, run_group, run_groups,
                       run_multihost, suggest_round_chunk)
from .registry import (Scenario, ScenarioBatch, SweepGroup, as_dense_schedule,
                       build_groups, catalogue, describe, expand, family_names,
                       register)
from .results import (ScenarioResult, manifest, summarize, summarize_group,
                      write_manifest)

__all__ = [
    "Scenario", "ScenarioBatch", "ScenarioResult", "SweepGroup", "TelemetryFrame",
    "as_dense_schedule", "build_groups", "catalogue", "compile_cache_size",
    "describe", "expand", "family_names", "last_pipeline_stats", "manifest",
    "pipeline_block_hlo", "register", "run", "run_group", "run_groups",
    "run_multihost", "suggest_round_chunk", "summarize", "summarize_group",
    "write_manifest",
]
