"""Results layer: per-scenario throughputs, baseline ratios, CIs, manifests.

Takes the raw (B, rounds, S) success arrays the executor produces per group
and folds them back onto scenarios: mean timely throughput per strategy
(averaged over Monte-Carlo repeats), the ratio against the scenario's
baseline strategy (the paper's headline LEA/static numbers), and a 95%
confidence interval — across repeats when ``seeds > 1``, else the per-round
Bernoulli normal approximation (rounds are not independent under a mixing
chain, so the single-seed CI is a lower bound on the true width; repeats
give the honest one).

Regret axis: whenever a scenario's strategies include the genie
``"oracle"``, every other strategy additionally gets its final cumulative
timely-throughput regret vs the oracle (:mod:`repro.policies.regret` —
paired per-round differences on the shared trajectory, summed over rounds,
averaged over Monte-Carlo repeats).  Manifest rows carry these as
``regret_<strategy>`` columns plus paired 95% CIs (``regret_ci95_<s>``:
across repeats when ``seeds > 1``, else the CLT width of the summed paired
per-round differences — same machinery and same single-seed caveat as the
throughput CI), so policy sweeps report throughput, baseline ratio AND
convergence-to-optimal with uncertainty in one document.

:func:`manifest` renders results as a JSON document in the ``BENCH_*.json``
trajectory shape (a ``bench`` name, run metadata, a flat ``results`` list),
and :func:`write_manifest` drops it at the repo root next to
``BENCH_fig3.json``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

from repro.obs.provenance import provenance as _provenance_fn
from repro.policies import regret as regret_mod

from .registry import Scenario, SweepGroup

_Z95 = 1.959963984540054  # two-sided 95% normal quantile


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    """Aggregated Monte-Carlo outcome for one scenario."""

    scenario: Scenario
    seeds: int
    throughput: dict[str, float]             # strategy -> mean R(d, eta)
    per_seed: dict[str, tuple[float, ...]]   # strategy -> per-repeat R
    ci95: dict[str, tuple[float, float]]     # strategy -> (lo, hi)
    ratio: dict[str, float]                  # strategy -> R_s / R_baseline
    # strategy -> mean final cumulative regret vs the oracle (empty when the
    # scenario does not simulate the oracle)
    regret: dict[str, float] = dataclasses.field(default_factory=dict)
    # strategy -> paired 95% CI on the mean final regret (same keys as regret)
    regret_ci95: dict[str, tuple[float, float]] = dataclasses.field(
        default_factory=dict
    )

    @property
    def name(self) -> str:
        return self.scenario.name

    @property
    def baseline_ratio(self) -> float:
        """The headline number: best non-baseline strategy vs the baseline."""
        others = [r for s, r in self.ratio.items() if s != self.scenario.baseline]
        return max(others) if others else 1.0

    def row(self) -> dict[str, Any]:
        """Flat JSON-able record for manifests.

        Non-finite ratios (a baseline that never succeeds) become ``None`` —
        ``json.dump`` would otherwise emit the literal ``Infinity``, which is
        not valid JSON (RFC 8259) and breaks non-Python consumers.
        """
        return {
            "scenario": self.scenario.name,
            "family": self.scenario.family,
            "rounds": self.scenario.rounds,
            "seeds": self.seeds,
            "kstar": self.scenario.lp.kstar,
            "n": self.scenario.lp.n,
            "baseline": self.scenario.baseline,
            "meta": self.scenario.meta_dict(),
            **{f"R_{s}": v for s, v in self.throughput.items()},
            **{f"ci95_{s}": list(v) for s, v in self.ci95.items()},
            **{
                f"ratio_{s}": (v if math.isfinite(v) else None)
                for s, v in self.ratio.items()
                if s != self.scenario.baseline
            },
            **{f"regret_{s}": v for s, v in self.regret.items()},
            **{f"regret_ci95_{s}": list(v) for s, v in self.regret_ci95.items()},
        }


def _half_across_seeds(per_seed: np.ndarray) -> float:
    """z * s / sqrt(n): the across-repeats half-width both CIs share."""
    return _Z95 * float(per_seed.std(ddof=1)) / math.sqrt(per_seed.size)


def _ci95(per_seed: np.ndarray, rounds: int) -> tuple[float, float]:
    """95% CI of the mean throughput (see module docstring)."""
    m = float(per_seed.mean())
    if per_seed.size > 1:
        half = _half_across_seeds(per_seed)
    else:
        half = _Z95 * math.sqrt(max(m * (1.0 - m), 0.0) / max(rounds, 1))
    return (max(m - half, 0.0), min(m + half, 1.0))


def _regret_ci95(
    finals: np.ndarray, per_round: np.ndarray | None
) -> tuple[float, float]:
    """Paired 95% CI of the mean final cumulative regret.

    ``finals`` is the (seeds,) per-repeat final regret, ``per_round`` the
    (1, rounds) paired per-round differences it sums (only materialised —
    and only needed — for single-seed runs).  With repeats the CI is the
    usual normal interval across seeds (the same machinery as the
    throughput :func:`_ci95`); a single seed falls back to the CLT width of
    the summed per-round differences, z * s_diff * sqrt(rounds) — paired
    per-round variation, with the same serial-correlation caveat as the
    single-seed throughput CI.  Regret is unbounded, so no clamping.
    """
    m = float(finals.mean())
    if finals.size > 1:
        half = _half_across_seeds(finals)
    else:
        rounds = per_round.shape[-1]
        sd = float(per_round[0].std(ddof=1)) if rounds > 1 else 0.0
        half = _Z95 * sd * math.sqrt(rounds)
    return (m - half, m + half)


def summarize_group(group: SweepGroup, succ: np.ndarray) -> list[ScenarioResult]:
    """Fold one group's (B, rounds, S) successes onto its scenarios."""
    b = len(group.rows)
    if succ.shape[0] != b:
        raise ValueError(f"expected {b} result rows, got {succ.shape[0]}")
    # per-row throughput by the engine's own reduction semantics
    # (core.throughput.timely_throughput: float32 mean).  One batched device
    # call, not B*S scalar reductions; a float32 sum of 0/1 indicators is
    # exact for rounds < 2^24, so the value is bit-identical to
    # throughput.compare() regardless of reduction order (seeds=1 registry
    # runs replicate the paper numbers exactly — the tests assert it).
    per_round = np.asarray(
        jnp.mean(jnp.asarray(succ).astype(jnp.float32), axis=1), np.float64
    )                                                        # (B, S)  exact cast
    results = []
    has_oracle = regret_mod.REFERENCE in group.strategies
    for si, sc in enumerate(group.scenarios):
        rows = [ri for ri, rm in enumerate(group.rows) if rm.scenario_index == si]
        seed_tp = per_round[rows]                            # (seeds, S)
        throughput, per_seed, ci95 = {}, {}, {}
        for j, strat in enumerate(group.strategies):
            vals = seed_tp[:, j]
            throughput[strat] = float(vals.mean())
            per_seed[strat] = tuple(float(v) for v in vals)
            ci95[strat] = _ci95(vals, group.rounds)
        base = throughput[sc.baseline]
        ratio = {
            s: (throughput[s] / base if base > 0 else float("inf"))
            for s in group.strategies
        }
        regret: dict[str, float] = {}
        regret_ci95: dict[str, tuple[float, float]] = {}
        if has_oracle:
            # (seeds, rounds, S) -> per-strategy mean final cumulative regret
            # plus a paired 95% CI from the same per-seed finals
            finals = regret_mod.final_regret(succ[rows], group.strategies)
            for s, v in finals.items():
                if s == regret_mod.REFERENCE:
                    continue
                regret[s] = float(v.mean())
                # the (seeds, rounds) diffs are only consumed by the
                # single-seed CLT fallback; across-seeds CIs never touch them
                diffs = None
                if v.size == 1:
                    diffs = np.asarray(
                        regret_mod.per_round_regret(succ[rows], group.strategies, s),
                        np.float64,
                    )                                    # (1, rounds)
                regret_ci95[s] = _regret_ci95(np.asarray(v, np.float64), diffs)
        results.append(ScenarioResult(
            scenario=sc, seeds=seed_tp.shape[0], throughput=throughput,
            per_seed=per_seed, ci95=ci95, ratio=ratio, regret=regret,
            regret_ci95=regret_ci95,
        ))
    return results


def summarize(
    groups: Sequence[SweepGroup],
    succs: Sequence[np.ndarray],
    *,
    scenario_order: Sequence[Scenario] | None = None,
) -> list[ScenarioResult]:
    """Fold every group; optionally reorder to the original expansion order."""
    results: list[ScenarioResult] = []
    for group, succ in zip(groups, succs):
        results.extend(summarize_group(group, succ))
    if scenario_order is not None:
        # key on the scenario VALUE, not its name: distinct scenarios may
        # share a name across concatenated expansions (e.g. the same family
        # expanded twice with different rounds), and names must not alias
        by_scenario = {r.scenario: r for r in results}
        results = [by_scenario[sc] for sc in scenario_order]
    return results


def manifest(
    results: Sequence[ScenarioResult],
    *,
    bench: str,
    extra: dict[str, Any] | None = None,
    timestamp: float | None = None,
) -> dict[str, Any]:
    """BENCH_*.json-shaped document: bench name, metadata, flat result rows.

    Every manifest is stamped with run ``provenance``
    (:func:`repro.obs.provenance`: git sha + dirty flag, jax/jaxlib
    versions, backend/device, host) and a ``warnings`` list (the
    ``benchmarks._softgate`` structured records; ``extra`` may supply it).
    ``timestamp`` is passed through to the provenance record —
    ``time.time()`` when the caller does not care about determinism.
    """
    doc: dict[str, Any] = {
        "bench": bench,
        "scenarios": len(results),
        "families": sorted({r.scenario.family for r in results}),
        "results": [r.row() for r in results],
    }
    if extra:
        doc.update(extra)
    doc.setdefault("warnings", [])
    doc.setdefault(
        "provenance",
        _provenance_fn(time.time() if timestamp is None else timestamp),
    )
    return doc


def _shard_path(spool_dir: str | os.PathLike, group_index: int,
                process_id: int, num_processes: int) -> str:
    return os.path.join(
        str(spool_dir),
        f"group{group_index}_shard{process_id}of{num_processes}.npy",
    )


def write_row_shard(
    spool_dir: str | os.PathLike,
    group_index: int,
    process_id: int,
    num_processes: int,
    succ: np.ndarray,
) -> str:
    """Atomically publish one host's interleaved row shard to the spool dir.

    The shard holds the success rows ``r`` of group ``group_index`` with
    ``r % num_processes == process_id`` (the executor's interleaved row
    split).  Write-to-temp + ``os.replace`` so the merging host can never
    observe a half-written file; returns the final path.
    """
    os.makedirs(str(spool_dir), exist_ok=True)
    path = _shard_path(spool_dir, group_index, process_id, num_processes)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:  # handle, not a name: np.save must not
        np.save(f, np.asarray(succ))  # append its own .npy suffix
    os.replace(tmp, path)
    return path


def merge_row_shards(
    spool_dir: str | os.PathLike,
    group_index: int,
    num_processes: int,
    *,
    timeout_s: float = 600.0,
    poll_s: float = 0.05,
) -> np.ndarray:
    """Re-interleave one group's row shards back into the full (B, ...) array.

    Polls the spool dir until every process's shard file exists (atomic
    renames make existence == completeness), then scatters shard ``p`` into
    rows ``p::num_processes`` — the exact inverse of the executor's split,
    so the merged array is bit-identical to a single-host run.  Raises
    ``TimeoutError`` listing the missing shards otherwise.
    """
    paths = [_shard_path(spool_dir, group_index, p, num_processes)
             for p in range(num_processes)]
    deadline = time.monotonic() + timeout_s
    while True:
        missing = [p for p in paths if not os.path.exists(p)]
        if not missing:
            break
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"row shards never arrived after {timeout_s:.0f}s: {missing}"
            )
        time.sleep(poll_s)
    shards = [np.load(p) for p in paths]
    rows = sum(s.shape[0] for s in shards)
    out = np.empty((rows,) + shards[0].shape[1:], shards[0].dtype)
    for p, s in enumerate(shards):
        out[p::num_processes] = s
    return out


def write_manifest(path: str | os.PathLike, doc: dict[str, Any]) -> None:
    """Write a BENCH_*.json document (RFC-8259 strict, trailing newline).

    The provenance/warnings stamps are backstopped here too, so writers
    that assemble their document by hand (bench_faults, bench_serving,
    bench_gf) still satisfy the manifest contract.

    Every successful write also appends a compact history record to
    ``BENCH_history.jsonl`` next to the manifest (``REPRO_BENCH_HISTORY``
    redirects it; see :mod:`repro.obs.history`) — the trajectory the
    trend detector and ``benchmarks/run.py --check`` gate on.  The append
    never raises: a read-only checkout degrades to no history, not a dead
    bench.
    """
    from repro.obs import history as _history

    doc.setdefault("warnings", [])
    doc.setdefault("provenance", _provenance_fn(time.time()))
    with open(path, "w") as f:
        # allow_nan=False: fail loudly rather than emit non-RFC JSON
        json.dump(doc, f, indent=2, allow_nan=False)
        f.write("\n")
    _history.append_record(
        _history.history_path(path), _history.record_from_manifest(path, doc)
    )
