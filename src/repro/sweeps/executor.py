"""Sharded, grouped, chunked execution of scenario batches.

One :class:`~repro.sweeps.registry.SweepGroup` = one compiled computation:
:func:`_run_group` is the single jitted entry point, with only ``(rounds,
strategies, round_chunk)`` static.  Load parameters (K*, ell_g, ell_b) and
the worker-pool mask are TRACED batch leaves fed to the shape-polymorphic
engine (:func:`repro.core.throughput.simulate_strategies_pool`), so a
heterogeneous-K* grid, a deadline/load sweep or an elastic pool ramp is ONE
compile for the whole family regardless of how many scenarios and seeds it
spans (:func:`compile_cache_size` exposes the cache counter the tests
assert on).

Sharding: sweep rows are embarrassingly parallel, so the executor lays the
flat (scenarios x seeds) batch over the ``"batch"`` axis of a 1-D
``jax.sharding`` mesh (:func:`repro.launch.mesh.make_sweep_mesh`) by
device_put-ing every batch leaf with ``NamedSharding(mesh, P("batch"))`` —
the jitted computation then partitions itself over the data.  Batches are
padded (by repeating the last row) to a multiple of the mesh size; padded
rows are sliced off the result, so sharded output is bit-identical to the
unsharded :func:`repro.core.throughput.sweep` on the same keys.

Memory: ``round_chunk`` is forwarded to the engine's ``lax.map``-over-round-
blocks path so paper-scale M = 1e5 grids hold peak memory at one block.
"""

from __future__ import annotations

import collections
import math
import time
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import throughput
from repro.core.lea import PoolLoad
from repro.obs import counters as _obs_counters
from repro.obs import metrics as _metrics

from .registry import ScenarioBatch, SweepGroup


@partial(jax.jit,
         static_argnames=("rounds", "strategies", "round_chunk", "telemetry",
                          "tap", "tap_stride"))
def _run_group(
    keys: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: jnp.ndarray,
    mu_b: jnp.ndarray,
    deadline: jnp.ndarray,
    pool: PoolLoad,
    *,
    rounds: int,
    strategies: tuple[str, ...],
    round_chunk: int | None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """(B,) rows -> (B, rounds, S) success indicators, one XLA computation."""
    fn = partial(
        throughput.simulate_strategies_pool,
        rounds=rounds, strategies=strategies, round_chunk=round_chunk,
        telemetry=telemetry, tap=tap, tap_stride=tap_stride,
    )
    if tap:
        rows = jnp.arange(keys.shape[0], dtype=jnp.int32)
        return jax.vmap(
            lambda k, pg, pb, mg, mb, d, pl, ri: fn(
                k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d,
                tap_row=ri,
            )
        )(keys, p_gg, p_bb, mu_g, mu_b, deadline, pool, rows)
    return jax.vmap(
        lambda k, pg, pb, mg, mb, d, pl: fn(
            k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d
        )
    )(keys, p_gg, p_bb, mu_g, mu_b, deadline, pool)


_obs_counters.register_compiled("sweeps.run_group", _run_group)


def compile_cache_size() -> int:
    """Number of distinct group computations compiled so far.

    Thin alias over the unified obs counter
    (``obs.compile_events("sweeps.run_group")``) — kept for the pre-obs
    tests and benchmarks."""
    return _obs_counters.compile_events("sweeps.run_group")


def _pad_batch(batch: ScenarioBatch, multiple: int) -> tuple[ScenarioBatch, int]:
    """Pad rows to a multiple of the mesh size by repeating the last row.

    Rows are vmapped independently, so pad rows cannot perturb real rows;
    they are sliced off the result.
    """
    b = batch.rows
    pad = (-b) % multiple
    if pad == 0:
        return batch, b
    rep = jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]), batch
    )
    return rep, b


def _shard_batch(batch: ScenarioBatch, mesh: Mesh) -> ScenarioBatch:
    sh = NamedSharding(mesh, PartitionSpec("batch"))
    return jax.tree.map(lambda x: jax.device_put(x, sh), batch)


# ---------------------------------------------------------------------------
# pipelined (async, donated-carry) execution path
# ---------------------------------------------------------------------------
#
# The sync path runs one fused computation per group: preamble + a blocking
# ``lax.map`` over round blocks, re-padding and re-device_put-ing the batch
# on every call.  The pipelined path rebuilds the hot loop host-side:
#
#   * the padded/sharded batch is CACHED per (group identity, mesh) — shard
#     once, dispatch many (the steady-state sweep driver pattern);
#   * ``_prepare_group`` computes the engine preamble for every row ONCE
#     (:func:`repro.core.throughput.engine_preamble` — the identical traced
#     ops the sync engine runs, so per-round values are bit-identical);
#   * ``_block_step`` scores ONE round block for every row with the
#     cumulative aggregates (success counts, estimator-error sums, tap
#     tokens) as DONATED carries — XLA aliases them in place instead of
#     double-buffering (verified: donated buffers are deleted after the
#     first step, and the compiled HLO carries ``input_output_alias``);
#   * the host loop dispatches block b+1 while folding block b's device
#     result into host memory (JAX dispatch is async) — at most
#     ``PIPELINE_DEPTH`` blocks in flight, one final ``block_until_ready``
#     drain.  With ``tap=True`` each block emits the same ``engine.pool``
#     events as the sync scan, timed at actual block completion, so
#     ``tap.engine_pool.block_seconds`` measures real overlap.
#
# Blocks are independent per-round work, so any dispatch partition is
# bit-identical to the sync path on the same keys (property-tested).

PIPELINE_DEPTH = 2          # max blocks in flight (double-buffered)

_SHARD_CACHE_MAX = 4
_shard_cache: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()

_PIPELINE_STATS: dict = {}


def last_pipeline_stats() -> dict:
    """Host-loop accounting of the most recent pipelined run_group call.

    Keys: ``blocks``, ``round_chunk``, ``donated`` (runtime proof: the
    donated carry buffer was consumed by the first block step), ``fold_s``
    (host-side per-block result folding, overlapped with device compute),
    ``dispatch_s`` (time spent enqueueing block steps), ``drain_s`` (the
    final block_until_ready), ``shard_cached`` (the padded/sharded batch
    came from the shard-once cache).
    """
    return dict(_PIPELINE_STATS)


def _cached_shard(group: SweepGroup, mesh: Mesh | None):
    """Padded + device_put batch for ``group`` on ``mesh``, cached by identity.

    The cache key holds a strong reference to the group and is verified
    with ``is`` — id() reuse after garbage collection can never alias two
    distinct groups.  Bounded FIFO (the executor is typically driven with a
    handful of live groups)."""
    if mesh is None:
        return group.batch, group.batch.rows, False
    key = (id(group), tuple(mesh.axis_names), tuple(d.id for d in mesh.devices.flat))
    hit = _shard_cache.get(key)
    if hit is not None and hit[0] is group:
        _shard_cache.move_to_end(key)
        return hit[1], hit[2], True
    batch, b = _pad_batch(group.batch, mesh.devices.size)
    batch = _shard_batch(batch, mesh)
    _shard_cache[key] = (group, batch, b)
    while len(_shard_cache) > _SHARD_CACHE_MAX:
        _shard_cache.popitem(last=False)
    return batch, b, False


@partial(jax.jit,
         static_argnames=("rounds", "strategies", "n_blocks", "round_chunk",
                          "tap"))
def _prepare_group(
    keys: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: jnp.ndarray,
    mu_b: jnp.ndarray,
    deadline: jnp.ndarray,
    pool: PoolLoad,
    *,
    rounds: int,
    strategies: tuple[str, ...],
    n_blocks: int,
    round_chunk: int,
    tap: bool,
):
    """Per-row engine preamble, round-padded to ``n_blocks * round_chunk``.

    Returns ``(states (B, Mp, n), round_keys (B, Mp, 2), p_alloc
    (B, A, Mp, n), est, pack_f (B, n + 3), pack_i (B, 3), mask (B, n),
    succ0, err0, tok0)`` — exactly the arrays the sync engine computes
    before its block loop (same PRNG discipline, same edge-round padding),
    materialised once so the block steps only slice.

    The calling convention is deliberately PACKED: per-row invariants that
    the block steps only read — ``pi_g``/``mu_g``/``mu_b``/``deadline``
    into ``pack_f``, the integer load params into ``pack_i`` — plus the
    zero carries, built HERE (sharding-tied to the batch so donation still
    aliases).  Dispatching a multi-device jit costs ~50us PER SHARDED
    ARGUMENT on this backend, and the block step is dispatched once per
    block per group: every leaf trimmed off its signature is wall-clock
    the async loop keeps.  ``est`` (the estimator-error stream), the error
    carry and the tap token are ``None`` when ``tap=False`` — zero leaves
    instead of dead arrays (``tap`` is already a static compile key).
    """

    def row(k, pg, pb, pl):
        states, round_keys, p_alloc, pi_g = throughput.engine_preamble(
            k, pl, pg, pb, rounds, strategies
        )
        est = (throughput.estimator_error_rounds(
            states, p_alloc, pg, pb, pi_g, pl.mask
        ) if tap else None)
        return states, round_keys, p_alloc, est, pi_g

    states, round_keys, p_alloc, est, pi_g = jax.vmap(row)(keys, p_gg, p_bb, pool)
    pad = n_blocks * round_chunk - rounds
    if pad:
        # edge-round padding, exactly the sync chunked path's convention:
        # blocks are independent, so pad rounds cannot perturb real rounds
        states = jnp.concatenate([states, states[:, -pad:]], axis=1)
        round_keys = jnp.concatenate([round_keys, round_keys[:, -pad:]], axis=1)
        p_alloc = jnp.concatenate([p_alloc, p_alloc[:, :, -pad:]], axis=2)
        if tap:
            est = jnp.concatenate([est, est[:, -pad:]], axis=1)
    pack_f = jnp.concatenate(
        [pi_g.astype(jnp.float32), mu_g[:, None].astype(jnp.float32),
         mu_b[:, None].astype(jnp.float32),
         deadline[:, None].astype(jnp.float32)], axis=1)
    pack_i = jnp.stack([pool.kstar, pool.ell_g, pool.ell_b], axis=1)
    # zero carries, arithmetic-tied to a batch-sharded operand so GSPMD
    # lays them out exactly like the block step's outputs (donation aliases)
    zero = pack_i[:, 0] * 0                                     # (B,) int32
    succ0 = zero[:, None] + jnp.zeros((1, len(strategies)), jnp.int32)
    err0 = (zero[:, None].astype(jnp.float32)
            + jnp.zeros((1, p_alloc.shape[1]), jnp.float32)) if tap else None
    tok0 = zero if tap else None
    return (states, round_keys, p_alloc, est, pack_f, pack_i, pool.mask,
            succ0, err0, tok0)


@partial(jax.jit,
         static_argnames=("rounds", "strategies", "round_chunk", "tap"),
         donate_argnums=(0, 1, 2))
def _block_step(
    succ_cum: jnp.ndarray,     # (B, S) int32 — DONATED
    err_cum,                   # (B, A) float32 — DONATED; None when tap=False
    token,                     # (B,) int32 tap token — DONATED; None w/o tap
    block_i: jnp.ndarray,      # traced scalar int32
    states: jnp.ndarray,       # (B, Mp, n)
    round_keys: jnp.ndarray,   # (B, Mp, 2)
    p_alloc: jnp.ndarray,      # (B, A, Mp, n)
    est,                       # (B, Mp, A) — None when tap=False
    pack_f: jnp.ndarray,       # (B, n + 3) f32: pi_g | mu_g | mu_b | deadline
    pack_i: jnp.ndarray,       # (B, 3) int32: kstar | ell_g | ell_b
    mask: jnp.ndarray,         # (B, n) bool worker mask
    *,
    rounds: int,
    strategies: tuple[str, ...],
    round_chunk: int,
    tap: bool,
):
    """Round block ``block_i`` for every row: donated carries + (B, m, S) succ.

    One compile serves every block (``block_i`` is traced; slicing is
    ``dynamic_slice``).  The block body is
    :func:`repro.core.throughput.engine_block` — the identical per-round
    ops the sync chunked ``lax.map`` runs — so dispatch order cannot change
    a single bit of the success stream.  Unpacking ``pack_f``/``pack_i``
    is free slicing inside the trace; what it buys is a short argument
    list, i.e. cheap per-block dispatch (see ``_prepare_group``).
    """
    m = round_chunk
    start = block_i * m
    in_round = jnp.arange(m, dtype=jnp.int32)
    valid = (start + in_round) < rounds                        # (m,)
    n = mask.shape[-1]
    rows_idx = jnp.arange(succ_cum.shape[0], dtype=jnp.int32)  # tap row labels

    def row(succ_c, err_c, tok, states_r, keys_r, p_alloc_r, est_r, pf, pi,
            mk, ri):
        pl = PoolLoad(kstar=pi[0], ell_g=pi[1], ell_b=pi[2], mask=mk)
        states_b = jax.lax.dynamic_slice_in_dim(states_r, start, m, axis=0)
        keys_b = jax.lax.dynamic_slice_in_dim(keys_r, start, m, axis=0)
        p_alloc_b = jax.lax.dynamic_slice_in_dim(p_alloc_r, start, m, axis=1)
        succ_b = throughput.engine_block(
            states_b, keys_b, p_alloc_b, pf[:n], pl, strategies,
            pf[n], pf[n + 1], pf[n + 2]
        )                                                      # (m, S)
        succ_c = succ_c + jnp.sum(
            jnp.where(valid[:, None], succ_b.astype(jnp.int32), 0), axis=0
        )
        if tap:
            from repro.obs import taps as _taps

            est_b = jax.lax.dynamic_slice_in_dim(est_r, start, m, axis=0)
            err_c = err_c + jnp.sum(jnp.where(valid[:, None], est_b, 0.0),
                                    axis=0)
            rounds_done = jnp.minimum((block_i + 1) * m, rounds)
            done_f = jnp.maximum(rounds_done.astype(jnp.float32), 1.0)
            tok = _taps.emit(
                "engine.pool", token=tok,
                block=jnp.asarray(block_i, jnp.int32),
                row=jnp.asarray(ri, jnp.int32),
                rounds_done=jnp.asarray(rounds_done, jnp.int32),
                succ_so_far=succ_c,
                throughput_so_far=succ_c.astype(jnp.float32) / done_f,
                est_err_so_far=err_c / done_f,
            )
        return succ_c, err_c, tok, succ_b

    return jax.vmap(row)(succ_cum, err_cum, token, states, round_keys,
                         p_alloc, est, pack_f, pack_i, mask, rows_idx)


_obs_counters.register_compiled("sweeps.prepare_group", _prepare_group)
_obs_counters.register_compiled("sweeps.block_step", _block_step)


def _pipeline_geometry(rounds: int, round_chunk: int | None) -> tuple[int, int]:
    """(chunk, n_blocks) for the pipelined loop — whole run = one block."""
    if round_chunk is not None and round_chunk <= 0:
        raise ValueError("round_chunk must be positive")
    chunk = rounds if round_chunk is None or round_chunk >= rounds else round_chunk
    return chunk, -(-rounds // chunk)


def _run_group_pipelined(
    group: SweepGroup,
    batch: ScenarioBatch,
    b: int,
    *,
    mesh: Mesh | None,
    round_chunk: int | None,
    tap: bool,
) -> np.ndarray:
    chunk, n_blocks = _pipeline_geometry(group.rounds, round_chunk)
    rounds, strategies = group.rounds, group.strategies
    (states, round_keys, p_alloc, est, pack_f, pack_i, mask,
     succ_cum, err_cum, token) = _prepare_group(
        batch.keys, batch.p_gg, batch.p_bb, batch.mu_g, batch.mu_b,
        batch.deadline, batch.pool,
        rounds=rounds, strategies=strategies, n_blocks=n_blocks,
        round_chunk=chunk, tap=tap,
    )

    first_carry = succ_cum
    host_blocks: list[np.ndarray | None] = [None] * n_blocks
    inflight: collections.deque = collections.deque()
    fold_s = dispatch_s = 0.0

    def fold_oldest():
        nonlocal fold_s
        j, sb = inflight.popleft()
        t0 = time.perf_counter()
        host_blocks[j] = np.asarray(sb)      # waits for block j only
        fold_s += time.perf_counter() - t0

    for bi in range(n_blocks):
        t0 = time.perf_counter()
        succ_cum, err_cum, token, succ_b = _block_step(
            succ_cum, err_cum, token, jnp.asarray(bi, jnp.int32),
            states, round_keys, p_alloc, est, pack_f, pack_i, mask,
            rounds=rounds, strategies=strategies, round_chunk=chunk, tap=tap,
        )
        dispatch_s += time.perf_counter() - t0
        inflight.append((bi, succ_b))
        if len(inflight) >= PIPELINE_DEPTH:
            fold_oldest()
    while inflight:
        fold_oldest()
    t0 = time.perf_counter()
    jax.block_until_ready((succ_cum, err_cum, token))
    drain_s = time.perf_counter() - t0

    _PIPELINE_STATS.update(
        blocks=n_blocks, round_chunk=chunk, donated=bool(first_carry.is_deleted()),
        fold_s=fold_s, dispatch_s=dispatch_s, drain_s=drain_s,
    )
    succ = (host_blocks[0] if n_blocks == 1
            else np.concatenate(host_blocks, axis=1))
    return succ[:b, :rounds]


def pipeline_block_hlo(
    group: SweepGroup,
    *,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    tap: bool = False,
) -> str:
    """Compiled HLO text of ``_block_step`` on this group's shapes.

    The donation introspection hook: the text carries
    ``input_output_alias`` entries iff XLA actually aliased the donated
    carries — what the tests and ``bench_speed`` assert instead of hoping.
    """
    batch, _, _ = _cached_shard(group, mesh)
    chunk, n_blocks = _pipeline_geometry(group.rounds, round_chunk)
    (states, round_keys, p_alloc, est, pack_f, pack_i, mask,
     succ0, err0, tok0) = _prepare_group(
        batch.keys, batch.p_gg, batch.p_bb, batch.mu_g, batch.mu_b,
        batch.deadline, batch.pool,
        rounds=group.rounds, strategies=group.strategies, n_blocks=n_blocks,
        round_chunk=chunk, tap=tap,
    )
    lowered = _block_step.lower(
        succ0, err0, tok0, jnp.asarray(0, jnp.int32),
        states, round_keys, p_alloc, est, pack_f, pack_i, mask,
        rounds=group.rounds, strategies=group.strategies, round_chunk=chunk,
        tap=tap,
    )
    return lowered.compile().as_text()


def run_group(
    group: SweepGroup,
    *,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
    pipeline: bool = False,
):
    """Execute one group; returns host (B, rounds, S) bool success array.

    With ``telemetry=True`` returns ``(succ, TelemetryFrame)`` — the frame
    leaves are host arrays with the same leading (B,) slicing as ``succ``
    (see :mod:`repro.obs.telemetry`); the group still compiles once.  With
    ``tap=True`` the engine streams per-row block aggregates to the
    registered tap handlers DURING the run (:mod:`repro.obs.taps`) — same
    bit-identity and one-compile contract.  Every call attributes its
    wall-clock (``phase.sweeps_run_group.seconds``) and any compile events
    it triggered (``compile.sweeps_run_group.*``) to the default metrics
    registry (:mod:`repro.obs.metrics`).

    ``pipeline=True`` selects the async double-buffered path: shard-once
    batch cache, donated-carry block steps, host folds overlapped with the
    in-flight block (see the pipelined section above) — bit-identical
    output, :func:`last_pipeline_stats` for the loop accounting.  Telemetry
    frames are a whole-run artifact and incompatible with per-block
    dispatch; tap events stream per block (``tap_stride`` is the sync
    path's knob and is ignored — the pipeline's block size IS
    ``round_chunk``).
    """
    if group.rounds < 1:
        names = ", ".join(sc.name for sc in group.scenarios[:3])
        raise ValueError(
            f"group [{names}, ...] has rounds={group.rounds}; catalogue-only "
            "scenario families (e.g. kstar_table) cannot be simulated"
        )
    if mesh is not None and tuple(mesh.axis_names) != ("batch",):
        raise ValueError(f'sweep mesh must have axes ("batch",), got {mesh.axis_names}')
    if pipeline:
        if telemetry:
            raise ValueError(
                "pipeline=True is incompatible with telemetry=True: telemetry "
                "frames are whole-run artifacts (use tap= for live streams)"
            )
        batch, b, cached = _cached_shard(group, mesh)
        c0 = _obs_counters.compile_events("sweeps.block_step") \
            + _obs_counters.compile_events("sweeps.prepare_group")
        h0 = _obs_counters.persistent_cache_hits()
        t0 = time.perf_counter()
        with _metrics.timed("phase.sweeps_pipeline"):
            succ = _run_group_pipelined(
                group, batch, b, mesh=mesh, round_chunk=round_chunk, tap=tap,
            )
        _PIPELINE_STATS["shard_cached"] = cached
        _metrics.record_compile(
            "sweeps.pipeline",
            max(_obs_counters.compile_events("sweeps.block_step")
                + _obs_counters.compile_events("sweeps.prepare_group") - c0
                - (_obs_counters.persistent_cache_hits() - h0), 0),
            time.perf_counter() - t0,
        )
        return succ
    batch, b = (group.batch, group.batch.rows)
    if mesh is not None:
        batch, b = _pad_batch(batch, mesh.devices.size)
        batch = _shard_batch(batch, mesh)
    c0 = _obs_counters.compile_events("sweeps.run_group")
    h0 = _obs_counters.persistent_cache_hits()
    t0 = time.perf_counter()
    with _metrics.timed("phase.sweeps_run_group"):
        out = _run_group(
            batch.keys, batch.p_gg, batch.p_bb, batch.mu_g, batch.mu_b,
            batch.deadline, batch.pool,
            rounds=group.rounds, strategies=group.strategies,
            round_chunk=round_chunk, telemetry=telemetry,
            tap=tap, tap_stride=tap_stride,
        )
        out = jax.block_until_ready(out)
    # a trace-cache entry served from the persistent compilation cache
    # (repro.launch.cache) is not a compile — subtract the hit delta so warm
    # restarts attribute 0 compile events
    _metrics.record_compile(
        "sweeps.run_group",
        max(_obs_counters.compile_events("sweeps.run_group") - c0
            - (_obs_counters.persistent_cache_hits() - h0), 0),
        time.perf_counter() - t0,
    )
    if not telemetry:
        return np.asarray(out[:b])
    succ, frame = out
    return np.asarray(succ[:b]), jax.tree.map(lambda x: np.asarray(x[:b]), frame)


def run_groups(
    groups: Sequence[SweepGroup],
    *,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    tap: bool = False,
    tap_stride: int | None = None,
    pipeline: bool = False,
) -> list[np.ndarray]:
    """Execute every group (one compile each); list aligned with ``groups``."""
    return [run_group(g, mesh=mesh, round_chunk=round_chunk,
                      tap=tap, tap_stride=tap_stride, pipeline=pipeline)
            for g in groups]


def suggest_round_chunk(
    group: SweepGroup,
    *,
    mesh: Mesh | None = None,
    budget_bytes: int = 1 << 30,
    pipeline: bool = False,
) -> int | None:
    """A round_chunk that keeps one group's per-device block under ``budget``.

    Per-block intermediates per (strategy, round) row: the O(n) DP/score
    arrays (~(S + A) * chunk * n floats with ~8x temporary headroom) PLUS the
    allocator's pairwise-rank elimination, whose unrolled compares
    materialise O(A * chunk * n^2) floats for n <= ``_PAIRWISE_RANK_MAX_N``
    — the term that dominates as n grows, exactly the memory-constrained
    case this knob exists for.  Returns None when the whole run already fits.

    ``pipeline=True`` halves the budget: the async path keeps up to
    ``PIPELINE_DEPTH`` (= 2) block results live at once (the in-flight block
    plus the one being folded), so a chunk sized for the full budget would
    double peak memory under overlap.
    """
    from repro.core.lea import _PAIRWISE_RANK_MAX_N

    if pipeline:
        budget_bytes //= PIPELINE_DEPTH
    b = group.batch.rows
    if mesh is not None:
        b = math.ceil(b / mesh.devices.size)
    n = group.n_max
    s = len(group.strategies)
    a = len(throughput.allocator_strategies(group.strategies))
    per_round = 4 * b * (8 * (s + 2) * n)
    if n <= _PAIRWISE_RANK_MAX_N:
        per_round += 4 * b * (a * n * n)
    chunk = max(1, budget_bytes // max(per_round, 1))
    return None if chunk >= group.rounds else int(chunk)


def run(
    family_or_scenarios,
    *,
    seeds: int = 1,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    tap: bool = False,
    tap_stride: int | None = None,
    pipeline: bool = False,
    **params,
):
    """The one-liner: expand -> group -> execute -> summarize.

    ``family_or_scenarios`` is a registered family name (with ``**params``
    forwarded to its expansion) or an iterable of
    :class:`~repro.sweeps.registry.Scenario`.  Returns a list of
    :class:`~repro.sweeps.results.ScenarioResult` in scenario order.
    """
    from . import results as results_mod
    from .registry import build_groups, expand

    if isinstance(family_or_scenarios, str):
        scenarios = expand(family_or_scenarios, **params)
    else:
        if params:
            raise TypeError("family params only apply to a named family")
        scenarios = tuple(family_or_scenarios)
    groups = build_groups(scenarios, seeds=seeds)
    succs = run_groups(groups, mesh=mesh, round_chunk=round_chunk,
                       tap=tap, tap_stride=tap_stride, pipeline=pipeline)
    return results_mod.summarize(groups, succs, scenario_order=scenarios)


def _slice_group_rows(group: SweepGroup, process_id: int,
                      num_processes: int) -> SweepGroup:
    """The sub-group of rows ``r`` with ``r % num_processes == process_id``.

    Rows are vmapped independently by the engine, so computing a row subset
    yields the SAME bits per row as the full batch (the padding argument in
    the module docstring, applied to interleaved selection instead) — the
    merged multi-host result is bit-identical to single-host.  Interleaving
    (not contiguous split) balances seeds/scenarios across hosts.
    """
    import dataclasses as _dc

    batch = jax.tree.map(lambda x: x[process_id::num_processes], group.batch)
    rows = tuple(group.rows[process_id::num_processes])
    return _dc.replace(group, batch=batch, rows=rows)


def run_multihost(
    family_or_scenarios,
    *,
    spool_dir,
    seeds: int = 1,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    pipeline: bool = False,
    timeout_s: float = 600.0,
    **params,
):
    """:func:`run` over a ``jax.distributed`` grid: per-host row shards,
    host-0 merge.

    Every process expands the same deterministic scenario list and group
    composition, computes the interleaved row shard ``rows[pid::P]`` of
    every group ON ITS LOCAL DEVICES (same engine, same executor path —
    ``pipeline=`` selects the async loop per host), and publishes it to
    ``spool_dir`` via atomic renames
    (:func:`repro.sweeps.results.write_row_shard`).  Process 0 merges the
    shards back into full row order, summarizes, and returns the scenario
    results; every other process returns ``None``.

    World size comes from :func:`repro.launch.mesh.world`; at world=1 this
    IS :func:`run` (no spool, no merge — the degeneration the tests pin to
    bit-identical manifests).
    """
    from repro.launch import mesh as mesh_mod

    from . import results as results_mod
    from .registry import build_groups, expand

    pid, nprocs = mesh_mod.world()
    if nprocs == 1:
        return run(family_or_scenarios, seeds=seeds, mesh=mesh,
                   round_chunk=round_chunk, pipeline=pipeline, **params)

    if isinstance(family_or_scenarios, str):
        scenarios = expand(family_or_scenarios, **params)
    else:
        if params:
            raise TypeError("family params only apply to a named family")
        scenarios = tuple(family_or_scenarios)
    groups = build_groups(scenarios, seeds=seeds)
    for gi, group in enumerate(groups):
        sub = _slice_group_rows(group, pid, nprocs)
        if sub.batch.rows == 0:      # more hosts than rows: empty shard
            succ = np.zeros((0, group.rounds, len(group.strategies)), bool)
        else:
            succ = run_group(sub, mesh=mesh, round_chunk=round_chunk,
                             pipeline=pipeline)
        results_mod.write_row_shard(spool_dir, gi, pid, nprocs, succ)
    if pid != 0:
        return None
    succs = [
        results_mod.merge_row_shards(spool_dir, gi, nprocs, timeout_s=timeout_s)
        for gi in range(len(groups))
    ]
    return results_mod.summarize(groups, succs, scenario_order=scenarios)
