"""Sharded, grouped, chunked execution of scenario batches.

One :class:`~repro.sweeps.registry.SweepGroup` = one compiled computation:
:func:`_run_group` is the single jitted entry point, with only ``(rounds,
strategies, round_chunk)`` static.  Load parameters (K*, ell_g, ell_b) and
the worker-pool mask are TRACED batch leaves fed to the shape-polymorphic
engine (:func:`repro.core.throughput.simulate_strategies_pool`), so a
heterogeneous-K* grid, a deadline/load sweep or an elastic pool ramp is ONE
compile for the whole family regardless of how many scenarios and seeds it
spans (:func:`compile_cache_size` exposes the cache counter the tests
assert on).

Sharding: sweep rows are embarrassingly parallel, so the executor lays the
flat (scenarios x seeds) batch over the ``"batch"`` axis of a 1-D
``jax.sharding`` mesh (:func:`repro.launch.mesh.make_sweep_mesh`) by
device_put-ing every batch leaf with ``NamedSharding(mesh, P("batch"))`` —
the jitted computation then partitions itself over the data.  Batches are
padded (by repeating the last row) to a multiple of the mesh size; padded
rows are sliced off the result, so sharded output is bit-identical to the
unsharded :func:`repro.core.throughput.sweep` on the same keys.

Memory: ``round_chunk`` is forwarded to the engine's ``lax.map``-over-round-
blocks path so paper-scale M = 1e5 grids hold peak memory at one block.
"""

from __future__ import annotations

import math
import time
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import throughput
from repro.core.lea import PoolLoad
from repro.obs import counters as _obs_counters
from repro.obs import metrics as _metrics

from .registry import ScenarioBatch, SweepGroup


@partial(jax.jit,
         static_argnames=("rounds", "strategies", "round_chunk", "telemetry",
                          "tap", "tap_stride"))
def _run_group(
    keys: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: jnp.ndarray,
    mu_b: jnp.ndarray,
    deadline: jnp.ndarray,
    pool: PoolLoad,
    *,
    rounds: int,
    strategies: tuple[str, ...],
    round_chunk: int | None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """(B,) rows -> (B, rounds, S) success indicators, one XLA computation."""
    fn = partial(
        throughput.simulate_strategies_pool,
        rounds=rounds, strategies=strategies, round_chunk=round_chunk,
        telemetry=telemetry, tap=tap, tap_stride=tap_stride,
    )
    if tap:
        rows = jnp.arange(keys.shape[0], dtype=jnp.int32)
        return jax.vmap(
            lambda k, pg, pb, mg, mb, d, pl, ri: fn(
                k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d,
                tap_row=ri,
            )
        )(keys, p_gg, p_bb, mu_g, mu_b, deadline, pool, rows)
    return jax.vmap(
        lambda k, pg, pb, mg, mb, d, pl: fn(
            k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d
        )
    )(keys, p_gg, p_bb, mu_g, mu_b, deadline, pool)


_obs_counters.register_compiled("sweeps.run_group", _run_group)


def compile_cache_size() -> int:
    """Number of distinct group computations compiled so far.

    Thin alias over the unified obs counter
    (``obs.compile_events("sweeps.run_group")``) — kept for the pre-obs
    tests and benchmarks."""
    return _obs_counters.compile_events("sweeps.run_group")


def _pad_batch(batch: ScenarioBatch, multiple: int) -> tuple[ScenarioBatch, int]:
    """Pad rows to a multiple of the mesh size by repeating the last row.

    Rows are vmapped independently, so pad rows cannot perturb real rows;
    they are sliced off the result.
    """
    b = batch.rows
    pad = (-b) % multiple
    if pad == 0:
        return batch, b
    rep = jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]), batch
    )
    return rep, b


def _shard_batch(batch: ScenarioBatch, mesh: Mesh) -> ScenarioBatch:
    sh = NamedSharding(mesh, PartitionSpec("batch"))
    return jax.tree.map(lambda x: jax.device_put(x, sh), batch)


def run_group(
    group: SweepGroup,
    *,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """Execute one group; returns host (B, rounds, S) bool success array.

    With ``telemetry=True`` returns ``(succ, TelemetryFrame)`` — the frame
    leaves are host arrays with the same leading (B,) slicing as ``succ``
    (see :mod:`repro.obs.telemetry`); the group still compiles once.  With
    ``tap=True`` the engine streams per-row block aggregates to the
    registered tap handlers DURING the run (:mod:`repro.obs.taps`) — same
    bit-identity and one-compile contract.  Every call attributes its
    wall-clock (``phase.sweeps_run_group.seconds``) and any compile events
    it triggered (``compile.sweeps_run_group.*``) to the default metrics
    registry (:mod:`repro.obs.metrics`).
    """
    if group.rounds < 1:
        names = ", ".join(sc.name for sc in group.scenarios[:3])
        raise ValueError(
            f"group [{names}, ...] has rounds={group.rounds}; catalogue-only "
            "scenario families (e.g. kstar_table) cannot be simulated"
        )
    batch, b = (group.batch, group.batch.rows)
    if mesh is not None:
        if tuple(mesh.axis_names) != ("batch",):
            raise ValueError(f'sweep mesh must have axes ("batch",), got {mesh.axis_names}')
        batch, b = _pad_batch(batch, mesh.devices.size)
        batch = _shard_batch(batch, mesh)
    c0 = _obs_counters.compile_events("sweeps.run_group")
    t0 = time.perf_counter()
    with _metrics.timed("phase.sweeps_run_group"):
        out = _run_group(
            batch.keys, batch.p_gg, batch.p_bb, batch.mu_g, batch.mu_b,
            batch.deadline, batch.pool,
            rounds=group.rounds, strategies=group.strategies,
            round_chunk=round_chunk, telemetry=telemetry,
            tap=tap, tap_stride=tap_stride,
        )
        out = jax.block_until_ready(out)
    _metrics.record_compile(
        "sweeps.run_group",
        _obs_counters.compile_events("sweeps.run_group") - c0,
        time.perf_counter() - t0,
    )
    if not telemetry:
        return np.asarray(out[:b])
    succ, frame = out
    return np.asarray(succ[:b]), jax.tree.map(lambda x: np.asarray(x[:b]), frame)


def run_groups(
    groups: Sequence[SweepGroup],
    *,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    tap: bool = False,
    tap_stride: int | None = None,
) -> list[np.ndarray]:
    """Execute every group (one compile each); list aligned with ``groups``."""
    return [run_group(g, mesh=mesh, round_chunk=round_chunk,
                      tap=tap, tap_stride=tap_stride) for g in groups]


def suggest_round_chunk(
    group: SweepGroup,
    *,
    mesh: Mesh | None = None,
    budget_bytes: int = 1 << 30,
) -> int | None:
    """A round_chunk that keeps one group's per-device block under ``budget``.

    Per-block intermediates per (strategy, round) row: the O(n) DP/score
    arrays (~(S + A) * chunk * n floats with ~8x temporary headroom) PLUS the
    allocator's pairwise-rank elimination, whose unrolled compares
    materialise O(A * chunk * n^2) floats for n <= ``_PAIRWISE_RANK_MAX_N``
    — the term that dominates as n grows, exactly the memory-constrained
    case this knob exists for.  Returns None when the whole run already fits.
    """
    from repro.core.lea import _PAIRWISE_RANK_MAX_N

    b = group.batch.rows
    if mesh is not None:
        b = math.ceil(b / mesh.devices.size)
    n = group.n_max
    s = len(group.strategies)
    a = len(throughput.allocator_strategies(group.strategies))
    per_round = 4 * b * (8 * (s + 2) * n)
    if n <= _PAIRWISE_RANK_MAX_N:
        per_round += 4 * b * (a * n * n)
    chunk = max(1, budget_bytes // max(per_round, 1))
    return None if chunk >= group.rounds else int(chunk)


def run(
    family_or_scenarios,
    *,
    seeds: int = 1,
    mesh: Mesh | None = None,
    round_chunk: int | None = None,
    tap: bool = False,
    tap_stride: int | None = None,
    **params,
):
    """The one-liner: expand -> group -> execute -> summarize.

    ``family_or_scenarios`` is a registered family name (with ``**params``
    forwarded to its expansion) or an iterable of
    :class:`~repro.sweeps.registry.Scenario`.  Returns a list of
    :class:`~repro.sweeps.results.ScenarioResult` in scenario order.
    """
    from . import results as results_mod
    from .registry import build_groups, expand

    if isinstance(family_or_scenarios, str):
        scenarios = expand(family_or_scenarios, **params)
    else:
        if params:
            raise TypeError("family params only apply to a named family")
        scenarios = tuple(family_or_scenarios)
    groups = build_groups(scenarios, seeds=seeds)
    succs = run_groups(groups, mesh=mesh, round_chunk=round_chunk,
                       tap=tap, tap_stride=tap_stride)
    return results_mod.summarize(groups, succs, scenario_order=scenarios)
