"""Fixed-capacity, mask-padded, device-resident request queue.

The queue is a NamedTuple pytree of (Q,) arrays — per-slot traced load
parameters, absolute deadline, arrival round and a validity (``occupied``)
mask — and every operation (admit, EDF ordering, slot recycling) is a pure
``jnp``/``lax`` update, so the whole serving loop stays inside one compiled
``lax.scan`` (:mod:`repro.serving.engine`).  The conventions mirror the
PR-5 mask-padded pools: a free slot is padding — it demands nothing,
receives nothing, and its parameter entries are ignored.

Ordering is EDF with FIFO tie-breaks: earliest absolute deadline first,
ties by arrival round, remaining ties by slot index (two stable argsorts —
``jnp.argsort`` is always stable).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# sort key for empty slots: past any reachable deadline / arrival round
_EMPTY_SLOT_KEY = jnp.int32(2**30)


class RequestSpec(NamedTuple):
    """Per-round request parameters (traced; scalars broadcast over rounds).

    Every arrival in round t enters the queue with round t's row of these:

      * ``kstar`` / ``ell_g`` / ``ell_b`` — the request's own recovery
        threshold and two-level loads (a queue slot is a PR-5 row);
      * ``deadline_rel``     — lifetime in rounds: a request arriving in
        round t is on time iff it completes by round t + deadline_rel;
      * ``admit_threshold``  — admission control: admit only when the
        policy's predicted best-prefix success probability for this spec
        on the full pool is at least this (0.0 = no prediction gate);
      * ``reserve_cap``      — admission control: admit only while the
        summed minimal worker demand of the queue (incl. the newcomer)
        stays within ``reserve_cap * n_valid`` workers (huge = no
        capacity gate).  :data:`ADMIT_ALL` disables both gates.
    """

    kstar: jnp.ndarray
    ell_g: jnp.ndarray
    ell_b: jnp.ndarray
    deadline_rel: jnp.ndarray = 0
    admit_threshold: jnp.ndarray = 0.0
    reserve_cap: jnp.ndarray = 2.0**20


# reserve_cap value that disables the capacity gate for any reachable pool
ADMIT_ALL_CAP = 2.0**20


class RequestQueue(NamedTuple):
    """One round's queue state: (Q,) per-slot arrays, ``occupied`` the mask."""

    occupied: jnp.ndarray      # (Q,) bool — True = live request
    kstar: jnp.ndarray         # (Q,) int32
    ell_g: jnp.ndarray         # (Q,) int32
    ell_b: jnp.ndarray         # (Q,) int32
    deadline_abs: jnp.ndarray  # (Q,) int32 — last on-time completion round
    arrival: jnp.ndarray       # (Q,) int32 — admission round

    @property
    def capacity(self) -> int:
        """The static queue capacity Q (it is a shape)."""
        return self.occupied.shape[-1]


def empty_queue(capacity: int) -> RequestQueue:
    """An all-free queue of ``capacity`` slots."""
    z = jnp.zeros((capacity,), jnp.int32)
    return RequestQueue(
        occupied=jnp.zeros((capacity,), bool),
        kstar=z, ell_g=z, ell_b=z, deadline_abs=z, arrival=z,
    )


def admit(
    queue: RequestQueue,
    t,
    count,
    kstar,
    ell_g,
    ell_b,
    deadline_rel,
) -> tuple[RequestQueue, jnp.ndarray]:
    """Admit up to ``count`` copies of round t's request spec.

    Newcomers fill the lowest-index free slots (slot index never encodes
    priority — ordering is :func:`edf_order`'s job), each stamped with
    ``deadline_abs = t + deadline_rel`` and ``arrival = t``.  Returns the
    updated queue and the number actually admitted (``min(count,
    free slots)``); the caller accounts the remainder as rejected.
    """
    free = ~queue.occupied
    n_admit = jnp.minimum(
        jnp.asarray(count, jnp.int32), jnp.sum(free.astype(jnp.int32))
    )
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1     # rank among free
    take = free & (free_rank < n_admit)
    as_i32 = lambda v: jnp.asarray(v, jnp.int32)
    return RequestQueue(
        occupied=queue.occupied | take,
        kstar=jnp.where(take, as_i32(kstar), queue.kstar),
        ell_g=jnp.where(take, as_i32(ell_g), queue.ell_g),
        ell_b=jnp.where(take, as_i32(ell_b), queue.ell_b),
        deadline_abs=jnp.where(
            take, as_i32(t) + as_i32(deadline_rel), queue.deadline_abs
        ),
        arrival=jnp.where(take, as_i32(t), queue.arrival),
    ), n_admit


def edf_order(queue: RequestQueue) -> jnp.ndarray:
    """(Q,) slot indices, most urgent first (EDF, FIFO + slot tie-breaks).

    Free slots sort last.  Two stable argsorts compose a lexicographic
    (deadline_abs, arrival, slot index) order without wide integer keys.
    """
    arr = jnp.where(queue.occupied, queue.arrival, _EMPTY_SLOT_KEY)
    dl = jnp.where(queue.occupied, queue.deadline_abs, _EMPTY_SLOT_KEY)
    by_arrival = jnp.argsort(arr)                          # FIFO, idx ties
    by_deadline = jnp.argsort(jnp.take(dl, by_arrival))    # stable: keeps FIFO
    return jnp.take(by_arrival, by_deadline)


def release(queue: RequestQueue, done: jnp.ndarray) -> RequestQueue:
    """Recycle ``done`` (Q,) slots: freed in place, parameters left stale
    (a free slot's entries are padding by convention and never read)."""
    return queue._replace(occupied=queue.occupied & ~done)
