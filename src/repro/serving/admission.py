"""Admission control: shed load the service would miss anyway.

Two traced gates, both riding in :class:`repro.serving.queue.RequestSpec`
so an admit-all run and a controlled run share ONE compiled computation:

  * the PREDICTION gate — :func:`predicted_success` evaluates the policy's
    p_good row through the same best-prefix Poisson-binomial machinery the
    allocator uses (``success_prob_all_prefixes`` over the full pool), and
    a request is admitted only when that predicted feasibility clears
    ``admit_threshold``;
  * the CAPACITY gate — :func:`admission_room` bounds how many newcomers
    fit before the queue's summed minimal worker demand (each slot's
    ``ceil(kstar / ell_g)``) exceeds ``reserve_cap * n_valid`` workers,
    so doomed requests never steal the minimal segments that feasible
    ones need (the EDF water-filling hands every active slot its minimal
    demand first — see :func:`repro.core.lea.allocate_queue`).

Both gates are precomputable outside the serving scan (the prediction
gate) or one cheap reduction inside it (the capacity gate); neither
branches, so admit-all (threshold 0, cap huge) pays nothing.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import lea as lea_mod


def predicted_success(
    p_alloc: jnp.ndarray,
    pool_mask: jnp.ndarray,
    kstar,
    ell_g,
    ell_b,
    *,
    impl: str | None = None,
) -> jnp.ndarray:
    """Best-prefix predicted success probability of a fresh request.

    ``p_alloc`` is (..., n) predicted p_good (any leading batch axes — the
    engine passes (A, M, n) policy rows); ``pool_mask`` (n,) bool; the
    request's ``kstar``/``ell_g``/``ell_b`` broadcast against the leading
    axes.  Returns (...,) = max over prefixes of the Poisson-binomial
    success probability on the FULL pool — i.e. the probability the
    allocator's own objective assigns to the request if it were granted
    the whole pool, ONE batched DP for every (policy, round) row.
    """
    n = p_alloc.shape[-1]
    mask = jnp.broadcast_to(pool_mask, p_alloc.shape)
    # demote padding exactly like allocate_masked, sort, pad the DP with
    # identity Bernoullis past the valid pool
    p_eff = jnp.where(mask, p_alloc, -1.0)
    if n <= lea_mod._PAIRWISE_RANK_MAX_N:
        ranks = lea_mod._ranks_descending(p_eff)
        p_sorted = lea_mod._take_by_rank(p_eff, ranks)
    else:
        p_sorted = jnp.take_along_axis(
            p_eff, jnp.argsort(-p_eff, axis=-1), axis=-1
        )
    n_valid = jnp.sum(mask.astype(jnp.int32), axis=-1)
    pos = jnp.arange(n)
    p_dp = jnp.where(pos < n_valid[..., None], p_sorted, 0.0)
    w = lea_mod.prefix_thresholds_traced(kstar, ell_g, ell_b, n_valid, n)
    from repro.kernels.poisson_binomial import success_tails

    probs = success_tails(p_dp, jnp.broadcast_to(w, p_dp.shape), impl=impl)
    return jnp.max(probs, axis=-1)


def minimal_demand(occupied, kstar, ell_g) -> jnp.ndarray:
    """Summed minimal worker demand of the occupied slots: sum of
    ``ceil(kstar / ell_g)`` (exact int32 ceil-div, 0 for free slots)."""
    occupied = jnp.asarray(occupied)
    ks = jnp.asarray(kstar, jnp.int32)
    eg = jnp.maximum(jnp.asarray(ell_g, jnp.int32), 1)
    return jnp.sum(jnp.where(occupied, -((-ks) // eg), 0), axis=-1)


def admission_room(
    m_active: jnp.ndarray,
    m_new: jnp.ndarray,
    n_valid: jnp.ndarray,
    reserve_cap: jnp.ndarray,
) -> jnp.ndarray:
    """How many newcomers (minimal demand ``m_new`` each) the capacity gate
    admits on top of ``m_active`` already-reserved workers.

    The worker budget is ``floor(reserve_cap * n_valid)``, clipped so a
    disabled gate (``reserve_cap`` huge) never overflows int32.
    """
    budget = jnp.clip(
        jnp.asarray(reserve_cap, jnp.float32) * n_valid, 0.0, 2.0**30
    ).astype(jnp.int32)
    return jnp.maximum(budget - m_active, 0) // jnp.maximum(
        jnp.asarray(m_new, jnp.int32), 1
    )
