"""Registered arrival processes: batched device-resident request streams.

An arrival process turns one PRNG key into a ``(rounds,) int32`` vector of
per-round request counts.  Every process is a NamedTuple pytree — TRACED
array parameters, static structure — with a ``sample(key, rounds)`` method
that is a pure function of its key, mirroring the ``repro.faults`` injector
convention:

  * vmapping the serving engine over a batch of processes with the SAME
    structure but different (traced) rates fuses a whole arrival-rate grid
    into one compiled computation (the ``repro.sweeps`` convention);
  * the arrival stream is keyed off :func:`arrival_key` — a dedicated
    ``fold_in`` tag on the simulation key — so arrival randomness never
    perturbs the trajectory / round-draw / policy streams the offline
    engine derives from the same key.  A zero-arrival serving run is
    therefore bit-identical to the idle engine (property-tested in
    tests/serving/).

Built-ins:

  ``constant``   — exactly ``per_round`` requests every round (consumes no
                   randomness; the degenerate one-job-per-round stream).
  ``poisson``    — iid Poisson(rate) counts per round.
  ``shift_exp``  — shift-exponential inter-arrival gaps, the paper's
                   Sec. 6.2 request model: gap = t_const + Exp(mean) in
                   round units, event times binned into rounds.
  ``mmpp``       — Markov-modulated Poisson (bursty): a 2-state calm/burst
                   chain modulates the per-round Poisson rate.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.markov import sample_trajectory_from

# fold_in tag separating the arrival-process PRNG stream from the engine's
# trajectory / round-key / policy / fault streams (cf. faults._FAULT_KEY_TAG)
_ARRIVAL_KEY_TAG = 0x5BD1E995 % (2**31)

# shift_exp materialises at most this many events per simulated round; a
# stream denser than this (mean gap << 1/density rounds) is truncated
_SHIFT_EXP_DENSITY = 8


def arrival_key(key: jax.Array) -> jax.Array:
    """The arrival-stream root for a simulation key.

    Derived by ``fold_in`` with a dedicated tag so request arrivals never
    collide with the trajectory, round-draw, policy or fault streams split
    from the same simulation key.
    """
    return jax.random.fold_in(key, _ARRIVAL_KEY_TAG)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_PROCESSES: dict[str, type] = {}


def register_process(name: str):
    """Decorator: register an arrival-process class under ``name``."""

    def deco(cls):
        if name in _PROCESSES:
            raise ValueError(f"arrival process {name!r} already registered")
        _PROCESSES[name] = cls
        cls.process_name = name
        return cls

    return deco


def process_names() -> tuple[str, ...]:
    return tuple(sorted(_PROCESSES))


def make_process(name: str, **params):
    """Build a registered arrival process from keyword parameters."""
    if name not in _PROCESSES:
        raise KeyError(
            f"unknown arrival process {name!r}; available: "
            f"{', '.join(process_names())}"
        )
    return _PROCESSES[name](**params)


def sample_arrivals(key: jax.Array, process, rounds: int) -> jnp.ndarray:
    """(rounds,) int32 per-round request counts on the dedicated stream.

    ``key`` is the SIMULATION key — the dedicated :func:`arrival_key`
    stream is derived here, so callers never thread a separate key.
    """
    return process.sample(arrival_key(key), rounds).astype(jnp.int32)


# ---------------------------------------------------------------------------
# built-in processes
# ---------------------------------------------------------------------------


@register_process("constant")
class Constant(NamedTuple):
    """Exactly ``per_round`` requests every round (no randomness consumed).

    ``per_round = 1`` is the degenerate stream that reduces the serving
    engine to the offline single-job engine; ``per_round = 0`` is the idle
    stream of the zero-arrival bit-identity property.
    """

    per_round: jnp.ndarray = 1

    def sample(self, key: jax.Array, rounds: int) -> jnp.ndarray:
        del key
        return jnp.broadcast_to(
            jnp.asarray(self.per_round, jnp.int32), (rounds,)
        )


@register_process("poisson")
class Poisson(NamedTuple):
    """iid Poisson(rate) request counts per round."""

    rate: jnp.ndarray

    def sample(self, key: jax.Array, rounds: int) -> jnp.ndarray:
        lam = jnp.asarray(self.rate, jnp.float32)
        return jax.random.poisson(key, lam, (rounds,)).astype(jnp.int32)


@register_process("shift_exp")
class ShiftExp(NamedTuple):
    """Shift-exponential inter-arrival gaps (paper Sec. 6.2's model).

    Successive gaps are ``t_const + Exp(mean)`` in ROUND units; the event
    times (their running sum) are binned into rounds.  A static budget of
    ``_SHIFT_EXP_DENSITY * rounds`` events is materialised — streams denser
    than that (mean rate above ~8 requests/round) are truncated, which is
    far past any serviceable load for the pools this repo simulates.
    """

    t_const: jnp.ndarray = 0.0
    mean: jnp.ndarray = 1.0

    def sample(self, key: jax.Array, rounds: int) -> jnp.ndarray:
        max_events = _SHIFT_EXP_DENSITY * rounds
        t_c = jnp.asarray(self.t_const, jnp.float32)
        mean = jnp.asarray(self.mean, jnp.float32)
        gaps = t_c + mean * jax.random.exponential(key, (max_events,))
        times = jnp.cumsum(gaps)
        idx = jnp.floor(times).astype(jnp.int32)
        valid = idx < rounds
        counts = jnp.zeros((rounds,), jnp.int32)
        return counts.at[jnp.clip(idx, 0, rounds - 1)].add(
            valid.astype(jnp.int32)
        )


@register_process("mmpp")
class MMPP(NamedTuple):
    """Markov-modulated Poisson process: bursty arrivals.

    A 2-state calm/burst chain (starting calm) modulates the per-round
    Poisson rate between ``rate_lo`` and ``rate_hi`` — the bursty-traffic
    regime where admission control earns its keep.  ``p_stay_lo`` /
    ``p_stay_hi`` are the chain's self-transition probabilities.
    """

    rate_lo: jnp.ndarray
    rate_hi: jnp.ndarray
    p_stay_lo: jnp.ndarray = 0.9
    p_stay_hi: jnp.ndarray = 0.7

    def sample(self, key: jax.Array, rounds: int) -> jnp.ndarray:
        k_chain, k_counts = jax.random.split(key)
        # reuse the worker-chain sampler with n=1: state 1 = calm
        calm = sample_trajectory_from(
            k_chain,
            jnp.asarray(self.p_stay_lo, jnp.float32),
            jnp.asarray(self.p_stay_hi, jnp.float32),
            rounds,
            jnp.ones((1,), jnp.int32),
        )[:, 0]                                            # (rounds,)
        lam = jnp.where(
            calm == 1,
            jnp.asarray(self.rate_lo, jnp.float32),
            jnp.asarray(self.rate_hi, jnp.float32),
        )
        return jax.random.poisson(k_counts, lam).astype(jnp.int32)
