"""The streaming serving engine: one compiled ``lax.scan`` over rounds.

Turns the offline sweep engine into an online service simulator: a
continuous arrival process feeds a fixed-capacity device-resident request
queue; every round the worker pool is split across the active queue slots
by greedy EDF water-filling (:func:`repro.core.lea.allocate_queue`), slots
are scored with the engine's on-time rule, and completed / expired
requests leave with full accounting (:class:`ServingOutcomes`).

Engine discipline (all inherited, none re-invented):

  * PRNG — the preamble is :func:`repro.core.throughput.serve_rollout`:
    the same ``split(key)``, masked trajectory and policy-stream
    ``fold_in`` as the offline engine, with arrivals on their own
    :func:`repro.serving.arrivals.arrival_key` stream and faults on
    :func:`repro.faults.channels.fault_key` — so a single-slot queue fed
    one always-admitted request per round with ``deadline_rel = 0``
    reproduces :func:`~repro.core.throughput.simulate_strategies_pool`
    BIT-IDENTICALLY on the same key, and a zero-arrival run leaves every
    engine stream untouched (both property-tested);
  * scoring — ``loads/speed <= t_cut + 1e-9`` per slot, the engine rule
    verbatim; ``t_cut`` is the deadline unless a ``repro.faults`` channel
    degrades it (time-axis injectors only: ``crash_restart``/``preempt``;
    packet-axis injectors are REJECTED loudly, never silently ignored);
  * accounting — every request ends in exactly one disposition:

        arrivals == admitted + rejected
        admitted == served_on_time + served_late + expired + in_flight

    (the never-silently-drop convention; asserted in tests/serving/).

Round order inside the scan body: (1) admit this round's arrivals (they
may be served the same round, like the offline engine's one-round jobs);
(2) allocate over active slots in EDF order; (3) score; (4) retire —
completions by ``deadline_abs`` are on time, completions within ``grace``
extra rounds are late, uncompleted requests past ``deadline_abs + grace``
expire; freed slots are recycled immediately.

:func:`sweep_serving` vmaps the whole thing over (B,) rows — keys, chains,
request specs, arrival-process and channel parameters are all traced — so
an arrival-rate x deadline grid (the ``arrival_grid`` family), admit-all
AND admission-controlled variants included, compiles ONCE per static
``(rounds, strategies, capacity, grace)`` signature
(:func:`serving_compile_cache_size` is the counter the tests and
``benchmarks/bench_serving.py`` assert on).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lea as lea_mod
from repro.core import throughput
from repro.obs import counters as _obs_counters
from repro.obs.profiling import phase as _phase
from repro.obs.telemetry import ServingTelemetry

from . import admission
from . import arrivals as arrivals_mod
from . import queue as rqueue

# event codes emitted per (round, slot)
EVENT_NONE, EVENT_ON_TIME, EVENT_LATE, EVENT_EXPIRED = 0, 1, 2, 3

# fault injectors that act on the time axis (t_cut) — the only ones the
# serving scorer consumes; packet-axis injectors would be silently inert
_TIME_INJECTORS = frozenset({"crash_restart", "preempt"})


class ServingOutcomes(NamedTuple):
    """Per-strategy serving accounting over one simulation.

    Counters are (S,) int32 (leading batch axes under :func:`sweep_serving`);
    ``events`` / ``sojourn`` are (S, rounds, Q) per-slot streams: the event
    code (EVENT_*) of any request LEAVING that slot that round, and its
    sojourn time ``t - arrival + 1`` in rounds (0 where no event) — the raw
    material for latency percentiles.

    Conservation (every request in exactly one disposition):
    ``arrivals == admitted + rejected`` and
    ``admitted == served_on_time + served_late + expired + in_flight``.
    """

    arrivals: jnp.ndarray
    admitted: jnp.ndarray
    served_on_time: jnp.ndarray
    served_late: jnp.ndarray
    rejected: jnp.ndarray
    expired: jnp.ndarray
    in_flight: jnp.ndarray
    events: jnp.ndarray
    sojourn: jnp.ndarray


class _Counters(NamedTuple):
    admitted: jnp.ndarray
    served_on_time: jnp.ndarray
    served_late: jnp.ndarray
    rejected: jnp.ndarray
    expired: jnp.ndarray


def _check_channel(channel) -> None:
    for inj in channel:
        name = getattr(type(inj), "injector_name", type(inj).__name__)
        if name not in _TIME_INJECTORS:
            raise ValueError(
                f"serving consumes the time axis (t_cut) of a fault trace "
                f"only; injector {name!r} acts on the packet axis and would "
                f"be silently ignored — use one of "
                f"{sorted(_TIME_INJECTORS)} or score packets through "
                f"repro.faults.engine instead"
            )


def _ceil_div(num, den):
    return -((-jnp.asarray(num, jnp.int32)) // jnp.maximum(
        jnp.asarray(den, jnp.int32), 1
    ))


def _simulate_serving_impl(
    key, pool_mask, p_gg, p_bb, mu_g, mu_b, deadline, spec, process, channel,
    rounds, strategies, capacity, grace, telemetry=False,
    tap=False, tap_stride=None, tap_row=None,
):
    states, p_alloc = throughput.serve_rollout(
        key, pool_mask, p_gg, p_bb, rounds, strategies
    )                                             # (M, n), (A, M, n)
    n = states.shape[-1]

    # -- per-round request spec rows (traced; scalars broadcast)
    as_rounds = lambda v, dt: jnp.broadcast_to(jnp.asarray(v, dt), (rounds,))
    ks_m = as_rounds(spec.kstar, jnp.int32)
    eg_m = as_rounds(spec.ell_g, jnp.int32)
    eb_m = as_rounds(spec.ell_b, jnp.int32)
    dl_m = as_rounds(spec.deadline_rel, jnp.int32)
    thr_m = as_rounds(spec.admit_threshold, jnp.float32)
    cap_m = as_rounds(spec.reserve_cap, jnp.float32)

    # -- arrival stream (dedicated key tag; never perturbs engine streams)
    counts = arrivals_mod.sample_arrivals(key, process, rounds)    # (M,)

    # -- compute-cutoff times: the deadline, optionally degraded by a
    #    time-axis fault channel on the dedicated fault stream
    _check_channel(channel)
    if len(channel):
        from repro.faults.channels import apply_channel, base_trace, fault_key

        trace = base_trace(rounds, n, 1, 1, deadline)
        t_cut = apply_channel(fault_key(key), channel, trace).t_cut
    else:
        t_cut = jnp.full((rounds, n), deadline, jnp.float32)       # (M, n)

    # -- admission prediction gate, ONE batched DP over (A, M) rows
    p_succ = admission.predicted_success(
        p_alloc, pool_mask, ks_m, eg_m, eb_m
    )                                             # (A, M)

    n_valid = jnp.sum(pool_mask.astype(jnp.int32))
    t_idx = jnp.arange(rounds, dtype=jnp.int32)

    def body(carry, xs):
        q, cnt = carry
        (t, states_t, p_t, p_succ_t, count_t, ks_t, eg_t, eb_t, dl_t,
         thr_t, cap_t, tcut_t) = xs
        # (1) admission: prediction gate x capacity gate x free slots
        m_active = admission.minimal_demand(q.occupied, q.kstar, q.ell_g)
        room = admission.admission_room(
            m_active, _ceil_div(ks_t, eg_t), n_valid, cap_t
        )
        want = jnp.where(
            p_succ_t >= thr_t, jnp.minimum(count_t, room), 0
        )
        q, n_admit = rqueue.admit(q, t, want, ks_t, eg_t, eb_t, dl_t)
        # (2) multi-job allocation: greedy EDF water-filling
        with _phase("allocate"):
            loads, _i_star, feas = lea_mod.allocate_queue(
                p_t, pool_mask, q.occupied, q.kstar, q.ell_g, q.ell_b,
                rqueue.edf_order(q),
            )                                     # (Q, n), (Q,), (Q,)
        # (3) score: the engine's on-time rule, per slot
        with _phase("score"):
            speeds = jnp.where(states_t == 1, mu_g, mu_b)          # (n,)
            on_time = loads.astype(jnp.float32) / speeds <= tcut_t + 1e-9
            received = jnp.sum(jnp.where(on_time, loads, 0), axis=-1)  # (Q,)
        complete = q.occupied & feas & (received >= q.kstar)
        # (4) disposition
        done_on_time = complete & (t <= q.deadline_abs)
        done_late = complete & (t > q.deadline_abs)
        overdue = q.occupied & ~complete & (t >= q.deadline_abs + grace)
        leave = complete | overdue
        event_t = (
            jnp.where(done_on_time, EVENT_ON_TIME, 0)
            + jnp.where(done_late, EVENT_LATE, 0)
            + jnp.where(overdue, EVENT_EXPIRED, 0)
        ).astype(jnp.int32)
        sojourn_t = jnp.where(leave, t - q.arrival + 1, 0)
        q = rqueue.release(q, leave)
        count_i = lambda m: jnp.sum(m.astype(jnp.int32))
        cnt = _Counters(
            admitted=cnt.admitted + n_admit,
            served_on_time=cnt.served_on_time + count_i(done_on_time),
            served_late=cnt.served_late + count_i(done_late),
            rejected=cnt.rejected + (count_t - n_admit),
            expired=cnt.expired + count_i(overdue),
        )
        if not telemetry:
            return (q, cnt), (event_t, sojourn_t)
        # extra per-round scan outputs: queue occupancy after departures
        # and the round's admission decisions (same traced values, so the
        # primary streams above are untouched)
        occ_t = jnp.sum(q.occupied.astype(jnp.int32))
        return (q, cnt), (event_t, sojourn_t, occ_t, n_admit,
                          count_t - n_admit)

    def run_one(p_a, p_succ_a, strat_i):
        zero = jnp.int32(0)
        carry0 = (
            rqueue.empty_queue(capacity),
            _Counters(zero, zero, zero, zero, zero),
        )
        xs = (t_idx, states, p_a, p_succ_a, counts, ks_m, eg_m, eb_m,
              dl_m, thr_m, cap_m, t_cut)
        if not tap:
            (q_f, cnt), ys = jax.lax.scan(body, carry0, xs=xs)
            return cnt, jnp.sum(q_f.occupied.astype(jnp.int32)), ys
        # tap=True: the ONE scan becomes a trace-time chain of per-block
        # scans of the SAME body over a partition of the same xs — the
        # carry threads through unchanged and the ys concatenate, so every
        # output is bit-identical — with a block-aggregate emit between
        # segments.  (io_callback cannot be cond-gated here: the body runs
        # under vmap — over strategies and sweep rows — and jax rejects IO
        # effects in vmap-of-cond; segmenting needs no cond at all.)
        from repro.obs import taps as _taps

        stride = _taps.resolve_stride(rounds, tap_stride)
        row = (jnp.int32(-1) if tap_row is None
               else jnp.asarray(tap_row, jnp.int32))
        carry, token, ys_blocks, done = carry0, None, [], 0
        for bi, bound in enumerate(_taps.stride_boundaries(rounds, stride)):
            xs_b = jax.tree.map(lambda x: x[done:bound], xs)
            carry, ys_b = jax.lax.scan(body, carry, xs=xs_b)
            ys_blocks.append(ys_b)
            q_b, cnt_b = carry
            token = _taps.emit(
                "serving", token=token,
                block=jnp.int32(bi), row=row,
                strategy=jnp.asarray(strat_i, jnp.int32),
                rounds_done=jnp.int32(bound),
                admitted_so_far=cnt_b.admitted,
                served_on_time_so_far=cnt_b.served_on_time,
                served_late_so_far=cnt_b.served_late,
                rejected_so_far=cnt_b.rejected,
                expired_so_far=cnt_b.expired,
                occupancy=jnp.sum(q_b.occupied.astype(jnp.int32)),
            )
            done = bound
        q_f, cnt = carry
        ys = jax.tree.map(
            lambda *bs: jnp.concatenate(bs, axis=0), *ys_blocks
        )
        return cnt, jnp.sum(q_f.occupied.astype(jnp.int32)), ys

    strat_idx = jnp.arange(len(strategies), dtype=jnp.int32)
    cnt, in_flight, ys = jax.vmap(run_one)(p_alloc, p_succ, strat_idx)
    events, sojourn = ys[0], ys[1]
    n_strat = len(strategies)
    outcomes = ServingOutcomes(
        arrivals=jnp.broadcast_to(jnp.sum(counts), (n_strat,)),
        admitted=cnt.admitted,
        served_on_time=cnt.served_on_time,
        served_late=cnt.served_late,
        rejected=cnt.rejected,
        expired=cnt.expired,
        in_flight=in_flight,
        events=events,
        sojourn=sojourn,
    )
    if not telemetry:
        return outcomes
    occ, admit_t, rej_t = ys[2], ys[3], ys[4]
    return outcomes, ServingTelemetry(
        arrivals_t=counts,
        occupancy=occ,
        admitted_t=admit_t,
        rejected_t=rej_t,
    )


@partial(jax.jit, static_argnames=("rounds", "strategies", "capacity",
                                   "grace", "telemetry", "tap", "tap_stride"))
def simulate_serving(
    key: jax.Array,
    pool_mask: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    spec: rqueue.RequestSpec,
    process,
    *,
    rounds: int,
    strategies: tuple[str, ...] = ("lea",),
    capacity: int = 4,
    grace: int = 0,
    channel: tuple = (),
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """One serving simulation (see module docstring).

    ``pool_mask`` (n,) bool marks real workers; ``spec`` is a
    :class:`~repro.serving.queue.RequestSpec` of traced scalars or
    (rounds,) rows; ``process`` a registered arrival process
    (:mod:`repro.serving.arrivals`); ``strategies`` unique policy names
    (static draws are rejected — serving allocates from predictions);
    ``channel`` an optional time-axis ``repro.faults`` channel.
    ``capacity`` (queue slots) and ``grace`` (late-completion window in
    rounds) are static.

    ``telemetry`` (static): True returns ``(ServingOutcomes,
    ServingTelemetry)`` — per-round arrivals, queue occupancy and
    admission decisions out of the same compiled scan; False (default) is
    the pre-existing path, bit-identical.

    ``tap`` (static): True streams per-(strategy) block aggregates —
    admissions, served-on-time/late, rejections, expiries, occupancy so
    far — to the host every ``tap_stride`` rounds WHILE the scan runs
    (:mod:`repro.obs.taps`): the round scan is segmented at trace time
    into equivalent per-block scans with an ``io_callback`` emit between
    segments, so outputs stay bit-identical and ``tap=False`` traces zero
    callbacks (one compile per static signature either way).
    """
    return _simulate_serving_impl(
        key, pool_mask, p_gg, p_bb, mu_g, mu_b, deadline, spec, process,
        channel, rounds, tuple(strategies), capacity, grace, telemetry,
        tap, tap_stride,
    )


@partial(jax.jit, static_argnames=("rounds", "strategies", "capacity",
                                   "grace", "telemetry", "tap", "tap_stride"))
def _run_serving_group(
    keys, pool_mask, p_gg, p_bb, mu_g, mu_b, deadline, spec, process, channel,
    *, rounds, strategies, capacity, grace, telemetry=False,
    tap=False, tap_stride=None,
):
    """(B,) rows -> ServingOutcomes of (B, S, ...) leaves, ONE computation."""
    fn = lambda k, m, pg, pb, mg, mb, d, sp, pr, ri: _simulate_serving_impl(
        k, m, pg, pb, mg, mb, d, sp, pr, channel,
        rounds, strategies, capacity, grace, telemetry, tap, tap_stride, ri,
    )
    if tap:
        rows = jnp.arange(keys.shape[0], dtype=jnp.int32)
        return jax.vmap(fn)(keys, pool_mask, p_gg, p_bb, mu_g, mu_b,
                            deadline, spec, process, rows)
    return jax.vmap(
        lambda k, m, pg, pb, mg, mb, d, sp, pr: fn(
            k, m, pg, pb, mg, mb, d, sp, pr, None
        )
    )(keys, pool_mask, p_gg, p_bb, mu_g, mu_b, deadline, spec, process)


_obs_counters.register_compiled("serving.sweep", _run_serving_group)
_obs_counters.register_compiled("serving.simulate", simulate_serving)


def serving_compile_cache_size() -> int:
    """Distinct serving-group computations compiled so far.

    Thin alias over the unified obs counter
    (``obs.compile_events("serving.sweep")``) — kept for the pre-obs tests
    and benchmarks."""
    return _obs_counters.compile_events("serving.sweep")


def sweep_serving(
    keys: jnp.ndarray,
    pool_mask: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    spec: rqueue.RequestSpec,
    process,
    *,
    rounds: int,
    strategies: tuple[str, ...] = ("lea",),
    capacity: int = 4,
    grace: int = 0,
    channel: tuple = (),
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """Batched :func:`simulate_serving`: every leaf carries a leading (B,).

    ``spec`` leaves and ``process`` parameters are (B,) traced rows (scalars
    broadcast), so a whole arrival-rate x deadline x admission grid fuses
    into ONE compile per static (rounds, strategies, capacity, grace)
    signature.  The fault ``channel`` (if any) is shared across rows with
    scalar parameters (per-row channel grids belong to
    :func:`repro.faults.engine.sweep_faults`).  ``telemetry=True`` returns
    ``(ServingOutcomes, ServingTelemetry)`` with a leading (B,) on every
    telemetry leaf — still ONE compile for the whole grid.  ``tap=True``
    streams per-(row, strategy) block aggregates mid-scan (see
    :func:`simulate_serving`) — same one-compile contract, outputs
    bit-identical.
    """
    strategies = tuple(strategies)
    b = p_gg.shape[0]
    as_b = lambda x, dt: jnp.broadcast_to(jnp.asarray(x, dt), (b,))
    spec = rqueue.RequestSpec(
        kstar=as_b(spec.kstar, jnp.int32),
        ell_g=as_b(spec.ell_g, jnp.int32),
        ell_b=as_b(spec.ell_b, jnp.int32),
        deadline_rel=as_b(spec.deadline_rel, jnp.int32),
        admit_threshold=as_b(spec.admit_threshold, jnp.float32),
        reserve_cap=as_b(spec.reserve_cap, jnp.float32),
    )
    process = jax.tree.map(lambda x: as_b(x, jnp.float32), process)
    return _run_serving_group(
        keys, pool_mask, p_gg, p_bb,
        as_b(mu_g, jnp.float32), as_b(mu_b, jnp.float32),
        as_b(deadline, jnp.float32), spec, process, channel,
        rounds=rounds, strategies=strategies, capacity=capacity, grace=grace,
        telemetry=telemetry, tap=tap, tap_stride=tap_stride,
    )
