"""repro.serving — streaming coded-serving over the batched engine.

The paper maximizes timely throughput for a SINGLE job on a fixed grid of
rounds; this package turns that offline engine into an online service
simulator: a continuous arrival process of requests — each with its own
recovery threshold, loads and deadline — competes for one worker pool
(cf. *Stream Distributed Coded Computing*, arXiv 2103.01921, and the
load-adaptive redundancy of *Slack Squeeze Coded Computing*, arXiv
1904.07098):

  * :mod:`~repro.serving.arrivals`  — registered arrival processes
    (Poisson, shift-exponential — the paper Sec. 6.2 model, MMPP bursts,
    constant) sampled as batched device-resident count streams on a
    dedicated PRNG tag;
  * :mod:`~repro.serving.queue`     — a fixed-capacity mask-padded
    :class:`RequestQueue` pytree with EDF/FIFO ordering and slot
    recycling as pure ``lax`` updates;
  * :mod:`~repro.serving.admission` — predicted-feasibility and
    capacity-reservation admission gates (both traced, so admit-all and
    controlled runs share one compile);
  * :mod:`~repro.serving.engine`    — the compiled ``lax.scan`` serving
    loop: multi-job EDF water-filling allocation
    (:func:`repro.core.lea.allocate_queue`), engine-rule scoring,
    optional time-axis fault channels, and full per-request accounting
    (:class:`ServingOutcomes` + sojourn streams for latency percentiles).
"""

from repro.obs.telemetry import ServingTelemetry

from .admission import admission_room, minimal_demand, predicted_success
from .arrivals import (arrival_key, make_process, process_names,
                       register_process, sample_arrivals)
from .engine import (EVENT_EXPIRED, EVENT_LATE, EVENT_NONE, EVENT_ON_TIME,
                     ServingOutcomes, serving_compile_cache_size,
                     simulate_serving, sweep_serving)
from .queue import (ADMIT_ALL_CAP, RequestQueue, RequestSpec, admit,
                    edf_order, empty_queue, release)

__all__ = [
    "ADMIT_ALL_CAP", "EVENT_EXPIRED", "EVENT_LATE", "EVENT_NONE",
    "EVENT_ON_TIME", "RequestQueue", "RequestSpec", "ServingOutcomes",
    "ServingTelemetry",
    "admission_room", "admit", "arrival_key", "edf_order", "empty_queue",
    "make_process", "minimal_demand", "predicted_success", "process_names",
    "register_process", "release", "sample_arrivals",
    "serving_compile_cache_size", "simulate_serving", "sweep_serving",
]
