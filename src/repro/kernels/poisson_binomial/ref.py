"""Pure-jnp oracle for the batched Poisson-binomial prefix-tail DP.

This is the seed implementation of ``core.lea.success_prob_all_prefixes``
generalised to arbitrary leading batch axes: a single ``lax.scan`` over the
worker axis convolves one Bernoulli at a time into the carried pmf, and the
tail P[count >= w(i~)] is read off after every prefix.  The element-wise float
operations are identical to the original unbatched scan, so per-row results
are bit-for-bit equal to the seed allocator.

Shape-polymorphic thresholds: ``w`` may be the classic shared ``(n,)`` vector
(static ``LoadParams``) or any shape broadcastable to ``probs`` — in
particular a per-row ``(..., n)`` array of TRACED thresholds, which is what
lets one compiled DP serve a batch of heterogeneous-K*/ell rows.  A shared
``(n,)`` w broadcast over the batch multiplies the pmf by the exact same
elementwise mask as before, so the generalisation is bit-identical to the
seed path on the same inputs.

Mask-padded pools ride the same generalisation with no extra machinery: a
padded (invalid) worker contributes success probability 0.0, whose Bernoulli
convolution is the identity (``pmf * 1.0 + shifted * 0.0``), and its prefix
threshold is set infeasible (``w > i~``) so the padded prefix scores exactly
0 — see ``core.lea.allocate_masked``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def success_tails_ref(probs: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Batched prefix success probabilities.

    Args:
      probs: (..., n) success probabilities, each row sorted descending.
      w: int32 thresholds w(i~) for prefixes i~ = 1..n — ``(n,)`` shared or
         any shape broadcastable to ``probs`` (per-row traced thresholds);
         entries with ``w > i~`` are infeasible and score 0, entries ``<= 0``
         always succeed.

    Returns:
      (..., n) float32 — P[Poisson-binomial(top i~ of row) >= w(i~)].
    """
    probs = jnp.asarray(probs, jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(w, jnp.int32), probs.shape)
    n = probs.shape[-1]
    batch_shape = probs.shape[:-1]
    counts = jnp.arange(n + 1)

    def body(pmf, xs):
        # pmf over counts 0..n (..., n+1); convolve one Bernoulli(p) per row,
        # then stream out this prefix's tail (materialising all n pmfs would
        # cost O(n^2 * batch) memory — the engine batches over every round of
        # a Monte-Carlo sweep, so batch can be millions of rows).
        p, w_i = xs
        shifted = jnp.concatenate([jnp.zeros_like(pmf[..., :1]), pmf[..., :-1]], axis=-1)
        new = pmf * (1.0 - p)[..., None] + shifted * p[..., None]
        tail_mask = counts >= jnp.maximum(w_i, 0)[..., None]
        tail = jnp.sum(new * tail_mask, axis=-1)
        return new, tail

    pmf0 = jnp.zeros(batch_shape + (n + 1,), jnp.float32).at[..., 0].set(1.0)
    _, tails = jax.lax.scan(
        body, pmf0, (jnp.moveaxis(probs, -1, 0), jnp.moveaxis(w, -1, 0))
    )  # (n, ...)

    tails = jnp.moveaxis(tails, 0, -1)                              # (..., n)
    i_tilde = jnp.arange(1, n + 1)
    return jnp.where(w > i_tilde, 0.0, tails)
