"""Pallas TPU kernel: batched Poisson-binomial prefix tails in one VMEM pass.

The EA allocator (eq. 7/8) needs, for every prefix i~ of a descending-sorted
probability vector, the tail P[count >= w(i~)] of the Poisson-binomial pmf of
the first i~ Bernoullis.  The seed computed this with an O(n^2) ``lax.scan``
per vector; here the whole DP runs for a *batch* of vectors at once:

  * grid over batch tiles only — each kernel instance owns a (bb, n_pad)
    probability tile and keeps the full (bb, c_pad) pmf resident in VMEM
    registers for all n convolution steps (n <= a few hundred in every
    deployed config, so the working set is a few hundred KB);
  * the worker loop is unrolled at trace time (n is static), so each step is
    a pure VPU shift-multiply-add over the batch tile — no scalar control
    flow on the device;
  * lanes are padded to 128 (pmf counts axis and prefix axis), MXU is never
    touched — this is a pure VPU kernel.

Two threshold conventions, two entry points:

  * :func:`success_tails_pallas` — the classic static path: ``w`` is a
    Python tuple baked in as trace-time constants (no SMEM traffic;
    feasibility ``w > i~`` and the ``max(w, 0)`` clamp resolve at trace
    time).  One kernel per distinct ``w`` — one compile per ``LoadParams``.
  * :func:`success_tails_pallas_w` — the shape-polymorphic path: ``w`` is a
    TRACED (B, n) int32 input riding the same VMEM tiling as the
    probabilities, so heterogeneous-K*/ell batches (and mask-padded pools,
    whose padded prefixes carry an infeasible threshold) run in ONE compiled
    kernel.  Feasibility and the clamp become per-row selects.  Both kernels
    are validated against the ref DP in interpret mode; static-vs-traced
    agreement is to float32 round-off only (XLA constant-folds the static
    kernel's baked-in tail masks into re-associated reductions), exactly the
    tolerance the static kernel always had against the ref scan.

``ref.success_tails_ref`` (the seed ``lax.scan`` DP) is the interpret-mode
oracle; on CPU the ops dispatcher routes to the ref path and the Pallas
kernels are exercised with ``interpret=True`` in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _pb_kernel(probs_ref, out_ref, *, n: int, w: tuple[int, ...]):
    probs = probs_ref[...].astype(jnp.float32)          # (bb, n_pad)
    bb, n_pad = probs.shape
    c_pad = _round_up(n + 1, _LANES)

    counts = jax.lax.broadcasted_iota(jnp.int32, (bb, c_pad), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bb, n_pad), 1)
    pmf = (counts == 0).astype(jnp.float32)             # point mass at count 0
    out = jnp.zeros((bb, n_pad), jnp.float32)

    for i in range(n):
        p_i = probs[:, i : i + 1]                       # (bb, 1), static slice
        shifted = jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.float32), pmf[:, :-1]], axis=1
        )
        pmf = pmf * (1.0 - p_i) + shifted * p_i
        if w[i] > i + 1:                                # infeasible prefix
            continue                                    # (out stays 0)
        # static slice to counts 0..n: summing the padded lanes too would pick
        # a different XLA reduction tree and break bit-equality with the ref DP
        tail = jnp.sum(
            jnp.where(counts[:, : n + 1] >= max(w[i], 0), pmf[:, : n + 1], 0.0),
            axis=1, keepdims=True,
        )                                               # (bb, 1)
        out = jnp.where(cols == i, tail, out)

    out_ref[...] = out


def _pb_kernel_w(probs_ref, w_ref, out_ref, *, n: int):
    """Traced-threshold body: identical DP, per-row w from a VMEM tile.

    The static kernel's trace-time branches become selects over the same
    expressions — an infeasible prefix writes the literal 0.0 the static
    kernel left in place, a feasible one the same masked tail sum (equal to
    the static kernel's to float32 round-off; XLA folds the static kernel's
    constant masks into re-associated reductions).
    """
    probs = probs_ref[...].astype(jnp.float32)          # (bb, n_pad)
    w = w_ref[...]                                      # (bb, n_pad) int32
    bb, n_pad = probs.shape
    c_pad = _round_up(n + 1, _LANES)

    counts = jax.lax.broadcasted_iota(jnp.int32, (bb, c_pad), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bb, n_pad), 1)
    pmf = (counts == 0).astype(jnp.float32)             # point mass at count 0
    out = jnp.zeros((bb, n_pad), jnp.float32)

    for i in range(n):
        p_i = probs[:, i : i + 1]                       # (bb, 1), static slice
        shifted = jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.float32), pmf[:, :-1]], axis=1
        )
        pmf = pmf * (1.0 - p_i) + shifted * p_i
        w_i = w[:, i : i + 1]                           # (bb, 1), static slice
        tail = jnp.sum(
            jnp.where(counts[:, : n + 1] >= jnp.maximum(w_i, 0),
                      pmf[:, : n + 1], 0.0),
            axis=1, keepdims=True,
        )                                               # (bb, 1)
        tail = jnp.where(w_i > i + 1, 0.0, tail)        # infeasible prefix
        out = jnp.where(cols == i, tail, out)

    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("w", "block_b", "interpret"))
def success_tails_pallas(
    probs: jnp.ndarray,
    w: tuple[int, ...],
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, n) descending-sorted probabilities -> (B, n) prefix tails.

    ``w`` must be a static tuple of n ints (from ``lea.prefix_thresholds``).
    """
    b, n = probs.shape
    assert len(w) == n, (len(w), n)
    bb = min(block_b, _round_up(b, 8))
    b_pad = _round_up(b, bb)
    n_pad = _round_up(n, _LANES)
    probs_p = jnp.pad(probs.astype(jnp.float32), ((0, b_pad - b), (0, n_pad - n)))

    out = pl.pallas_call(
        functools.partial(_pb_kernel, n=n, w=tuple(int(v) for v in w)),
        grid=(b_pad // bb,),
        in_specs=[pl.BlockSpec((bb, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(probs_p)
    return out[:b, :n]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def success_tails_pallas_w(
    probs: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, n) probabilities + (B, n) TRACED int32 thresholds -> (B, n) tails.

    The shape-polymorphic kernel: one compile serves every per-row
    (K*, ell) combination and every mask padding (padded prefixes carry
    ``w > i~`` and probability 0.0, so they score exactly 0).
    """
    b, n = probs.shape
    assert w.shape == (b, n), (w.shape, (b, n))
    bb = min(block_b, _round_up(b, 8))
    b_pad = _round_up(b, bb)
    n_pad = _round_up(n, _LANES)
    probs_p = jnp.pad(probs.astype(jnp.float32), ((0, b_pad - b), (0, n_pad - n)))
    # pad thresholds with n + 1 (> any i~): pad rows/cols are infeasible by
    # construction, not just sliced off — belt and braces for the batch pad.
    w_p = jnp.pad(w.astype(jnp.int32), ((0, b_pad - b), (0, n_pad - n)),
                  constant_values=n + 1)

    out = pl.pallas_call(
        functools.partial(_pb_kernel_w, n=n),
        grid=(b_pad // bb,),
        in_specs=[pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
                  pl.BlockSpec((bb, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(probs_p, w_p)
    return out[:b, :n]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
