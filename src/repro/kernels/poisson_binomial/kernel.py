"""Pallas TPU kernel: batched Poisson-binomial prefix tails in one VMEM pass.

The EA allocator (eq. 7/8) needs, for every prefix i~ of a descending-sorted
probability vector, the tail P[count >= w(i~)] of the Poisson-binomial pmf of
the first i~ Bernoullis.  The seed computed this with an O(n^2) ``lax.scan``
per vector; here the whole DP runs for a *batch* of vectors at once:

  * grid over batch tiles only — each kernel instance owns a (bb, n_pad)
    probability tile and keeps the full (bb, c_pad) pmf resident in VMEM
    registers for all n convolution steps (n <= a few hundred in every
    deployed config, so the working set is a few hundred KB);
  * the worker loop is unrolled at trace time (n is static), so each step is
    a pure VPU shift-multiply-add over the batch tile — no scalar control
    flow on the device;
  * the thresholds w(i~) depend only on static ``LoadParams`` and are baked
    in as Python constants (no SMEM traffic, feasibility ``w > i~`` and the
    ``max(w, 0)`` clamp are resolved at trace time);
  * lanes are padded to 128 (pmf counts axis and prefix axis), MXU is never
    touched — this is a pure VPU kernel.

``ref.success_tails_ref`` (the seed ``lax.scan`` DP) is the interpret-mode
oracle; on CPU the ops dispatcher routes to the ref path and the Pallas
kernel is exercised with ``interpret=True`` in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _pb_kernel(probs_ref, out_ref, *, n: int, w: tuple[int, ...]):
    probs = probs_ref[...].astype(jnp.float32)          # (bb, n_pad)
    bb, n_pad = probs.shape
    c_pad = _round_up(n + 1, _LANES)

    counts = jax.lax.broadcasted_iota(jnp.int32, (bb, c_pad), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bb, n_pad), 1)
    pmf = (counts == 0).astype(jnp.float32)             # point mass at count 0
    out = jnp.zeros((bb, n_pad), jnp.float32)

    for i in range(n):
        p_i = probs[:, i : i + 1]                       # (bb, 1), static slice
        shifted = jnp.concatenate(
            [jnp.zeros((bb, 1), jnp.float32), pmf[:, :-1]], axis=1
        )
        pmf = pmf * (1.0 - p_i) + shifted * p_i
        if w[i] > i + 1:                                # infeasible prefix
            continue                                    # (out stays 0)
        # static slice to counts 0..n: summing the padded lanes too would pick
        # a different XLA reduction tree and break bit-equality with the ref DP
        tail = jnp.sum(
            jnp.where(counts[:, : n + 1] >= max(w[i], 0), pmf[:, : n + 1], 0.0),
            axis=1, keepdims=True,
        )                                               # (bb, 1)
        out = jnp.where(cols == i, tail, out)

    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("w", "block_b", "interpret"))
def success_tails_pallas(
    probs: jnp.ndarray,
    w: tuple[int, ...],
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """(B, n) descending-sorted probabilities -> (B, n) prefix tails.

    ``w`` must be a static tuple of n ints (from ``lea.prefix_thresholds``).
    """
    b, n = probs.shape
    assert len(w) == n, (len(w), n)
    bb = min(block_b, _round_up(b, 8))
    b_pad = _round_up(b, bb)
    n_pad = _round_up(n, _LANES)
    probs_p = jnp.pad(probs.astype(jnp.float32), ((0, b_pad - b), (0, n_pad - n)))

    out = pl.pallas_call(
        functools.partial(_pb_kernel, n=n, w=tuple(int(v) for v in w)),
        grid=(b_pad // bb,),
        in_specs=[pl.BlockSpec((bb, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(probs_p)
    return out[:b, :n]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
