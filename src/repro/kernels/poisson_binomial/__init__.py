from .ops import success_tails, success_tails_pallas, success_tails_ref  # noqa: F401
