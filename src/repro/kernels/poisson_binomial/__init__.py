from .ops import (  # noqa: F401
    success_tails,
    success_tails_pallas,
    success_tails_pallas_w,
    success_tails_ref,
)
