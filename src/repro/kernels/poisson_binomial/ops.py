"""Dispatcher for the batched Poisson-binomial prefix-tail computation.

``success_tails`` is the single entry point the allocator uses:

  * ``impl="pallas"`` — the VMEM-tiled batch kernel (TPU; ``interpret=True``
    on CPU for testing).  Static (tuple / numpy) thresholds are baked into
    the kernel as trace-time constants; traced threshold ARRAYS ride a VMEM
    tile through the shape-polymorphic twin kernel instead.
  * ``impl="ref"``    — the seed ``lax.scan`` DP, batched over leading axes.
    This is the XLA path used on CPU/GPU and the oracle the kernels are
    tested against.  Thresholds may be static or traced ((..., n)-broadcast).
  * ``impl=None``     — pallas on TPU, ref elsewhere (overridable via
    ``REPRO_KERNEL_IMPL`` / ``REPRO_KERNEL_INTERPRET`` — see
    :mod:`repro.kernels.dispatch`).

Any leading batch shape is accepted; rows are flattened to (B, n) for the
kernels and reshaped back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import default_interpret, resolve_impl

from .kernel import success_tails_pallas, success_tails_pallas_w
from .ref import success_tails_ref


def success_tails(
    probs: jnp.ndarray,
    w,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(..., n) descending-sorted probabilities -> (..., n) prefix tails.

    ``w``: (n,) static thresholds (tuple/list/numpy) shared across rows, or
    a traced int32 array broadcastable to ``probs`` for per-row thresholds
    (heterogeneous K*/ell, mask-padded pools).
    """
    impl = resolve_impl(impl, allowed=("pallas", "ref"))
    if impl == "ref":
        return success_tails_ref(probs, jnp.asarray(w, jnp.int32))
    interpret = default_interpret(interpret)
    batch_shape = probs.shape[:-1]
    n = probs.shape[-1]
    flat = probs.reshape((-1, n)) if batch_shape else probs.reshape((1, n))
    if isinstance(w, jax.Array):
        w_flat = jnp.broadcast_to(
            jnp.asarray(w, jnp.int32), probs.shape
        ).reshape(flat.shape)
        out = success_tails_pallas_w(flat, w_flat, interpret=interpret)
    else:
        w_static = tuple(int(v) for v in np.asarray(w).reshape(-1))
        out = success_tails_pallas(flat, w_static, interpret=interpret)
    return out.reshape(batch_shape + (n,))


__all__ = [
    "success_tails", "success_tails_pallas", "success_tails_pallas_w",
    "success_tails_ref",
]
