"""Dispatcher for the batched Poisson-binomial prefix-tail computation.

``success_tails`` is the single entry point the allocator uses:

  * ``impl="pallas"`` — the VMEM-tiled batch kernel (TPU; ``interpret=True``
    on CPU for testing).  Requires concrete thresholds (they are baked into
    the kernel as static constants).
  * ``impl="ref"``    — the seed ``lax.scan`` DP, batched over leading axes.
    This is the XLA path used on CPU/GPU and the oracle the kernel is tested
    against.
  * ``impl=None``     — pallas on TPU, ref elsewhere.

Any leading batch shape is accepted; rows are flattened to (B, n) for the
kernel and reshaped back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import success_tails_pallas
from .ref import success_tails_ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def success_tails(
    probs: jnp.ndarray,
    w,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(..., n) descending-sorted probabilities -> (..., n) prefix tails."""
    if impl is None:
        impl = _default_impl()
    if impl == "ref":
        return success_tails_ref(probs, jnp.asarray(w, jnp.int32))
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    w_static = tuple(int(v) for v in np.asarray(w).reshape(-1))
    batch_shape = probs.shape[:-1]
    n = probs.shape[-1]
    flat = probs.reshape((-1, n)) if batch_shape else probs.reshape((1, n))
    out = success_tails_pallas(flat, w_static, interpret=interpret)
    return out.reshape(batch_shape + (n,))


__all__ = ["success_tails", "success_tails_pallas", "success_tails_ref"]
