"""Pallas TPU kernel: causal/sliding-window GQA flash attention (forward).

Online-softmax tiling (FlashAttention) adapted to the TPU memory hierarchy:

  * grid = (B, Hq, Sq/bq, Sk/bk); the KV axis is the innermost, "arbitrary"
    dimension — running max/denominator/accumulator live in VMEM scratch and
    are carried across KV steps;
  * bq x D accumulator in float32; m/l broadcast across the 128-lane minor
    dim (TPU vector layout);
  * causal and sliding-window blocks that are fully masked are skipped with
    ``pl.when`` (no MXU work issued);
  * GQA: query head h reads KV head h // (Hq//Hkv) via the BlockSpec index
    map — no KV repeat is materialized.

On this CPU container the kernel is validated with ``interpret=True`` against
``ref.attention_ref``; the LM stack's XLA path (models/layers.py) is the
compile-target used by the dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_LANES = 128

# jax 0.4.x names this TPUCompilerParams; newer releases rename it to
# CompilerParams — accept either so the kernel tracks both.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or getattr(
    pltpu, "CompilerParams"
)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    sq: int, sk: int, bq: int, bk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- block-level skip decision (causal diagonal + window band) --------
    off = sk - sq                       # query positions are right-aligned
    q_lo = iq * bq + off
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    run = jnp.asarray(True)
    if causal:
        run &= k_lo <= q_hi             # some key not in the future
    if window is not None:
        run &= k_hi > q_lo - window     # some key inside the window
    run &= k_lo < sk                    # not a fully-padded KV block

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk                           # key padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0]                        # (bq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                 # exp(-inf - -inf) guards
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,           # (B, Hq, Sq, D)
    k: jnp.ndarray,           # (B, Hkv, Sk, D)
    v: jnp.ndarray,           # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = float(d) ** -0.5

    bq = min(block_q, _round_up(sq, 8))
    bk = min(block_k, _round_up(sk, 8))
    sq_p, sk_p = _round_up(sq, bq), _round_up(sk, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        sq=sq, sk=sk, bq=bq, bk=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, sq_p // bq, sk_p // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
