"""jit'd public wrapper for flash attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret

from .kernel import flash_attention_pallas
from .ref import attention_ref


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: int | None = None,
    scale: float | None = None, interpret: bool | None = None,
) -> jnp.ndarray:
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        interpret=default_interpret(interpret),
    )


__all__ = ["flash_attention", "attention_ref"]
