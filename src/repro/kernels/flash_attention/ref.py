"""Pure-jnp oracle for causal/windowed GQA attention."""

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,          # (B, Hq, Sq, D)
    k: jnp.ndarray,          # (B, Hkv, Sk, D)
    v: jnp.ndarray,          # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,   # sliding-window size (None = full)
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode-friendly)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
