"""Dispatcher for device-resident exact GF(p) linear algebra, p = 2^31 - 1.

Two entry points the coding layer uses:

  * :func:`matmul_gf`          — exact (m, c) @ (c, n) mod p
  * :func:`lagrange_basis_gf`  — batched Lagrange basis matrices over GF(p)
                                 (generator / erasure-pattern decode builder)

``matmul_gf`` impls:

  * ``impl="pallas"`` — the blocked VMEM kernel (TPU; ``interpret=True`` on
    CPU for testing).
  * ``impl="dot"``    — the XLA fast path used on CPU/GPU: residues are
    decomposed into four 8-bit limbs and contracted with SIXTEEN float32
    GEMMs per K-chunk of 256 (256 * 255^2 < 2^24, so every float32 partial
    sum is an exactly-representable integer regardless of reduction order),
    then the limb planes are recombined with Mersenne rotations
    (2^31 === 1).  This rides the platform's optimised sgemm instead of an
    elementwise modular loop — where the >= 5x-over-numpy speedup in
    BENCH_gf.json comes from.  The GEMMs are pinned to
    ``Precision.HIGHEST``: JAX's default precision allows TF32 on Ampere+
    GPUs, whose 10-bit mantissa would round the limb products.
  * ``impl="ref"``    — the lax fori_loop fold path, the kernel's
    interpret-mode oracle.
  * ``impl=None``     — pallas on TPU, dot elsewhere.

Residues are exact, so ALL impls return bit-identical uint32 arrays — the
tests assert pairwise equality (not allclose) across every path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret, resolve_impl

from .kernel import matmul_gf_pallas
from .ref import (FIELD_P, add_gf, lagrange_basis_gf_ref, matmul_gf_ref,
                  rot_gf, to_gf)

# K-chunk bound for the float32 limb dot: 256 terms of (2^8-1)^2 products
# sum to 16_646_400 < 2^24, the largest integer float32 represents exactly.
_DOT_CHUNK = 256
_LIMBS = 4          # 31 bits as 8+8+8+7

_IMPLS = ("pallas", "dot", "ref")


def _limbs_f32(x: jnp.ndarray) -> jnp.ndarray:
    """(..., ) uint32 residues -> (4, ...) float32 8-bit limb planes (exact)."""
    return jnp.stack(
        [((x >> jnp.uint32(8 * i)) & jnp.uint32(0xFF)).astype(jnp.float32)
         for i in range(_LIMBS)]
    )


@jax.jit
def matmul_gf_dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Exact mod-p matmul on canonical uint32 residues via limb float32 GEMMs.

    The 16 limb-pair products are laid out as ONE (4m, kc) @ (kc, 4n) block
    GEMM per K-chunk — a single large sgemm the platform BLAS runs at full
    rate, instead of 16 skinny ones — then the (i, j) blocks are recombined
    with Mersenne rotations.
    """
    m, c = a.shape
    n = b.shape[1]
    a_l = _limbs_f32(a).reshape(_LIMBS * m, c)             # (4m, c) stacked rows
    b_l = jnp.moveaxis(_limbs_f32(b), 0, 1).reshape(c, _LIMBS * n)  # (c, 4n)
    acc = jnp.zeros((m, n), jnp.uint32)
    for k0 in range(0, c, _DOT_CHUNK):
        k1 = min(k0 + _DOT_CHUNK, c)
        # Precision.HIGHEST is load-bearing: JAX's default matmul precision
        # permits TF32 on Ampere+ GPUs (10-bit mantissa), which would round
        # the 16-bit limb products and the < 2^24 partial sums — silently
        # wrong residues.  HIGHEST guarantees a true float32 GEMM everywhere.
        part = jnp.dot(
            a_l[:, k0:k1], b_l[k0:k1, :],
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )                                                  # (4m, 4n), exact ints
        part_u = part.astype(jnp.uint32)                   # < 2^24, exact
        part_u = part_u.reshape(_LIMBS, m, _LIMBS, n)
        for i in range(_LIMBS):
            for j in range(_LIMBS):
                acc = add_gf(acc, rot_gf(part_u[i, :, j, :], 8 * (i + j)))
    return acc


def matmul_gf(
    a,
    b,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Exact (m, c) @ (c, n) mod p.  Any int dtype in, uint32 residues out."""
    a = to_gf(a)
    b = to_gf(b)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul_gf: bad shapes {a.shape} @ {b.shape}")
    impl = resolve_impl(impl, allowed=_IMPLS, host_impl="dot")
    if impl == "ref":
        return _matmul_gf_ref_jit(a, b)
    if impl == "dot":
        return matmul_gf_dot(a, b)
    return matmul_gf_pallas(a, b, interpret=default_interpret(interpret))


_matmul_gf_ref_jit = jax.jit(matmul_gf_ref)


def bmm_gf(
    a,
    b,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Exact batched (..., m, c) @ (..., c, n) mod p — vmapped 2-D matmuls.

    Leading axes must match exactly (no broadcasting — the coded-computing
    callers batch over worker chunks, which both operands carry).  Same impl
    set as :func:`matmul_gf`; residues are exact, so all impls agree bit for
    bit.  2-D inputs fall through to :func:`matmul_gf` unchanged.
    """
    a = to_gf(a)
    b = to_gf(b)
    if a.ndim < 2 or b.ndim < 2 or a.ndim != b.ndim:
        raise ValueError(f"bmm_gf: bad ranks {a.shape} @ {b.shape}")
    if a.shape[:-2] != b.shape[:-2] or a.shape[-1] != b.shape[-2]:
        raise ValueError(f"bmm_gf: bad shapes {a.shape} @ {b.shape}")
    if a.ndim == 2:
        return matmul_gf(a, b, impl=impl, interpret=interpret)
    impl = resolve_impl(impl, allowed=_IMPLS, host_impl="dot")
    if impl == "ref":
        core = _matmul_gf_ref_jit
    elif impl == "dot":
        core = matmul_gf_dot
    else:
        core = partial(matmul_gf_pallas, interpret=default_interpret(interpret))
    lead = a.shape[:-2]
    a3 = a.reshape((-1,) + a.shape[-2:])
    b3 = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(core)(a3, b3)
    return out.reshape(lead + out.shape[-2:])


@jax.jit
def lagrange_basis_gf(eval_pts, nodes) -> jnp.ndarray:
    """Batched exact Lagrange basis M[..., e, j] over GF(p).

    ``eval_pts`` (E,), ``nodes`` (..., J) — leading axes of ``nodes`` batch
    over node sets, so a (B, K*) batch of erasure patterns builds all B
    decode matrices in one call.  ``nodes`` may be a traced gather (the
    received alpha points): fully jittable, no host round-trip.
    """
    return lagrange_basis_gf_ref(eval_pts, nodes)


__all__ = [
    "FIELD_P", "bmm_gf", "lagrange_basis_gf", "matmul_gf", "matmul_gf_dot",
    "matmul_gf_pallas", "matmul_gf_ref",
]
