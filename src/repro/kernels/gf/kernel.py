"""Pallas TPU kernel: blocked exact GF(p) matmul, p = 2^31 - 1 (Mersenne-31).

C = A @ B over the prime field, on int32/uint32 residues, bit-identical to
the numpy int64 host path — the device half of the paper's finite field F.

Design (mirrors the float matmul revisiting pattern, VPU-only):

  * grid (M/bm, N/bn, K/bk) with the contraction axis INNERMOST: each (i, j)
    output tile stays resident in VMEM across its K/bk visits, initialised at
    the first visit (``pl.when(pl.program_id(2) == 0)``) and accumulated
    in-place after that — tiled accumulation, never a partial sum > 32 bits;
  * inside one visit, a ``fori_loop`` over the bk contraction steps does a
    broadcast (bm, 1) x (1, bn) multiply-fold-add per step.  Products of two
    31-bit residues are formed as four 16-bit-limb uint32 partial products
    and reduced with the Mersenne fold 2^31 === 1 (shift-adds, no division,
    no int64) — see :mod:`repro.kernels.gf.ref` for the arithmetic;
  * the MXU is never touched: exact integer dots don't fit a float systolic
    array, so this is a pure VPU kernel with lanes padded to 128.  Zero
    padding is harmless (0 is the additive identity).

``ref.matmul_gf_ref`` is the interpret-mode oracle; on CPU the ops
dispatcher routes to the XLA paths and this kernel is exercised with
``interpret=True`` in tests (exactness makes every path bit-equal).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import add_gf, mul_gf

_LANES = 128


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _gf_matmul_kernel(a_ref, b_ref, out_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                    # (bm, bk) uint32 residues
    b = b_ref[...]                    # (bk, bn)

    def body(i, acc):
        col = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)    # (bm, 1)
        row = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)    # (1, bn)
        return add_gf(acc, mul_gf(col, row))

    out_ref[...] = jax.lax.fori_loop(0, bk, body, out_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_gf_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 64,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(m, c) uint32 @ (c, n) uint32 -> (m, n) canonical residues mod p.

    Inputs must already be canonical residues in [0, p) (the ops dispatcher
    guarantees this); blocks are padded to the (8, 128) float32-class tile
    grid with zeros.
    """
    m, c = a.shape
    n = b.shape[1]
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, _LANES))
    # bk is A's minormost (lane) dim, so like bn it must stay a multiple of
    # 128 for Mosaic tiling — small K is padded up, never shrunk below a lane
    # tile (c=50 pads to bk=128; an explicit non-128-multiple block_k is
    # honoured only in interpret mode, for small-grid tests).
    bk = min(block_k, _round_up(c, _LANES))
    if not interpret and (bk % _LANES or bn % _LANES):
        raise ValueError(
            f"matmul_gf_pallas: lane-dim blocks (bk={bk}, bn={bn}) must be "
            f"multiples of {_LANES} on real hardware; pass a conforming "
            "block_k/block_n or interpret=True"
        )
    m_pad, c_pad, n_pad = _round_up(m, bm), _round_up(c, bk), _round_up(n, bn)
    a_p = jnp.pad(a.astype(jnp.uint32), ((0, m_pad - m), (0, c_pad - c)))
    b_p = jnp.pad(b.astype(jnp.uint32), ((0, c_pad - c), (0, n_pad - n)))

    out = pl.pallas_call(
        functools.partial(_gf_matmul_kernel, bk=bk),
        grid=(m_pad // bm, n_pad // bn, c_pad // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.uint32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
