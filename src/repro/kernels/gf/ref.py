"""GF(p) field primitives + lax-level reference implementations, p = 2^31 - 1.

This module is the arithmetic core of the exact coded-computing path and the
interpret-mode oracle the Pallas kernel is bit-compared against.  Everything
is built from uint32 operations only:

  * JAX runs with x64 disabled (and TPUs have no native int64), so the
    "int64 product" of two 31-bit residues is formed from four 16-bit-limb
    partial products — each of which fits uint32 exactly — and reduced with
    the Mersenne identity 2^31 === 1 (mod p): high bits are FOLDED back onto
    the low 31 bits with shift-adds instead of a division (`fold31`).
  * every public primitive returns canonical residues in [0, p), and every
    intermediate stays below 2^32, so the matmul can accumulate with one
    fold-and-norm per term and never overflow.

Unlike the float kernels there is no reduction-order sensitivity: residues
are exact, so ANY correct implementation (numpy int64, the lax reference,
the Pallas kernel, the limb-decomposed dot path) produces bit-identical
arrays — the tests assert exactly that.

Reference entry points (pure jax.lax, no Pallas):

  * :func:`matmul_gf_ref`         — (m, c) @ (c, n) mod p via a fori_loop of
                                    broadcast multiply-fold-adds
  * :func:`lagrange_basis_gf_ref` — batched Lagrange basis matrices over
                                    GF(p) (the encode/decode matrix builder),
                                    Fermat inversion via 31 fixed squarings
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Mersenne prime 2^31 - 1 (shared with repro.core.lagrange.FIELD_P).
FIELD_P = (1 << 31) - 1

# NOTE: field constants appear as Python int literals (weak-typed scalars),
# never as jnp arrays — module-level jnp constants would be captured consts
# inside the Pallas kernel, which pallas_call rejects.
_MASK31 = 0x7FFF_FFFF   # == FIELD_P


def norm31(x: jnp.ndarray) -> jnp.ndarray:
    """One conditional subtract: [0, 2p) -> [0, p).  uint32 in, uint32 out."""
    return jnp.where(x >= _MASK31, x - _MASK31, x)


def fold31(x: jnp.ndarray) -> jnp.ndarray:
    """Fold bits 31.. back onto bits 0..30: exact mod-p for any uint32.

    2^31 === 1 (mod p), so x = hi * 2^31 + lo === hi + lo.  The sum is at
    most (2^31 - 1) + 1 = 2^31 < 2p, so one :func:`norm31` canonicalises.
    """
    return norm31((x & _MASK31) + (x >> 31))


def to_gf(x) -> jnp.ndarray:
    """Any int array-like -> canonical uint32 residues in [0, p).

    Signed inputs may be negative (Python-sign remainder maps them into
    [0, p)); values must fit int32 on the way in (JAX has no x64 here).
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"GF(p) arrays must be integer-typed, got {x.dtype}")
    if jnp.issubdtype(x.dtype, jnp.unsignedinteger):
        return fold31(x.astype(jnp.uint32))
    return jnp.mod(x.astype(jnp.int32), jnp.int32(FIELD_P)).astype(jnp.uint32)


def from_gf(x: jnp.ndarray) -> jnp.ndarray:
    """Canonical residues -> int32 (values < p < 2^31 always fit)."""
    return x.astype(jnp.int32)


def add_gf(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a + b) mod p for canonical residues (sum < 2p: one norm)."""
    return norm31(a + b)


def sub_gf(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a - b) mod p for canonical residues (a + (p - b) < 2p)."""
    return norm31(a + (_MASK31 - b))


def mul_gf(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a * b) mod p via 16-bit-limb products + Mersenne folding.

    Exact for ANY a, b < 2^31 (canonical residues and the value p itself):
    with a = ah*2^16 + al and b = bh*2^16 + bl (ah, bh < 2^15) the partial
    products and their pairwise sums all fit uint32

        a*b = hh*2^32 + (lh + hl)*2^16 + ll

    and each power of two folds by 2^31 === 1:  2^32 === 2, and the middle
    word m = mh*2^15 + ml gives m*2^16 === ml*2^16 + mh.  Every intermediate
    sum stays < 2^32 and every norm31 input stays < 2p.  Output is canonical.
    """
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    al = a & 0xFFFF
    ah = a >> 16
    bl = b & 0xFFFF
    bh = b >> 16
    ll = al * bl                       # < 2^32, exact in uint32
    mid = al * bh + ah * bl            # each term < 2^31.x: sum < 2^32, exact
    hh = ah * bh                       # < 2^32
    ml = mid & 0x7FFF                  # < 2^15
    mh = mid >> 15                     # < 2^17
    t = fold31(ll)                                  # [0, p)
    t = norm31(t + (ml << 16))                      # + ml*2^16 < 2^31
    t = norm31(t + mh)
    # hh*2^32 === 2*hh; hh < 2^32 so fold first, then double via one add
    hh2 = fold31(hh)
    t = norm31(t + hh2)
    return norm31(t + hh2)


def rot_gf(x: jnp.ndarray, s: int) -> jnp.ndarray:
    """(x * 2^s) mod p for x < 2^31 — a rotate within the low 31 bits.

    ``s`` is a static Python int (any value; reduced mod 31 since
    2^31 === 1).  The high ``s`` bits wrap to the bottom: result
    <= 2^31 - 1, canonicalised with one norm.
    """
    s = int(s) % 31
    if s == 0:
        return norm31(x)
    lo_bits = 31 - s
    hi = x >> lo_bits
    lo = (x & ((1 << lo_bits) - 1)) << s
    return norm31(lo + hi)


def inv_gf(a: jnp.ndarray) -> jnp.ndarray:
    """Modular inverse via Fermat: a^(p-2) mod p, 31 fixed squarings.

    Vectorised square-and-multiply over the static 31-bit exponent
    p - 2 = 0b111...1101; inv_gf(0) = 0 (callers guarantee nonzero
    denominators — distinct interpolation nodes).
    """
    a = jnp.asarray(a, jnp.uint32)
    e = FIELD_P - 2
    result = jnp.ones_like(a)
    base = a
    for bit in range(31):
        if (e >> bit) & 1:
            result = mul_gf(result, base)
        if bit != 30:
            base = mul_gf(base, base)
    return result


# ---------------------------------------------------------------------------
# lax-level reference implementations
# ---------------------------------------------------------------------------

def matmul_gf_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Exact (m, c) @ (c, n) mod p — the kernel's interpret-mode oracle.

    A ``fori_loop`` over the contraction axis of broadcast
    multiply-fold-adds; every partial sum is renormalised per step, so
    nothing ever exceeds 32 bits.  Inputs any int dtype; output uint32
    canonical residues.
    """
    a = to_gf(a)
    b = to_gf(b)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul_gf: bad shapes {a.shape} @ {b.shape}")
    m, c = a.shape
    n = b.shape[1]

    def body(i, acc):
        col = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)    # (m, 1)
        row = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)    # (1, n)
        return add_gf(acc, mul_gf(col, row))

    return jax.lax.fori_loop(0, c, body, jnp.zeros((m, n), jnp.uint32))


def _prod_gf(x: jnp.ndarray) -> jnp.ndarray:
    """Product over the last axis, mod p (fori_loop of mul_gf steps)."""
    j = x.shape[-1]

    def body(l, acc):
        return mul_gf(acc, jax.lax.dynamic_slice_in_dim(x, l, 1, axis=-1)[..., 0])

    return jax.lax.fori_loop(
        0, j, body, jnp.ones(x.shape[:-1], jnp.uint32)
    )


def lagrange_basis_gf_ref(eval_pts: jnp.ndarray, nodes: jnp.ndarray) -> jnp.ndarray:
    """Batched exact Lagrange basis: M[..., e, j] = prod_{l != j}
    (x_e - u_l) / (u_j - u_l) over GF(p).

    ``eval_pts`` is (E,); ``nodes`` is (..., J) — leading axes batch over
    node sets (erasure patterns), which is what makes a (B, K*) batch of
    received sets one call.  Division is Fermat inversion of the (…, J)
    denominator products.  Bit-identical to the numpy host oracle
    (``repro.core.lagrange._lagrange_basis_modp``) by exactness.
    """
    x = to_gf(eval_pts)                       # (E,)
    u = to_gf(nodes)                          # (..., J)
    if x.ndim != 1:
        raise ValueError(f"eval_pts must be 1-D, got {x.shape}")
    j_count = u.shape[-1]
    diff = sub_gf(x[:, None], u[..., None, :])          # (..., E, J) over l
    j_idx = jnp.arange(j_count)

    def num_body(l, acc):
        col = jax.lax.dynamic_slice_in_dim(diff, l, 1, axis=-1)   # (..., E, 1)
        factor = jnp.where(j_idx == l, jnp.uint32(1), col)        # (..., E, J)
        return mul_gf(acc, factor)

    num = jax.lax.fori_loop(
        0, j_count, num_body,
        jnp.ones(diff.shape[:-2] + (x.shape[0], j_count), jnp.uint32),
    )
    # den[..., j] = prod_{l != j} (u_j - u_l): (…, J, J) pair table, diagonal
    # masked to 1 (J is small — the coding matrices are (nr, k) / (k, K*))
    pair = sub_gf(u[..., :, None], u[..., None, :])               # (..., J, J)
    eye = jnp.eye(j_count, dtype=bool)
    den = _prod_gf(jnp.where(eye, jnp.uint32(1), pair))           # (..., J)
    return mul_gf(num, inv_gf(den)[..., None, :])                 # (..., E, J)


__all__ = [
    "FIELD_P", "add_gf", "fold31", "from_gf", "inv_gf", "lagrange_basis_gf_ref",
    "matmul_gf_ref", "mul_gf", "norm31", "rot_gf", "sub_gf", "to_gf",
]
