from .ops import (  # noqa: F401
    FIELD_P,
    bmm_gf,
    lagrange_basis_gf,
    matmul_gf,
    matmul_gf_dot,
    matmul_gf_pallas,
    matmul_gf_ref,
)
from .ref import (  # noqa: F401
    add_gf,
    from_gf,
    inv_gf,
    lagrange_basis_gf_ref,
    mul_gf,
    sub_gf,
    to_gf,
)
