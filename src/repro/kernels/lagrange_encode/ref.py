"""Pure-jnp oracle for the Lagrange encode/decode GEMM."""

import jax.numpy as jnp


def encode_matrix_ref(g: jnp.ndarray, x2d: jnp.ndarray) -> jnp.ndarray:
    """(nr, k) @ (k, cols) in float32 accumulation."""
    return jnp.dot(g, x2d, preferred_element_type=jnp.float32).astype(x2d.dtype)


def encode_ref(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(nr, k) x (k, *dims) -> (nr, *dims)."""
    lead = x.shape[0]
    out2d = encode_matrix_ref(g, x.reshape(lead, -1))
    return out2d.reshape((g.shape[0],) + x.shape[1:])
