"""Pallas TPU kernel: Lagrange encode as a VMEM-tiled GEMM.

Encoding is ``X~ = G @ X`` with a small, reused generator ``G`` (nr x k —
nr<=few hundred in all paper settings) and a wide data matrix ``X``
(k x cols, cols = chunk_rows*chunk_cols, typically 1e5..1e7).  The TPU-native
shape of this computation:

  * grid over (nr-tiles, col-tiles); the *entire* contraction axis k is kept
    resident in VMEM per tile (k <= 512 in every deployed config, so a
    (bm, k) G-tile plus a (k, bn) X-tile is < 1 MB at bm=bn=128*q);
  * MXU-aligned tiles (multiples of 128 on both output dims);
  * float32 accumulation regardless of the storage dtype (bf16 in prod).

The same kernel serves decode (D @ Y) — it is the identical GEMM shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(g_ref, x_ref, o_ref):
    g = g_ref[...]
    x = x_ref[...]
    o_ref[...] = jnp.dot(
        g.astype(jnp.float32), x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def encode_matrix_pallas(
    g: jnp.ndarray,
    x2d: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """(nr, k) @ (k, cols) -> (nr, cols) with explicit VMEM tiling.

    Pads nr/cols up to tile multiples (k is kept whole — it is the small,
    always-resident axis).
    """
    nr, k = g.shape
    k2, cols = x2d.shape
    assert k == k2, (g.shape, x2d.shape)
    bm = min(block_m, _round_up(nr, 8))
    bn = min(block_n, _round_up(cols, 128))
    nr_p = _round_up(nr, bm)
    cols_p = _round_up(cols, bn)
    g_p = jnp.pad(g, ((0, nr_p - nr), (0, 0)))
    x_p = jnp.pad(x2d, ((0, 0), (0, cols_p - cols)))

    out = pl.pallas_call(
        _encode_kernel,
        grid=(nr_p // bm, cols_p // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr_p, cols_p), x2d.dtype),
        interpret=interpret,
    )(g_p, x_p)
    return out[:nr, :cols]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
