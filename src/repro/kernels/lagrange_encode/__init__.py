from .ops import encode, encode_matrix  # noqa: F401
