"""jit'd public wrappers for the Lagrange-encode kernel.

On CPU (this container) the Pallas kernel runs in ``interpret=True``; on TPU
set ``interpret=False`` (the default flips on backend detection).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import encode_matrix_pallas
from .ref import encode_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def encode_matrix(g: jnp.ndarray, x2d: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return encode_matrix_pallas(g, x2d, interpret=interpret)


def encode(g: jnp.ndarray, x: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for ``repro.core.lagrange.encode``: (nr,k) x (k,*dims)."""
    lead = x.shape[0]
    out2d = encode_matrix(g, x.reshape(lead, -1), interpret=interpret)
    return out2d.reshape((g.shape[0],) + x.shape[1:])


__all__ = ["encode", "encode_matrix", "encode_ref"]
