"""jit'd public wrappers for the Lagrange-encode kernel.

On CPU (this container) the Pallas kernel runs in ``interpret=True``; on TPU
set ``interpret=False`` (the default flips on backend detection).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret

from .kernel import encode_matrix_pallas
from .ref import encode_ref


def encode_matrix(g: jnp.ndarray, x2d: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    return encode_matrix_pallas(g, x2d, interpret=default_interpret(interpret))


def encode(g: jnp.ndarray, x: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for ``repro.core.lagrange.encode``: (nr,k) x (k,*dims)."""
    lead = x.shape[0]
    out2d = encode_matrix(g, x.reshape(lead, -1), interpret=interpret)
    return out2d.reshape((g.shape[0],) + x.shape[1:])


__all__ = ["encode", "encode_matrix", "encode_ref"]
