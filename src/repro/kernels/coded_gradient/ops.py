"""jit'd public wrapper for the fused coded-gradient kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret

from .kernel import coded_gradient_pallas
from .ref import coded_gradient_ref


def coded_gradient(
    x_tilde: jnp.ndarray, y_tilde: jnp.ndarray, w: jnp.ndarray,
    *, interpret: bool | None = None,
) -> jnp.ndarray:
    """(nr,R,C),(nr,R,P),(C,P) -> (nr,C,P): all chunk gradients, fused.

    Accepts vector targets/weights ((nr,R) and (C,)) and squeezes back.
    """
    squeeze = False
    if y_tilde.ndim == 2 and w.ndim == 1:
        y_tilde = y_tilde[..., None]
        w = w[:, None]
        squeeze = True
    out = coded_gradient_pallas(
        x_tilde, y_tilde, w, interpret=default_interpret(interpret)
    )
    return out[..., 0] if squeeze else out


__all__ = ["coded_gradient", "coded_gradient_ref"]
