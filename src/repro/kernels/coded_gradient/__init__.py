from .ops import coded_gradient  # noqa: F401
