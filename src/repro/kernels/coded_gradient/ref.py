"""Pure-jnp oracle for the fused degree-2 coded gradient."""

import jax
import jax.numpy as jnp


def chunk_gradient_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """X^T (X W - Y) for one chunk: (R,C),(R,P),(C,P) -> (C,P), f32 accum."""
    resid = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                    preferred_element_type=jnp.float32) - y.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32).T, resid,
                   preferred_element_type=jnp.float32).astype(w.dtype)


def coded_gradient_ref(x_tilde: jnp.ndarray, y_tilde: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(nr,R,C),(nr,R,P),(C,P) -> (nr,C,P)."""
    return jax.vmap(chunk_gradient_ref, in_axes=(0, 0, None))(x_tilde, y_tilde, w)
