"""Pallas TPU kernel: fused worker-side degree-2 evaluation X~^T (X~ W - Y).

This is the per-round compute the paper's workers execute (linear-regression
gradient, Sec. 2.1 example).  Fusing the two GEMMs keeps the residual
``X~ W - Y`` in VMEM — it never round-trips through HBM, halving the HBM
traffic for the common case P << C (arithmetic intensity of the pair is
dominated by streaming X~ once instead of twice).

Layout per grid step (one encoded chunk v, one C-tile):
  x   (R, C)  chunk           — R<=256 rows, full row block resident
  w   (C, P)  round input     — resident
  y   (R, P)  targets         — resident
  out (C, P)  gradient

The residual needs the FULL C contraction, so the C axis of ``x`` is kept
whole per chunk (R*C*4 bytes <= a few MB in all paper configs; asserted).
Grid is over chunks only — chunks are embarrassingly parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _coded_grad_kernel(x_ref, y_ref, w_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)          # (R, C)
    y = y_ref[0].astype(jnp.float32)          # (R, P)
    w = w_ref[...].astype(jnp.float32)        # (C, P)
    resid = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    o_ref[0, :, :] = jnp.dot(x.T, resid, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coded_gradient_pallas(
    x_tilde: jnp.ndarray,   # (nr, R, C)
    y_tilde: jnp.ndarray,   # (nr, R, P)
    w: jnp.ndarray,         # (C, P)
    *,
    interpret: bool = False,
) -> jnp.ndarray:           # (nr, C, P)
    nr, r_rows, c = x_tilde.shape
    _, _, p = y_tilde.shape
    assert w.shape == (c, p), (w.shape, c, p)
    footprint = 4 * (r_rows * c + r_rows * p + 2 * c * p)
    if footprint > _VMEM_BUDGET_BYTES:
        raise ValueError(
            f"chunk working set {footprint/2**20:.1f} MiB exceeds VMEM budget; "
            "shrink chunk rows R or split C externally"
        )
    return pl.pallas_call(
        _coded_grad_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, r_rows, c), lambda v: (v, 0, 0)),
            pl.BlockSpec((1, r_rows, p), lambda v: (v, 0, 0)),
            pl.BlockSpec((c, p), lambda v: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, p), lambda v: (v, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, c, p), w.dtype),
        interpret=interpret,
    )(x_tilde, y_tilde, w)
