"""Pallas TPU kernels for the compute hot spots.

  * ``lagrange_encode``   — LCC encode/decode GEMM (generator resident in VMEM)
  * ``coded_gradient``    — fused worker-side degree-2 evaluation X~^T(X~W - Y)
  * ``flash_attention``   — causal/SWA GQA online-softmax attention
  * ``poisson_binomial``  — batched EA-allocator prefix-tail DP (B, n)->(B, n)
  * ``gf``                — exact GF(2^31 - 1) linear algebra: blocked
                            Mersenne-31 matmul + batched Lagrange-basis
                            construction (the paper's finite field F)

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with CPU-interpret fallback) and ``ref.py`` (pure-jnp oracle).
"""
