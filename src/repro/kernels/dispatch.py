"""Shared impl/interpret dispatch for the Pallas kernel packages.

Every kernel package (``poisson_binomial``, ``coded_gradient``,
``flash_attention``, ``gf``, ``lagrange_encode``) used to carry its own copy
of the same two decisions:

  * which implementation to run by default — the Pallas kernel on TPU, the
    XLA path (``ref`` / ``dot``) elsewhere;
  * whether ``pallas_call`` should run in ``interpret=True`` — yes anywhere
    but a real TPU, so CPU CI exercises the kernels through the Pallas
    interpreter.

This module is the single copy.  Two environment variables override the
defaults globally (useful for CI matrices and for flushing out
impl-divergence bugs without touching call sites):

  * ``REPRO_KERNEL_IMPL``      — force the impl name for every dispatcher
    that supports it (a dispatcher whose ``allowed`` set does not contain
    the forced name raises, loudly, rather than silently falling back);
  * ``REPRO_KERNEL_INTERPRET`` — "1"/"true" forces ``interpret=True``,
    "0"/"false" forces ``interpret=False``.

Explicit keyword arguments at a call site always win over the environment.
"""

from __future__ import annotations

import os

import jax

ENV_IMPL = "REPRO_KERNEL_IMPL"
ENV_INTERPRET = "REPRO_KERNEL_INTERPRET"

_TRUTHY = ("1", "true", "True", "yes")
_FALSY = ("0", "false", "False", "no")


def on_tpu() -> bool:
    """Is the default JAX backend a real TPU?"""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret=`` argument: explicit > env > backend default.

    The backend default is ``True`` everywhere but TPU — the Pallas kernels
    are written for the TPU lowering and run through the interpreter on
    CPU/GPU (tests, CI containers).
    """
    if interpret is not None:
        return interpret
    env = os.environ.get(ENV_INTERPRET)
    if env is not None:
        if env in _TRUTHY:
            return True
        if env in _FALSY:
            return False
        raise ValueError(f"{ENV_INTERPRET}={env!r}: expected a boolean flag")
    return not on_tpu()


def resolve_impl(
    impl: str | None,
    *,
    allowed: tuple[str, ...],
    device_impl: str = "pallas",
    host_impl: str = "ref",
) -> str:
    """Resolve an ``impl=`` argument: explicit > env > backend default.

    ``allowed`` is the dispatcher's implementation set; an explicit or
    env-forced name outside it raises ``ValueError`` (never a silent
    fallback).  The backend default is ``device_impl`` on TPU and
    ``host_impl`` elsewhere.
    """
    if impl is None:
        impl = os.environ.get(ENV_IMPL) or (device_impl if on_tpu() else host_impl)
    if impl not in allowed:
        raise ValueError(f"unknown impl {impl!r}; expected one of {allowed}")
    return impl


__all__ = ["ENV_IMPL", "ENV_INTERPRET", "default_interpret", "on_tpu",
           "resolve_impl"]
