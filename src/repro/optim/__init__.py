from .adamw import TrainState, adamw_init, adamw_update, global_norm  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
