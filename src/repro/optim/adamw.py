"""Pure-JAX AdamW with global-norm clipping and dtype-configurable states.

Optimizer states inherit the parameter sharding (they are elementwise), so
FSDP-sharded params automatically give ZeRO-sharded optimizer states.
``opt_state_dtype`` in the arch config selects fp32 (default) or bf16 moments
— the latter is what lets nemotron-340b fit 256 chips (DESIGN §5).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray


def adamw_init(params, state_dtype=jnp.float32) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return TrainState(
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    state: TrainState,
    grads,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float | None = 1.0,
) -> tuple[TrainState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    # flatten-based to stay agnostic to tuple-containing param pytrees
    leaves_p, treedef = jax.tree.flatten(state.params)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(state.m)
    leaves_v = jax.tree.leaves(state.v)
    triples = [upd(p, g, m, v) for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    new_state = TrainState(
        params=jax.tree.unflatten(treedef, [t[0] for t in triples]),
        m=jax.tree.unflatten(treedef, [t[1] for t in triples]),
        v=jax.tree.unflatten(treedef, [t[2] for t in triples]),
        step=step,
    )
    return new_state, {"grad_norm": gnorm}
