"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=32_768,
    mlp_type="swiglu",
    n_experts=8,
    top_k=2,
    window=4096,
    microbatch=16,
    scan_groups=8,
    opt_state_dtype="bfloat16",
    grad_accum_dtype="bfloat16",      # §Perf B2
    remat_policy="save_rowparallel",  # §Perf B1: -26%% collective term
    source="[arXiv:2401.04088; hf]",
)

SMOKE = ArchConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    mlp_type="swiglu",
    n_experts=4,
    top_k=2,
    window=32,
    dtype="float32",
    remat=False,
)
