"""olmoe-1b-7b [moe] — 64 experts top-8.  [arXiv:2409.02060; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50_304,
    mlp_type="swiglu",
    qk_norm=True,
    n_experts=64,
    top_k=8,
    microbatch=8,
    scan_groups=4,
    moe_impl="ep",   # §Perf D: expert parallelism, collective term -90%
    source="[arXiv:2409.02060; hf]",
)

SMOKE = ArchConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    mlp_type="swiglu",
    qk_norm=True,
    n_experts=8,
    top_k=2,
    dtype="float32",
    remat=False,
)
