"""The paper's OWN workloads (Sec. 6) as configs for benchmarks/examples."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LEASimConfig:
    """Sec. 6.1 numerical analysis: n=15 t2.micro-like workers, K*=99."""

    n: int = 15
    r: int = 10
    k: int = 50
    deg_f: int = 2
    mu_g: float = 10.0
    mu_b: float = 3.0
    deadline: float = 1.0
    rounds: int = 20_000
    # the 4 scenarios: (p_gg, p_bb)
    scenarios: tuple[tuple[float, float], ...] = (
        (0.8, 0.8), (0.8, 0.7), (0.8, 0.533), (0.9, 0.6)
    )


@dataclasses.dataclass(frozen=True)
class LEAEC2Config:
    """Sec. 6.2 EC2 experiments: linear f(X)=X^T B, K*=50, 6 scenarios."""

    n: int = 15
    r: int = 10
    deg_f: int = 1
    mu_g: float = 10.0
    mu_b: float = 1.0          # credit-exhausted t2.micro: ~10x slower (Fig. 1)
    rounds: int = 2_000
    # (rows of X_j, k, lambda, deadline)
    scenarios: tuple[tuple[int, int, float, float], ...] = (
        (25, 120, 10.0, 2.5),
        (25, 120, 30.0, 2.5),
        (30, 100, 10.0, 3.0),
        (30, 100, 30.0, 3.0),
        (60, 50, 10.0, 6.0),
        (60, 50, 30.0, 6.0),
    )
    cols: int = 3000


SIM = LEASimConfig()
EC2 = LEAEC2Config()
