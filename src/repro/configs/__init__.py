"""Architecture configs: one module per assigned architecture + the paper's
own workload.  ``get_config(name)`` / ``list_configs()`` are the registry."""

from .base import ArchConfig, SHAPE_CELLS, ShapeCell, get_config, list_configs  # noqa: F401
