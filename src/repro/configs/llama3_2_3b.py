"""llama3.2-3b [dense] — small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]

24 heads do not divide the 16-way model axis, so attention activations shard
over the query-sequence axis instead (context parallel) — DESIGN §5.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    mlp_type="swiglu",
    rope_theta=500_000.0,
    microbatch=8,
    scan_groups=7,
    source="[hf:meta-llama/Llama-3.2-1B; unverified]",
)

SMOKE = ArchConfig(
    name="llama3.2-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)
