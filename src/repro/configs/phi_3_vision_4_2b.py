"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub frontend.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision tower is a STUB per the assignment: ``input_specs`` supplies 576
precomputed patch embeddings; the backbone prepends them to the text tokens.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    mlp_type="swiglu",
    frontend="vision_stub",
    frontend_tokens=576,
    microbatch=8,
    scan_groups=8,
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
)

SMOKE = ArchConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    frontend="vision_stub",
    frontend_tokens=8,
    dtype="float32",
    remat=False,
)
