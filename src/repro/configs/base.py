"""Config system: frozen dataclasses + registry + the assigned shape cells."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture.  Field semantics follow the assignment table."""

    name: str
    family: str                 # dense | vlm | audio | hybrid | moe | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None   # sliding-window attention (tokens)

    # mlp
    mlp_type: str = "swiglu"    # swiglu | squared_relu | gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm / hybrid (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0         # zamba2: shared attn block every N mamba blocks

    # xlstm
    slstm_at: tuple[int, ...] = ()

    # enc-dec / multimodal
    encoder_layers: int = 0
    frontend: str | None = None   # audio_stub | vision_stub
    frontend_tokens: int = 0      # whisper: 1500 frames; phi3v: 576 patches

    # numerics / training
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = False
    vocab_pad_to: int = 128

    # distribution hints
    microbatch: int = 1           # grad-accumulation steps in train_step
    scan_groups: int = 1          # two-level remat scan: groups x (L/groups)
    accum_mode: str = "grads"     # grads (explicit f32 accumulator) | loss_scan
                                  # (single grad over scanned loss; bf16 grads,
                                  #  one deferred reduce — §Perf)
    act_seq_shard: bool = False   # Megatron-SP: activations sharded over seq on
                                  # the tp axis between blocks -> TP reductions
                                  # become reduce-scatter + all-gather (§Perf)
    bf16_reduce: bool = False     # row-parallel projection outputs in bf16 ->
                                  # TP partial-sum + grad reduces in bf16 (§Perf)
    remat_policy: str = "full"    # full | save_rowparallel (save post-all-reduce
                                  # activations so backward never replays TP
                                  # collectives — §Perf A5)
    grad_accum_dtype: str = "float32"   # bfloat16 halves accumulator buffers
                                        # and grad-reduce bytes (§Perf A7)
    attn_impl: str = "ref"        # ref (XLA) | flash (Pallas; TPU runtime)
    moe_impl: str = "dense"       # dense (sort-free per-example) | ep (all_to_all)
    decode_attn: str = "auto"     # auto | sharded_lse | local

    source: str = ""              # provenance note [source; verified-tier]

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def n_params(self) -> int:
        """Analytic parameter count (excludes tiny norm vectors ~O(L*d))."""
        d, f, v, hd = self.d_model, self.d_ff, self.padded_vocab, self.head_dim_
        L = self.n_layers
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "ssm" and not self.slstm_at and self.ssm_state:
            pass
        if self.mlp_type == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.n_experts:
            mlp = self.n_experts * mlp + d * self.n_experts
        if self.family == "ssm" and self.d_ff == 0:
            # xlstm: blocks own their projections; rough count
            d_in = 2 * d
            mlp = 0
            attn = 2 * d * d_in + d_in * d + 4 * d_in * hd  # proj + gates
        per_layer = attn + mlp
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            mamba = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
            per_layer = mamba
            shared_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            shared_mlp = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
            return L * per_layer + shared_attn + shared_mlp + 2 * v * d
        total = L * per_layer + 2 * v * d
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + mlp)
            cross = L * (d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d)
            total += enc + cross
        return total

    def active_params(self) -> int:
        """Active (per-token) params — differs from n_params() only for MoE."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        mlp_one = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
        full = self.n_params()
        return full - self.n_layers * (self.n_experts - self.top_k) * mlp_one


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell for the LM family."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

_ARCHS = (
    "qwen3_0_6b",
    "nemotron_4_340b",
    "yi_9b",
    "llama3_2_3b",
    "phi_3_vision_4_2b",
    "whisper_tiny",
    "zamba2_7b",
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "xlstm_125m",
)


def list_configs() -> tuple[str, ...]:
    return _ARCHS


def get_config(name: str, **overrides: Any) -> ArchConfig:
    """Load ``repro.configs.<name>.CONFIG`` (accepts dashes)."""
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str, **overrides: Any) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.SMOKE
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
