"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified]

81 Mamba2 layers; one weight-shared attention+MLP block applied every 6
layers (14 applications -> 14 KV-cache slots).  ssm_state=64.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,
    vocab_size=32_000,
    mlp_type="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    attn_every=6,
    microbatch=8,
    source="[arXiv:2411.15242; unverified]",
)

SMOKE = ArchConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_conv=4,
    attn_every=2,
    dtype="float32",
    remat=False,
)
