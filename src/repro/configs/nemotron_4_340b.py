"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.  [arXiv:2402.16819; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    mlp_type="squared_relu",
    microbatch=16,
    scan_groups=12,
    opt_state_dtype="bfloat16",   # fits 256 x 16 GB (DESIGN §5)
    grad_accum_dtype="bfloat16",  # §Perf A7b
    source="[arXiv:2402.16819; unverified]",
)

SMOKE = ArchConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=384,
    vocab_size=512,
    mlp_type="squared_relu",
    dtype="float32",
    remat=False,
)
