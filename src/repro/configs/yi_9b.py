"""yi-9b [dense] — llama-arch GQA.  [arXiv:2403.04652; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    vocab_size=64_000,
    mlp_type="swiglu",
    rope_theta=5_000_000.0,
    microbatch=8,
    scan_groups=8,
    decode_attn="sharded_lse",   # §Perf C1/C2: flash-decoding over seq shards
    source="[arXiv:2403.04652; hf]",
)

SMOKE = ArchConfig(
    name="yi-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=176,
    vocab_size=512,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)
