"""qwen3-0.6b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    microbatch=4,
    scan_groups=7,
    source="[hf:Qwen/Qwen3-8B; hf]",
)

SMOKE = ArchConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)
