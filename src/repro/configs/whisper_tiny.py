"""whisper-tiny [audio] — enc-dec, conv frontend stubbed.  [arXiv:2212.04356]

``input_specs`` provides 1500 precomputed frame embeddings (the post-conv
mel-spectrogram stream); 4 encoder + 4 decoder layers, GELU MLP.  Positional:
RoPE substitutes whisper's learned/sinusoidal embeddings (DESIGN §9).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    mlp_type="gelu",
    frontend="audio_stub",
    frontend_tokens=1500,
    microbatch=8,
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = ArchConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="gelu",
    frontend="audio_stub",
    frontend_tokens=16,
    dtype="float32",
    remat=False,
)
