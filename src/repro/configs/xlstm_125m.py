"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (d_ff=0: blocks own their
projections).  [arXiv:2405.04517; unverified]

12 blocks, sLSTM at positions (3, 9) (~the paper's mLSTM:sLSTM ratio).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    slstm_at=(3, 9),
    microbatch=4,
    source="[arXiv:2405.04517; unverified]",
)

SMOKE = ArchConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=512,
    slstm_at=(1,),
    dtype="float32",
    remat=False,
)
