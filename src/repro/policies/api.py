"""Policy protocol for the scheduling-policy subsystem.

A *policy* is a pluggable scheduler for the timely-throughput engine: given
the (M, n) worker-state trajectory it emits the (M, n) per-round predicted
probability that each worker is good next round.  The engine then feeds
every round of every policy through ONE batched
:func:`repro.core.lea.allocate` call (Lemma 4.5's two-level assignment),
exactly as it always did for LEA — a policy IS its estimator-state replay,
written as a closed-form batched trajectory function instead of a
sequential per-round update loop.

Why closed form: the batched engine vectorises over rounds, so a policy
may not carry Python-side state between rounds.  Anything expressible as a
(parallel-prefix) function of the observed trajectory qualifies — running
transition counts are a ``cumsum``, sliding windows are a cumsum
difference, discounted counts are a first-order linear recurrence
(``lax.associative_scan``), Thompson sampling is a posterior draw per
round from those counts.  All built-ins live in
:mod:`repro.policies.estimators`.

Causality contract: round m's prediction may read ``states[:m]`` only
(what the master has observed by the start of round m).  The genie oracle
is the one sanctioned exception — it additionally reads the true chain
parameters (``ctx.p_gg`` / ``ctx.p_bb``) and is the regret reference.
:mod:`repro.policies.regret` measures every other policy against it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PolicyContext(NamedTuple):
    """Everything a policy's trajectory function may look at.

    ``p_gg``/``p_bb`` are the TRUE chain parameters — ``(n,)`` for a
    stationary chain or ``(M, n)`` for a non-stationary one (row t governs
    the transition into round t; row 0 the initial distribution).  Only
    genie policies (``uses_model=True``) may read them.  ``key`` is a
    policy-private PRNG key derived from the simulation key; it is only
    consumed by ``needs_key`` policies (Thompson sampling), so
    deterministic policies stay bit-identical whether or not it exists.
    """

    states: jnp.ndarray   # (M, n) int32 observed trajectory, 1=good
    p_gg: jnp.ndarray     # (n,) or (M, n) true transition probabilities
    p_bb: jnp.ndarray     # (n,) or (M, n)
    pi_g: jnp.ndarray     # (n,) stationary dist of the round-0 chain
    key: jax.Array        # policy-private PRNG key


@dataclasses.dataclass(frozen=True)
class Policy:
    """A named scheduler: trajectory function + capability flags.

    ``trajectory(ctx) -> (M, n)`` predicted p_good per round, feeding the
    engine's batched allocator.  Values must be float32 in [0, 1].
    """

    name: str
    trajectory: Callable[[PolicyContext], jnp.ndarray]
    needs_key: bool = False    # consumes ctx.key (randomised policy)
    uses_model: bool = False   # genie: reads the true p_gg/p_bb
    description: str = ""

    def __post_init__(self):
        if not self.name or not self.name.isidentifier():
            raise ValueError(f"policy name must be an identifier, got {self.name!r}")

    def p_good_trajectory(self, ctx: PolicyContext) -> jnp.ndarray:
        """Run the estimator replay; validates the output shape at trace time."""
        p = self.trajectory(ctx)
        if p.shape != ctx.states.shape:
            raise ValueError(
                f"policy {self.name!r} returned shape {p.shape}, "
                f"expected {ctx.states.shape}"
            )
        return p
