"""repro.policies — pluggable scheduling policies with regret accounting.

Generalises the paper's LEA into one of many registry-resolved schedulers:
the engine (:mod:`repro.core.throughput`) looks every non-static strategy
name up here, replays the policy's estimator state as a closed-form
batched trajectory function, and feeds all rounds x policies through ONE
batched :func:`repro.core.lea.allocate` call.

  * :mod:`~repro.policies.api`        — the :class:`Policy` protocol;
  * :mod:`~repro.policies.estimators` — built-ins: paper LEA, sliding-window
    and discounted-count LEA (non-stationary chains), Beta-posterior
    Thompson sampling, optimistic UCB, the genie oracle;
  * :mod:`~repro.policies.registry`   — ``@policies.register``, dynamic
    ``lea_window<W>`` / ``lea_discount<D>`` family spellings;
  * :mod:`~repro.policies.regret`     — per-round / cumulative
    timely-throughput regret vs the oracle, batched over sweep grids.

Quick use::

    from repro import sweeps
    res = sweeps.run("drifting_chains", rounds=2000)
    for r in res:
        print(r.name, r.throughput["lea_window64"], r.regret["lea"])
"""

from .api import Policy, PolicyContext
from .estimators import discounted_lea, lea_p_good, oracle_p_good, windowed_lea
from .registry import (catalogue, describe, is_registered, names, register,
                       register_policy, resolve)
from .regret import (cumulative_regret, final_regret, per_round_regret,
                     regret_curve_summary)

__all__ = [
    "Policy", "PolicyContext", "catalogue", "cumulative_regret", "describe",
    "discounted_lea", "final_regret", "is_registered", "lea_p_good", "names",
    "oracle_p_good", "per_round_regret", "register", "register_policy",
    "regret_curve_summary", "resolve", "windowed_lea",
]
