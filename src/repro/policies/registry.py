"""Policy registry: named schedulers the engine resolves strategy strings to.

Registration mirrors ``repro.sweeps``: ``@register("name", ...)`` wraps a
trajectory function into a :class:`~repro.policies.api.Policy`, or
:func:`register_policy` adds a ready-made instance.  The engine
(:mod:`repro.core.throughput`) resolves every non-static strategy name
through :func:`resolve` at trace time, so a new scheduler becomes a legal
``strategies=(...)`` entry everywhere — ``simulate_strategies``, ``sweep``,
the sweeps executor, benchmarks — the moment it is registered.

Parameterised names: windowed and discounted LEA form families, so
``resolve`` also accepts dynamic spellings —

  * ``lea_window<W>``    (e.g. ``lea_window48``)  — sliding window of W
    transitions;
  * ``lea_discount<D>``  (e.g. ``lea_discount97`` = gamma 0.97,
    ``lea_discount995`` = gamma 0.995; gamma = D / 10**len(D)).

Dynamic resolutions are memoised into the registry, so repeated lookups
return the same :class:`Policy` object (jit caches stay warm).
"""

from __future__ import annotations

import re
from typing import Callable

from .api import Policy

_POLICIES: dict[str, Policy] = {}
_BUILTINS_LOADED = False

_WINDOW_RE = re.compile(r"^lea_window(\d+)$")
_DISCOUNT_RE = re.compile(r"^lea_discount(\d+)$")


def register_policy(policy: Policy) -> Policy:
    """Add a ready-made Policy; duplicate names are an error."""
    if policy.name in _POLICIES:
        raise ValueError(f"policy {policy.name!r} already registered")
    _POLICIES[policy.name] = policy
    return policy


def register(
    name: str,
    *,
    needs_key: bool = False,
    uses_model: bool = False,
    description: str = "",
):
    """Decorator: register ``fn(ctx) -> (M, n)`` as policy ``name``."""

    def deco(fn: Callable) -> Callable:
        desc = description or (fn.__doc__ or "").strip()
        register_policy(Policy(
            name=name, trajectory=fn, needs_key=needs_key,
            uses_model=uses_model,
            description=desc.splitlines()[0] if desc else "",
        ))
        return fn

    return deco


def _ensure_builtins() -> None:
    # built-in policies live in estimators.py; importing it registers them.
    # The flag is set only AFTER the import succeeds: a failed import (e.g. a
    # user pre-registered a builtin name) must not latch a half-populated
    # registry — the next call retries and surfaces the real error.
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        from . import estimators  # noqa: F401

        _BUILTINS_LOADED = True


def _resolve_dynamic(name: str) -> Policy | None:
    """Materialise a parameterised family member (memoised into _POLICIES)."""
    from . import estimators

    m = _WINDOW_RE.match(name)
    if m:
        window = int(m.group(1))
        if window < 1:
            raise KeyError(f"{name!r}: window must be >= 1")
        return register_policy(estimators.windowed_lea(window, name=name))
    m = _DISCOUNT_RE.match(name)
    if m:
        digits = m.group(1)
        gamma = int(digits) / 10 ** len(digits)
        if not 0.0 < gamma < 1.0:
            raise KeyError(f"{name!r}: discount must be in (0, 1)")
        return register_policy(estimators.discounted_lea(gamma, name=name))
    return None


def is_registered(name: str) -> bool:
    """Would :func:`resolve` succeed?  Dynamic spellings are checked against
    the same parameter bounds resolve enforces (``lea_window0`` and
    ``lea_discount0`` are invalid, not merely unresolved-yet)."""
    _ensure_builtins()
    if name in _POLICIES:
        return True
    m = _WINDOW_RE.match(name)
    if m:
        return int(m.group(1)) >= 1
    m = _DISCOUNT_RE.match(name)
    if m:
        digits = m.group(1)
        return 0.0 < int(digits) / 10 ** len(digits) < 1.0
    return False


def resolve(name: str) -> Policy:
    """Look up a policy by name (dynamic family spellings allowed)."""
    _ensure_builtins()
    pol = _POLICIES.get(name)
    if pol is None:
        pol = _resolve_dynamic(name)
    if pol is None:
        raise KeyError(
            f"unknown policy {name!r}; registered: {', '.join(sorted(_POLICIES))} "
            "(or dynamic lea_window<W> / lea_discount<D>)"
        )
    return pol


def names() -> tuple[str, ...]:
    """All concretely-registered policy names (dynamic memos included)."""
    _ensure_builtins()
    return tuple(sorted(_POLICIES))


def describe(name: str) -> str:
    return resolve(name).description


def catalogue() -> str:
    """Human-readable one-line-per-policy catalogue (ROADMAP / --help text)."""
    _ensure_builtins()
    width = max((len(n) for n in _POLICIES), default=0)
    return "\n".join(
        f"{n:<{width}}  {_POLICIES[n].description}" for n in sorted(_POLICIES)
    )
