"""Timely-throughput regret accounting against the genie oracle.

The paper's optimality claim (Thm. 5.1) is a vanishing-regret statement:
LEA's timely throughput approaches the genie-aided optimum R*(d) as the
horizon grows.  This module makes that measurable for ANY policy the
registry knows: per-round regret is the oracle's success indicator minus
the policy's on the SAME worker trajectory (the engine already runs all
strategies on one shared trajectory, so the comparison is paired, not
independent), and cumulative regret is its running sum.

Shapes are batched over the sweep grid: ``succ`` is any ``(..., M, S)``
success array — a single simulation's (M, S), a sweep row batch's
(B, M, S) — and every function maps over the leading axes.  Sums of 0/1
indicators are taken in float32 (exact below 2^24 rounds, the engine-wide
convention).

Sublinear cumulative regret == the policy converges to the oracle;
linear == a persistent gap (e.g. vanilla LEA on a drifting chain whose
all-history counts never track the current regime).  The acceptance tests
assert both regimes.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

REFERENCE = "oracle"


def _strategy_index(strategies: Sequence[str], name: str) -> int:
    try:
        return tuple(strategies).index(name)
    except ValueError:
        raise ValueError(
            f"strategy {name!r} not in {tuple(strategies)}; regret needs the "
            f"reference policy in the simulated strategy tuple"
        ) from None


def per_round_regret(
    succ,
    strategies: Sequence[str],
    policy: str,
    reference: str = REFERENCE,
):
    """(..., M) per-round regret of ``policy`` vs ``reference``.

    +1 where the oracle succeeded and the policy failed, -1 the other way
    (a policy can win single rounds by luck; only cumulative sums are
    meaningful), 0 where they agree.
    """
    succ = jnp.asarray(succ)
    j_ref = _strategy_index(strategies, reference)
    j_pol = _strategy_index(strategies, policy)
    return (
        succ[..., j_ref].astype(jnp.float32) - succ[..., j_pol].astype(jnp.float32)
    )


def cumulative_regret(
    succ,
    strategies: Sequence[str],
    policy: str,
    reference: str = REFERENCE,
):
    """(..., M) running cumulative regret along the round axis."""
    return jnp.cumsum(
        per_round_regret(succ, strategies, policy, reference), axis=-1
    )


def final_regret(
    succ,
    strategies: Sequence[str],
    reference: str = REFERENCE,
) -> Mapping[str, np.ndarray]:
    """Total regret per non-reference strategy, reduced over rounds only.

    Returns ``{strategy: (...,) float64}`` — one value per leading batch
    element (a scalar array for an unbatched (M, S) input).  The reference
    maps to exact zeros, kept so consumers can iterate uniformly.
    """
    succ = jnp.asarray(succ)
    out = {}
    for s in strategies:
        out[s] = np.asarray(
            jnp.sum(per_round_regret(succ, strategies, s, reference), axis=-1),
            np.float64,
        )
    return out


def regret_curve_summary(
    succ,
    strategies: Sequence[str],
    policy: str,
    reference: str = REFERENCE,
    *,
    points: int = 16,
) -> tuple[np.ndarray, np.ndarray]:
    """(rounds, mean cumulative regret) sampled at ``points`` horizons.

    Averages over all leading batch axes — the paired Monte-Carlo estimate
    of E[Regret(m)] used by the sublinearity tests and bench_policies.
    """
    cum = np.asarray(cumulative_regret(succ, strategies, policy, reference),
                     np.float64)
    rounds_total = cum.shape[-1]
    idx = np.unique(
        np.linspace(1, rounds_total, num=min(points, rounds_total), dtype=int)
    ) - 1
    mean_cum = cum.reshape(-1, rounds_total).mean(axis=0)
    return idx + 1, mean_cum[idx]
