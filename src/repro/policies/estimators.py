"""Built-in policies: estimator-state replays as closed-form batched functions.

Every policy here predicts round m's per-worker P[good] from the observed
trajectory prefix ``states[:m]`` (plus, for the genie, the true chain), in
one vectorised pass over all M rounds — no sequential per-round updates.
The engine stacks these (M, n) trajectories and solves ONE batched
allocator DP for all rounds x policies.

Catalogue:

  ``lea``            — the paper's LEA estimator (Sec. 3.2 phase 4): running
                       transition counts with add-one smoothing, replayed
                       as an exact cumsum (bit-identical to sequential
                       ``lea.update_estimator`` — PR-1's invariant, kept).
  ``lea_window<W>``  — sliding-window LEA: counts over the last W observed
                       transitions only (cumsum difference).  Tracks
                       non-stationary chains at the cost of variance.
  ``lea_discount<D>``— discounted-count LEA: counts decayed by gamma per
                       round (first-order recurrence via
                       ``lax.associative_scan``); effective memory
                       ~1/(1-gamma) transitions.
  ``thompson``       — Beta-posterior Thompson sampling on the transition
                       probabilities: each round draws p_gg/p_bb from the
                       posterior the counts induce and predicts with the
                       sample (randomised exploration).
  ``ucb``            — optimistic UCB: the LEA point estimate plus a
                       sqrt(2 ln m / visits) confidence bonus, clipped.
  ``oracle``         — genie-aided optimum of Thm. 4.6: the true one-step
                       conditional given the previous true state (and, on
                       non-stationary chains, the true current chain).

All count-based variants share the same prediction rule given counts
(:func:`predict_from_counts` == ``lea.smoothed_transitions`` + prev-state
select + the round-0 0.5 fill), so they differ ONLY in how history is
weighted — vanilla (all of it), windowed (last W), discounted (geometric).
With ``window >= M`` or ``gamma -> 1`` they recover vanilla LEA exactly
(the window case bit-for-bit; the tests assert it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lea as lea_mod

from .api import Policy, PolicyContext
from .registry import register

# ---------------------------------------------------------------------------
# shared count machinery
# ---------------------------------------------------------------------------


def transition_increments(states: jnp.ndarray) -> jnp.ndarray:
    """(M-1, n, 4) one-hot transition indicators between consecutive rounds.

    The same ``lea.transition_onehot`` expression the sequential estimator
    uses — every count variant below is a weighted sum of these, which is
    what keeps the vanilla cumsum replay bit-identical to per-round updates.
    """
    return lea_mod.transition_onehot(states[:-1], states[1:])


def counts_before_round(states: jnp.ndarray) -> jnp.ndarray:
    """Vanilla LEA counts entering each round: (M, n, 4) exact cumsum.

    Round m sees the transition tallies among ``states[0..m-1]`` — a shifted
    cumsum of the increments (exact in float32: integer counts < 2^24).
    Rounds 0 and 1 have no completed transition and see zeros.
    """
    rounds_total, n = states.shape
    if rounds_total < 2:
        return jnp.zeros((rounds_total, n, 4), jnp.float32)
    csum = jnp.cumsum(transition_increments(states), axis=0)  # (M-1, n, 4)
    zeros = jnp.zeros((1, n, 4), jnp.float32)
    return jnp.concatenate([zeros, zeros, csum[:-1]], axis=0)


def windowed_counts_before_round(states: jnp.ndarray, window: int) -> jnp.ndarray:
    """Sliding-window counts entering each round: last ``window`` transitions.

    cs[j] = sum of the first j increments, so round m's window is
    ``cs[m-1] - cs[max(m-1-window, 0)]`` — a difference of exact integer
    float32 cumsums, so ``window >= M`` reproduces
    :func:`counts_before_round` bit-for-bit.
    """
    rounds_total, n = states.shape
    if rounds_total < 2:
        return jnp.zeros((rounds_total, n, 4), jnp.float32)
    csum = jnp.cumsum(transition_increments(states), axis=0)  # (M-1, n, 4)
    cs = jnp.concatenate(
        [jnp.zeros((1, n, 4), jnp.float32), csum], axis=0
    )                                                          # cs[j], j=0..M-1
    m = jnp.arange(rounds_total)
    hi = jnp.maximum(m - 1, 0)
    lo = jnp.maximum(m - 1 - window, 0)
    return cs[hi] - cs[lo]


def discounted_counts_before_round(states: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Geometrically-discounted counts entering each round.

    z[j] = gamma * z[j-1] + inc[j] — a first-order linear recurrence, run as
    a ``lax.associative_scan`` over (coefficient, value) pairs (O(log M)
    depth, same shape discipline as the trajectory sampler).  Round m sees
    ``z[m-2]``, mirroring the vanilla shift.
    """
    rounds_total, n = states.shape
    if rounds_total < 2:
        return jnp.zeros((rounds_total, n, 4), jnp.float32)
    inc = transition_increments(states)                        # (M-1, n, 4)
    coef = jnp.full(inc.shape, jnp.float32(gamma))

    def combine(a, b):
        ca, va = a
        cb, vb = b
        return (ca * cb, cb * va + vb)

    _, z = jax.lax.associative_scan(combine, (coef, inc), axis=0)
    zeros = jnp.zeros((1, n, 4), jnp.float32)
    return jnp.concatenate([zeros, zeros, z[:-1]], axis=0)


def prev_state_rows(states: jnp.ndarray) -> jnp.ndarray:
    """(M, n) state observed entering each round (round 0 repeats itself —
    masked out by the round-0 fill everywhere it is used)."""
    return jnp.concatenate([states[:1], states[:-1]], axis=0)


def predict_from_counts(states: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """The LEA prediction rule given per-round counts: smoothed transition
    estimates, selected by the last observed state; 0.5 before any
    observation.  Shared verbatim by all count-based policies."""
    p_gg_hat, p_bb_hat = lea_mod.smoothed_transitions(counts)
    prev_state = prev_state_rows(states)
    p_good = jnp.where(prev_state == 1, p_gg_hat, 1.0 - p_bb_hat)
    first = (jnp.arange(states.shape[0]) == 0)[:, None]
    return jnp.where(first, 0.5, p_good)


def lea_p_good(states: jnp.ndarray) -> jnp.ndarray:
    """Vanilla LEA's (M, n) predicted p_good — the PR-1 closed-form replay,
    bit-identical to sequential ``lea.update_estimator`` calls."""
    return predict_from_counts(states, counts_before_round(states))


def oracle_p_good(
    states: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    pi_g: jnp.ndarray,
) -> jnp.ndarray:
    """Genie p_good per round: the exact conditional given last round's true
    state (round 0: the initial stationary distribution).  ``p_gg``/``p_bb``
    may be (n,) or, for a non-stationary chain, (M, n) with row t governing
    the transition into round t — the genie always knows the current chain.
    """
    rounds = states.shape[0]
    if p_gg.ndim == 1:
        p_gg_t, p_bb_t = p_gg[None, :], p_bb[None, :]
    else:
        p_gg_t, p_bb_t = p_gg, p_bb
    prev_state = prev_state_rows(states)
    p_good = jnp.where(prev_state == 1, p_gg_t, 1.0 - p_bb_t)
    first = (jnp.arange(rounds) == 0)[:, None]
    return jnp.where(first, pi_g[None, :], p_good)


# ---------------------------------------------------------------------------
# registered policies
# ---------------------------------------------------------------------------


@register("lea", description="paper LEA: all-history transition counts (Sec. 3.2)")
def _lea(ctx: PolicyContext) -> jnp.ndarray:
    return lea_p_good(ctx.states)


@register("oracle", uses_model=True,
          description="genie-aided optimum (Thm. 4.6): true one-step conditional")
def _oracle(ctx: PolicyContext) -> jnp.ndarray:
    return oracle_p_good(ctx.states, ctx.p_gg, ctx.p_bb, ctx.pi_g)


def windowed_lea(window: int, name: str | None = None) -> Policy:
    """A sliding-window LEA policy instance (``resolve("lea_window<W>")``)."""
    if window < 1:
        raise ValueError("window must be >= 1")

    def traj(ctx: PolicyContext) -> jnp.ndarray:
        return predict_from_counts(
            ctx.states, windowed_counts_before_round(ctx.states, window)
        )

    return Policy(
        name=name or f"lea_window{window}", trajectory=traj,
        description=f"windowed LEA: counts over the last {window} transitions",
    )


def _discount_name(gamma: float) -> str:
    """The canonical ``lea_discount<D>`` spelling with D = gamma's decimal
    digits (gamma = D / 10**len(D)): 0.97 -> lea_discount97, 0.995 ->
    lea_discount995 — exactly what the registry's dynamic resolver parses
    back, so registration and resolution can never disagree."""
    digits = f"{gamma:.12f}".rstrip("0")[2:]   # "0.97" -> "97"
    if not digits or int(digits) / 10 ** len(digits) != gamma:
        raise ValueError(
            f"gamma={gamma!r} has no exact lea_discount<D> spelling; pass an "
            "explicit name="
        )
    return f"lea_discount{digits}"


def discounted_lea(gamma: float, name: str | None = None) -> Policy:
    """A discounted-count LEA policy instance (``resolve("lea_discount<D>")``)."""
    if not 0.0 < gamma < 1.0:
        raise ValueError("gamma must be in (0, 1)")

    def traj(ctx: PolicyContext) -> jnp.ndarray:
        return predict_from_counts(
            ctx.states, discounted_counts_before_round(ctx.states, gamma)
        )

    return Policy(
        name=name or _discount_name(gamma), trajectory=traj,
        description=f"discounted LEA: counts decayed by gamma={gamma:g} per round",
    )


@register("thompson", needs_key=True,
          description="Beta-posterior Thompson sampling on transition probs")
def _thompson(ctx: PolicyContext) -> jnp.ndarray:
    """Posterior draw per round: p_gg ~ Beta(C_gg+1, C_gb+1) and
    p_bb ~ Beta(C_bb+1, C_bg+1) (the Laplace-smoothed counts ARE the
    posterior parameters), predict with the sample.  Rounds with no data
    draw from the uniform prior — native exploration."""
    counts = counts_before_round(ctx.states)
    kg, kb = jax.random.split(ctx.key)
    s_gg = jax.random.beta(kg, counts[..., 0] + 1.0, counts[..., 1] + 1.0)
    s_bb = jax.random.beta(kb, counts[..., 3] + 1.0, counts[..., 2] + 1.0)
    prev_state = prev_state_rows(ctx.states)
    return jnp.where(prev_state == 1, s_gg, 1.0 - s_bb).astype(jnp.float32)


@register("ucb", description="optimistic UCB: LEA estimate + sqrt(2 ln m / visits)")
def _ucb(ctx: PolicyContext) -> jnp.ndarray:
    """Optimism in the face of uncertainty: the LEA point estimate plus a
    per-worker confidence bonus shrinking with the visits to the current
    conditioning state, clipped into [0, 1]."""
    states = ctx.states
    counts = counts_before_round(states)
    p_gg_hat, p_bb_hat = lea_mod.smoothed_transitions(counts)
    prev_state = prev_state_rows(states)
    p_hat = jnp.where(prev_state == 1, p_gg_hat, 1.0 - p_bb_hat)
    visits = jnp.where(
        prev_state == 1,
        counts[..., 0] + counts[..., 1],
        counts[..., 2] + counts[..., 3],
    )
    m = jnp.arange(states.shape[0], dtype=jnp.float32)[:, None]
    bonus = jnp.sqrt(2.0 * jnp.log1p(m) / (visits + 1.0))
    return jnp.clip(p_hat + bonus, 0.0, 1.0).astype(jnp.float32)


# concrete members of the parameterised families, pre-registered so
# ``policies.names()`` / the catalogue show canonical instances
from .registry import register_policy as _register_policy  # noqa: E402

_register_policy(windowed_lea(64))
_register_policy(windowed_lea(256))
_register_policy(discounted_lea(0.97))
