"""xLSTM-125m: interleaved mLSTM (matrix-memory, chunk-parallel) and sLSTM
(scalar-memory, time-scan) blocks.  12 layers — unrolled Python loop (no scan;
the per-block param shapes differ between the two cell types)."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .lm import _logits
from .sharding import shard

Params = dict[str, Any]


def block_types(cfg) -> list[str]:
    return ["slstm" if i in cfg.slstm_at else "mlstm" for i in range(cfg.n_layers)]


def _mlstm_block_params(key, cfg, dtype):
    d = cfg.d_model
    d_in = 2 * d
    h = cfg.n_heads
    ks = jax.random.split(key, 8)

    def w(k, *shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dtype)

    return {
        "ln": jnp.ones((d,), dtype),
        "w_up": w(ks[0], d, 2 * d_in),
        "conv_w": (jax.random.normal(ks[1], (4, d_in), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": w(ks[2], d_in, d_in),
        "wk": w(ks[3], d_in, d_in),
        "wv": w(ks[4], d_in, d_in),
        "w_if": w(ks[5], d_in, 2 * h),
        "b_if": jnp.zeros((2 * h,), jnp.float32),
        "gn": jnp.ones((d_in,), dtype),
        "w_down": w(ks[6], d_in, d),
    }


def _slstm_block_params(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    f = ((4 * d // 3) + 63) // 64 * 64
    ks = jax.random.split(key, 6)

    def w(k, *shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dtype)

    return {
        "ln": jnp.ones((d,), dtype),
        "w_gates": w(ks[0], d, 4 * d),       # (z,i,f,o) x (H*Dh)
        "r": (jax.random.normal(ks[1], (h, 4, dh, dh), jnp.float32) * 0.02).astype(dtype),
        "gn": jnp.ones((d,), dtype),
        "w_o": w(ks[2], d, d),
        "ln2": jnp.ones((d,), dtype),
        "w1": w(ks[3], d, 2 * f),
        "w2": w(ks[4], f, d),
    }


def init_params(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = []
    for i, kind in enumerate(block_types(cfg)):
        mk = _slstm_block_params if kind == "slstm" else _mlstm_block_params
        blocks.append(mk(keys[i], cfg, dtype))
    return {
        "embed": (jax.random.normal(keys[-3], (v, d), jnp.float32) * 0.02).astype(dtype),
        "blocks": tuple(blocks),
        "ln_f": jnp.ones((d,), dtype),
        "lm_head": (jax.random.normal(keys[-2], (d, v), jnp.float32) * 0.02).astype(dtype),
    }


# ---------------------------------------------------------------------------
# blocks (train/prefill form)
# ---------------------------------------------------------------------------

def _mlstm_block(x, p, cfg, *, state=None, return_state=False):
    b, s, d = x.shape
    h = cfg.n_heads
    d_in = 2 * d
    dh = d_in // h
    z = L.rms_norm(x, p["ln"])
    up = L.dot(z, p["w_up"])
    x_in, gate = jnp.split(up, 2, axis=-1)

    if state is None:
        conv_in = x_in
        conv_state_out = x_in[:, -3:, :]
    else:
        (cell, conv_state) = state
        conv_in = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
        conv_state_out = conv_in[:, -3:, :]
    x_c = L.silu(_conv_slice(conv_in, p, s))

    q = L.dot(x_c, p["wq"]).reshape(b, s, h, dh)
    k = L.dot(x_c, p["wk"]).reshape(b, s, h, dh)
    v = L.dot(x_in, p["wv"]).reshape(b, s, h, dh)
    if_pre = L.dot(x_in, p["w_if"]).astype(jnp.float32) + p["b_if"]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)          # (B,S,H)

    chunk = min(128, s) if s % 128 != 0 else 128
    if s % chunk != 0:
        chunk = s  # small smoke shapes: single chunk
    cell_in = None if state is None else state[0]
    out = L.mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk,
                          initial=cell_in, return_state=return_state)
    if return_state:
        out, cell_state = out
    hid = out.reshape(b, s, d_in).astype(x.dtype)
    hid = L.rms_norm(hid, p["gn"])
    y = L.dot(hid * L.silu(gate), p["w_down"])
    if return_state:
        return x + y, (cell_state, conv_state_out)
    return x + y


def _conv_slice(conv_in, p, s):
    """Causal depthwise conv4 returning only the last s positions."""
    out = L._causal_conv(conv_in, p["conv_w"], p["conv_b"])
    return out[:, -s:, :]


def _slstm_block(x, p, cfg, *, state=None, return_state=False):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    z = L.rms_norm(x, p["ln"])
    gates = L.dot(z, p["w_gates"]).reshape(b, s, 4, h, dh).swapaxes(2, 3)  # (B,S,H,4,D)
    out = L.slstm_scan(gates, p["r"], initial=state, return_state=return_state)
    if return_state:
        out, new_state = out
    hid = out.reshape(b, s, d).astype(x.dtype)
    hid = L.rms_norm(hid, p["gn"])
    y = x + L.dot(hid, p["w_o"])
    # post GLU MLP (proj factor 4/3)
    u = L.dot(L.rms_norm(y, p["ln2"]), p["w1"])
    a, g = jnp.split(u, 2, axis=-1)
    y = y + L.dot(a * L.silu(g), p["w2"])
    if return_state:
        return y, new_state
    return y


def _forward(params, tokens, cfg, caches=None, return_states=False):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = shard(x, "dp", None, None)
    states = []
    kinds = block_types(cfg)
    for i, p in enumerate(params["blocks"]):
        blk = _slstm_block if kinds[i] == "slstm" else _mlstm_block
        st = None if caches is None else caches[i]

        def run(x_, p_, st_, blk=blk):
            return blk(x_, p_, cfg, state=st_, return_state=return_states)

        fn = jax.checkpoint(run) if cfg.remat else run
        if return_states:
            x, s_out = fn(x, p, st)
            states.append(s_out)
        else:
            x = fn(x, p, st)
    x = L.rms_norm(x, params["ln_f"])
    return (x, states) if return_states else x


def train_loss(params, batch, cfg):
    tokens = batch["tokens"]
    x = _forward(params, tokens, cfg)
    logits = _logits(params, x, cfg)
    pred, tgt = logits[:, :-1], tokens[:, 1:]
    lse = jax.nn.logsumexp(pred, axis=-1)
    true = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=None) -> Params:
    """xLSTM state is O(1) in sequence length (the 500k-context win)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.n_heads
    caches = []
    for kind in block_types(cfg):
        if kind == "mlstm":
            d_in = 2 * d
            dh = d_in // h
            cell = (
                jnp.zeros((batch_size, h, dh, dh), jnp.float32),
                jnp.zeros((batch_size, h, dh), jnp.float32),
                jnp.full((batch_size, h), -jnp.inf),
            )
            conv = jnp.zeros((batch_size, 3, d_in), dtype)
            caches.append((cell, conv))
        else:
            dh = d // h
            caches.append(tuple(jnp.zeros((batch_size, h, dh), jnp.float32) for _ in range(4)))
    return {"blocks": tuple(caches), "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, *, max_len: int | None = None):
    tokens = batch["tokens"]
    x, states = _forward(params, tokens, cfg,
                         caches=init_cache(cfg, tokens.shape[0], 0)["blocks"],
                         return_states=True)
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    cache = {"blocks": tuple(states), "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, cache


def decode_step(params, batch, cache, cfg):
    tok = batch["next_token"]
    x, states = _forward(params, tok[:, None], cfg, caches=cache["blocks"],
                         return_states=True)
    logits = _logits(params, x, cfg)[:, 0]
    return logits, {"blocks": tuple(states), "pos": cache["pos"] + 1}
