"""Decoder-only LM (dense / MoE / VLM / audio-backbone) with scan-over-layers.

Covers qwen3, nemotron, yi, llama3.2, phi-3-vision (vision stub), mixtral,
olmoe.  Whisper (enc-dec), zamba2 (hybrid) and xlstm live in their own
modules but share this file's embedding/loss helpers.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from . import layers as L
from .sharding import shard

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_block_params(key, cfg, dtype, n_layers):
    d, f = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 12)
    L_ = n_layers

    def w(k, *shape, scale=None):
        scale = scale if scale is not None else 0.02
        return (jax.random.normal(k, (L_, *shape), jnp.float32) * scale).astype(dtype)

    p = {
        "ln1": jnp.ones((L_, d), dtype),
        "ln2": jnp.ones((L_, d), dtype),
        "wq": w(ks[0], d, hq * hd),
        "wk": w(ks[1], d, hkv * hd),
        "wv": w(ks[2], d, hkv * hd),
        "wo": w(ks[3], hq * hd, d, scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((L_, hd), dtype)
        p["k_scale"] = jnp.ones((L_, hd), dtype)
    if cfg.n_experts:
        e = cfg.n_experts
        p["router"] = w(ks[4], d, e)
        p["w_gate"] = w(ks[5], e, d, f)
        p["w_up"] = w(ks[6], e, d, f)
        p["w_down"] = w(ks[7], e, f, d, scale=0.02 / math.sqrt(2 * cfg.n_layers))
    elif cfg.mlp_type == "swiglu":
        p["w_gate"] = w(ks[5], d, f)
        p["w_up"] = w(ks[6], d, f)
        p["w_down"] = w(ks[7], f, d, scale=0.02 / math.sqrt(2 * cfg.n_layers))
    else:
        p["w_up"] = w(ks[6], d, f)
        p["w_down"] = w(ks[7], f, d, scale=0.02 / math.sqrt(2 * cfg.n_layers))
    return p


def init_params(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    k_embed, k_blocks, k_head, k_front = jax.random.split(key, 4)
    params: Params = {
        "embed": (jax.random.normal(k_embed, (v, d), jnp.float32) * 0.02).astype(dtype),
        "blocks": _dense_block_params(k_blocks, cfg, dtype, cfg.n_layers),
        "ln_f": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (d, v), jnp.float32) * 0.02).astype(dtype)
    if cfg.frontend == "vision_stub":
        # projection for precomputed patch embeddings (stub frontend)
        params["patch_proj"] = (
            jax.random.normal(k_front, (d, d), jnp.float32) * 0.02
        ).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(x, bp, cfg, positions):
    h = L.attention_train(L.rms_norm(x, bp["ln1"]), bp, cfg, positions=positions)
    if cfg.remat_policy == "save_rowparallel":
        h = _checkpoint_name(h, "rowparallel_out")
    x = x + h
    z = L.rms_norm(x, bp["ln2"])
    m = L.moe(z, bp, cfg) if cfg.n_experts else L.mlp(z, bp, cfg)
    if cfg.remat_policy == "save_rowparallel":
        m = _checkpoint_name(m, "rowparallel_out")
    x = x + m
    # Megatron-SP option: inter-block activations sharded over sequence on the
    # tp axis, turning the TP output all-reduces into reduce-scatters (§Perf).
    return shard(x, "dp", "tp" if cfg.act_seq_shard else None, None)


def _remat_policy(cfg):
    if cfg.remat_policy == "save_rowparallel":
        # backward never replays the TP partial-sum all-reduces (§Perf A5)
        return jax.checkpoint_policies.save_only_these_names("rowparallel_out")
    return None


def _run_blocks(x, params, cfg, positions):
    body = _block
    if cfg.remat:
        body = jax.checkpoint(_block, static_argnums=(2,), policy=_remat_policy(cfg))

    def scan_fn(carry, bp):
        return body(carry, bp, cfg, positions), None

    g = max(1, cfg.scan_groups)
    blocks = params["blocks"]
    if g > 1 and cfg.n_layers % g == 0:
        # two-level remat scan: outer saves G carries, each group's backward
        # recomputes its K=L/G layers — O(G + K) residuals instead of O(L).
        k = cfg.n_layers // g
        grouped = jax.tree.map(lambda a: a.reshape((g, k) + a.shape[1:]), blocks)

        def group_fn(carry, gp):
            out, _ = jax.lax.scan(scan_fn, carry, gp)
            return out, None

        if cfg.remat:
            group_fn = jax.checkpoint(group_fn, policy=_remat_policy(cfg))
        x, _ = jax.lax.scan(group_fn, x, grouped)
        return x
    x, _ = jax.lax.scan(scan_fn, x, blocks)
    return x


def _embed_sequence(params, batch, cfg):
    """Tokens (+ optional stub-frontend embeddings) -> (B, S_total, d), plus
    the number of prefix (non-text) positions."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    prefix = 0
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(x.dtype)        # (B, P, d) precomputed
        patches = L.dot(patches, params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
        prefix = patches.shape[1]
    return shard(x, "dp", None, None), prefix


def _logits(params, x, cfg):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jax.lax.dot_general(
        x, head, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    if cfg.padded_vocab != cfg.vocab_size:                # mask padded vocab
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return shard(logits, "dp", None, "tp")


def train_loss(params, batch, cfg):
    """Mean next-token cross-entropy over text positions."""
    x, prefix = _embed_sequence(params, batch, cfg)
    positions = jnp.arange(x.shape[1])
    x = _run_blocks(x, params, cfg, positions)
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x, cfg)                      # (B, S_total, V) f32
    tokens = batch["tokens"]
    text_logits = logits[:, prefix:, :]
    pred = text_logits[:, :-1]
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(pred, axis=-1)
    true = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((cfg.n_layers, batch_size, hkv, max_len, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch_size, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg, *, max_len: int | None = None):
    """Forward the prompt, return (last-position logits, KV cache)."""
    x, prefix = _embed_sequence(params, batch, cfg)
    s_total = x.shape[1]
    max_len = max_len or s_total
    positions = jnp.arange(s_total)

    def body(carry, bp):
        att, (k, v) = L.attention_train(
            L.rms_norm(carry, bp["ln1"]), bp, cfg, positions=positions, return_kv=True
        )
        x2 = carry + att
        z = L.rms_norm(x2, bp["ln2"])
        x2 = x2 + (L.moe(z, bp, cfg) if cfg.n_experts else L.mlp(z, bp, cfg))
        pad = max_len - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return shard(x2, "dp", None, None), (k.astype(carry.dtype), v.astype(carry.dtype))

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(s_total, jnp.int32)}
    return logits, cache


def decode_step(params, batch, cache, cfg):
    """One-token decode.  batch = {"next_token": (B,)}; cache from init/prefill.

    The stacked KV cache rides the layer scan as a CARRY with in-place slice
    updates (aliases the donated buffer) — the scan-ys alternative rebuilds
    the whole cache every token (§Perf C2).
    """
    tok = batch["next_token"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = cache["pos"]

    def body(carry, xs):
        x_c, ks, vs = carry
        bp, idx = xs
        ck = jax.lax.dynamic_index_in_dim(ks, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(vs, idx, 0, keepdims=False)
        att, ck, cv = L.attention_decode(L.rms_norm(x_c, bp["ln1"]), bp, cfg, ck, cv, pos)
        ks = jax.lax.dynamic_update_index_in_dim(ks, ck, idx, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, cv, idx, 0)
        x2 = x_c + att
        z = L.rms_norm(x2, bp["ln2"])
        x2 = x2 + (L.moe(z, bp, cfg) if cfg.n_experts else L.mlp(z, bp, cfg))
        return (x2, ks, vs), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["blocks"], jnp.arange(cfg.n_layers)),
    )
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x, cfg)[:, 0]                # (B, V)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
