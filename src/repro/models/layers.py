"""Shared layer library for all 10 assigned architectures.

Everything is a pure function over explicit param pytrees (dicts of arrays),
bf16 storage / f32 accumulation, and shardable under pjit via the logical
constraints in :mod:`repro.models.sharding`.

Attention implementations:
  * ``ref``       — dense masked softmax (baseline; memory-roofline honest)
  * ``blockwise`` — online-softmax lax.scan over KV blocks (pure XLA flash;
                    the beyond-paper memory-term optimization, §Perf)
  * ``flash``     — Pallas kernel (TPU runtime path; validated in interpret)

Sequence mixers: GQA attention (qk-norm, sliding window), Mamba2/SSD
(chunk-parallel scan + O(1) decode step), mLSTM (stabilized chunkwise form),
sLSTM (time scan).  MoE: per-example capacity routing (sort-free, shardable).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .sharding import shard

Params = dict[str, Any]
_NEG = -1e30


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def dot(x: jnp.ndarray, w: jnp.ndarray, *, native_out: bool = False) -> jnp.ndarray:
    """Matmul with f32 accumulation, output in x.dtype.

    ``native_out=True`` emits the dot with the output dtype directly (no f32
    intermediate).  For row-parallel projections under TP this is what makes
    the SPMD partitioner reduce partial sums in bf16 instead of f32 — the MXU
    still accumulates the contraction in f32 internally (§Perf A4).
    """
    if native_out:
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=x.dtype
        )
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(x.dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                               # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# attention (GQA + qk-norm + sliding window)
# ---------------------------------------------------------------------------

def _split_heads(x: jnp.ndarray, n: int, d: int) -> jnp.ndarray:
    return x.reshape(x.shape[:-1] + (n, d))


def _qk_normalize(q, k, p, cfg):
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"])
        k = rms_norm(k, p["k_scale"])
    return q, k


def attention_train(
    x: jnp.ndarray,            # (B, S, d)
    p: Params,
    cfg,
    *,
    positions: jnp.ndarray,    # (S,)
    causal: bool = True,
    kv_x: jnp.ndarray | None = None,   # cross-attention source (B, Sk, d)
    return_kv: bool = False,
):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = _split_heads(dot(x, p["wq"]), hq, hd)            # (B, S, Hq, Dh)
    k = _split_heads(dot(src, p["wk"]), hkv, hd)
    v = _split_heads(dot(src, p["wv"]), hkv, hd)
    q, k = _qk_normalize(q, k, p, cfg)
    if kv_x is None:                                     # self-attn: rotary
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions[:sk] if positions.shape[0] >= sk else positions, cfg.rope_theta)
    # attention activation sharding: heads over tp when divisible, otherwise
    # context-parallel (query-sequence over tp) — DESIGN §5.
    if hq % 16 == 0:
        q = shard(q.swapaxes(1, 2), "dp", "tp", None, None)
    else:
        q = shard(q.swapaxes(1, 2), "dp", None, "tp", None)
    k = k.swapaxes(1, 2)                                 # (B, Hkv, Sk, Dh)
    v = v.swapaxes(1, 2)

    impl = getattr(cfg, "attn_impl", "ref")
    if impl == "flash":
        from repro.kernels.flash_attention import flash_attention

        o = flash_attention(q, k, v, causal=causal and kv_x is None, window=cfg.window)
    elif impl == "blockwise":
        o = _blockwise_attention(q, k, v, causal=causal and kv_x is None, window=cfg.window)
    else:
        o = _dense_attention(q, k, v, causal=causal and kv_x is None, window=cfg.window)
    o = o.swapaxes(1, 2).reshape(b, s, hq * hd)
    y = dot(o, p["wo"], native_out=getattr(cfg, "bf16_reduce", False))
    if return_kv:
        return y, (k, v)
    return y


def _gqa_scores(q, k):
    """(B,Hq,S,D) x (B,Hkv,Sk,D) -> f32 (B,Hq,S,Sk) without repeating KV.

    bf16 x bf16 -> f32 via preferred_element_type (MXU-style accumulation);
    no materialized f32 copies of Q/K.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d)
    out = jnp.einsum("bkgsd,bktd->bkgst", qg, k, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, s, k.shape[2])


def _gqa_combine(w, v):
    """f32 (B,Hq,S,Sk) x (B,Hkv,Sk,D) -> f32 (B,Hq,S,D).

    Attention weights are cast to the value dtype for the PV matmul (the
    standard flash-attention convention) to avoid f32 copies of V.
    """
    b, hq, s, sk = w.shape
    hkv = v.shape[1]
    g = hq // hkv
    wg = w.reshape(b, hkv, g, s, sk).astype(v.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", wg, v, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, s, v.shape[3])


def _attn_mask(sq: int, sk: int, causal: bool, window: int | None) -> jnp.ndarray:
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    return m


def _dense_attention(q, k, v, *, causal: bool, window: int | None):
    d = q.shape[-1]
    s = _gqa_scores(q, k) * (d ** -0.5)                  # f32 (B,H,S,Sk)
    mask = _attn_mask(q.shape[2], k.shape[2], causal, window)
    s = jnp.where(mask[None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_combine(w, v).astype(q.dtype)


def _blockwise_attention(q, k, v, *, causal: bool, window: int | None, block: int = 512):
    """Online-softmax over KV blocks — O(S*block) memory, pure XLA."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    scale = d ** -0.5
    nk = (sk + block - 1) // block
    pad = nk * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nk, block, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, block, d).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kblk, vblk, ik = xs
        s = _gqa_scores(q, kblk) * scale                 # f32 (B,H,S,block)
        q_pos = jnp.arange(sq)[:, None] + (sk - sq)
        k_pos = ik * block + jnp.arange(block)[None, :]
        mask = k_pos < sk
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None], s, _NEG)
        m_new = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = alpha * l_prev + p.sum(-1)
        acc = acc * alpha[..., None] + _gqa_combine(p, vblk)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hq, sq), _NEG, jnp.float32),
        jnp.zeros((b, hq, sq), jnp.float32),
        jnp.zeros((b, hq, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def attention_decode(
    x_t: jnp.ndarray,          # (B, 1, d)
    p: Params,
    cfg,
    cache_k: jnp.ndarray,      # (B, Hkv, S, Dh)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,          # scalar int32 — number of tokens already cached
    *,
    cross: bool = False,       # cross-attn: read-only cache, no rope, attend [0, pos)
):
    b = x_t.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = _split_heads(dot(x_t, p["wq"]), hq, hd)          # (B,1,Hq,Dh)
    g = hq // hkv
    from .sharding import _current
    sharded = (getattr(cfg, "decode_attn", "auto") == "sharded_lse" and not cross
               and _current()[0] is not None)   # needs an active mesh
    if not cross:
        k_new = _split_heads(dot(x_t, p["wk"]), hkv, hd)
        v_new = _split_heads(dot(x_t, p["wv"]), hkv, hd)
        q, k_new = _qk_normalize(q, k_new, p, cfg)
        q = rope(q, pos[None], cfg.rope_theta)
        k_new = rope(k_new, pos[None], cfg.rope_theta)
        k_new = k_new.swapaxes(1, 2).astype(cache_k.dtype)   # (B,Hkv,1,Dh)
        v_new = v_new.swapaxes(1, 2).astype(cache_v.dtype)
        if sharded:
            qg = q[:, 0].reshape(b, hkv, g, hd)
            o, cache_k, cache_v = _sharded_lse_decode(
                qg, k_new, v_new, cache_k, cache_v, pos, cfg)
            o = o.reshape(b, 1, hq * hd).astype(x_t.dtype)
            return dot(o, p["wo"]), cache_k, cache_v
        cache_k = jax.lax.dynamic_update_slice(cache_k, k_new, (0, 0, pos, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v_new, (0, 0, pos, 0))
        valid_len = pos + 1
    else:
        q, _ = _qk_normalize(q, q, p, cfg) if cfg.qk_norm else (q, None)
        valid_len = pos

    qg = q[:, 0].reshape(b, hkv, g, hd)
    # bf16 reads of the cache with f32 accumulation — no f32 cache copies
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(cache_k.dtype), cache_k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    k_pos = jnp.arange(cache_k.shape[2])[None, None, None, :]
    mask = k_pos < valid_len
    if cfg.window is not None and not cross:
        mask &= k_pos > valid_len - 1 - cfg.window
    s = jnp.where(mask, s, _NEG)
    w = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bkgs,bksd->bkgd", w, cache_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, hq * hd).astype(x_t.dtype)
    y = dot(o, p["wo"])
    return y, cache_k, cache_v


def _sharded_lse_decode(qg, k_new, v_new, cache_k, cache_v, pos, cfg):
    """Flash-decoding over a sequence-sharded KV cache (§Perf C).

    shard_map over the mesh: each ``tp`` shard holds a contiguous seq slice of
    the cache.  The owning shard performs a 1-token read-modify-write (never a
    full-shard masked rewrite — the naive pjit lowering of a dynamic update on
    a sharded dim), computes partial attention over its slice, and the shards
    merge with a log-sum-exp correction (pmax/psum over ``tp``).

    qg (B,Hkv,G,Dh) replicated over tp; caches (B,Hkv,S,Dh) P(dp,·,tp,·).
    Falls back to the dense path when no mesh is active (CPU tests).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from .sharding import _current, resolve

    mesh, _ = _current()
    if mesh is None or "model" not in mesh.axis_names:
        raise RuntimeError("decode_attn=sharded_lse requires an active mesh")
    hd = qg.shape[-1]
    scale = hd ** -0.5
    window = cfg.window

    def local(qg_l, kn_l, vn_l, ck_l, cv_l, pos_l):
        tp_i = jax.lax.axis_index("model")
        s_loc = ck_l.shape[2]
        start = tp_i * s_loc
        rel = pos_l - start
        in_range = (rel >= 0) & (rel < s_loc)
        relc = jnp.clip(rel, 0, s_loc - 1)
        # 1-token read-modify-write on the local slice
        old_k = jax.lax.dynamic_slice(ck_l, (0, 0, relc, 0), kn_l.shape)
        old_v = jax.lax.dynamic_slice(cv_l, (0, 0, relc, 0), vn_l.shape)
        ck_l = jax.lax.dynamic_update_slice(
            ck_l, jnp.where(in_range, kn_l, old_k), (0, 0, relc, 0))
        cv_l = jax.lax.dynamic_update_slice(
            cv_l, jnp.where(in_range, vn_l, old_v), (0, 0, relc, 0))
        # partial attention over the local slice
        s = jnp.einsum("bkgd,bksd->bkgs", qg_l.astype(ck_l.dtype), ck_l,
                       preferred_element_type=jnp.float32) * scale
        k_pos = start + jnp.arange(s_loc)[None, None, None, :]
        mask = k_pos <= pos_l
        if window is not None:
            mask &= k_pos > pos_l - window
        s = jnp.where(mask, s, _NEG)
        m_loc = jnp.max(s, axis=-1)                          # (B,Hkv,G)
        p_ = jnp.exp(s - m_loc[..., None])
        p_ = jnp.where(mask, p_, 0.0)
        l_loc = jnp.sum(p_, axis=-1)
        o_loc = jnp.einsum("bkgs,bksd->bkgd", p_.astype(cv_l.dtype), cv_l,
                           preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, "model")
        o = jax.lax.psum(o_loc * corr[..., None], "model")
        o = o / jnp.maximum(l_g, 1e-30)[..., None]
        return o, ck_l, cv_l

    dp = resolve(("dp",))[0]
    cache_spec = P(dp, None, "model", None)
    rep4 = P(dp, None, None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P()),
        out_specs=(rep4, cache_spec, cache_spec),
        check_rep=False,
    )
    return fn(qg, k_new, v_new, cache_k, cache_v, pos)


# ---------------------------------------------------------------------------
# MLPs + MoE
# ---------------------------------------------------------------------------

def mlp(x: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    nat = getattr(cfg, "bf16_reduce", False)
    if cfg.mlp_type == "swiglu":
        return dot(silu(dot(x, p["w_gate"])) * dot(x, p["w_up"]), p["w_down"],
                   native_out=nat)
    if cfg.mlp_type == "squared_relu":
        h = jax.nn.relu(dot(x, p["w_up"]))
        return dot(h * h, p["w_down"], native_out=nat)
    if cfg.mlp_type == "gelu":
        return dot(jax.nn.gelu(dot(x, p["w_up"])), p["w_down"], native_out=nat)
    raise ValueError(cfg.mlp_type)


def moe(x: jnp.ndarray, p: Params, cfg) -> jnp.ndarray:
    """Token-choice top-k MoE with per-example capacity (sort-free, GShard-style).

    Routing/dispatch happen independently per example, so the batch axis
    shards with zero routing communication; expert FFN weights shard over
    ``fsdp``/``tp`` like dense MLPs.  Dropped tokens (capacity overflow) pass
    through the residual unchanged, as in GShard/Switch.

    ``cfg.moe_impl == "ep"`` (requires E % tp == 0 and an active mesh):
    expert-parallel — each tp shard OWNS E/tp experts outright (no fsdp
    weight gathers), routes its local experts' tokens, and the shards'
    partial outputs psum-combine.  16x smaller dispatch buffers and zero
    expert-weight collectives, at the cost of one (B,S,d) reduce (§Perf).
    """
    from .sharding import _current

    mesh, _ = _current()
    if (getattr(cfg, "moe_impl", "dense") == "ep" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        return _moe_ep(x, p, cfg, mesh)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, math.ceil(s * k * cfg.capacity_factor / e))
    logits = dot(x, p["router"]).astype(jnp.float32)       # (B,S,E)
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)   # (B,S,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    def route_one(xb, gb, ib):
        # xb (S,d), gb/ib (S,k)
        flat_e = ib.reshape(-1)                            # (S*k,)
        flat_g = gb.reshape(-1)
        tok = jnp.repeat(jnp.arange(s), k)
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)  # (S*k, E)
        ranks = (jnp.cumsum(oh, axis=0) - oh)              # prior count per expert
        rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0].astype(jnp.int32)
        keep = rank < cap
        buf = jnp.zeros((e, cap, d), xb.dtype)
        buf = buf.at[flat_e, jnp.minimum(rank, cap - 1)].add(
            jnp.where(keep[:, None], xb[tok], 0.0)
        )
        # expert FFN on (E, cap, d)
        if cfg.mlp_type == "swiglu":
            h = silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
                "ecd,edf->ecf", buf, p["w_up"]
            )
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
        out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])   # (E, cap, d)
        gathered = out[flat_e, jnp.minimum(rank, cap - 1)] # (S*k, d)
        contrib = gathered * (flat_g * keep)[:, None]
        y = jnp.zeros((s, d), xb.dtype).at[tok].add(contrib)
        return y

    return jax.vmap(route_one)(x, gates, eidx)


def _moe_ep(x: jnp.ndarray, p: Params, cfg, mesh) -> jnp.ndarray:
    """Expert-parallel MoE over the tp axis (see :func:`moe`)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from .sharding import resolve

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ep = mesh.shape["model"]
    e_loc = e // ep
    cap = max(1, math.ceil(s * k * cfg.capacity_factor / e))

    def local(x_l, router_l, wg_l, wu_l, wd_l):
        shard_i = jax.lax.axis_index("model")
        lo = shard_i * e_loc
        logits = dot(x_l, router_l).astype(jnp.float32)         # (B,S,E)
        gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

        def route_one(xb, gb, ib):
            flat_e = ib.reshape(-1)
            flat_g = gb.reshape(-1)
            tok = jnp.repeat(jnp.arange(s), k)
            mine = (flat_e >= lo) & (flat_e < lo + e_loc)
            loc_e = jnp.clip(flat_e - lo, 0, e_loc - 1)
            oh = jax.nn.one_hot(loc_e, e_loc, dtype=jnp.float32) * mine[:, None]
            ranks = (jnp.cumsum(oh, axis=0) - oh)
            rank = jnp.take_along_axis(ranks, loc_e[:, None], axis=1)[:, 0].astype(jnp.int32)
            keep = mine & (rank < cap)
            buf = jnp.zeros((e_loc, cap, d), xb.dtype)
            buf = buf.at[loc_e, jnp.minimum(rank, cap - 1)].add(
                jnp.where(keep[:, None], xb[tok], 0.0))
            if cfg.mlp_type == "swiglu":
                hdn = silu(jnp.einsum("ecd,edf->ecf", buf, wg_l)) * jnp.einsum(
                    "ecd,edf->ecf", buf, wu_l)
            else:
                hdn = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wu_l))
            out = jnp.einsum("ecf,efd->ecd", hdn, wd_l)
            gathered = out[loc_e, jnp.minimum(rank, cap - 1)]
            contrib = gathered * (flat_g * keep)[:, None]
            return jnp.zeros((s, d), xb.dtype).at[tok].add(contrib)

        y = jax.vmap(route_one)(x_l, gates, eidx)
        return jax.lax.psum(y, "model")        # combine shards' expert outputs

    dp = resolve(("dp",))[0]
    rep = P(dp, None, None)
    espec = P("model", None, None)             # experts owned per shard
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(rep, P(), espec, espec, espec),
        out_specs=rep,
        check_rep=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d as K shifted FMAs.  x (B,S,C), w (K,C), b (C).

    NOT lax.conv_general_dilated: XLA's autodiff of a feature-grouped conv
    materializes a FULL (C x C) weight-gradient convolution (observed 1.7e16
    bogus FLOPs on zamba2 train).  K is 4 — four shifted multiply-adds are
    exact, cheap (O(K*S*C)), and differentiate cleanly.
    """
    k = w.shape[0]
    s = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j: j + s, :] * w[j]
    return out + b


def _ssd_project(x, p, cfg):
    d_in, nh, ds, hd = mamba2_dims(cfg)
    zxbcdt = dot(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ds], axis=-1)
    return z, xbc, dt


def mamba2_scan(x: jnp.ndarray, p: Params, cfg, *, chunk: int = 128,
                return_state: bool = False):
    """Chunk-parallel SSD forward.  x (B,S,d) -> y (B,S,d).

    Intra-chunk: masked quadratic form; inter-chunk: lax.scan over chunk
    states (B, nh, hd, ds).  All decays <= 1, so no stabilizer is needed.
    """
    b, s, _ = x.shape
    d_in, nh, ds, hd = mamba2_dims(cfg)
    z, xbc, dt = _ssd_project(x, p, cfg)
    xbc = silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + ds], axis=-1)   # (B,S,*)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # (nh,)
    la = dt * a                                                   # log-decay (B,S,nh) < 0

    if s < chunk or s % chunk != 0:
        chunk = s                                         # small/ragged: one chunk
    nc = s // chunk
    xh = xs.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, ds).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, ds).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, nh)
    lac = la.reshape(b, nc, chunk, nh)

    def body(h, xs_):
        xq, bq, cq, dtq, laq = xs_                 # per-chunk (B,chunk,...)
        cum = jnp.cumsum(laq, axis=1)              # (B,Q,nh) inclusive
        # intra-chunk
        cb = jnp.einsum("bqd,bsd->bqs", cq, bq)    # (B,Q,Q)
        seg = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,Q,S,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        seg = jnp.where(tri[None, :, :, None], seg, 0.0)
        w = cb[..., None] * seg * dtq[:, None, :, :]             # (B,Q,S,nh)
        y = jnp.einsum("bqsh,bshp->bqhp", w, xq)
        # inter-chunk contribution from carry state h (B,nh,hd,ds)
        y += jnp.einsum("bqd,bhpd,bqh->bqhp", cq, h, jnp.exp(cum))
        # state update
        rev = jnp.exp(cum[:, -1:, :] - cum)                      # decay s+1..end
        h = jnp.exp(cum[:, -1])[:, :, None, None] * h + jnp.einsum(
            "bsh,bsd,bshp->bhpd", rev * dtq, bq, xq
        )
        return h, y

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    h_fin, ys = jax.lax.scan(
        body, h0,
        (xh.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3),
         cc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
         lac.transpose(1, 0, 2, 3)),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.reshape(b, s, nh, hd)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(y * silu(z), p["norm"])
    out = dot(y, p["out_proj"])
    if return_state:
        # conv state holds PRE-activation inputs (the raw xbc stream tail)
        zxbcdt_raw = dot(x, p["in_proj"])
        raw_xbc = zxbcdt_raw[..., d_in:2 * d_in + 2 * ds]
        conv_state = raw_xbc[:, -(cfg.ssm_conv - 1):, :]
        return out, (h_fin.astype(jnp.float32), conv_state)
    return out


def mamba2_decode(x_t: jnp.ndarray, p: Params, cfg, h: jnp.ndarray, conv_state: jnp.ndarray):
    """One-token SSD step.  x_t (B,1,d); h (B,nh,hd,ds); conv_state (B,K-1,C)."""
    b = x_t.shape[0]
    d_in, nh, ds, hd = mamba2_dims(cfg)
    z, xbc, dt = _ssd_project(x_t, p, cfg)                 # (B,1,*)
    window = jnp.concatenate([conv_state, xbc], axis=1)    # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xbc_t = silu(conv_out)[:, None, :].astype(x_t.dtype)
    xs, bmat, cmat = jnp.split(xbc_t, [d_in, d_in + ds], axis=-1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * a)                               # (B,nh)
    xh = xs[:, 0].reshape(b, nh, hd).astype(jnp.float32)
    bv = bmat[:, 0].astype(jnp.float32)                    # (B,ds)
    cv = cmat[:, 0].astype(jnp.float32)
    h = decay[:, :, None, None] * h + jnp.einsum(
        "bh,bd,bhp->bhpd", dtv, bv, xh
    )
    y = jnp.einsum("bd,bhpd->bhp", cv, h)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x_t.dtype)
    y = rms_norm(y * silu(z), p["norm"])
    out = dot(y, p["out_proj"])
    new_conv_state = window[:, 1:, :]
    return out, h, new_conv_state


# ---------------------------------------------------------------------------
# xLSTM cells
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                  initial=None, return_state: bool = False):
    """Stabilized chunkwise mLSTM.  q,k,v (B,S,H,D); i_pre,f_pre (B,S,H).

    C_t = f_t C + i_t k v^T ; n_t = f_t n + i_t k ;
    h_t = (q·C) / max(|q·n|, exp(-m)) with running stabilizer m.
    """
    b, s, h, d = q.shape
    scale = d ** -0.5
    nc = s // chunk
    assert nc * chunk == s
    log_f = -jax.nn.softplus(-f_pre.astype(jnp.float32))   # log sigmoid
    log_i = i_pre.astype(jnp.float32)

    qc = (q.astype(jnp.float32) * scale).reshape(b, nc, chunk, h, d)
    kc = k.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    vc = v.astype(jnp.float32).reshape(b, nc, chunk, h, d)
    lfc = log_f.reshape(b, nc, chunk, h)
    lic = log_i.reshape(b, nc, chunk, h)

    if initial is None:
        c0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf)
    else:
        c0, n0, m0 = initial

    def body(carry, xs_):
        cmat, nvec, m = carry
        qq, kk, vv, lf, li = xs_                   # (B,Q,...)
        cum = jnp.cumsum(lf, axis=1)               # inclusive (B,Q,H)
        # candidate stabilizers
        logd = cum[:, :, None, :] - cum[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        logd = jnp.where(tri[None, :, :, None], logd, -jnp.inf)
        m_intra = jnp.max(logd, axis=2)            # (B,Q,H)
        m_inter = cum + m[:, None, :]              # carry decayed to t
        m_new = jnp.maximum(m_intra, m_inter)      # (B,Q,H)
        m_new = jnp.maximum(m_new, -1e30)          # guard all -inf rows
        w = jnp.exp(logd - m_new[:, :, None, :])   # (B,Q,S,H)
        scores = jnp.einsum("bqhd,bshd->bqsh", qq, kk)
        num = jnp.einsum("bqsh,bqsh,bshd->bqhd", scores, w, vv)
        den = jnp.einsum("bqsh,bqsh->bqh", scores, w)
        inter_scale = jnp.exp(m_inter - m_new)     # (B,Q,H)
        num += jnp.einsum("bqhd,bhde,bqh->bqhe", qq, cmat, inter_scale)
        den += jnp.einsum("bqhd,bhd,bqh->bqh", qq, nvec, inter_scale)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        # chunk-end state
        tot = cum[:, -1]                           # (B,H)
        m_out = jnp.maximum(tot + m, jnp.max(cum[:, -1:, :] - cum + li, axis=1))
        decay_in = jnp.exp(tot + m - m_out)        # (B,H)
        wk = jnp.exp(cum[:, -1:, :] - cum + li - m_out[:, None, :])   # (B,Q,H)
        cmat = decay_in[:, :, None, None] * cmat + jnp.einsum(
            "bqh,bqhd,bqhe->bhde", wk, kk, vv
        )
        nvec = decay_in[:, :, None] * nvec + jnp.einsum("bqh,bqhd->bhd", wk, kk)
        return (cmat, nvec, m_out), hout

    (c_f, n_f, m_f), hs = jax.lax.scan(
        body, (c0, n0, m0),
        (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4), lfc.transpose(1, 0, 2, 3),
         lic.transpose(1, 0, 2, 3)),
    )
    out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    if return_state:
        return out, (c_f, n_f, m_f)
    return out


def mlstm_decode(q, k, v, i_pre, f_pre, state):
    """One-step mLSTM.  q,k,v (B,H,D); i_pre,f_pre (B,H)."""
    c, n, m = state
    d = q.shape[-1]
    qf = q.astype(jnp.float32) * (d ** -0.5)
    log_f = -jax.nn.softplus(-f_pre.astype(jnp.float32))
    log_i = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, log_i)
    f_s = jnp.exp(log_f + m - m_new)
    i_s = jnp.exp(log_i - m_new)
    c = f_s[..., None, None] * c + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = f_s[..., None] * n + i_s[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new))
    return num / den[..., None], (c, n, m_new)


def slstm_scan(x_gates: jnp.ndarray, r: jnp.ndarray, *, initial=None,
               return_state: bool = False):
    """sLSTM over time.  x_gates (B,S,H,4,D) input preacts (z,i,f,o); r (H,4,D,D)
    recurrent weights applied to h_{t-1}."""
    b, s, h, _, d = x_gates.shape

    if initial is None:
        hid = jnp.zeros((b, h, d), jnp.float32)
        c = jnp.zeros((b, h, d), jnp.float32)
        n = jnp.zeros((b, h, d), jnp.float32)
        m = jnp.zeros((b, h, d), jnp.float32)
    else:
        hid, c, n, m = initial

    rf = r.astype(jnp.float32)

    def step(carry, g_t):
        hid, c, n, m = carry
        rec = jnp.einsum("bhd,hgde->bhge", hid, rf)        # (B,H,4,D)
        pre = g_t.astype(jnp.float32) + rec
        z = jnp.tanh(pre[:, :, 0])
        i_t = pre[:, :, 1]
        f_t = pre[:, :, 2]
        o = jax.nn.sigmoid(pre[:, :, 3])
        log_f = -jax.nn.softplus(-f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c = f_s * c + i_s * z
        n = f_s * n + i_s
        hid = o * c / jnp.maximum(n, 1e-6)
        return (hid, c, n, m_new), hid

    (hid, c, n, m), hs = jax.lax.scan(step, (hid, c, n, m), x_gates.swapaxes(0, 1))
    out = hs.swapaxes(0, 1)                                # (B,S,H,D)
    if return_state:
        return out, (hid, c, n, m)
    return out
