"""Sharding utilities: mesh context + activation constraints + param rules.

The model code calls :func:`shard` at strategic activation points with logical
axis names; outside a mesh context (CPU smoke tests) it is a no-op, inside the
dry-run/trainer it pins the SPMD partitioner to the intended layout.

Logical names:  ``dp`` — batch axis (maps to ("pod","data") or ("data",)),
``tp`` — tensor axis ("model"), ``fsdp`` — parameter shard axis ("data").
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> tuple[Mesh | None, dict[str, Any]]:
    return getattr(_state, "mesh", None), getattr(_state, "axes", {})


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    """Activate a mesh for activation-sharding constraints.

    Logical-axis resolution: ``dp`` -> ("pod","data") when a 'pod' axis exists
    else "data"; ``tp`` -> "model"; ``fsdp`` -> "data".
    """
    prev = getattr(_state, "mesh", None), getattr(_state, "axes", {})
    if mesh is None:
        _state.mesh, _state.axes = None, {}
    else:
        names = mesh.axis_names
        axes = {
            "dp": ("pod", "data") if "pod" in names else "data",
            "fsdp": "data",
            "tp": "model",
        }
        _state.mesh, _state.axes = mesh, axes
    try:
        yield
    finally:
        _state.mesh, _state.axes = prev


def resolve(spec: tuple) -> P:
    """Map logical names in a spec tuple to mesh axis names."""
    _, axes = _current()
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, str):
            out.append(axes.get(s, s))
        else:  # tuple of logical names
            flat = []
            for t in s:
                r = axes.get(t, t)
                flat.extend(r if isinstance(r, tuple) else (r,))
            out.append(tuple(flat))
    return P(*out)


def shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint with logical axis names; no-op without mesh."""
    mesh, _ = _current()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, resolve(spec)))


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    with use_mesh(mesh):
        return NamedSharding(mesh, resolve(spec))


def divisible(dim: int, mesh: Mesh | None, axis: str) -> bool:
    """Can `dim` shard over mesh axis `axis`?  (axis may be a logical name)."""
    if mesh is None:
        return False
    with use_mesh(mesh):
        p = resolve((axis,))[0]
    if p is None:
        return False
    names = p if isinstance(p, tuple) else (p,)
    size = int(np.prod([mesh.shape[n] for n in names]))
    return dim % size == 0
