"""Zamba2-style hybrid: Mamba2 backbone + SHARED attention block every
``attn_every`` layers (weights shared across applications).

Layer schedule is realized as explicit group scans (no data-dependent
lax.cond): ``G = L // attn_every`` full groups of [shared-attn -> attn_every
Mamba2 layers] plus a tail group of [shared-attn -> L % attn_every layers].
Applications = G (+1 if tail) — 14 KV slots for the 81-layer config.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .lm import _logits
from .sharding import shard

Params = dict[str, Any]


def group_split(cfg) -> tuple[int, int]:
    """(full_groups, tail_layers)."""
    return cfg.n_layers // cfg.attn_every, cfg.n_layers % cfg.attn_every


def n_attn_apps(cfg) -> int:
    g, t = group_split(cfg)
    return g + (1 if t else 0)


def _mamba_params(key, cfg, dtype):
    d = cfg.d_model
    d_in, nh, ds, hd = L.mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    Ln = cfg.n_layers
    proj_out = 2 * d_in + 2 * ds + nh
    conv_ch = d_in + 2 * ds
    return {
        "ln": jnp.ones((Ln, d), dtype),
        "in_proj": (jax.random.normal(ks[0], (Ln, d, proj_out), jnp.float32) * 0.02).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (Ln, cfg.ssm_conv, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((Ln, conv_ch), dtype),
        "dt_bias": jnp.zeros((Ln, nh), jnp.float32),
        "a_log": jnp.zeros((Ln, nh), jnp.float32),
        "d_skip": jnp.ones((Ln, nh), jnp.float32),
        "norm": jnp.ones((Ln, d_in), dtype),
        "out_proj": (
            jax.random.normal(ks[2], (Ln, d_in, d), jnp.float32)
            * 0.02 / math.sqrt(2 * Ln)
        ).astype(dtype),
    }


def _shared_attn_params(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 8)

    def w(k, *shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dtype)

    return {
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
        "wq": w(ks[0], d, hq * hd),
        "wk": w(ks[1], d, hkv * hd),
        "wv": w(ks[2], d, hkv * hd),
        "wo": w(ks[3], hq * hd, d),
        "w_gate": w(ks[4], d, f),
        "w_up": w(ks[5], d, f),
        "w_down": w(ks[6], f, d),
    }


def init_params(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(k1, (v, d), jnp.float32) * 0.02).astype(dtype),
        "mamba": _mamba_params(k2, cfg, dtype),
        "shared": _shared_attn_params(k3, cfg, dtype),
        "ln_f": jnp.ones((d,), dtype),
        "lm_head": (jax.random.normal(k4, (d, v), jnp.float32) * 0.02).astype(dtype),
    }


def _split_groups(tree, cfg):
    g, t = group_split(cfg)
    main = jax.tree.map(
        lambda a: a[: g * cfg.attn_every].reshape((g, cfg.attn_every) + a.shape[1:]), tree
    )
    tail = jax.tree.map(lambda a: a[g * cfg.attn_every:], tree) if t else None
    return main, tail


def _shared_block_train(x, sp, cfg, positions, *, return_kv=False):
    out = L.attention_train(
        L.rms_norm(x, sp["ln1"]), sp, cfg, positions=positions, return_kv=return_kv
    )
    att, kv = (out if return_kv else (out, None))
    x = x + att
    x = x + L.mlp(L.rms_norm(x, sp["ln2"]), sp, cfg)
    return (x, kv) if return_kv else x


def train_loss(params, batch, cfg):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = shard(x, "dp", None, None)
    positions = jnp.arange(x.shape[1])
    sp = params["shared"]

    def mamba_body(carry, mp):
        y = L.mamba2_scan(L.rms_norm(carry, mp["ln"]), mp, cfg)
        return shard(carry + y, "dp", None, None), None

    def group_body(carry, gp):
        x2 = _shared_block_train(carry, sp, cfg, positions)
        x2, _ = jax.lax.scan(mamba_body, x2, gp)
        return x2, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    main, tail = _split_groups(params["mamba"], cfg)
    x, _ = jax.lax.scan(group_body, x, main)
    if tail is not None:
        x = _shared_block_train(x, sp, cfg, positions)
        x, _ = jax.lax.scan(mamba_body, x, tail)
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x, cfg)
    pred, tgt = logits[:, :-1], tokens[:, 1:]
    lse = jax.nn.logsumexp(pred, axis=-1)
    true = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


def init_cache(cfg, batch_size: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d_in, nh, ds, hd_ssm = L.mamba2_dims(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    napps = n_attn_apps(cfg)
    conv_ch = d_in + 2 * ds
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch_size, nh, hd_ssm, ds), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1, conv_ch), dtype),
        "k": jnp.zeros((napps, batch_size, hkv, max_len, hd), dtype),
        "v": jnp.zeros((napps, batch_size, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def _pad_kv(k, v, max_len, dtype):
    pad = max_len - k.shape[2]
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(dtype)
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(dtype)
    return k, v


def prefill(params, batch, cfg, *, max_len: int | None = None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = shard(x, "dp", None, None)
    positions = jnp.arange(s)
    sp = params["shared"]

    def mamba_body(carry, mp):
        y, st = L.mamba2_scan(L.rms_norm(carry, mp["ln"]), mp, cfg, return_state=True)
        return shard(carry + y, "dp", None, None), st

    def group_body(carry, gp):
        x2, (k, v) = _shared_block_train(carry, sp, cfg, positions, return_kv=True)
        k, v = _pad_kv(k, v, max_len, carry.dtype)
        x2, states = jax.lax.scan(mamba_body, x2, gp)
        return x2, ((k, v), states)

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    main, tail = _split_groups(params["mamba"], cfg)
    x, ((ks, vs), main_states) = jax.lax.scan(group_body, x, main)
    ssm_list = [main_states[0].reshape((-1,) + main_states[0].shape[2:])]
    conv_list = [main_states[1].reshape((-1,) + main_states[1].shape[2:])]
    if tail is not None:
        x, (k_t, v_t) = _shared_block_train(x, sp, cfg, positions, return_kv=True)
        k_t, v_t = _pad_kv(k_t, v_t, max_len, x.dtype)
        ks = jnp.concatenate([ks, k_t[None]], axis=0)
        vs = jnp.concatenate([vs, v_t[None]], axis=0)
        x, tail_states = jax.lax.scan(mamba_body, x, tail)
        ssm_list.append(tail_states[0])
        conv_list.append(tail_states[1])
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    cache = {
        "ssm": jnp.concatenate(ssm_list, axis=0),
        "conv": jnp.concatenate(conv_list, axis=0).astype(jnp.dtype(cfg.dtype)),
        "k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32),
    }
    return logits, cache


def _shared_block_decode(x, sp, cfg, ck, cv, pos):
    att, ck, cv = L.attention_decode(L.rms_norm(x, sp["ln1"]), sp, cfg, ck, cv, pos)
    x = x + att
    x = x + L.mlp(L.rms_norm(x, sp["ln2"]), sp, cfg)
    return x, ck, cv


def decode_step(params, batch, cache, cfg):
    tok = batch["next_token"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = cache["pos"]
    sp = params["shared"]
    g, t = group_split(cfg)
    ae = cfg.attn_every

    def mamba_body(carry, xs):
        mp, h, conv_s = xs
        y, h, conv_s = L.mamba2_decode(L.rms_norm(carry, mp["ln"]), mp, cfg, h, conv_s)
        return carry + y, (h, conv_s)

    main, tail = _split_groups(params["mamba"], cfg)
    ssm_main = jax.tree.map(
        lambda a: a[: g * ae].reshape((g, ae) + a.shape[1:]), cache["ssm"]
    )
    conv_main = jax.tree.map(
        lambda a: a[: g * ae].reshape((g, ae) + a.shape[1:]), cache["conv"]
    )

    def group_body(carry, xs):
        gp, ck, cv, ssm_g, conv_g = xs
        x2, ck, cv = _shared_block_decode(carry, sp, cfg, ck, cv, pos)
        x2, states = jax.lax.scan(mamba_body, x2, (gp, ssm_g, conv_g))
        return x2, ((ck, cv), states)

    x, ((ks, vs), main_states) = jax.lax.scan(
        group_body, x, (main, cache["k"][:g], cache["v"][:g], ssm_main, conv_main)
    )
    ssm_out = [main_states[0].reshape((-1,) + main_states[0].shape[2:])]
    conv_out = [main_states[1].reshape((-1,) + main_states[1].shape[2:])]
    if tail is not None:
        x, ck_t, cv_t = _shared_block_decode(
            x, sp, cfg, cache["k"][g], cache["v"][g], pos
        )
        ks = jnp.concatenate([ks, ck_t[None]], axis=0)
        vs = jnp.concatenate([vs, cv_t[None]], axis=0)
        x, tail_states = jax.lax.scan(
            mamba_body, x,
            (tail, cache["ssm"][g * ae:], cache["conv"][g * ae:]),
        )
        ssm_out.append(tail_states[0])
        conv_out.append(tail_states[1])
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x, cfg)[:, 0]
    new_cache = {
        "ssm": jnp.concatenate(ssm_out, axis=0),
        "conv": jnp.concatenate(conv_out, axis=0),
        "k": ks, "v": vs, "pos": pos + 1,
    }
    return logits, new_cache
