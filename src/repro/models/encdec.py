"""Whisper-style encoder-decoder.  The conv/audio frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings (B, T, d)."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .lm import _dense_block_params, _logits
from .sharding import shard

Params = dict[str, Any]


def init_params(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, v = cfg.d_model, cfg.padded_vocab
    k_embed, k_enc, k_dec, k_cross, k_head = jax.random.split(key, 5)
    params: Params = {
        "embed": (jax.random.normal(k_embed, (v, d), jnp.float32) * 0.02).astype(dtype),
        "enc_blocks": _dense_block_params(k_enc, cfg, dtype, cfg.encoder_layers),
        "blocks": _dense_block_params(k_dec, cfg, dtype, cfg.n_layers),
        "cross_blocks": _cross_params(k_cross, cfg, dtype),
        "ln_enc": jnp.ones((d,), dtype),
        "ln_f": jnp.ones((d,), dtype),
        "lm_head": (jax.random.normal(k_head, (d, v), jnp.float32) * 0.02).astype(dtype),
    }
    return params


def _cross_params(key, cfg, dtype):
    d = cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    Ln = cfg.n_layers

    def w(k, *shape):
        return (jax.random.normal(k, (Ln, *shape), jnp.float32) * 0.02).astype(dtype)

    return {
        "ln": jnp.ones((Ln, d), dtype),
        "wq": w(ks[0], d, hq * hd),
        "wk": w(ks[1], d, hkv * hd),
        "wv": w(ks[2], d, hkv * hd),
        "wo": w(ks[3], hq * hd, d),
    }


def encode(params, frames, cfg):
    """Bidirectional encoder over stub frame embeddings (B, T, d)."""
    x = shard(frames, "dp", None, None)
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        h = L.attention_train(
            L.rms_norm(carry, bp["ln1"]), bp, cfg, positions=positions, causal=False
        )
        x2 = carry + h
        x2 = x2 + L.mlp(L.rms_norm(x2, bp["ln2"]), bp, cfg)
        return shard(x2, "dp", None, None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["ln_enc"])


def _decoder(params, tokens, enc_out, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    positions = jnp.arange(x.shape[1])

    def body(carry, bps):
        bp, cp = bps
        h = L.attention_train(L.rms_norm(carry, bp["ln1"]), bp, cfg, positions=positions)
        x2 = carry + h
        h = L.attention_train(
            L.rms_norm(x2, cp["ln"]), cp, cfg, positions=positions, kv_x=enc_out
        )
        x2 = x2 + h
        x2 = x2 + L.mlp(L.rms_norm(x2, bp["ln2"]), bp, cfg)
        return shard(x2, "dp", None, None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["blocks"], params["cross_blocks"]))
    return L.rms_norm(x, params["ln_f"])


def train_loss(params, batch, cfg):
    enc_out = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)), cfg)
    x = _decoder(params, batch["tokens"], enc_out, cfg)
    logits = _logits(params, x, cfg)
    pred, tgt = logits[:, :-1], batch["tokens"][:, 1:]
    lse = jax.nn.logsumexp(pred, axis=-1)
    true = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


def init_cache(cfg, batch_size: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    t = cfg.frontend_tokens
    return {
        "k": jnp.zeros((cfg.n_layers, batch_size, hkv, max_len, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch_size, hkv, max_len, hd), dtype),
        "ck": jnp.zeros((cfg.n_layers, batch_size, hkv, t, hd), dtype),
        "cv": jnp.zeros((cfg.n_layers, batch_size, hkv, t, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg, *, max_len: int | None = None):
    """Encode frames, precompute cross-KV, prefill decoder self-KV."""
    enc_out = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)), cfg)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    positions = jnp.arange(s)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_

    def body(carry, bps):
        bp, cp = bps
        att, (k, v) = L.attention_train(
            L.rms_norm(carry, bp["ln1"]), bp, cfg, positions=positions, return_kv=True
        )
        x2 = carry + att
        ck = L._split_heads(L.dot(enc_out, cp["wk"]), hkv, hd).swapaxes(1, 2)
        cv = L._split_heads(L.dot(enc_out, cp["wv"]), hkv, hd).swapaxes(1, 2)
        h = L.attention_train(
            L.rms_norm(x2, cp["ln"]), cp, cfg, positions=positions, kv_x=enc_out
        )
        x2 = x2 + h
        x2 = x2 + L.mlp(L.rms_norm(x2, bp["ln2"]), bp, cfg)
        pad = max_len - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return shard(x2, "dp", None, None), (
            k.astype(carry.dtype), v.astype(carry.dtype),
            ck.astype(carry.dtype), cv.astype(carry.dtype),
        )

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, (params["blocks"], params["cross_blocks"]))
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs, "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, batch, cache, cfg):
    tok = batch["next_token"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pos = cache["pos"]
    t_enc = jnp.asarray(cfg.frontend_tokens, jnp.int32)

    def body(carry, xs):
        bp, cp, ck_self, cv_self, ck, cv = xs
        att, ck_self, cv_self = L.attention_decode(
            L.rms_norm(carry, bp["ln1"]), bp, cfg, ck_self, cv_self, pos
        )
        x2 = carry + att
        catt, _, _ = L.attention_decode(
            L.rms_norm(x2, cp["ln"]), cp, cfg, ck, cv, t_enc, cross=True
        )
        x2 = x2 + catt
        x2 = x2 + L.mlp(L.rms_norm(x2, bp["ln2"]), bp, cfg)
        return x2, (ck_self, cv_self)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["blocks"], params["cross_blocks"],
         cache["k"], cache["v"], cache["ck"], cache["cv"]),
    )
    x = L.rms_norm(x, params["ln_f"])
    logits = _logits(params, x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"], "pos": pos + 1}
