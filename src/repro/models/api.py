"""Public model API: family dispatch, step builders, input specs, shardings.

This is the layer the launcher/dry-run consume:

  * :func:`get_model`       — family -> (init_params, train_loss, prefill, ...)
  * :func:`make_train_step` — loss+grad+microbatch-accumulate+AdamW, jit-ready
  * :func:`make_serve_step` / :func:`make_prefill_step`
  * :func:`input_specs`     — ShapeDtypeStruct stand-ins per (arch x cell)
  * :func:`state_shardings` / :func:`batch_shardings` / :func:`cache_shardings`
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.optim import TrainState, adamw_init, adamw_update, cosine_warmup
from . import encdec, hybrid, lm, xlstm
from .sharding import use_mesh, resolve


class Model(NamedTuple):
    init_params: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def get_model(cfg: ArchConfig) -> Model:
    if cfg.is_encdec:
        mod = encdec
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "ssm" and cfg.d_ff == 0:
        mod = xlstm
    else:
        mod = lm
    return Model(mod.init_params, mod.train_loss, mod.prefill, mod.decode_step, mod.init_cache)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if cell.kind in ("train", "prefill"):
        specs: dict[str, Any] = {}
        if cfg.frontend == "vision_stub":
            text = s - cfg.frontend_tokens
            specs["tokens"] = jax.ShapeDtypeStruct((b, text), i32)
            specs["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_tokens, cfg.d_model), f)
        elif cfg.frontend == "audio_stub":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.frontend_tokens, cfg.d_model), f)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"next_token": jax.ShapeDtypeStruct((b,), i32)}


def make_batch(cfg: ArchConfig, cell: ShapeCell, key) -> dict[str, jnp.ndarray]:
    """Concrete random batch (smoke tests / examples)."""
    specs = input_specs(cfg, cell)
    out = {}
    for name, sd in specs.items():
        k = jax.random.fold_in(key, hash(name) % (2**31))
        if sd.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sd.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, sd.shape, jnp.float32).astype(sd.dtype)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, grad_transform: Callable | None = None,
                    grad_shardings=None):
    """(TrainState, batch) -> (TrainState, metrics) with microbatch grad accum.

    ``grad_transform(grads) -> grads`` is the hook where runtime features
    (gradient compression, coded-DP decode) plug in.  ``grad_shardings``
    (pytree of NamedSharding matching params) pins the microbatch gradient
    accumulator to the parameter layout so the partitioner emits per-micro
    reduce-scatters instead of full-tensor all-reduces (§Perf A2).
    """
    model = get_model(cfg)
    mb = max(1, cfg.microbatch)

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, grad_shardings)

    def train_step(state: TrainState, batch):
        def loss_fn(params, sub):
            return model.train_loss(params, sub, cfg)

        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        elif cfg.accum_mode == "loss_scan":
            # §Perf optimization: one jax.grad over the scanned-microbatch
            # loss.  The per-micro forward is checkpointed (one micro's
            # activations live at a time) and the parameter cotangent is
            # accumulated by scan-backward in the PARAM dtype (bf16) with a
            # single deferred cross-data reduce — vs. the baseline's f32
            # accumulator + per-micro all-reduces.
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

            def total_loss(params):
                def body(acc, sub):
                    return acc + loss_fn(params, sub), None

                body = jax.checkpoint(body)
                total, _ = jax.lax.scan(body, jnp.zeros(()), split)
                return total / mb

            loss, grads = jax.value_and_grad(total_loss)(state.params)
        else:
            split = jax.tree.map(lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def acc_fn(carry, sub):
                loss_acc, g_acc = carry
                loss_i, g_i = jax.value_and_grad(loss_fn)(state.params, sub)
                g_acc = _pin(jax.tree.map(
                    lambda a, b_: a + b_.astype(acc_dt), g_acc, _pin(g_i)))
                return (loss_acc + loss_i, g_acc), None

            zero = (jnp.zeros(()),
                    _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                      state.params)))
            (loss, grads), _ = jax.lax.scan(acc_fn, zero, split)
            loss = loss / mb
            grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32), grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        lr = cosine_warmup(state.step + 1, peak_lr=peak_lr, warmup=warmup, total=total_steps)
        new_state, om = adamw_update(state, grads, lr)
        return new_state, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig, *, max_len: int | None = None,
                      attn_impl: str | None = None):
    """attn_impl override: prefill at >=8k sequence defaults to ``blockwise``
    (online-softmax in XLA) — dense S^2 scores do not fit HBM at 32k."""
    if attn_impl is None and max_len is not None and max_len >= 8192:
        attn_impl = "blockwise"
    if attn_impl is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cfg, max_len=max_len)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    model = get_model(cfg)

    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(params, batch, cache, cfg)
        return logits, cache

    return serve_step


def init_state(cfg: ArchConfig, key) -> TrainState:
    params = get_model(cfg).init_params(key, cfg)
    return adamw_init(params, jnp.dtype(cfg.opt_state_dtype))


def abstract_state(cfg: ArchConfig) -> TrainState:
    """TrainState of ShapeDtypeStructs — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_state(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(cfg, batch, max_len))


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: get_model(cfg).init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

_IN_NAMES = {"wq", "wk", "wv", "w_up", "w_gate", "in_proj", "w_if", "w_gates",
             "w1", "embed_in", "patch_proj"}
_OUT_NAMES = {"wo", "w_down", "out_proj", "w_o", "w2"}


def _axis_size(mesh: Mesh, logical: str) -> int:
    with use_mesh(mesh):
        spec = resolve((logical,))[0]
    if spec is None:
        return 1
    names = spec if isinstance(spec, tuple) else (spec,)
    return int(np.prod([mesh.shape[n] for n in names]))


def _param_spec(path: tuple[str, ...], shape: tuple[int, ...], tp: int, fsdp: int,
                ep_mode: bool = False) -> P:
    name = path[-1]
    nd = len(shape)

    def ok(dim, size):
        return size > 1 and shape[dim] % size == 0

    if (ep_mode and nd == 4 and name in ("w_gate", "w_up", "w_down")
            and ok(1, tp)):
        # expert parallelism: each tp shard owns E/tp experts outright
        return P(None, "tp", None, None)

    if name == "embed":                       # (V, d): vocab over tp, d over fsdp
        return P("tp" if ok(0, tp) else None, "fsdp" if ok(1, fsdp) else None)
    if name == "lm_head":                     # (d, V)
        return P("fsdp" if ok(0, fsdp) else None, "tp" if ok(1, tp) else None)
    if name == "router":                      # (L, d, E)
        return P(None, "fsdp" if ok(1, fsdp) else None, None)
    if name in ("conv_w", "conv_b"):          # depthwise conv: shard channels
        ch = nd - 1
        spec = [None] * nd
        if ok(ch, tp):
            spec[ch] = "tp"
        return P(*spec)
    if name in _IN_NAMES or name in _OUT_NAMES:
        # trailing two dims are (in, out); leading dims (layer stack / experts)
        # stay unsharded.
        spec: list = [None] * nd
        d_in, d_out = nd - 2, nd - 1
        if name in _IN_NAMES:
            if ok(d_in, fsdp):
                spec[d_in] = "fsdp"
            if ok(d_out, tp):
                spec[d_out] = "tp"
        else:
            if ok(d_in, tp):
                spec[d_in] = "tp"
            if ok(d_out, fsdp):
                spec[d_out] = "fsdp"
        return P(*spec)
    # norms, biases, gates, small vectors: replicate
    return P()


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_tree) -> Any:
    tp = _axis_size(mesh, "tp")
    fsdp = _axis_size(mesh, "fsdp")
    ep_mode = (cfg.n_experts > 0 and getattr(cfg, "moe_impl", "dense") == "ep"
               and tp > 1 and cfg.n_experts % tp == 0)

    def assign(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        with use_mesh(mesh):
            spec = _param_spec(keys, leaf.shape, tp, fsdp, ep_mode)
            return NamedSharding(mesh, resolve(tuple(spec)))

    return jax.tree_util.tree_map_with_path(assign, params_tree)


def state_shardings(cfg: ArchConfig, mesh: Mesh, state: TrainState) -> TrainState:
    ps = param_shardings(cfg, mesh, state.params)
    return TrainState(
        params=ps,
        m=param_shardings(cfg, mesh, state.m),
        v=param_shardings(cfg, mesh, state.v),
        step=NamedSharding(mesh, P()),
    )


def batch_shardings(cfg: ArchConfig, mesh: Mesh, specs: dict) -> dict:
    dp = _axis_size(mesh, "dp")

    def assign(leaf):
        b = leaf.shape[0]
        lead = "dp" if (dp > 1 and b % dp == 0) else None
        with use_mesh(mesh):
            return NamedSharding(mesh, resolve((lead,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(assign, specs)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, cache_tree) -> Any:
    """KV caches: batch over dp, sequence over tp (+dp when batch can't shard).

    SSM/conv/xlstm states: batch over dp; largest model dim over tp when
    divisible.  Exact layouts per DESIGN.md §5.
    """
    dp = _axis_size(mesh, "dp")
    tp = _axis_size(mesh, "tp")

    def assign(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path)
        name = keys[0] if keys else ""
        shape = leaf.shape
        with use_mesh(mesh):
            if name == "pos" or not shape:
                return NamedSharding(mesh, P())
            if name in ("k", "v", "ck", "cv"):
                # (L|napps, B, Hkv, S, Dh)
                b, s_dim = shape[1], shape[3]
                batch_ok = dp > 1 and b % dp == 0
                seq = []
                if not batch_ok and dp > 1 and s_dim % (dp * tp) == 0:
                    seq_spec = ("dp", "tp")
                elif tp > 1 and s_dim % tp == 0:
                    seq_spec = "tp"
                else:
                    seq_spec = None
                return NamedSharding(
                    mesh,
                    resolve((None, "dp" if batch_ok else None, None, seq_spec, None)),
                )
            if name == "ssm":                  # (L, B, nh, hd, ds)
                b, nh = shape[1], shape[2]
                return NamedSharding(mesh, resolve((
                    None, "dp" if dp > 1 and b % dp == 0 else None,
                    "tp" if tp > 1 and nh % tp == 0 else None, None, None)))
            if name == "conv":                 # (L, B, K-1, C)
                b, ch = shape[1], shape[3]
                return NamedSharding(mesh, resolve((
                    None, "dp" if dp > 1 and b % dp == 0 else None, None,
                    "tp" if tp > 1 and ch % tp == 0 else None)))
            # xlstm block states: (B, ...) — batch over dp, biggest tail dim over tp
            spec: list = [None] * len(shape)
            if dp > 1 and shape[0] % dp == 0:
                spec[0] = "dp"
            if len(shape) > 1:
                tail = int(np.argmax(shape[1:])) + 1
                if tp > 1 and shape[tail] % tp == 0:
                    spec[tail] = "tp"
            return NamedSharding(mesh, resolve(tuple(spec)))

    return jax.tree_util.tree_map_with_path(assign, cache_tree)
