"""Model zoo: the 10 assigned architectures on a shared layer library."""

from .api import (  # noqa: F401
    Model,
    get_model,
    input_specs,
    make_serve_step,
    make_train_step,
)
