"""End-to-end trainer: data pipeline -> (coded-DP | plain) train loop with
checkpoint/restart, LEA straggler mitigation, and optional gradient
compression.

CPU-runnable examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --smoke \\
      --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --coded-dp
Resume is automatic: re-running with the same --ckpt-dir picks up the latest
checkpoint, the data cursor, and the LEA estimator counts.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeCell, get_config, get_smoke_config
from repro.data import DataPipeline
from repro.models import api
from repro.optim import adamw_update, cosine_warmup
from repro.runtime.compression import make_compressor
from repro.runtime.fault_tolerance import CodedDPConfig, CodedDataParallelExecutor


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--coded-dp", action="store_true",
                    help="LEA-coded microbatch DP with simulated worker dynamics")
    ap.add_argument("--dp-workers", type=int, default=8)
    ap.add_argument("--dp-r", type=int, default=4)
    ap.add_argument("--dp-shards", type=int, default=8)
    ap.add_argument("--compress", default="none", choices=["none", "int8", "topk"])
    args = ap.parse_args(argv)
    # REPRO_COMPILE_CACHE=<dir>: persistent XLA compile cache across restarts
    from repro.launch.cache import enable_compile_cache

    enable_compile_cache()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, microbatch=1)
    cell = ShapeCell("cli", args.seq, args.batch, "train")
    model = api.get_model(cfg)

    pipe = DataPipeline(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    state = api.init_state(cfg, jax.random.PRNGKey(args.seed))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def loss_fn(params, batch):
        return model.train_loss(params, {k: jnp.asarray(v) for k, v in batch.items()}, cfg)

    grad_fn = jax.jit(jax.grad(loss_fn))
    loss_jit = jax.jit(loss_fn)

    executor = None
    if args.coded_dp:
        executor = CodedDataParallelExecutor(
            CodedDPConfig(n_workers=args.dp_workers, r=args.dp_r, k=args.dp_shards),
            lambda p, b: grad_fn(p, b), seed=args.seed,
        )

    comp_state = None
    comp_apply = None
    if args.compress != "none":
        comp_init, comp_apply = make_compressor(args.compress)

    @jax.jit
    def apply_grads(state, grads, step_lr):
        return adamw_update(state, grads, step_lr)

    start_step = 0
    if mgr is not None:
        s, restored, meta = mgr.restore_latest(state)
        if s is not None:
            state = restored
            start_step = s
            pipe.restore(meta["pipeline"])
            if executor is not None and "lea" in meta:
                executor.load_state_dict(meta["lea"])
            print(f"[resume] step {s}")

    history = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = pipe.next()
        grads = None
        if executor is not None:
            grads, info = executor.round(state.params, batch)
            if grads is None:
                history.append({"step": step, "missed_deadline": True})
                print(f"step {step}: deadline MISS "
                      f"(on-time workers {info['on_time_workers']})")
        else:
            grads = grad_fn(state.params, batch)
        if grads is not None:
            if comp_apply is not None:
                if comp_state is None:
                    comp_state = jax.tree.map(
                        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
                grads, comp_state = comp_apply(grads, comp_state)
            lr = cosine_warmup(jnp.asarray(step + 1), peak_lr=args.lr, warmup=5,
                               total=args.steps)
            state, metrics = apply_grads(state, grads, lr)
            loss = float(loss_jit(state.params, batch))
            history.append({"step": step, "loss": loss})
            print(f"step {step}: loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
        # checkpoint regardless of deadline misses (a miss must not stall FT)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            meta = {"pipeline": pipe.state.to_dict()}
            if executor is not None:
                meta["lea"] = executor.state_dict()
            mgr.save_async(step + 1, state, extra_meta=meta)
    if mgr is not None:
        mgr.wait()
    out = {
        "history": history,
        "steps_done": len([h for h in history if "loss" in h]),
        "wall_s": time.time() - t0,
    }
    if executor is not None:
        out["timely_throughput"] = executor.timely_throughput
        print(f"timely computation throughput: {executor.timely_throughput:.3f}")
    return out


if __name__ == "__main__":
    main()
