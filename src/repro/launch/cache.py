"""Persistent XLA compilation cache wiring (opt-in via ``REPRO_COMPILE_CACHE``).

The engine's one-compile-per-family story (``repro.sweeps`` traced-K*
grouping) holds within a process; every restart still pays the full XLA
compile for each family signature.  JAX ships a persistent compilation
cache (supported on cpu/gpu/tpu backends) keyed by the computation
fingerprint; pointing every entry process at a shared directory makes the
per-family compile a one-time cost per container.

:func:`enable_compile_cache` is the single switch:

  * reads ``REPRO_COMPILE_CACHE=<dir>`` (or an explicit ``path``) — unset
    means disabled, return ``None``, zero config touched;
  * sets ``jax_compilation_cache_dir`` plus the two thresholds that
    default to skipping fast-compiling modules
    (``jax_persistent_cache_min_compile_time_secs`` and
    ``..._min_entry_size_bytes`` both to 0 — the sweep families compile in
    O(seconds) but the unit-test families compile in milliseconds, and a
    cache that silently skips them cannot back the warm-restart tests);
  * installs a ``jax.monitoring`` listener feeding persistent-cache HIT
    events into :func:`repro.obs.counters.note_persistent_cache_hits`, so
    the unified compile counter can tell "compiled" from "served from
    cache" (the warm-restart-records-0-compile-events contract).

Callers: ``benchmarks/run.py`` and the launch CLIs
(``repro.launch.serve``, ``repro.launch.train``) call this before any
jitted work; it is idempotent per process.
"""

from __future__ import annotations

import os

CACHE_ENV = "REPRO_COMPILE_CACHE"

_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_MISS_EVENT = "/jax/compilation_cache/cache_misses"

_STATE = {"enabled_dir": None, "listener": False, "misses": 0}


def _listener(event: str, **kwargs) -> None:
    if event == _HIT_EVENT:
        from repro.obs import counters as _counters

        _counters.note_persistent_cache_hits(1)
    elif event == _MISS_EVENT:
        _STATE["misses"] += 1


def persistent_cache_misses() -> int:
    """Persistent-cache misses observed this process (0 unless enabled)."""
    return int(_STATE["misses"])


def cache_dir() -> str | None:
    """The directory the cache was enabled with, or None."""
    return _STATE["enabled_dir"]


def enable_compile_cache(path: str | None = None) -> str | None:
    """Enable the persistent compilation cache if configured; returns the dir.

    ``path`` overrides the ``REPRO_COMPILE_CACHE`` environment variable.
    Returns ``None`` (and changes nothing) when neither is set.  Safe to
    call repeatedly; re-enabling with a DIFFERENT directory raises — a
    process mixing cache directories would double-count its own compiles.
    """
    target = path if path is not None else os.environ.get(CACHE_ENV)
    if not target:
        return None
    target = os.path.abspath(target)
    if _STATE["enabled_dir"] is not None:
        if _STATE["enabled_dir"] != target:
            raise RuntimeError(
                f"compile cache already enabled at {_STATE['enabled_dir']!r}; "
                f"cannot re-enable at {target!r}"
            )
        return target
    os.makedirs(target, exist_ok=True)
    import jax

    jax.config.update("jax_compilation_cache_dir", target)
    # the defaults skip computations compiling faster than 1s / smaller than
    # a floor — useless for test-scale families; cache everything
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    if not _STATE["listener"]:
        from jax import monitoring as _monitoring

        _monitoring.register_event_listener(_listener)
        _STATE["listener"] = True
    _STATE["enabled_dir"] = target
    return target
