"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 16x16 = 256 chips per
pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N first)"
        )
    return jax.make_mesh(shape, axes)


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
