"""Production mesh definitions and the multi-host grid entry point.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 16x16 = 256 chips per
pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).

Multi-host: :func:`init_distributed` joins a ``jax.distributed`` grid when
the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
environment (or explicit arguments) describe one, and is a strict no-op at
world size 1 — single-process runs never touch distributed state, so
world=1 behaviour (and bits) degenerate to the plain path.  :func:`world`
reports ``(process_index, process_count)`` either way.  The sweep executor
(:func:`repro.sweeps.executor.run_multihost`) shards scenario ROWS over
the grid, so each host only ever computes on its local devices —
:func:`make_sweep_mesh` therefore spans ``jax.local_devices()``, which is
identical to ``jax.devices()`` in a single-process run.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N first)"
        )
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(num_devices: int | None = None):
    """1-D ``("batch",)`` mesh for Monte-Carlo sweep sharding (repro.sweeps).

    Sweep rows are embarrassingly parallel, so the executor lays the flat
    (scenarios x seeds) batch over a single mesh axis spanning however many
    devices exist (or the first ``num_devices``).  Works the same on a real
    TPU slice and on forced host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    LOCAL devices only: under a ``jax.distributed`` grid each host shards
    its own scenario rows over its own devices (row sharding crosses hosts
    through the spool files, not through a global mesh), and in a
    single-process run ``local_devices() == devices()``.
    """
    avail = jax.local_devices()
    n = len(avail) if num_devices is None else num_devices
    if n < 1 or n > len(avail):
        raise RuntimeError(f"sweep mesh needs 1..{len(avail)} devices, asked for {n}")
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("batch",))


def init_distributed(
    *,
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> tuple[int, int]:
    """Join a ``jax.distributed`` grid if one is configured; returns ``world()``.

    Configuration comes from the arguments or, when omitted, the
    environment: ``REPRO_COORDINATOR`` (``host:port``),
    ``REPRO_NUM_PROCESSES``, ``REPRO_PROCESS_ID``.  With no coordinator or
    ``num_processes <= 1`` this is a STRICT no-op returning ``(0, 1)`` —
    the world=1 degeneration the tests pin down.  Safe to call twice
    (already-initialised grids are detected, not re-joined).
    """
    coord = coordinator if coordinator is not None else os.environ.get(
        "REPRO_COORDINATOR")
    n = num_processes if num_processes is not None else int(
        os.environ.get("REPRO_NUM_PROCESSES", "1"))
    if not coord or n <= 1:
        return (0, 1)
    pid = process_id if process_id is not None else int(
        os.environ.get("REPRO_PROCESS_ID", "0"))
    # a module flag, NOT jax.process_count(): probing the backend would
    # initialise it single-process and poison distributed.initialize
    if not _DIST["joined"]:
        jax.distributed.initialize(
            coordinator_address=coord, num_processes=n, process_id=pid
        )
        _DIST["joined"] = True
    return world()


_DIST = {"joined": False}


def world() -> tuple[int, int]:
    """``(process_index, process_count)`` — ``(0, 1)`` outside any grid."""
    return (jax.process_index(), jax.process_count())


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
