"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 16x16 = 256 chips per
pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N first)"
        )
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(num_devices: int | None = None):
    """1-D ``("batch",)`` mesh for Monte-Carlo sweep sharding (repro.sweeps).

    Sweep rows are embarrassingly parallel, so the executor lays the flat
    (scenarios x seeds) batch over a single mesh axis spanning however many
    devices exist (or the first ``num_devices``).  Works the same on a real
    TPU slice and on forced host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    avail = jax.devices()
    n = len(avail) if num_devices is None else num_devices
    if n < 1 or n > len(avail):
        raise RuntimeError(f"sweep mesh needs 1..{len(avail)} devices, asked for {n}")
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("batch",))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
