import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production mesh, extract memory/cost/collective numbers for EXPERIMENTS.md.

The XLA_FLAGS line above MUST run before any jax import — jax locks the device
count at first init.  (Tests/benches import other modules and see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_0_6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
  ... add --multi-pod for the (2,16,16) pod mesh.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPE_CELLS, get_config, list_configs
from repro.models import api
from repro.models.sharding import use_mesh
from repro.launch import hlo_cost
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

# long_500k needs sub-quadratic attention; these archs have a mechanism
# (SSM state / rolling SWA window); pure full-attention archs are N/A
# (documented in DESIGN.md §6).
LONG_CTX_ARCHS = {"zamba2_7b", "xlstm_125m", "mixtral_8x22b"}

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\s*\("
)
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
for _k in list(_DTYPE_BYTES):
    pass


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    base = _DTYPE_BYTES.get(dtype, 4 if not dtype.startswith("f8") else 1)
    return n * base


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum operand bytes of every collective in the (per-device) optimized HLO."""
    per_op: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # operands are inside the call parens; take shapes appearing after the
        # op name (the result shape(s) precede the op name).
        args = line[m.end():]
        size = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(args))
        if size == 0:  # e.g. `all-reduce(%param)` without inline shapes
            head = line[: m.start()]
            size = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        per_op[op] = per_op.get(op, 0) + size
        count[op] = count.get(op, 0) + 1
    return {"per_op_bytes": per_op, "counts": count,
            "total_bytes": int(sum(per_op.values()))}


def model_flops(cfg, cell) -> float:
    """Analytic useful FLOPs per step: 6ND train / 2ND forward (MoE: active)."""
    n = cfg.active_params() if cfg.n_experts else cfg.n_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch      # decode: one token per sequence


def runnable(arch: str, cell_name: str) -> tuple[bool, str]:
    if cell_name == "long_500k" and arch not in LONG_CTX_ARCHS:
        return False, "N/A: pure full-attention arch; no sub-quadratic mechanism (DESIGN §6)"
    return True, ""


def lower_cell(arch: str, cell_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Build + lower + compile one cell; returns the result record."""
    cfg = get_config(arch, **(overrides or {}))
    cell = SHAPE_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: dict = {
        "arch": arch, "cell": cell_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": int(n_dev),
    }
    t0 = time.time()
    with mesh, use_mesh(mesh):
        specs = api.input_specs(cfg, cell)
        batch_sh = api.batch_shardings(cfg, mesh, specs)
        if cell.kind == "train":
            state_abs = api.abstract_state(cfg)
            state_sh = api.state_shardings(cfg, mesh, state_abs)
            step = api.make_train_step(cfg, grad_shardings=state_sh.params)
            jitted = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,),
            )
            lowered = jitted.lower(state_abs, specs)
        elif cell.kind == "prefill":
            params_abs = api.abstract_params(cfg)
            params_sh = api.param_shardings(cfg, mesh, params_abs)
            step = api.make_prefill_step(cfg, max_len=cell.seq_len)
            # shard the produced KV cache (seq over tp) — it is the big output
            _, cache_out_abs = jax.eval_shape(step, params_abs, specs)
            cache_out_sh = api.cache_shardings(cfg, mesh, cache_out_abs)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=(None, cache_out_sh))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            params_abs = api.abstract_params(cfg)
            params_sh = api.param_shardings(cfg, mesh, params_abs)
            cache_abs = api.abstract_cache(cfg, cell.global_batch, cell.seq_len)
            cache_sh = api.cache_shardings(cfg, mesh, cache_abs)
            step = api.make_serve_step(cfg)
            jitted = jax.jit(
                step, in_shardings=(params_sh, cache_sh, batch_sh),
                out_shardings=(None, cache_sh), donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, cache_abs, specs)
        rec["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    # ---- analyses --------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        rec["memory"]["per_device_total"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    # Backend cost_analysis does NOT multiply while-loop bodies by their trip
    # count on CPU (verified; see hlo_cost module docstring), so the roofline
    # numbers come from our own HLO walker; the backend dict is kept as aux.
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_backend"] = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float))
                               and ("flops" in k or "bytes" in k)}
    except Exception as e:  # pragma: no cover
        rec["cost_backend"] = {"error": str(e)}

    hlo = compiled.as_text()
    walked = hlo_cost.analyze(hlo)
    flops = walked.flops
    bytes_acc = walked.hbm_bytes
    rec["cost"] = {
        "matmul_flops": walked.matmul_flops,
        "other_flops": walked.other_flops,
        "flops": flops,
        "bytes_accessed": bytes_acc,
    }
    coll = {
        "per_op_bytes": {k: int(v) for k, v in walked.per_collective.items()},
        "total_bytes": int(walked.collective_bytes),
    }
    rec["collectives"] = coll

    # ---- roofline terms (per-chip seconds; DESIGN §7 / task spec) ---------
    cfg_cell = SHAPE_CELLS[cell_name]
    mf = model_flops(get_config(arch), cfg_cell)
    compute_t = flops / PEAK_FLOPS_BF16
    memory_t = bytes_acc / HBM_BW
    collective_t = coll["total_bytes"] / ICI_BW
    dom = max(
        ("compute", compute_t), ("memory", memory_t), ("collective", collective_t),
        key=lambda kv: kv[1],
    )[0]
    rec["roofline"] = {
        "per_device_flops": flops,
        "per_device_bytes": bytes_acc,
        "per_device_collective_bytes": coll["total_bytes"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "dominant": dom,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops * n_dev, 1.0),
        "bound_s": max(compute_t, memory_t, collective_t),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (repeatable) — §Perf iterations")
    ap.add_argument("--tag", default=None, help="suffix for the output json")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    cells = []
    if args.all:
        for a in list_configs():
            for c in SHAPE_CELLS:
                cells.append((a, c))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    pod_tag = "multipod" if args.multi_pod else "pod"
    if args.tag:
        pod_tag = f"{pod_tag}__{args.tag}"
    failures = []
    for arch, cell in cells:
        out_path = os.path.join(args.out, f"{arch}__{cell}__{pod_tag}.json")
        if args.skip_existing and os.path.exists(out_path):
            print(f"[skip-existing] {arch} x {cell}")
            continue
        ok, why = runnable(arch, cell)
        if not ok:
            rec = {"arch": arch, "cell": cell, "skipped": why}
            print(f"[SKIP] {arch} x {cell}: {why}")
        else:
            print(f"[dryrun] {arch} x {cell} ({pod_tag}) "
                  f"{overrides if overrides else ''}...", flush=True)
            try:
                rec = lower_cell(arch, cell, args.multi_pod, overrides)
                rec["overrides"] = overrides
                r = rec["roofline"]
                print(
                    f"  ok: compile={rec['compile_s']}s "
                    f"mem/dev={rec['memory'].get('per_device_total', 0)/2**30:.2f}GiB "
                    f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                    f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                    f"useful={r['useful_flops_ratio']:.2f}",
                    flush=True,
                )
            except Exception as e:
                rec = {"arch": arch, "cell": cell, "error": str(e),
                       "traceback": traceback.format_exc()}
                failures.append((arch, cell, str(e)[:200]))
                print(f"  FAIL: {e}", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
    if failures:
        print(f"\n{len(failures)} failures:")
        for a, c, e in failures:
            print(f"  {a} x {c}: {e}")
        raise SystemExit(1)
    print("\nall cells ok")


if __name__ == "__main__":
    main()
