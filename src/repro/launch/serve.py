"""Streaming coded-serving CLI: a thin front end over :mod:`repro.serving`.

Runs the compiled serving loop — a continuous arrival process (default the
paper Sec. 6.2 shift-exponential gaps), a device-resident request queue,
EDF water-filling multi-job allocation and admission control — on one
worker pool, and prints the timely-throughput / latency accounting.

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --rounds 2000 \\
      --process shift_exp --arrival-const 0.2 --arrival-mean 0.8 \\
      --deadline-rel 2 --admit-threshold 0.5 --reserve-cap 0.7

Any registered arrival process is legal (``--process poisson --rate 1.5``,
``--process mmpp ...``); ``--admit-threshold 0 --reserve-cap big`` is
admit-all.  Exit is always 0 unless the accounting identities fail.

Live observability (:mod:`repro.obs`): ``--progress`` turns on the serving
engine's ``tap=`` stream and renders a stderr progress line (rounds/sec,
ETA) DURING the compiled scan; ``--tap-stride N`` sets the block size
(default ``rounds // 8``); ``--tap-log FILE`` appends every tap event to a
JSONL event log.  Tap-off runs are bit-identical to the flags' absence.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import CodeSpec, LoadParams
from repro.obs import metrics as _metrics
from repro.obs import taps as _taps


def _build_process(args):
    if args.process == "shift_exp":
        return serving.make_process(
            "shift_exp", t_const=args.arrival_const, mean=args.arrival_mean
        )
    if args.process == "poisson":
        return serving.make_process("poisson", rate=args.rate)
    if args.process == "mmpp":
        return serving.make_process(
            "mmpp", rate_lo=args.rate_lo, rate_hi=args.rate_hi
        )
    if args.process == "constant":
        return serving.make_process("constant", per_round=args.per_round)
    raise SystemExit(
        f"unknown arrival process {args.process!r}; registered: "
        f"{', '.join(serving.process_names())}"
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run (CI gate)")
    ap.add_argument("--rounds", type=int, default=1000)
    # pool (paper Sec. 6.2 simulation scale by default)
    ap.add_argument("--n", type=int, default=15)
    ap.add_argument("--r", type=int, default=10)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--deg-f", type=int, default=1)
    ap.add_argument("--mu-g", type=float, default=10.0)
    ap.add_argument("--mu-b", type=float, default=3.0)
    ap.add_argument("--deadline", type=float, default=1.0)
    ap.add_argument("--p-gg", type=float, default=0.8)
    ap.add_argument("--p-bb", type=float, default=0.7)
    # arrivals (registered processes; shift_exp is the paper's model)
    ap.add_argument("--process", default="shift_exp")
    ap.add_argument("--arrival-const", type=float, default=0.2,
                    help="shift_exp: constant gap component, in rounds")
    ap.add_argument("--arrival-mean", type=float, default=0.8,
                    help="shift_exp: mean of the exponential gap component")
    ap.add_argument("--rate", type=float, default=1.0, help="poisson rate")
    ap.add_argument("--rate-lo", type=float, default=0.3)
    ap.add_argument("--rate-hi", type=float, default=3.0)
    ap.add_argument("--per-round", type=int, default=1)
    # service / admission
    ap.add_argument("--deadline-rel", type=int, default=1,
                    help="per-request deadline, in rounds after arrival")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--grace", type=int, default=0)
    ap.add_argument("--strategies", default="lea",
                    help="comma-separated policy names")
    ap.add_argument("--admit-threshold", type=float, default=0.5)
    ap.add_argument("--reserve-cap", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    # live observability (repro.obs taps)
    ap.add_argument("--progress", action="store_true",
                    help="stream tap events; stderr progress line mid-scan")
    ap.add_argument("--tap-stride", type=int, default=None,
                    help="rounds per tap block (default rounds // 8)")
    ap.add_argument("--tap-log", default=None, metavar="FILE",
                    help="append tap events to this JSONL file")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rounds = min(args.rounds, 64)
    # REPRO_COMPILE_CACHE=<dir>: persistent XLA compile cache, so restarting
    # the CLI on an already-seen config skips the cold compile entirely
    from repro.launch.cache import enable_compile_cache

    enable_compile_cache()

    spec = CodeSpec(args.n, args.r, args.k, deg_f=args.deg_f)
    lp = LoadParams(
        n=args.n, kstar=spec.recovery_threshold,
        ell_g=int(min(args.mu_g * args.deadline, args.r)),
        ell_b=int(args.mu_b * args.deadline),
    )
    strategies = tuple(args.strategies.split(","))
    print(f"pool   : n={args.n} workers, K*={lp.kstar}, "
          f"loads ({lp.ell_g}/{lp.ell_b}), strategies={strategies}")

    req = serving.RequestSpec(
        kstar=lp.kstar, ell_g=lp.ell_g, ell_b=lp.ell_b,
        deadline_rel=args.deadline_rel,
        admit_threshold=args.admit_threshold, reserve_cap=args.reserve_cap,
    )
    tap = bool(args.progress or args.tap_log)
    stride = args.tap_stride
    if tap and stride is None:
        stride = max(args.rounds // 8, 1)
    progress = _metrics.ProgressLine(total=args.rounds, enabled=args.progress,
                                     label="serve")
    handlers = [("serve.progress", progress)] if args.progress else []
    if args.tap_log:
        handlers.append(("serve.jsonl", _metrics.JsonlSink(args.tap_log)))
    for hname, h in handlers:
        _taps.add_tap(hname, h)
    try:
        out = serving.simulate_serving(
            jax.random.PRNGKey(args.seed), jnp.ones((args.n,), bool),
            jnp.full((args.n,), args.p_gg), jnp.full((args.n,), args.p_bb),
            args.mu_g, args.mu_b, args.deadline, req, _build_process(args),
            rounds=args.rounds, strategies=strategies,
            capacity=args.capacity, grace=args.grace,
            tap=tap, tap_stride=stride,
        )
        out = jax.block_until_ready(out)
    finally:
        for hname, _ in handlers:
            _taps.remove_tap(hname)
        progress.close()

    summary = {}
    arr = int(out.arrivals[0])
    for j, name in enumerate(strategies):
        adm = int(out.admitted[j])
        on_t = int(out.served_on_time[j])
        late = int(out.served_late[j])
        exp = int(out.expired[j])
        rej = int(out.rejected[j])
        fly = int(out.in_flight[j])
        assert arr == adm + rej and adm == on_t + late + exp + fly
        ev = np.asarray(out.events)[j]
        sj = np.asarray(out.sojourn)[j]
        lat = sj[(ev == serving.EVENT_ON_TIME) | (ev == serving.EVENT_LATE)]
        pct = (np.percentile(lat, [50, 95, 99]) if lat.size
               else np.zeros(3))
        print(f"{name:>7}: {arr} arrivals | {adm} admitted ({rej} shed) | "
              f"{on_t} on time, {late} late, {exp} expired, {fly} in flight")
        print(f"{'':>7}  timely throughput {on_t / max(arr, 1):.3f} | "
              f"sojourn p50/p95/p99 = "
              f"{pct[0]:.0f}/{pct[1]:.0f}/{pct[2]:.0f} rounds")
        summary[name] = {
            "arrivals": arr, "admitted": adm, "served_on_time": on_t,
            "served_late": late, "expired": exp, "rejected": rej,
            "in_flight": fly,
            "timely_throughput": on_t / max(arr, 1),
            "latency_p50": float(pct[0]), "latency_p95": float(pct[1]),
            "latency_p99": float(pct[2]),
        }
    print("OK")
    return summary


if __name__ == "__main__":
    main()
