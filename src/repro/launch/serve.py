"""Batched serving driver with deadline accounting (the paper's metric, on an
LM): requests arrive with shift-exponential inter-arrival (Sec. 6.2's model),
each round must prefill + decode ``tokens_out`` tokens before its deadline.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \\
      --rounds 5 --batch 4 --prompt 32 --tokens-out 8 --deadline 2.0
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, get_config, get_smoke_config
from repro.models import api


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens-out", type=int, default=8)
    ap.add_argument("--deadline", type=float, default=5.0)
    ap.add_argument("--arrival-const", type=float, default=0.0)
    ap.add_argument("--arrival-mean", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.get_model(cfg).init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt + args.tokens_out + 4
    prefill = jax.jit(api.make_prefill_step(cfg, max_len=max_len))
    serve = jax.jit(api.make_serve_step(cfg))

    rng = np.random.default_rng(args.seed)
    cell = ShapeCell("serve", args.prompt, args.batch, "prefill")
    key = jax.random.PRNGKey(args.seed)

    on_time = 0
    lat = []
    for r in range(args.rounds):
        # shift-exponential arrival gap (paper Sec. 6.2)
        time.sleep(min(args.arrival_const + rng.exponential(args.arrival_mean), 0.2))
        batch = api.make_batch(cfg, cell, jax.random.fold_in(key, r))
        t0 = time.time()
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(args.tokens_out):
            logits, cache = serve(params, cache, {"next_token": tok})
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        lat.append(dt)
        ok = dt <= args.deadline
        on_time += int(ok)
        print(f"round {r}: {dt*1e3:.1f} ms {'OK' if ok else 'MISS'}")
    tput = on_time / args.rounds
    print(f"timely serving throughput: {tput:.3f}  (median {np.median(lat)*1e3:.1f} ms)")
    return {"timely_throughput": tput, "latencies": lat}


if __name__ == "__main__":
    main()
