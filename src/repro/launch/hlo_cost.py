"""HLO-text cost walker with while-loop trip-count multiplication.

XLA:CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
in-container: an 8-trip scan of a 256^3 matmul reports 1/8 of the true FLOPs).
Every model here wraps its layer stack in ``lax.scan``, so backend numbers are
useless for the roofline.  This module walks ``compiled.as_text()`` instead:

  * builds a global instruction table (name -> shape / opcode / operands / attrs)
  * resolves each ``while``'s trip count from the ``constant(N)`` in its
    condition computation (scan lowers to a 0..N counter loop)
  * cost(while) = trips x cost(body); cost(call/fusion) recurses
  * FLOPs: ``dot`` = 2*prod(out)*K (K from lhs shape + contracting dims);
    ``convolution`` = 2*prod(out)*prod(window)*(Cin/groups); reduce = prod(in)
  * HBM bytes: operands + outputs of materializing instructions (fusions count
    their boundary, not their interior — XLA:CPU/TPU keep fusion temporaries
    out of HBM)
  * collective bytes: operand sizes of all-gather / all-reduce / reduce-scatter
    / all-to-all / collective-permute, trip-multiplied like everything else
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|c64|c128|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\s([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "call", "conditional", "partition-id",
    "replica-id",
}


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """Sum (elements, bytes) over every concrete shape token in `text`."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return elems, tot


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shape: str          # raw shape text (may be tuple)
    args: str               # raw operand text inside the call parens
    attrs: str              # text after the call parens
    line: str


@dataclasses.dataclass
class Costs:
    matmul_flops: float = 0.0
    other_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)

    def add(self, o: "Costs", mult: float = 1.0):
        self.matmul_flops += o.matmul_flops * mult
        self.other_flops += o.other_flops * mult
        self.hbm_bytes += o.hbm_bytes * mult
        self.collective_bytes += o.collective_bytes * mult
        for k, v in o.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult

    @property
    def flops(self):
        return self.matmul_flops + self.other_flops


def _split_call(rest: str) -> tuple[str, str, str, str]:
    """rest = 'SHAPE opcode(args), attrs' -> (shape, opcode, args, attrs)."""
    m = _OP_RE.search(" " + rest)
    if not m:
        return rest, "", "", ""
    op_start = m.start(1)          # offset in " "+rest
    shape = rest[: op_start - 1].strip()
    opcode = m.group(1)
    # balanced-paren scan for the args
    i = m.end(1)                   # at '(' in " "+rest -> rest index = i-1
    s = rest
    j = i - 1
    depth = 0
    while j < len(s):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    args = s[i:j]
    attrs = s[j + 1:]
    return shape, opcode, args, attrs


def parse_module(hlo: str) -> tuple[dict[str, list[Instr]], dict[str, str], str]:
    """Returns (computations, name->shape, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    shapes: dict[str, str] = {}
    entry = ""
    cur: list[Instr] | None = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            name = hdr.group(1)
            comps[name] = []
            cur = comps[name]
            if line.startswith("ENTRY"):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape, opcode, args, attrs = _split_call(rest)
        if not opcode:
            continue
        ins = Instr(name=name, opcode=opcode, out_shape=shape, args=args,
                    attrs=attrs, line=line)
        cur.append(ins)
        shapes[name] = shape
    return comps, shapes, entry


def _dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _trip_count(comps: dict, cond_name: str) -> int:
    """Largest s32 constant in the condition computation (scan counter bound)."""
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.opcode == "constant" and ins.out_shape.strip().startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.out_shape)
    lhs_name_m = _NAME_RE.search(ins.args)
    k = 1
    if lhs_name_m:
        lhs_shape = shapes.get(lhs_name_m.group(1), "")
        dims = _dims(lhs_shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        if m and dims:
            for idx in m.group(1).split(","):
                if idx:
                    i = int(idx)
                    if i < len(dims):
                        k *= dims[i]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.out_shape)
    win = 1
    m = re.search(r"window=\{[^}]*size=([\dx]+)", ins.attrs)
    if m:
        for d in m.group(1).split("x"):
            win *= int(d)
    groups = 1
    g = re.search(r"feature_group_count=(\d+)", ins.attrs)
    if g:
        groups = int(g.group(1))
    # rhs shape gives input-feature count
    names = _NAME_RE.findall(ins.args)
    cin = 1
    if len(names) >= 2:
        rdims = _dims(shapes.get(names[1], ""))
        if len(rdims) >= 2:
            cin = rdims[-2] if groups == 1 else 1
    return 2.0 * out_elems * win * max(cin, 1)


_ELEMENTWISE_HEAVY = {
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "divide",
    "sine", "cosine", "logistic", "erf",
}


def cost_of_computation(name: str, comps: dict, shapes: dict,
                        memo: dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    total = Costs()
    for ins in comps.get(name, []):
        total.add(cost_of_instruction(ins, comps, shapes, memo))
    memo[name] = total
    return total


def cost_of_instruction(ins: Instr, comps: dict, shapes: dict,
                        memo: dict[str, Costs]) -> Costs:
    c = Costs()
    op = ins.opcode
    if op == "while":
        body = _called(ins.attrs, "body")
        cond = _called(ins.attrs, "condition")
        trips = _trip_count(comps, cond) if cond else 1
        if body:
            c.add(cost_of_computation(body, comps, shapes, memo), mult=trips)
        return c
    if op in ("call", "async-start"):
        tgt = _called(ins.attrs, "to_apply") or _called(ins.attrs, "called_computation")
        if tgt:
            c.add(cost_of_computation(tgt, comps, shapes, memo))
        return c
    if op == "conditional":
        # max over branches (upper bound; the models avoid data-dependent conds)
        branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
        names = []
        if branches:
            names = [b.strip().lstrip("%") for b in branches[0].split(",")]
        else:
            for key in ("true_computation", "false_computation"):
                t = _called(ins.attrs, key)
                if t:
                    names.append(t)
        best = Costs()
        for n in names:
            cc = cost_of_computation(n, comps, shapes, memo)
            if cc.flops + cc.hbm_bytes > best.flops + best.hbm_bytes:
                best = cc
        c.add(best)
        return c

    # ---- leaf instruction costs ------------------------------------------
    started = op.endswith("-start")
    base_op = op[:-6] if started else op
    if base_op in COLLECTIVES:
        _, arg_bytes = _shape_elems_bytes(
            " ".join(shapes.get(n, "") for n in _NAME_RE.findall(ins.args))
        )
        if arg_bytes == 0:  # fall back to result shape
            _, arg_bytes = _shape_elems_bytes(ins.out_shape)
        c.collective_bytes += arg_bytes
        c.per_collective[base_op] = c.per_collective.get(base_op, 0.0) + arg_bytes
        return c
    if op.endswith("-done"):
        return c

    if op == "fusion":
        tgt = _called(ins.attrs, "calls")
        if not tgt:
            _, out_b = _shape_elems_bytes(ins.out_shape)
            c.hbm_bytes += out_b + _operand_bytes(ins, shapes)
            return c
        inner_instrs = comps.get(tgt, [])
        inner = cost_of_computation(tgt, comps, shapes, memo)
        # fusion interior stays in registers/VMEM: take only its flops
        c.matmul_flops += inner.matmul_flops
        c.other_flops += inner.other_flops
        c.collective_bytes += inner.collective_bytes
        for k, v in inner.per_collective.items():
            c.per_collective[k] = c.per_collective.get(k, 0.0) + v
        c.hbm_bytes += _fusion_boundary_bytes(ins, inner_instrs, shapes)
        return c

    if op == "dot":
        c.matmul_flops += _dot_flops(ins, shapes)
    elif op == "convolution":
        c.matmul_flops += _conv_flops(ins, shapes)
    elif op in ("reduce", "reduce-window"):
        in_elems, _ = _shape_elems_bytes(
            " ".join(shapes.get(n, "") for n in _NAME_RE.findall(ins.args))
        )
        c.other_flops += in_elems
    elif op in _ELEMENTWISE_HEAVY:
        out_elems, _ = _shape_elems_bytes(ins.out_shape)
        c.other_flops += 10.0 * out_elems       # transcendental ~10 flops
    elif op not in SKIP_BYTES_OPS:
        out_elems, _ = _shape_elems_bytes(ins.out_shape)
        c.other_flops += out_elems

    if op not in SKIP_BYTES_OPS:
        _, out_b = _shape_elems_bytes(ins.out_shape)
        if op in ("dynamic-slice", "gather"):
            # reads only the selected slice (~ output size), not the operand
            c.hbm_bytes += 2 * out_b
        elif op in ("dynamic-update-slice", "scatter"):
            # in-place: traffic ~ 2x the update operand (read-modify-write)
            names = _NAME_RE.findall(ins.args)
            upd_b = 0
            if len(names) >= 2:
                _, upd_b = _shape_elems_bytes(shapes.get(names[1], ""))
            c.hbm_bytes += 2 * max(upd_b, 1)
        else:
            c.hbm_bytes += out_b + _operand_bytes(ins, shapes)
    return c


def _operand_bytes(ins: Instr, shapes: dict[str, str]) -> int:
    return sum(_shape_elems_bytes(shapes.get(n, ""))[1]
               for n in _NAME_RE.findall(ins.args))


def _fusion_boundary_bytes(ins: Instr, inner: list[Instr], shapes: dict[str, str]) -> int:
    """HBM traffic at a fusion's boundary, slice- and alias-aware.

    * a parameter consumed ONLY via (dynamic-)slice ops inside the fusion is
      charged at the slice size, not the full buffer (paged KV-cache reads);
    * when the fusion root is a dynamic-update-slice (possibly behind a
      convert), the aliased big buffer is charged at the update size
      (in-place cache write), not the whole buffer;
    * pure dtype-convert fusions are charged at boundary size as usual — on
      TPU these fuse away, but flagging them is the optimizer's job, not the
      cost model's (they show up honestly as memory traffic).
    """
    # map: inner parameter name -> parameter index
    param_idx: dict[str, int] = {}
    for it in inner:
        if it.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", it.line)
            if m:
                param_idx[it.name] = int(m.group(1))
    operands = _NAME_RE.findall(ins.args)

    # find the root (last instruction); unwrap converts/bitcasts
    root = inner[-1] if inner else None
    dus_alias_param = None
    dus_update_bytes = 0
    seen = {i.name: i for i in inner}
    r = root
    hops = 0
    while r is not None and r.opcode in ("convert", "bitcast", "copy") and hops < 4:
        src = _NAME_RE.findall(r.args)
        r = seen.get(src[0]) if src else None
        hops += 1
    if r is not None and r.opcode == "dynamic-update-slice":
        names = _NAME_RE.findall(r.args)
        if names:
            # operand 0 (possibly via convert chain) is the aliased buffer
            buf = seen.get(names[0])
            bhops = 0
            buf_name = names[0]
            while buf is not None and buf.opcode in ("convert", "bitcast", "copy") and bhops < 4:
                srcs = _NAME_RE.findall(buf.args)
                if not srcs:
                    break
                buf_name = srcs[0]
                buf = seen.get(buf_name)
                bhops += 1
            if buf is not None and buf.opcode == "parameter":
                dus_alias_param = param_idx.get(buf.name)
            elif buf_name in param_idx:
                dus_alias_param = param_idx[buf_name]
        if len(names) >= 2:
            upd = seen.get(names[1])
            if upd is not None:
                _, dus_update_bytes = _shape_elems_bytes(upd.out_shape)
            else:
                _, dus_update_bytes = _shape_elems_bytes(shapes.get(names[1], ""))

    # per-parameter effective read size
    sliced_param_bytes: dict[int, int] = {}
    consumers: dict[str, list[Instr]] = defaultdict(list)
    for it in inner:
        for n in set(_NAME_RE.findall(it.args)):
            consumers[n].append(it)
    for it in inner:
        if it.opcode != "parameter" or it.name not in param_idx:
            continue
        cons = consumers.get(it.name, [])
        if cons and all(cc.opcode in ("dynamic-slice", "slice", "gather") for cc in cons):
            eff = sum(_shape_elems_bytes(cc.out_shape)[1] for cc in cons)
            full = _shape_elems_bytes(it.out_shape)[1]
            sliced_param_bytes[param_idx[it.name]] = min(eff, full)

    total = 0
    for j, name in enumerate(operands):
        full = _shape_elems_bytes(shapes.get(name, ""))[1]
        if dus_alias_param is not None and j == dus_alias_param:
            continue                       # aliased in-place buffer: no read
        total += sliced_param_bytes.get(j, full)

    if dus_update_bytes:
        total += dus_update_bytes          # in-place write of the slice
    else:
        total += _shape_elems_bytes(ins.out_shape)[1]
    return total


def analyze(hlo: str) -> Costs:
    comps, shapes, entry = parse_module(hlo)
    memo: dict[str, Costs] = {}
    # fusions' interiors are counted when the fusion instruction is visited;
    # exclude called computations from the entry walk by only walking ENTRY.
    return cost_of_computation(entry, comps, shapes, memo)


# ---------------------------------------------------------------------------
# CLI: lower the engine's pool-path entry points and walk their HLO
# ---------------------------------------------------------------------------
#
# The walker above is a pure text pass; the functions below are the bridge
# to the live engine: each builds a SMALL representative invocation of one
# of the current pool-path entry points (traced-K* engine, fault sweep,
# serving sweep), lowers + compiles it, and hands ``compiled.as_text()`` to
# :func:`analyze`.  Shapes are tiny on purpose — the point is static
# FLOP/byte structure per round (``benchmarks/run.py obs_report`` divides
# them out as per-target cost rows), not a benchmark.

_ENTRY_ROUNDS = 16
_ENTRY_N = 8


def _hlo_simulate_strategies_pool() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core import throughput
    from repro.core.lea import PoolLoad

    n = _ENTRY_N
    pool = PoolLoad(
        kstar=jnp.int32(20), ell_g=jnp.int32(5), ell_b=jnp.int32(1),
        mask=jnp.ones((n,), bool),
    )
    return throughput.simulate_strategies_pool.lower(
        jax.random.PRNGKey(0), pool,
        jnp.full((n,), 0.8, jnp.float32), jnp.full((n,), 0.7, jnp.float32),
        5.0, 1.0, 1.0,
        rounds=_ENTRY_ROUNDS, strategies=("lea", "static"),
    ).compile().as_text()


def _hlo_sweep_faults() -> str:
    import jax
    import jax.numpy as jnp

    from repro import faults
    from repro.core.lea import PoolLoad

    n, b = _ENTRY_N, 2
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    pool = PoolLoad(
        kstar=jnp.full((b,), 20, jnp.int32),
        ell_g=jnp.full((b,), 5, jnp.int32),
        ell_b=jnp.full((b,), 1, jnp.int32),
        mask=jnp.ones((b, n), bool),
    )
    p_gg = jnp.full((b, n), 0.8, jnp.float32)
    p_bb = jnp.full((b, n), 0.7, jnp.float32)
    channel = faults.make_channel([
        ("preempt", {"p_preempt": jnp.full((b,), 0.2, jnp.float32)}),
    ])
    fn = jax.jit(lambda k, pl, pg, pb, ch: faults.sweep_faults(
        k, pl, pg, pb, 5.0, 1.0, 1.0, ch, 10,
        rounds=_ENTRY_ROUNDS, strategies=("lea", "static"), r=2, packets=2,
    ))
    return fn.lower(keys, pool, p_gg, p_bb, channel).compile().as_text()


def _hlo_sweep_serving() -> str:
    import jax
    import jax.numpy as jnp

    from repro import serving

    n, b = _ENTRY_N, 2
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    mask = jnp.ones((b, n), bool)
    p_gg = jnp.full((b, n), 0.8, jnp.float32)
    p_bb = jnp.full((b, n), 0.7, jnp.float32)
    spec = serving.RequestSpec(
        kstar=jnp.full((b,), 20, jnp.int32),
        ell_g=jnp.full((b,), 5, jnp.int32),
        ell_b=jnp.full((b,), 1, jnp.int32),
        deadline_rel=jnp.full((b,), 2, jnp.int32),
        admit_threshold=jnp.zeros((b,), jnp.float32),
        reserve_cap=jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32),
    )
    process = serving.make_process(
        "poisson", rate=jnp.full((b,), 1.0, jnp.float32)
    )
    fn = jax.jit(lambda k, m, pg, pb, sp, pr: serving.sweep_serving(
        k, m, pg, pb, 5.0, 1.0, 1.0, sp, pr,
        rounds=_ENTRY_ROUNDS, strategies=("lea",), capacity=2, grace=0,
    ))
    return fn.lower(keys, mask, p_gg, p_bb, spec, process).compile().as_text()


# name -> HLO builder; the names ARE the engine's pool-path entry points
ENTRY_POINTS = {
    "simulate_strategies_pool": _hlo_simulate_strategies_pool,
    "sweep_faults": _hlo_sweep_faults,
    "sweep_serving": _hlo_sweep_serving,
}


def entry_point_names() -> tuple[str, ...]:
    return tuple(sorted(ENTRY_POINTS))


def estimate_entry(name: str) -> dict:
    """Lower entry point ``name`` at the reference small shapes and return
    its static cost row (JSON-able; rounds-normalised columns included)."""
    if name not in ENTRY_POINTS:
        raise KeyError(
            f"unknown entry point {name!r}; available: "
            f"{', '.join(entry_point_names())}"
        )
    costs = analyze(ENTRY_POINTS[name]())
    flops = costs.flops
    return {
        "target": name,
        "rounds": _ENTRY_ROUNDS,
        "n": _ENTRY_N,
        "matmul_flops": costs.matmul_flops,
        "other_flops": costs.other_flops,
        "flops": flops,
        "hbm_bytes": costs.hbm_bytes,
        "collective_bytes": costs.collective_bytes,
        "per_collective": dict(costs.per_collective),
        "flops_per_round": flops / _ENTRY_ROUNDS,
        "hbm_bytes_per_round": costs.hbm_bytes / _ENTRY_ROUNDS,
        "arithmetic_intensity": flops / max(costs.hbm_bytes, 1.0),
    }


def main(argv: list[str] | None = None) -> None:
    import argparse
    import json

    parser = argparse.ArgumentParser(
        prog="python -m repro.launch.hlo_cost",
        description=(
            "Static FLOP/byte cost walk of the engine's pool-path entry "
            "points (or a raw HLO text dump)."
        ),
    )
    parser.add_argument(
        "targets", nargs="*",
        help=f"entry points to lower (default: all of "
             f"{', '.join(entry_point_names())})",
    )
    parser.add_argument("--list", action="store_true",
                        help="print the known entry points and exit")
    parser.add_argument("--hlo-file", metavar="PATH",
                        help="analyze a raw HLO text file instead of lowering")
    parser.add_argument("--json", action="store_true",
                        help="emit one JSON document instead of CSV rows")
    args = parser.parse_args(argv)

    if args.list:
        print("\n".join(entry_point_names()))
        return
    if args.hlo_file:
        with open(args.hlo_file) as f:
            costs = analyze(f.read())
        rows = [{
            "target": args.hlo_file,
            "matmul_flops": costs.matmul_flops,
            "other_flops": costs.other_flops,
            "flops": costs.flops,
            "hbm_bytes": costs.hbm_bytes,
            "collective_bytes": costs.collective_bytes,
            "per_collective": dict(costs.per_collective),
        }]
    else:
        targets = args.targets or list(entry_point_names())
        unknown = [t for t in targets if t not in ENTRY_POINTS]
        if unknown:
            raise SystemExit(
                f"unknown entry point(s): {', '.join(unknown)}\n"
                f"available: {', '.join(entry_point_names())}"
            )
        rows = [estimate_entry(t) for t in targets]

    if args.json:
        print(json.dumps(rows, indent=2, allow_nan=False))
        return
    cols = ("target", "flops", "matmul_flops", "hbm_bytes",
            "collective_bytes", "arithmetic_intensity")
    print(",".join(cols))
    for row in rows:
        print(",".join(
            f"{row[c]:.3f}" if isinstance(row.get(c), float) else str(row.get(c, ""))
            for c in cols
        ))


if __name__ == "__main__":
    main()
