"""Gradient compression for cross-pod reduction (DESIGN §7).

Two schemes, both with error feedback (the residual of what compression
dropped is carried into the next step, preserving convergence):

  * ``int8``  — per-tensor symmetric quantization (4x bf16 / 2x fp32 saving)
  * ``topk``  — magnitude top-k sparsification (k_frac of entries kept)

``make_compressor`` returns (init_state, transform) where
``transform(grads, state) -> (decompressed_grads, new_state)`` — it plugs
into ``make_train_step(grad_transform=...)`` wrapped with the EF state.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _topk_mask(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * k_frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def make_compressor(kind: str, *, k_frac: float = 0.05):
    """Returns (init_state_fn, transform_fn) with error feedback."""

    if kind == "int8":
        def transform(g, residual):
            total = g.astype(jnp.float32) + residual
            q, s = _quantize_int8(total)
            deq = _dequantize_int8(q, s)
            return deq, total - deq
    elif kind == "topk":
        def transform(g, residual):
            total = g.astype(jnp.float32) + residual
            mask = _topk_mask(total, k_frac)
            kept = total * mask
            return kept, total - kept
    elif kind == "none":
        def transform(g, residual):
            return g.astype(jnp.float32), residual
    else:
        raise ValueError(kind)

    def init_state(grads_like):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    def apply(grads, state):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = jax.tree.leaves(state)
        outs = [transform(g, s) for g, s in zip(flat_g, flat_s)]
        new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_s = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_g, new_s

    return init_state, apply


def compressed_bytes(kind: str, n_elems: int, *, k_frac: float = 0.05) -> int:
    """Wire size of one compressed gradient — for the collective roofline."""
    if kind == "int8":
        return n_elems + 4
    if kind == "topk":
        k = max(1, int(n_elems * k_frac))
        return k * (4 + 4)     # value + index
    return n_elems * 4
