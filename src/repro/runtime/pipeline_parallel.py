"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Fill-drain schedule: with S stages and M microbatches the loop runs
M + S - 1 ticks; stage s computes microbatch t-s at tick t and forwards its
activation to stage s+1 over ``collective-permute`` (ICI neighbours).  Used
on the ``pod`` axis when ``pipeline_stages > 1`` — the cross-pod link then
carries one activation per tick instead of a full gradient all-reduce.

The paper's LEA layer composes: each *stage group* is a worker in the
Markov model, and the allocator decides microbatch counts per group.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn, stage_params, x_microbatches, mesh: Mesh,
                     axis: str = "pod"):
    """Run ``stage_fn(params_s, x) -> x`` over S pipeline stages.

    stage_params: pytree, leaves (S, ...)   — sharded over ``axis``
    x_microbatches: (M, mb, ...)            — replicated over ``axis``
    Returns (M, mb, ...) final-stage outputs, replicated over ``axis``.
    """
    s_count = mesh.shape[axis]
    m_count = x_microbatches.shape[0]

    def per_stage(params_local, xs):
        params_local = jax.tree.map(lambda a: a[0], params_local)   # (1,...) -> (...)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        zeros = jnp.zeros(mb_shape, xs.dtype)
        perm = [(i, i + 1) for i in range(s_count - 1)]

        def tick(t, carry):
            recv, outbuf = carry
            idx = jnp.clip(t, 0, m_count - 1)
            first_in = jax.lax.dynamic_index_in_dim(xs, idx, 0, keepdims=False)
            inp = jnp.where(stage == 0, first_in, recv)
            out = stage_fn(params_local, inp)
            # forward to the next stage
            recv_next = jax.lax.ppermute(out, axis, perm)
            # last stage collects microbatch t-(S-1)
            out_t = t - (s_count - 1)
            do_write = (stage == s_count - 1) & (out_t >= 0)
            write_idx = jnp.clip(out_t, 0, m_count - 1)
            cur = jax.lax.dynamic_index_in_dim(outbuf, write_idx, 0, keepdims=False)
            upd = jnp.where(do_write, out, cur)
            outbuf = jax.lax.dynamic_update_index_in_dim(outbuf, upd, write_idx, 0)
            return recv_next, outbuf

        outbuf = jnp.zeros_like(xs)
        recv = zeros
        recv, outbuf = jax.lax.fori_loop(0, m_count + s_count - 1, tick, (recv, outbuf))
        # replicate the last stage's buffer to every stage (masked psum)
        mask = (stage == s_count - 1).astype(outbuf.dtype)
        outbuf = jax.lax.psum(outbuf * mask, axis)
        return outbuf

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x_microbatches)


def reference_forward(stage_fn, stage_params, x_microbatches):
    """Sequential oracle for tests: apply all stages to every microbatch."""
    s_count = jax.tree.leaves(stage_params)[0].shape[0]

    def apply_all(x):
        for s in range(s_count):
            ps = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(ps, x)
        return x

    return jax.vmap(apply_all)(x_microbatches)
