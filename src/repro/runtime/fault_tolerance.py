"""LEA-driven coded data parallelism + fault tolerance (DESIGN §3/§7).

This is the paper's scheduling layer embedded in the trainer:

  * the global batch is split into ``k`` microbatch shards, repetition-coded
    (the paper's ``nr < k deg f - 1`` branch — valid for arbitrary, i.e.
    non-polynomial, gradient functions) across ``n`` worker groups, each
    storing ``r`` shard-copies (copy ``v`` holds shard ``v mod k``);
  * per round, the EA algorithm allocates ``ell_g``/``ell_b`` shard
    evaluations per worker from the estimated Markov state — exactly
    Sec. 3.2, with K* = nr - floor(nr/k) + 1;
  * a round SUCCEEDS iff every shard has an on-time copy (repetition-branch
    coverage); the master averages one copy of each shard into the step
    gradient;
  * permanently-dead workers shrink the pool; when ``n_live * r < k`` decode
    becomes infeasible and the manager signals restart-from-checkpoint.

Graceful degradation (the ``repro.faults`` integration)
-------------------------------------------------------
Each shard-copy's result streams out as ``packets`` packet blocks scored by
the partial-work-conserving rule of :func:`repro.faults.packets.packet_on_time`
under an optional fault channel (crash/preempt/erasure injectors from
:mod:`repro.faults.channels`), and shard coverage is per PACKET: shard j's
packet q is covered iff ANY stored copy of j delivered packet q — partial
work from different preempted copies composes into a full shard.

A round that misses coverage is RETRIED up to ``max_retries`` times with
exponential backoff (each retry first lets the worker chains advance
``backoff_base * 2^(attempt-1)`` extra Markov steps — waiting out a bad
spell — then re-plans loads from the updated estimator).  Coverage
accumulates across attempts, so retries only add packets.  Every round ends
in exactly ONE of four dispositions, counted in ``outcomes`` (the
never-silently-drop invariant: the counts always sum to ``rounds``):

  ``on_time``  — full coverage on the first attempt;
  ``late``     — full coverage after >= 1 retry;
  ``partial``  — still short after retries, but every shard's first ``p1``
                 packet indices are covered and ``allow_partial`` is set:
                 the round is served degraded (hierarchical layer-1);
  ``dropped``  — none of the above; the round returns ``None``.

Worker speeds follow the paper's two-state Markov model.  In this container
they are simulated (CPU has no real host telemetry); on a real cluster the
observation hook is per-host wall-clock completion times.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lea
from repro.core.lagrange import CodeSpec
from repro.core.markov import step_states, initial_states
from repro.faults.channels import apply_channel, base_trace
from repro.faults.packets import packet_on_time
from repro.runtime.elastic import remap_estimator

OUTCOMES = ("on_time", "late", "partial", "dropped")


@partial(jax.jit, static_argnames=("lp",))
def _plan_round(est: lea.EstimatorState, live: jnp.ndarray, lp: lea.LoadParams):
    """Phase (1) as one compiled computation: predicted p_good (dead workers
    forced bad) -> batched allocate -> dead workers get zero load."""
    p_good = jnp.where(
        est.seen_prev, lea.predicted_good_prob(est), jnp.full((lp.n,), 0.5)
    )
    p_good = jnp.where(live, p_good, 0.0)
    loads, i_star = lea.allocate(p_good, lp)
    return jnp.where(live, loads, 0), i_star


_update_estimator = jax.jit(lea.update_estimator)


@dataclasses.dataclass(frozen=True)
class CodedDPConfig:
    n_workers: int = 8
    r: int = 4                 # shard-copies stored per worker group
    k: int = 16                # microbatch shards per round
    deadline: float = 1.0
    mu_g: float = 10.0         # shard evaluations / second, good state
    mu_b: float = 3.0
    p_gg: float = 0.8          # simulation-only: true (unknown) dynamics
    p_bb: float = 0.7
    # --- graceful degradation (repro.faults) ---
    packets: int = 1           # packet blocks per shard-copy result
    max_retries: int = 0       # extra attempts for an uncovered round
    backoff_base: int = 1      # Markov steps waited before retry 1 (then x2)
    allow_partial: bool = False  # serve layer-1-covered rounds degraded
    p1: int = 1                # layer-1 packet-prefix length (see faults.packets)

    @property
    def spec(self) -> CodeSpec:
        # deg_f = "infinity" for non-polynomial f -> repetition branch
        return CodeSpec(self.n_workers, self.r, self.k, deg_f=10**9)

    @property
    def load_params(self) -> lea.LoadParams:
        return lea.LoadParams(
            n=self.n_workers,
            kstar=self.spec.recovery_threshold,
            ell_g=int(min(self.mu_g * self.deadline, self.r)),
            ell_b=int(self.mu_b * self.deadline),
        )


class CodedDataParallelExecutor:
    """Runs LEA-coded gradient rounds on top of a grad_fn.

    ``grad_fn(params, shard_batch) -> grads``; the executor owns shard
    assignment, per-round allocation, completion simulation/observation,
    estimator updates, shard-copy decoding, retry/degrade dispositioning
    and elastic pool resizes.  ``channel`` is an optional tuple of fault
    injectors (:mod:`repro.faults.channels`) applied to every attempt's
    completion times and packet deliveries.
    """

    def __init__(self, cfg: CodedDPConfig, grad_fn: Callable, *, seed: int = 0,
                 channel: Sequence = ()):
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.channel = tuple(channel)
        self.est = lea.init_estimator(cfg.n_workers)
        self.key = jax.random.PRNGKey(seed)
        self.key, k0 = jax.random.split(self.key)
        n = cfg.n_workers
        self._true_states = initial_states(
            k0, jnp.full((n,), cfg.p_gg), jnp.full((n,), cfg.p_bb)
        )
        self.live = np.ones(cfg.n_workers, bool)
        self.rounds = 0
        self.successes = 0
        self.outcomes = {name: 0 for name in OUTCOMES}

    # -- estimator state round-trips through checkpoints (DESIGN §7) --------
    def state_dict(self) -> dict:
        return {
            "counts": np.asarray(self.est.counts).tolist(),
            "prev_state": np.asarray(self.est.prev_state).tolist(),
            "seen_prev": bool(self.est.seen_prev),
            "live": self.live.tolist(),
            "rounds": self.rounds,
            "successes": self.successes,
            "outcomes": dict(self.outcomes),
        }

    def load_state_dict(self, d: dict) -> None:
        self.est = lea.EstimatorState(
            counts=jnp.asarray(d["counts"], jnp.float32),
            prev_state=jnp.asarray(d["prev_state"], jnp.int32),
            seen_prev=jnp.asarray(d["seen_prev"]),
        )
        self.live = np.asarray(d["live"], bool)
        self.rounds = int(d["rounds"])
        self.successes = int(d["successes"])
        self.outcomes = {
            name: int(d.get("outcomes", {}).get(name, 0)) for name in OUTCOMES
        }

    def mark_dead(self, worker: int) -> None:
        """Permanent host failure.  Infeasibility triggers restart upstream."""
        self.live[worker] = False

    @property
    def decode_feasible(self) -> bool:
        return int(self.live.sum()) * self.cfg.r >= self.cfg.k

    def resize(self, new_n: int, survivors: list[int] | None = None) -> None:
        """Elastic pool resize: carry estimator history across grow/shrink.

        ``survivors`` maps old worker indices onto the first slots of the
        new pool (default: the identity prefix); newcomers start live with
        the pooled estimator prior (:func:`repro.runtime.elastic.remap_estimator`)
        and a fresh stationary state draw.
        """
        cfg = self.cfg
        old_n = cfg.n_workers
        if survivors is None:
            survivors = list(range(min(old_n, new_n)))
        self.est = remap_estimator(self.est, old_n, new_n, survivors)
        self.cfg = dataclasses.replace(cfg, n_workers=new_n)
        self.key, k_new = jax.random.split(self.key)
        fresh = initial_states(
            k_new, jnp.full((new_n,), cfg.p_gg), jnp.full((new_n,), cfg.p_bb)
        )
        states = np.asarray(fresh).copy()
        live = np.ones(new_n, bool)
        old_states = np.asarray(self._true_states)
        for i, s in enumerate(survivors[:new_n]):
            states[i] = old_states[s]
            live[i] = self.live[s]
        self._true_states = jnp.asarray(states)
        self.live = live

    def _advance_network(self, steps: int = 1):
        cfg = self.cfg
        for _ in range(steps):
            self.key, k = jax.random.split(self.key)
            self._true_states = step_states(
                k, self._true_states,
                jnp.full((cfg.n_workers,), cfg.p_gg),
                jnp.full((cfg.n_workers,), cfg.p_bb),
            )

    def _attempt(self) -> tuple[np.ndarray, np.ndarray, dict]:
        """One delivery attempt: plan, simulate completion, observe.

        Returns ``(packet mask (n*r, packets), loads, attempt info)``.
        """
        cfg = self.cfg
        lp = cfg.load_params
        loads_dev, _ = _plan_round(self.est, jnp.asarray(self.live), lp)
        loads = np.array(loads_dev)      # writable host copy
        states = np.asarray(self._true_states)

        trace = base_trace(1, cfg.n_workers, cfg.r, cfg.packets, cfg.deadline)
        if self.channel:
            self.key, k_fault = jax.random.split(self.key)
            trace = apply_channel(k_fault, self.channel, trace)
        mask = np.array(packet_on_time(
            jnp.asarray(states), jnp.asarray(loads[None]),
            cfg.mu_g, cfg.mu_b, cfg.deadline, cfg.r, cfg.packets,
            trace=trace, conserve=True,
        ))[0]                                            # (n*r, packets)
        mask &= np.repeat(self.live, cfg.r)[:, None]

        # (4) estimator update — completion times reveal the round's states
        self.est = _update_estimator(self.est, jnp.asarray(states))

        speeds = np.where(states == 1, cfg.mu_g, cfg.mu_b)
        on_time_workers = int(
            (((loads / np.maximum(speeds, 1e-9)) <= cfg.deadline + 1e-9)
             & self.live).sum()
        )
        info = {"on_time_workers": on_time_workers, "loads": loads.tolist()}
        return mask, loads, info

    def _coverage(self, mask: np.ndarray) -> np.ndarray:
        """(n*r, packets) arrivals -> (k, packets) shard-packet coverage.

        Repetition code: shard j's packet q is covered iff ANY stored copy
        v (v mod k == j) delivered packet q — partial work from different
        copies composes.
        """
        cfg = self.cfg
        covered = np.zeros((cfg.k, cfg.packets), bool)
        for j in range(cfg.k):
            covered[j] = mask[j::cfg.k].any(axis=0)
        return covered

    def round(self, params, batch) -> tuple[dict | None, dict]:
        """One LEA round (with bounded retry + degrade — module docstring).

        Returns ``(gradient | None, info)``; ``info["outcome"]`` is one of
        ``OUTCOMES`` and the running ``outcomes`` counts always sum to
        ``rounds``.
        """
        cfg = self.cfg
        lp = cfg.load_params
        self.rounds += 1

        covered = np.zeros((cfg.k, cfg.packets), bool)
        attempts = 0
        first_info: dict = {}
        arrived_copies = 0
        for attempt in range(cfg.max_retries + 1):
            # attempt 0 advances one round; retries wait out an exponentially
            # growing backoff of extra Markov steps before redelivering
            steps = 1 if attempt == 0 else cfg.backoff_base * (2 ** (attempt - 1))
            self._advance_network(steps)
            mask, loads, info = self._attempt()
            if attempt == 0:
                first_info = info
            attempts = attempt + 1
            arrived_copies = int(mask.all(axis=-1).sum())
            covered |= self._coverage(mask)
            if covered.all():
                break

        full = bool(covered.all())
        layer1 = bool(covered[:, : cfg.p1].all())
        if full:
            outcome = "on_time" if attempts == 1 else "late"
        elif cfg.allow_partial and layer1:
            outcome = "partial"
        else:
            outcome = "dropped"
        self.outcomes[outcome] += 1

        info = {
            "success": full and attempts == 1,
            "outcome": outcome,
            "attempts": attempts,
            "on_time_workers": first_info.get("on_time_workers", 0),
            "arrived_copies": arrived_copies,
            "covered_packets": int(covered.sum()),
            "kstar": lp.kstar,
            "loads": first_info.get("loads", []),
        }
        if outcome == "dropped":
            return None, info
        if full:
            self.successes += 1

        # master decodes: one on-time copy of each shard, average grads.
        # Degraded (partial) rounds serve the layer-1 prefix of every shard;
        # the gradient estimate still averages over all k shards (coverage
        # guaranteed the layer-1 packets of each), flagged by the outcome.
        shards = _split_batch(batch, cfg.k)
        grads = None
        for j in range(cfg.k):
            g = self.grad_fn(params, shards[j])          # computed by copy owner
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
        grads = jax.tree.map(lambda a: a / cfg.k, grads)
        return grads, info

    @property
    def timely_throughput(self) -> float:
        return self.successes / max(self.rounds, 1)


def _split_batch(batch: dict, k: int) -> list[dict]:
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])

    stacked = jax.tree.map(split, batch)
    return [jax.tree.map(lambda a: a[j], stacked) for j in range(k)]
