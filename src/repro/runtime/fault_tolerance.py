"""LEA-driven coded data parallelism + fault tolerance (DESIGN §3/§7).

This is the paper's scheduling layer embedded in the trainer:

  * the global batch is split into ``k`` microbatch shards, repetition-coded
    (the paper's ``nr < k deg f - 1`` branch — valid for arbitrary, i.e.
    non-polynomial, gradient functions) across ``n`` worker groups, each
    storing ``r`` shard-copies (copy ``v`` holds shard ``v mod k``);
  * per round, the EA algorithm allocates ``ell_g``/``ell_b`` shard
    evaluations per worker from the estimated Markov state — exactly
    Sec. 3.2, with K* = nr - floor(nr/k) + 1;
  * a round SUCCEEDS iff >= K* shard evaluations land by the deadline, which
    (repetition bound) guarantees every shard has an on-time copy; the master
    averages one copy of each shard into the step gradient;
  * permanently-dead workers shrink the pool; when ``n_live * r < k`` decode
    becomes infeasible and the manager signals restart-from-checkpoint.

Worker speeds follow the paper's two-state Markov model.  In this container
they are simulated (CPU has no real host telemetry); on a real cluster the
observation hook is per-host wall-clock completion times.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lea
from repro.core.lagrange import CodeSpec
from repro.core.markov import step_states, initial_states


@partial(jax.jit, static_argnames=("lp",))
def _plan_round(est: lea.EstimatorState, live: jnp.ndarray, lp: lea.LoadParams):
    """Phase (1) as one compiled computation: predicted p_good (dead workers
    forced bad) -> batched allocate -> dead workers get zero load."""
    p_good = jnp.where(
        est.seen_prev, lea.predicted_good_prob(est), jnp.full((lp.n,), 0.5)
    )
    p_good = jnp.where(live, p_good, 0.0)
    loads, i_star = lea.allocate(p_good, lp)
    return jnp.where(live, loads, 0), i_star


_update_estimator = jax.jit(lea.update_estimator)


@dataclasses.dataclass(frozen=True)
class CodedDPConfig:
    n_workers: int = 8
    r: int = 4                 # shard-copies stored per worker group
    k: int = 16                # microbatch shards per round
    deadline: float = 1.0
    mu_g: float = 10.0         # shard evaluations / second, good state
    mu_b: float = 3.0
    p_gg: float = 0.8          # simulation-only: true (unknown) dynamics
    p_bb: float = 0.7

    @property
    def spec(self) -> CodeSpec:
        # deg_f = "infinity" for non-polynomial f -> repetition branch
        return CodeSpec(self.n_workers, self.r, self.k, deg_f=10**9)

    @property
    def load_params(self) -> lea.LoadParams:
        return lea.LoadParams(
            n=self.n_workers,
            kstar=self.spec.recovery_threshold,
            ell_g=int(min(self.mu_g * self.deadline, self.r)),
            ell_b=int(self.mu_b * self.deadline),
        )


class CodedDataParallelExecutor:
    """Runs LEA-coded gradient rounds on top of a grad_fn.

    ``grad_fn(params, shard_batch) -> grads``; the executor owns shard
    assignment, per-round allocation, completion simulation/observation,
    estimator updates, and shard-copy decoding.
    """

    def __init__(self, cfg: CodedDPConfig, grad_fn: Callable, *, seed: int = 0):
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.est = lea.init_estimator(cfg.n_workers)
        self.key = jax.random.PRNGKey(seed)
        self.key, k0 = jax.random.split(self.key)
        n = cfg.n_workers
        self._true_states = initial_states(
            k0, jnp.full((n,), cfg.p_gg), jnp.full((n,), cfg.p_bb)
        )
        self.live = np.ones(cfg.n_workers, bool)
        self.rounds = 0
        self.successes = 0

    # -- estimator state round-trips through checkpoints (DESIGN §7) --------
    def state_dict(self) -> dict:
        return {
            "counts": np.asarray(self.est.counts).tolist(),
            "prev_state": np.asarray(self.est.prev_state).tolist(),
            "seen_prev": bool(self.est.seen_prev),
            "live": self.live.tolist(),
            "rounds": self.rounds,
            "successes": self.successes,
        }

    def load_state_dict(self, d: dict) -> None:
        self.est = lea.EstimatorState(
            counts=jnp.asarray(d["counts"], jnp.float32),
            prev_state=jnp.asarray(d["prev_state"], jnp.int32),
            seen_prev=jnp.asarray(d["seen_prev"]),
        )
        self.live = np.asarray(d["live"], bool)
        self.rounds = int(d["rounds"])
        self.successes = int(d["successes"])

    def mark_dead(self, worker: int) -> None:
        """Permanent host failure.  Infeasibility triggers restart upstream."""
        self.live[worker] = False

    @property
    def decode_feasible(self) -> bool:
        return int(self.live.sum()) * self.cfg.r >= self.cfg.k

    def _advance_network(self):
        cfg = self.cfg
        self.key, k = jax.random.split(self.key)
        self._true_states = step_states(
            k, self._true_states,
            jnp.full((cfg.n_workers,), cfg.p_gg), jnp.full((cfg.n_workers,), cfg.p_bb),
        )

    def round(self, params, batch) -> tuple[dict | None, dict]:
        """One LEA round.  Returns (mean gradient | None on miss, info)."""
        cfg = self.cfg
        lp = cfg.load_params
        self.rounds += 1
        self._advance_network()

        # (1) Load assignment from estimated state (dead workers forced bad);
        # one jitted call — predicted p_good + batched allocate fused.
        loads_dev, _ = _plan_round(self.est, jnp.asarray(self.live), lp)
        loads = np.array(loads_dev)      # writable host copy

        # (2) Local computation + (3) observation: deterministic speeds
        states = np.asarray(self._true_states)
        speeds = np.where(states == 1, cfg.mu_g, cfg.mu_b)
        on_time = (loads / np.maximum(speeds, 1e-9)) <= cfg.deadline + 1e-9
        on_time &= self.live

        # which encoded shard-copies arrived: worker i's copies i*r..i*r+l-1
        arrived = np.zeros(cfg.spec.nr, bool)
        for i in range(cfg.n_workers):
            if on_time[i] and loads[i] > 0:
                arrived[i * cfg.r: i * cfg.r + loads[i]] = True
        shard_covered = np.zeros(cfg.k, bool)
        shard_covered[np.unique(arrived.nonzero()[0] % cfg.k)] = True
        success = bool(shard_covered.all())

        # (4) estimator update — completion times reveal the round's states
        self.est = _update_estimator(self.est, jnp.asarray(states))

        info = {
            "success": success,
            "on_time_workers": int(on_time.sum()),
            "arrived_copies": int(arrived.sum()),
            "kstar": lp.kstar,
            "loads": loads.tolist(),
        }
        if not success:
            return None, info
        self.successes += 1

        # master decodes: first on-time copy of each shard, average grads
        shards = _split_batch(batch, cfg.k)
        grads = None
        for j in range(cfg.k):
            copies = np.nonzero(arrived & (np.arange(cfg.spec.nr) % cfg.k == j))[0]
            g = self.grad_fn(params, shards[j])          # computed by copy owner
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            del copies
        grads = jax.tree.map(lambda a: a / cfg.k, grads)
        return grads, info

    @property
    def timely_throughput(self) -> float:
        return self.successes / max(self.rounds, 1)


def _split_batch(batch: dict, k: int) -> list[dict]:
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])

    stacked = jax.tree.map(split, batch)
    return [jax.tree.map(lambda a: a[j], stacked) for j in range(k)]
