"""Elastic scaling: reshard state across mesh-size changes (DESIGN §7).

The checkpoint format is mesh-agnostic (full host arrays), so growing or
shrinking the cluster = restore with the new mesh's shardings.  LEA estimator
counts follow the worker pool: survivors keep their history, newcomers start
from the pooled average (a better prior than the 0.5 cold start).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lea


def reshard_state(state, shardings):
    """device_put every leaf onto the new mesh's shardings."""
    flat_s, treedef = jax.tree.flatten(state)
    flat_sh = jax.tree.leaves(shardings)
    out = [jax.device_put(np.asarray(x), sh) for x, sh in zip(flat_s, flat_sh)]
    return jax.tree.unflatten(treedef, out)


def remap_estimator(est: lea.EstimatorState, old_n: int, new_n: int,
                    survivors: list[int] | None = None) -> lea.EstimatorState:
    """Carry LEA counts across an elastic resize."""
    counts = np.asarray(est.counts)
    prev = np.asarray(est.prev_state)
    if survivors is None:
        survivors = list(range(min(old_n, new_n)))
    new_counts = np.zeros((new_n, 4), np.float32)
    new_prev = np.zeros((new_n,), np.int32)
    pooled = counts[survivors].mean(axis=0) if survivors else np.zeros(4, np.float32)
    for i in range(new_n):
        if i < len(survivors):
            new_counts[i] = counts[survivors[i]]
            new_prev[i] = prev[survivors[i]]
        else:
            new_counts[i] = pooled       # newcomer: pooled prior
            new_prev[i] = 1
    return lea.EstimatorState(
        counts=jnp.asarray(new_counts),
        prev_state=jnp.asarray(new_prev),
        seen_prev=est.seen_prev,
    )
