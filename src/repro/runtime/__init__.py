from .fault_tolerance import CodedDPConfig, CodedDataParallelExecutor  # noqa: F401
from .compression import make_compressor  # noqa: F401
