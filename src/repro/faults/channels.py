"""Composable fault processes over engine trajectories.

A fault process consumes the base "everything arrives" trace of a batch of
rounds and degrades it.  Every injector is a NamedTuple pytree — traced
array parameters, static structure — with an ``apply(key, trace)`` method
that is a pure function of its key, so

  * a *channel* (tuple of injectors) composes by folding the trace through
    each injector with a ``fold_in``-derived subkey;
  * vmapping the engine over a batch of channels with the SAME structure
    but different (traced) parameters fuses a whole fault-parameter grid
    into one compiled computation (the ``repro.sweeps`` convention);
  * the same key always reproduces the same faults, so two decode modes
    scored "under the same fault traces" literally share the trace.

The trace (:class:`FaultTrace`) separates the two physical failure axes:

  ``t_cut``  (rounds, n) float32 — the time at which worker i's round-m
             compute is CUT OFF (crash, preemption).  Work finishing after
             ``t_cut`` is lost; the base value is the deadline itself.
  ``keep``   (rounds, n, r, packets) bool — per-packet NETWORK delivery:
             packet q of stored chunk j either traverses the channel or is
             erased (Bernoulli, Gilbert-Elliott bursts, correlated events).

Injectors are MONOTONE by construction — ``t_cut`` only decreases and
``keep`` only loses packets — so applying a channel can never manufacture
work, and the all-or-nothing/conserving decode containment proved in
:mod:`repro.faults.packets` survives any channel.

Registry: injectors register under a name (:func:`register_injector`) and
are constructible from config dictionaries via :func:`make_injector` /
:func:`make_channel`, which is how sweep-family metadata turns into traced
channel parameters in ``benchmarks/bench_faults.py``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.markov import sample_trajectory_from

# fold_in tag separating the fault-process PRNG stream from the engine's
# trajectory / round-key / policy streams (cf. throughput._POLICY_KEY_TAG)
_FAULT_KEY_TAG = 0x7F4A7C15 % (2**31)


def fault_key(key: jax.Array) -> jax.Array:
    """The fault-process stream root for a simulation key.

    Derived by ``fold_in`` with a dedicated tag so fault draws never collide
    with the trajectory, round-draw or policy streams split from the same
    simulation key — and so every decode mode scored on one simulation key
    sees the SAME faults.
    """
    return jax.random.fold_in(key, _FAULT_KEY_TAG)


class FaultTrace(NamedTuple):
    """One batch of rounds' fault realisation (see module docstring)."""

    t_cut: jnp.ndarray   # (rounds, n) float32 — compute cutoff time
    keep: jnp.ndarray    # (rounds, n, r, packets) bool — network delivery

    @property
    def rounds(self) -> int:
        return self.t_cut.shape[0]


def base_trace(rounds: int, n: int, r: int, packets: int, deadline) -> FaultTrace:
    """The no-fault trace: full deadline to compute, every packet delivered."""
    return FaultTrace(
        t_cut=jnp.full((rounds, n), deadline, jnp.float32),
        keep=jnp.ones((rounds, n, r, packets), bool),
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_INJECTORS: dict[str, type] = {}


def register_injector(name: str):
    """Decorator: register an injector class under ``name``."""

    def deco(cls):
        if name in _INJECTORS:
            raise ValueError(f"fault injector {name!r} already registered")
        _INJECTORS[name] = cls
        cls.injector_name = name
        return cls

    return deco


def injector_names() -> tuple[str, ...]:
    return tuple(sorted(_INJECTORS))


def make_injector(name: str, **params):
    """Build a registered injector from keyword parameters."""
    if name not in _INJECTORS:
        raise KeyError(
            f"unknown fault injector {name!r}; available: "
            f"{', '.join(injector_names())}"
        )
    return _INJECTORS[name](**params)


def make_channel(spec: Sequence[tuple[str, dict]]) -> tuple:
    """((name, params), ...) -> a channel: an ordered tuple of injectors."""
    return tuple(make_injector(name, **params) for name, params in spec)


def apply_channel(key: jax.Array, channel: Sequence, trace: FaultTrace) -> FaultTrace:
    """Fold the trace through every injector, each on its own subkey.

    Subkeys are ``fold_in(key, position)``, so a channel realisation depends
    on the injector ORDER as well as the key — two channels sharing a prefix
    share that prefix's faults exactly.
    """
    for i, inj in enumerate(channel):
        trace = inj.apply(jax.random.fold_in(key, i), trace)
    return trace


# ---------------------------------------------------------------------------
# built-in injectors
# ---------------------------------------------------------------------------


@register_injector("crash_restart")
class CrashRestart(NamedTuple):
    """Worker crash/restart: a persistent alive/crashed chain per worker.

    Every worker runs an independent 2-state chain over rounds, starting
    ALIVE: an alive worker crashes with probability ``p_crash`` per round
    and a crashed one restarts with probability ``p_restart``.  A crashed
    worker's round produces nothing (``t_cut`` -> 0); its stored chunks
    survive the restart (the executor's ``mark_dead`` models the permanent
    variant).
    """

    p_crash: jnp.ndarray
    p_restart: jnp.ndarray

    def apply(self, key: jax.Array, trace: FaultTrace) -> FaultTrace:
        rounds, n = trace.t_cut.shape
        alive = sample_trajectory_from(
            key,
            1.0 - jnp.asarray(self.p_crash, jnp.float32),
            1.0 - jnp.asarray(self.p_restart, jnp.float32),
            rounds,
            jnp.ones((n,), jnp.int32),
        )                                                      # (rounds, n)
        return trace._replace(
            t_cut=jnp.where(alive == 1, trace.t_cut, 0.0)
        )


@register_injector("preempt")
class Preempt(NamedTuple):
    """Preemption ramp: a hit worker keeps only a fraction of its round.

    With probability ``p_preempt`` per (round, worker), the worker is
    reclaimed mid-round at a uniform fraction in [``min_frac``, 1) of its
    remaining cutoff: ``t_cut -> frac * t_cut``.  Work finished before the
    preemption point survives — exactly the partial results the conserving
    decode (and the hierarchical layer) exist to harvest.
    """

    p_preempt: jnp.ndarray
    min_frac: jnp.ndarray = 0.0

    def apply(self, key: jax.Array, trace: FaultTrace) -> FaultTrace:
        k_hit, k_frac = jax.random.split(key)
        shape = trace.t_cut.shape
        hit = jax.random.uniform(k_hit, shape) < self.p_preempt
        min_frac = jnp.asarray(self.min_frac, jnp.float32)
        frac = min_frac + (1.0 - min_frac) * jax.random.uniform(k_frac, shape)
        return trace._replace(
            t_cut=jnp.where(hit, frac * trace.t_cut, trace.t_cut)
        )


@register_injector("packet_bernoulli")
class PacketBernoulli(NamedTuple):
    """iid per-packet erasure: every packet is dropped with prob ``p_drop``."""

    p_drop: jnp.ndarray

    def apply(self, key: jax.Array, trace: FaultTrace) -> FaultTrace:
        u = jax.random.uniform(key, trace.keep.shape)
        return trace._replace(keep=trace.keep & (u >= self.p_drop))


@register_injector("gilbert_elliott")
class GilbertElliott(NamedTuple):
    """Gilbert-Elliott bursty packet loss: a 2-state channel per worker link.

    Each worker's link runs a good/bad channel chain over rounds (starting
    good): good -> bad with ``p_gb``, bad -> good with ``p_bg``; packets
    drop with ``drop_good`` in the good state and ``drop_bad`` in the bad
    one — the classic bursty-erasure model of the packet-erasure-channel
    literature (arXiv 1901.03610).
    """

    p_gb: jnp.ndarray
    p_bg: jnp.ndarray
    drop_good: jnp.ndarray = 0.0
    drop_bad: jnp.ndarray = 0.5

    def apply(self, key: jax.Array, trace: FaultTrace) -> FaultTrace:
        rounds, n = trace.t_cut.shape
        k_chain, k_drop = jax.random.split(key)
        good = sample_trajectory_from(
            k_chain,
            1.0 - jnp.asarray(self.p_gb, jnp.float32),
            1.0 - jnp.asarray(self.p_bg, jnp.float32),
            rounds,
            jnp.ones((n,), jnp.int32),
        )                                                      # (rounds, n)
        p = jnp.where(good == 1, self.drop_good, self.drop_bad)
        u = jax.random.uniform(k_drop, trace.keep.shape)
        return trace._replace(keep=trace.keep & (u >= p[..., None, None]))


@register_injector("burst")
class Burst(NamedTuple):
    """Correlated burst loss: one shared event wipes a packet-tail fleet-wide.

    With probability ``p_event`` per round, EVERY worker loses its last
    ``frac`` fraction of packet indices that round (a shared network event —
    switch congestion, a rack brown-out) — the correlated-loss regime where
    per-worker redundancy cannot help but per-packet position can.
    """

    p_event: jnp.ndarray
    frac: jnp.ndarray = 0.5

    def apply(self, key: jax.Array, trace: FaultTrace) -> FaultTrace:
        rounds = trace.keep.shape[0]
        packets = trace.keep.shape[-1]
        hit = jax.random.uniform(key, (rounds,)) < self.p_event  # (rounds,)
        # packet index q survives a burst iff q/packets < 1 - frac
        pos = jnp.arange(packets, dtype=jnp.float32) / packets   # (packets,)
        survive = pos < (1.0 - jnp.asarray(self.frac, jnp.float32))
        keep = trace.keep & (
            survive[None, None, None, :] | ~hit[:, None, None, None]
        )
        return trace._replace(keep=keep)
