"""Batched fault-sweep engine: score decode modes under shared fault traces.

One compiled computation per batch: rolls out the shape-polymorphic engine
(:func:`repro.core.throughput.rollout_pool` semantics — traced K*/ell,
mask-padded pools), realises the fault channel ONCE per row from the
dedicated fault key, and scores every strategy's every round under three
decode modes on the SAME trajectory and the SAME faults:

  ``full_aon``       — all-or-nothing packet rule meets K* at every packet
                       index (the classic ``chunk_on_time`` model);
  ``full_conserve``  — partial-work-conserving rule meets K* at every
                       packet index (preempted workers' finished packets
                       count).  AON ⊆ conserve pointwise, so
                       ``full_aon => full_conserve`` round by round;
  ``partial``        — full decode infeasible but the hierarchical layer-1
                       code (threshold ``k1star`` over the first ``p1``
                       packet indices) decodes — the degraded serving mode.

Channel parameters are TRACED pytree leaves: :func:`sweep_faults` vmaps the
whole thing over (B,) rows — keys, chains, pool, channel parameters — so a
fault-parameter grid compiles ONCE per (rounds, strategies, geometry)
signature, exactly the ``repro.sweeps`` convention
(:func:`fault_compile_cache_size` exposes the cache counter the benchmark
and tests assert on).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import throughput
from repro.obs import counters as _obs_counters
from repro.obs.profiling import phase as _phase
from repro.obs.telemetry import FaultTelemetry

from .channels import apply_channel, base_trace, fault_key
from .packets import layer1_recovery, packet_counts, packet_on_time


class FaultOutcomes(NamedTuple):
    """Per-round, per-strategy decode outcomes ((rounds, S) bool each).

    ``partial`` is exclusive of ``full_conserve`` (layer-1 only); a round's
    conserving-mode disposition is full_conserve / partial / neither.
    """

    full_aon: jnp.ndarray
    full_conserve: jnp.ndarray
    partial: jnp.ndarray


def _simulate_faults_impl(
    key, pool, p_gg, p_bb, mu_g, mu_b, deadline, channel, k1star,
    rounds, strategies, r, packets, p1, telemetry=False,
    tap=False, tap_stride=None, tap_row=None,
):
    states, loads, feasible = throughput._rollout_impl(
        key, pool, p_gg, p_bb, rounds, strategies
    )                                   # (M, n), (S, M, n), (S, M)
    n = states.shape[-1]
    trace = base_trace(rounds, n, r, packets, deadline)
    trace = apply_channel(fault_key(key), channel, trace)

    with _phase("decode"):
        mask_aon = packet_on_time(states, loads, mu_g, mu_b, deadline, r,
                                  packets, trace=trace, conserve=False)
        mask_con = packet_on_time(states, loads, mu_g, mu_b, deadline, r,
                                  packets, trace=trace, conserve=True)
        counts_aon = packet_counts(mask_aon)                 # (S, M, P)
        counts_con = packet_counts(mask_con)

    kstar = pool.kstar
    full_aon = feasible & jnp.all(counts_aon >= kstar, axis=-1)
    full_con = feasible & jnp.all(counts_con >= kstar, axis=-1)
    l1 = feasible & layer1_recovery(counts_con, k1star, p1)
    to_ms = lambda x: jnp.moveaxis(x, 0, 1)                  # (S, M) -> (M, S)
    outcomes = FaultOutcomes(
        full_aon=to_ms(full_aon),
        full_conserve=to_ms(full_con),
        partial=to_ms(l1 & ~full_con),
    )
    count_i = lambda m, ax: jnp.sum(m.astype(jnp.int32), axis=ax)
    if tap:
        # the engine is fully vectorised (no scan), so stride aggregates
        # are prefix sums of the per-round streams; emitting them is a pure
        # extra effect of the same traced values — outcomes untouched
        from repro.obs import taps as _taps

        stride = _taps.resolve_stride(rounds, tap_stride)
        cum = jax.tree.map(
            lambda x: jnp.cumsum(x.astype(jnp.int32), axis=0), outcomes
        )
        pre_cum = jnp.cumsum(count_i(trace.t_cut < deadline, -1))
        lost_cum = jnp.cumsum(count_i(~trace.keep, (-3, -2, -1)))
        row = (jnp.int32(-1) if tap_row is None
               else jnp.asarray(tap_row, jnp.int32))
        token = None
        for bi, bound in enumerate(_taps.stride_boundaries(rounds, stride)):
            token = _taps.emit(
                "faults.sweep", token=token,
                block=jnp.int32(bi), row=row,
                rounds_done=jnp.int32(bound),
                recovered_aon_so_far=cum.full_aon[bound - 1],
                recovered_conserve_so_far=cum.full_conserve[bound - 1],
                partial_so_far=cum.partial[bound - 1],
                preempted_so_far=pre_cum[bound - 1],
                packets_lost_so_far=lost_cum[bound - 1],
            )
    if not telemetry:
        return outcomes
    # fault-event counts + binding received margins: pure extra outputs of
    # the same traced values (the outcome streams above are untouched)
    tel = FaultTelemetry(
        preempted=count_i(trace.t_cut < deadline, -1),       # (M,)
        packets_lost=count_i(~trace.keep, (-3, -2, -1)),     # (M,)
        received_aon=to_ms(jnp.min(counts_aon, axis=-1)),    # (M, S)
        received_conserve=to_ms(jnp.min(counts_con, axis=-1)),
    )
    return outcomes, tel


@partial(jax.jit, static_argnames=("rounds", "strategies", "r", "packets",
                                   "p1", "telemetry", "tap", "tap_stride"))
def simulate_faults(
    key: jax.Array,
    pool,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    channel: tuple,
    k1star,
    *,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static"),
    r: int,
    packets: int,
    p1: int = 1,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """One row's fault-scored simulation (see module docstring).

    ``pool`` is a :class:`repro.core.lea.PoolLoad` (traced K*/ell + mask);
    ``channel`` a tuple of injectors from :mod:`repro.faults.channels`;
    ``k1star`` the hierarchical layer-1 threshold (traced scalar); ``r`` /
    ``packets`` / ``p1`` the static packet geometry.  With an empty channel
    the conserving mode still differs from AON (prefix credit for slow
    workers); with an empty channel AND ``packets=1`` the ``full_aon``
    column reproduces :func:`repro.core.throughput.simulate_strategies_pool`
    success indicators exactly (the same loads, the same on-time rule).

    ``telemetry`` (static): True returns ``(FaultOutcomes,
    FaultTelemetry)`` — per-round fault-event counts and binding received
    margins out of the same traced computation; False (default) is the
    pre-existing path, bit-identical.

    ``tap`` (static): True streams stride-aggregated decode/fault counts
    to the host mid-run (:mod:`repro.obs.taps`); outputs stay
    bit-identical and ``tap=False`` traces zero callbacks.
    """
    return _simulate_faults_impl(
        key, pool, p_gg, p_bb, mu_g, mu_b, deadline, channel, k1star,
        rounds, strategies, r, packets, p1, telemetry, tap, tap_stride,
    )


@partial(jax.jit, static_argnames=("rounds", "strategies", "r", "packets",
                                   "p1", "telemetry", "tap", "tap_stride"))
def _run_fault_group(
    keys, pool, p_gg, p_bb, mu_g, mu_b, deadline, channel, k1star,
    *, rounds, strategies, r, packets, p1, telemetry=False,
    tap=False, tap_stride=None,
):
    """(B,) rows -> (B, rounds, S) outcomes, one XLA computation."""
    rows = jnp.arange(keys.shape[0], dtype=jnp.int32) if tap else None
    fn = lambda k, pl, pg, pb, mg, mb, d, ch, k1, ri: _simulate_faults_impl(
        k, pl, pg, pb, mg, mb, d, ch, k1,
        rounds, strategies, r, packets, p1, telemetry, tap, tap_stride, ri,
    )
    if tap:
        return jax.vmap(fn)(keys, pool, p_gg, p_bb, mu_g, mu_b, deadline,
                            channel, k1star, rows)
    return jax.vmap(
        lambda k, pl, pg, pb, mg, mb, d, ch, k1: fn(
            k, pl, pg, pb, mg, mb, d, ch, k1, None
        )
    )(keys, pool, p_gg, p_bb, mu_g, mu_b, deadline, channel, k1star)


_obs_counters.register_compiled("faults.sweep", _run_fault_group)
_obs_counters.register_compiled("faults.simulate", simulate_faults)


def fault_compile_cache_size() -> int:
    """Distinct fault-group computations compiled so far.

    Thin alias over the unified obs counter
    (``obs.compile_events("faults.sweep")``) — kept for the pre-obs tests
    and benchmarks."""
    return _obs_counters.compile_events("faults.sweep")


def sweep_faults(
    keys: jnp.ndarray,
    pool,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    channel: tuple,
    k1star,
    *,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static"),
    r: int,
    packets: int,
    p1: int = 1,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """Batched :func:`simulate_faults`: every leaf carries a leading (B,) axis.

    ``channel`` injector parameters are (B,) traced leaves (same structure
    per row), so a whole fault-parameter grid — different drop rates,
    preemption probabilities, burst rates per row — fuses into ONE compile
    per static (rounds, strategies, r, packets, p1) signature.  Returns
    :class:`FaultOutcomes` of (B, rounds, S) arrays; with
    ``telemetry=True``, ``(FaultOutcomes, FaultTelemetry)`` with a leading
    (B,) axis on every telemetry leaf (same one-compile contract — a
    telemetry-on grid is still ONE computation).  ``tap=True`` streams
    per-row stride aggregates mid-run (events carry the batch ``row``; see
    :mod:`repro.obs.taps`) under the same contract.
    """
    strategies = tuple(strategies)
    b = p_gg.shape[0]
    as_b = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (b,))
    channel = jax.tree.map(as_b, channel)   # scalar params ride every row
    return _run_fault_group(
        keys, pool, p_gg, p_bb, as_b(mu_g), as_b(mu_b), as_b(deadline),
        channel, jnp.broadcast_to(jnp.asarray(k1star, jnp.int32), (b,)),
        rounds=rounds, strategies=strategies, r=r, packets=packets, p1=p1,
        telemetry=telemetry, tap=tap, tap_stride=tap_stride,
    )
