"""Packets-within-chunks: the erasure model below chunk granularity.

``coded_ops.chunk_on_time`` is all-or-nothing per worker: a worker whose
whole load misses the deadline contributes nothing.  Here each chunk's
result rows are split into ``packets`` equal blocks streamed out as they
finish, giving two refinements:

Partial-work conservation (the ``conserve=True`` rule)
------------------------------------------------------
Worker i evaluates its assigned prefix of chunks in order, emitting packet
q of its j-th chunk at time ``(j + (q+1)/packets) / speed``.  A packet is
on time iff that instant is within the worker's cutoff ``t_cut`` (the
deadline, shortened by crash/preemption injectors) AND the network kept it
(``FaultTrace.keep``).  A preempted worker's finished packets therefore
still count — exactly the partial results *Hierarchical Coded Elastic
Computing* (arXiv 2206.09399) conserves.

All-or-nothing reference (``conserve=False``)
---------------------------------------------
The classic rule at packet granularity: a worker's packets all arrive iff
its WHOLE load meets ``t_cut`` — the same comparison
``loads/speed <= t_cut + 1e-9`` as :func:`repro.core.coded_ops.chunk_on_time`.
Two containment properties anchor the tests and the benchmark:

  * AON ⊆ conserve, bitwise: the conserving numerator of worker i's last
    assigned packet is ``(loads-1) + packets/packets = loads`` — the SAME
    float32 expression the AON rule compares — and earlier packets have
    strictly smaller numerators, so every AON packet is a conserve packet
    on any trace, and a conserving decode can only recover MORE rounds.
  * At ``packets=1`` on the no-fault trace, the AON packet mask reshaped to
    chunks IS ``chunk_on_time`` bit-for-bit, and the per-packet decode
    below literally calls the same jitted ``_decode_on_time`` /
    ``_decode_on_time_modp`` computation — so the packet path degrades to
    the existing all-or-nothing path exactly (float AND GF(p)), not just
    approximately.

Per-packet decode
-----------------
LCC decode is row-wise: decoded chunk rows are fixed linear (or GF(p))
combinations of the SAME rows of the received evaluations.  Splitting each
chunk's ``rows`` into ``packets`` blocks therefore decouples the blocks:
packet q of every output chunk is decodable from any K* workers' chunk
evaluations whose packet q arrived — different packets may decode from
DIFFERENT K*-subsets.  :func:`coded_matmul_packets` (float) and
:func:`coded_matmul_exact_packets` (GF(p)) run the existing traced-pattern
device decode once per packet index (a static Python loop — ``packets`` is
a small static constant) and concatenate the row blocks.

Hierarchical two-layer option
-----------------------------
``layer1_recovery`` models a second, lower-rate code protecting the first
``p1`` packet indices of a smaller ``k1``-chunk summary (threshold
``K1 = (k1-1) deg_f + 1 < K*``): when the full decode is infeasible, the
round can still be served PARTIALLY from the layer-1 packets — the
degraded mode the executor accounts as ``partial``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.coded_ops import (CodedDataset, CodedDatasetModp,
                                  _decode_on_time, _decode_on_time_modp)

from .channels import FaultTrace


def packet_on_time(
    states: jnp.ndarray,
    loads: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    r: int,
    packets: int,
    trace: FaultTrace | None = None,
    conserve: bool = True,
) -> jnp.ndarray:
    """Per-packet on-time masks: (..., n) states/loads -> (..., n*r, packets).

    The packet generalisation of :func:`repro.core.coded_ops.chunk_on_time`
    (same speed model, same deadline tolerance — see the module docstring
    for the exact containment/degradation guarantees).  ``trace`` supplies
    per-round cutoffs and delivery masks from the fault channel; ``None``
    is the no-fault trace (``t_cut = deadline``, everything delivered).
    Leading axes broadcast: (M, n) states with (S, M, n) loads and an
    (M, n)-cutoff trace score every strategy against the SAME faults.
    """
    speeds = jnp.where(states == 1, mu_g, mu_b)                  # (..., n)
    if trace is not None:
        t_cut = jnp.minimum(trace.t_cut, jnp.asarray(deadline, jnp.float32))
        tc = t_cut[..., None, None]                              # (..., n, 1, 1)
    else:
        # scalar deadline kept raw so the AON comparison below is the exact
        # expression chunk_on_time evaluates (bit-identity anchor)
        t_cut = deadline
        tc = deadline
    if conserve:
        # packet q of assigned chunk j completes at (j + (q+1)/P) / speed
        frac = (jnp.arange(packets, dtype=jnp.float32) + 1.0) / packets
        num = jnp.arange(r, dtype=jnp.float32)[:, None] + frac[None, :]
        done = num / speeds[..., None, None] <= tc + 1e-9        # (..., n, r, P)
    else:
        whole = loads.astype(jnp.float32) / speeds <= t_cut + 1e-9
        done = jnp.broadcast_to(
            whole[..., None, None],
            whole.shape + (r, packets),
        )
    assigned = jnp.arange(r) < loads[..., None]                  # (..., n, r)
    ok = done & assigned[..., None]
    if trace is not None:
        ok = ok & trace.keep
    return ok.reshape(ok.shape[:-3] + (ok.shape[-3] * r, packets))


def packet_counts(packet_masks: jnp.ndarray) -> jnp.ndarray:
    """(..., nr, packets) masks -> (..., packets) received-evaluation counts.

    Count of distinct chunk evaluations whose packet q arrived — the
    quantity compared against K* for per-packet decodability.
    """
    return jnp.sum(packet_masks.astype(jnp.int32), axis=-2)


def layer1_recovery(counts: jnp.ndarray, k1_threshold, p1: int) -> jnp.ndarray:
    """(..., packets) counts -> (...,) layer-1 (partial) decodability.

    True iff every one of the first ``p1`` packet indices reached the
    layer-1 threshold ``K1`` — the smaller summary code decodes even when
    the full-rate layer cannot.
    """
    return jnp.all(counts[..., :p1] >= k1_threshold, axis=-1)


def _split_rows(results: jnp.ndarray, packets: int) -> int:
    rows = results.shape[1]
    if rows % packets != 0:
        raise ValueError(
            f"chunk rows ({rows}) must divide into packets ({packets})"
        )
    return rows // packets


def coded_matmul_packets(
    coded: CodedDataset, w: jnp.ndarray, packet_masks: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-packet float decode of f(X_j) = X_j @ w.

    ``packet_masks`` is (nr, packets) from :func:`packet_on_time`.  Packet q
    of every output chunk decodes independently from the chunk evaluations
    whose packet q arrived.  Returns ``(decoded (k, rows[, d]), ok
    (packets,))``; packet q's rows are meaningful only where ``ok[q]``.
    At ``packets=1`` with a full mask this is the exact
    :func:`~repro.core.coded_ops.coded_matmul_device` computation.
    """
    packets = packet_masks.shape[-1]
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)    # (nr, rows, ...)
    rp = _split_rows(results, packets)
    outs, oks = [], []
    for q in range(packets):
        out_q, ok_q = _decode_on_time(
            coded.spec, results[:, q * rp:(q + 1) * rp], packet_masks[:, q]
        )
        outs.append(out_q)
        oks.append(ok_q)
    return jnp.concatenate(outs, axis=1), jnp.stack(oks)


def coded_matmul_exact_packets(
    coded: CodedDatasetModp, w, packet_masks: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-packet EXACT GF(p) decode — the finite-field twin of
    :func:`coded_matmul_packets`.

    Same packet decoupling, same per-packet ``ok`` flags; each packet block
    runs the existing jitted ``_decode_on_time_modp`` traced-pattern device
    decode, so at ``packets=1`` with a full mask the computation — and its
    bit-exactness against the numpy modp oracle — is exactly
    :func:`~repro.core.coded_ops.coded_matmul_exact`'s.
    """
    from repro.core.lagrange import _gf

    gf = _gf()
    packets = packet_masks.shape[-1]
    w = jnp.asarray(w)
    squeeze = w.ndim == 1
    w2 = w[:, None] if squeeze else w
    nr, rows = coded.x_tilde.shape[0], coded.x_tilde.shape[1]
    flat = coded.x_tilde.reshape(nr * rows, -1)
    results = gf.from_gf(gf.matmul_gf(flat, w2))
    results = results.reshape(nr, rows, w2.shape[1])
    rp = _split_rows(results, packets)
    outs, oks = [], []
    for q in range(packets):
        out_q, ok_q = _decode_on_time_modp(
            coded.spec, results[:, q * rp:(q + 1) * rp], packet_masks[:, q]
        )
        outs.append(out_q)
        oks.append(ok_q)
    out = jnp.concatenate(outs, axis=1)
    return (out[..., 0] if squeeze else out), jnp.stack(oks)
