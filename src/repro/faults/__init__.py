"""repro.faults — packet-level fault injection + partial-work conservation.

The paper's two-state Markov model makes a slow worker's round all-or-
nothing; real cloud rounds fail at finer grain — packets drop, preempted
workers leave partial results, nodes crash mid-job.  This package layers
those failure modes on top of the batched engine:

  * :mod:`~repro.faults.channels` — composable, registry-driven fault
    injectors (worker crash/restart, preemption ramps, correlated burst
    loss, per-packet Bernoulli / Gilbert-Elliott erasure) producing a
    :class:`FaultTrace` — batched ``(rounds, n)`` work-cutoff times plus
    ``(rounds, n, r, packets)`` delivery masks — as pure pytree transforms
    over the engine's Markov trajectories (cf. *Coded Distributed Computing
    over Packet Erasure Channels*, arXiv 1901.03610);
  * :mod:`~repro.faults.packets` — ``chunk_on_time`` generalised to
    packets-within-chunks with a partial-work-conserving prefix rule,
    per-packet decode through the existing device decode machinery
    (bit-identical to the all-or-nothing path at packets=1 with no faults),
    and a hierarchical two-layer recovery option so preempted workers'
    finished packets still count (cf. *Hierarchical Coded Elastic
    Computing*, arXiv 2206.09399);
  * :mod:`~repro.faults.engine` — the batched fault sweep: one compiled
    computation scores all-or-nothing vs conserving vs hierarchical decode
    per round per strategy on SHARED trajectories and SHARED fault traces
    (per-row channel parameters are traced, so a whole parameter grid fuses
    into one compile — the same convention as ``repro.sweeps``).
"""

from repro.obs.telemetry import FaultTelemetry

from .channels import (FaultTrace, apply_channel, base_trace, fault_key,
                       injector_names, make_channel, make_injector,
                       register_injector)
from .engine import (FaultOutcomes, fault_compile_cache_size, simulate_faults,
                     sweep_faults)
from .packets import (coded_matmul_exact_packets, coded_matmul_packets,
                      layer1_recovery, packet_counts, packet_on_time)

__all__ = [
    "FaultOutcomes", "FaultTelemetry", "FaultTrace", "apply_channel",
    "base_trace", "coded_matmul_exact_packets", "coded_matmul_packets",
    "fault_compile_cache_size", "fault_key", "injector_names",
    "layer1_recovery", "make_channel", "make_injector", "packet_counts",
    "packet_on_time", "register_injector", "simulate_faults", "sweep_faults",
]
