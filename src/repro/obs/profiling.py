"""Profiling hooks: named phase scopes, host spans, REPRO_PROFILE traces.

Three layers, all zero-cost when unused:

  * :func:`phase` — ``jax.named_scope`` around the engine phases
    (``trajectory`` -> ``policy_replay`` -> ``allocate`` -> ``score`` ->
    ``decode``).  Pure trace-time metadata: the names land in the HLO (and
    therefore in profiler timelines) and add NOTHING at runtime, so the
    engines wrap their phases unconditionally.
  * :func:`annotate` — a host-side ``jax.profiler.TraceAnnotation`` span
    (e.g. around one benchmark target).  No-op unless a profiler trace is
    being collected.
  * :func:`profile_trace` — the collection gate: when the
    ``REPRO_PROFILE`` env var names a directory, the context manager wraps
    its body in ``jax.profiler.start_trace``/``stop_trace`` and dumps a
    trace viewable in Perfetto / TensorBoard there; unset, it is a no-op.
    ``benchmarks/run.py`` wraps every selected suite in it, so

        REPRO_PROFILE=/tmp/trace python -m benchmarks.run bench_serving

    profiles a whole target with the engine phases labelled.

jax is imported lazily so ``--list``-style cold paths never pay for it.
"""

from __future__ import annotations

import contextlib
import os
from typing import Iterator

PROFILE_ENV = "REPRO_PROFILE"

# engine phases, in execution order — the catalogue ROADMAP documents
ENGINE_PHASES = ("trajectory", "policy_replay", "allocate", "score", "decode")


def profile_dir() -> str | None:
    """The REPRO_PROFILE trace directory, or None when profiling is off."""
    return os.environ.get(PROFILE_ENV) or None


def phase(name: str):
    """``jax.named_scope`` for one engine phase (trace-time metadata only)."""
    import jax

    return jax.named_scope(f"repro.{name}")


@contextlib.contextmanager
def annotate(name: str) -> Iterator[None]:
    """Host-side profiler span; inert when no trace is being collected."""
    import jax

    with jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def profile_trace(label: str = "repro") -> Iterator[str | None]:
    """Collect a jax profiler trace into $REPRO_PROFILE, if set.

    Yields the trace directory (or None when profiling is off).  The
    directory is created if missing; ``stop_trace`` runs even when the
    body raises, so a crashing benchmark still leaves a usable trace.
    """
    out = profile_dir()
    if out is None:
        yield None
        return
    import jax

    os.makedirs(out, exist_ok=True)
    jax.profiler.start_trace(out)
    try:
        with jax.profiler.TraceAnnotation(label):
            yield out
    finally:
        jax.profiler.stop_trace()
