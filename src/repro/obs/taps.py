"""In-run telemetry taps: block aggregates streamed to the host mid-scan.

The ``telemetry=`` flag (PR 8) returns per-round streams *with* the result
— nothing reaches the host until the compiled computation finishes, which
at paper scale (M = 1e5 sweeps, overnight ``arrival_grid`` serving runs)
means hours of silence.  The ``tap=`` static flag (a sibling, threaded
through the same engines) instead emits BLOCK AGGREGATES — rounds done,
timely throughput so far, estimator error so far, queue admissions, fault
counts — to the host DURING the scan, via ``jax.experimental.io_callback``
at every ``round_chunk`` block boundary (and a configurable ``tap_stride``
inside unchunked computations).

Contract (property-tested in tests/obs/test_taps.py, mirroring
``telemetry=``):

  * ``tap=False`` (the default) is literally the pre-existing code path:
    bit-identical outputs and ZERO host callbacks (no ``emit`` is traced);
  * ``tap=True`` leaves the primary streams bit-identical (events are pure
    extra effects of the same traced values) and still compiles exactly
    once per static family signature (unified ``obs.counters`` registry);
  * events arrive IN ORDER per (engine, row, strategy): every ``emit``
    returns an int32 token that the next ``emit`` folds into an operand,
    a pure data dependence that serialises unordered callbacks without
    ``ordered=True`` (which vmap rejects — and every engine tap runs
    under at least one vmap).

Event schema: each event is a flat dict with ``engine`` (one of
:data:`TAP_ENGINES`), ``host_time`` (``time.perf_counter()`` at delivery)
and the engine's streams from :data:`EVENT_STREAMS` — scalars or small
per-strategy vectors, as numpy arrays.  Batched engines add ``row`` (the
vmapped batch index, -1 for unbatched calls); serving adds ``strategy``.

Handlers are looked up at CALL time, not trace time, so a handler
registered after a tapped computation compiled still receives its events
(the compile-once property and live handler swapping coexist).  A handler
that raises is dropped from that event, never the computation — the
never-raise convention of ``repro.obs``.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Callable, Iterator

import numpy as np

Handler = Callable[[dict], None]

# engine identifiers stamped into every event
TAP_ENGINES = ("engine.pool", "faults.sweep", "serving")

# per-engine payload streams (beyond the common engine/block/row/host_time);
# the catalogue the ROADMAP documents and validate_event checks against
EVENT_STREAMS: dict[str, tuple[str, ...]] = {
    "engine.pool": (
        "rounds_done", "succ_so_far", "throughput_so_far", "est_err_so_far",
    ),
    "faults.sweep": (
        "rounds_done", "recovered_aon_so_far", "recovered_conserve_so_far",
        "partial_so_far", "preempted_so_far", "packets_lost_so_far",
    ),
    "serving": (
        "rounds_done", "admitted_so_far", "served_on_time_so_far",
        "served_late_so_far", "rejected_so_far", "expired_so_far",
        "occupancy", "strategy",
    ),
}

_COMMON_KEYS = ("engine", "block", "row", "host_time")

_HANDLERS: dict[str, Handler] = {}
_LOCK = threading.Lock()


def add_tap(name: str, handler: Handler) -> None:
    """Register (or replace) a tap handler under ``name``.

    The handler receives one dict per event (see module docstring); it runs
    on the io_callback host thread, so it should be quick and must tolerate
    concurrent calls when several devices run tapped computations.
    """
    if not callable(handler):
        raise TypeError(f"tap handler {name!r} is not callable: {handler!r}")
    with _LOCK:
        _HANDLERS[name] = handler


def remove_tap(name: str) -> None:
    """Unregister a handler; unknown names are a no-op (teardown-safe)."""
    with _LOCK:
        _HANDLERS.pop(name, None)


def tap_names() -> tuple[str, ...]:
    """Registered handler names, sorted."""
    with _LOCK:
        return tuple(sorted(_HANDLERS))


@contextlib.contextmanager
def capture_taps() -> Iterator[list[dict]]:
    """Collect every tap event fired inside the block into the yielded list.

    The canonical test fixture::

        with obs.capture_taps() as events:
            run_group(group, tap=True)
        assert events and events[-1]["rounds_done"] == rounds
    """
    import jax

    events: list[dict] = []
    name = f"_capture_{id(events)}"
    # unordered io_callbacks may still be in flight from a computation that
    # finished OUTSIDE this block (block_until_ready on outputs does not
    # fence pure effects) — drain them at both boundaries so the list holds
    # exactly the events of the block: no stragglers leak in, none leak out
    jax.effects_barrier()
    add_tap(name, events.append)
    try:
        yield events
        jax.effects_barrier()
    finally:
        remove_tap(name)


def _dispatch(engine: str, names: tuple[str, ...], vals: tuple) -> None:
    """Build the event dict and fan it out to every registered handler."""
    event: dict[str, Any] = {"engine": engine, "host_time": time.perf_counter()}
    for k, v in zip(names, vals):
        a = np.asarray(v)
        event[k] = a[()] if a.ndim == 0 else a
    with _LOCK:
        handlers = list(_HANDLERS.values())
    for handler in handlers:
        try:
            handler(dict(event))
        except Exception:  # never-raise: a broken sink must not kill the run
            pass


def emit(engine: str, *, token=None, **streams):
    """Trace one tap event into the current computation; returns a token.

    ``streams`` are traced scalars/vectors (the event payload); ``token``
    is the previous ``emit``'s return value — folding it into the first
    operand forces host delivery order (unordered callbacks have no
    ordering of their own, and ``ordered=True`` is rejected under vmap).
    Call this ONLY under a ``tap=True`` static branch: an un-traced path
    must stay zero-callback.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import io_callback

    names = tuple(streams)
    vals = [jnp.asarray(v) for v in streams.values()]

    def cb(*args):
        _dispatch(engine, names, args[: len(names)])
        return np.int32(0)

    if token is not None:
        vals.append(jnp.asarray(token))  # cb ignores it; pure ordering dep
    return io_callback(
        cb, jax.ShapeDtypeStruct((), jnp.int32), *vals, ordered=False
    )


def validate_event(event: dict) -> None:
    """Raise ``ValueError`` unless ``event`` matches the tap schema.

    Checks the common keys, the engine id, the engine's exact stream set
    and the monotonicity preconditions a single event can carry
    (``rounds_done`` positive, ``block`` non-negative).
    """
    missing = [k for k in _COMMON_KEYS if k not in event]
    if missing:
        raise ValueError(f"tap event missing common keys {missing}: {sorted(event)}")
    engine = event["engine"]
    if engine not in EVENT_STREAMS:
        raise ValueError(f"unknown tap engine {engine!r}; known: {TAP_ENGINES}")
    want = set(EVENT_STREAMS[engine])
    got = set(event) - set(_COMMON_KEYS)
    if got != want:
        raise ValueError(
            f"{engine} event streams mismatch: missing {sorted(want - got)}, "
            f"unexpected {sorted(got - want)}"
        )
    if int(np.asarray(event["rounds_done"])) <= 0:
        raise ValueError(f"rounds_done must be positive: {event['rounds_done']}")
    if int(np.asarray(event["block"])) < 0:
        raise ValueError(f"block must be non-negative: {event['block']}")


def resolve_stride(rounds: int, tap_stride: int | None) -> int:
    """The emission stride inside an unchunked computation.

    ``None`` means one final aggregate at round M (the cheapest honest
    default); an explicit positive stride emits at every multiple (and
    always at M).  Validated here so every engine rejects bad strides the
    same way.
    """
    if tap_stride is None:
        return rounds
    if tap_stride <= 0:
        raise ValueError(f"tap_stride must be positive, got {tap_stride}")
    return min(tap_stride, rounds)


def stride_boundaries(rounds: int, stride: int) -> tuple[int, ...]:
    """Static emission boundaries: stride, 2*stride, ..., and always M."""
    bounds = list(range(stride, rounds + 1, stride))
    if not bounds or bounds[-1] != rounds:
        bounds.append(rounds)
    return tuple(bounds)
