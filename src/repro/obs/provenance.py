"""Run provenance: the who/where/when stamped into every BENCH_*.json.

A committed manifest is a regression baseline; a baseline without
provenance is unfalsifiable ("was that number from this machine? this
jax? a dirty tree?").  :func:`provenance` answers with a small JSON-able
dict; :func:`repro.sweeps.results.write_manifest` stamps it into every
manifest it writes, and ``benchmarks/run.py obs_report`` surfaces it in
the cross-bench regression summary.

The timestamp is PASSED IN by the caller (``time.time()`` at the call
site) rather than read here — the one field that would otherwise make two
provenance calls in the same process disagree, which would break the
exporter round-trip tests and pollute manifest diffs with noise.
"""

from __future__ import annotations

import os
import platform
import subprocess
import sys
from typing import Any

_SCHEMA_KEYS = (
    "git_sha", "git_dirty", "jax", "jaxlib", "backend", "device",
    "python", "platform", "timestamp",
)


def _repo_root() -> str:
    # src/repro/obs/provenance.py -> the checkout root three levels up
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _git(args: list[str], cwd: str) -> str | None:
    try:
        proc = subprocess.run(
            ["git", *args], capture_output=True, text=True, timeout=30,
            cwd=cwd,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    return proc.stdout.strip() if proc.returncode == 0 else None


def provenance(
    timestamp: float | str | None = None, *, root: str | None = None
) -> dict[str, Any]:
    """The run's provenance record (all keys always present, None if unknown).

    ``timestamp`` is caller-supplied (see module docstring); ``root`` the
    git checkout to interrogate (defaults to this package's checkout).
    Device facts come from the default jax backend; outside a usable git
    checkout ``git_sha``/``git_dirty`` are None rather than raising —
    provenance must never fail a benchmark run.
    """
    cwd = root or _repo_root()
    sha = _git(["rev-parse", "HEAD"], cwd)
    status = _git(["status", "--porcelain"], cwd)
    doc: dict[str, Any] = {
        "git_sha": sha,
        "git_dirty": bool(status) if status is not None else None,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "timestamp": timestamp,
    }
    try:  # jax facts: best-effort, never the reason a bench dies
        import jax
        import jaxlib

        doc["jax"] = jax.__version__
        doc["jaxlib"] = jaxlib.__version__
        doc["backend"] = jax.default_backend()
        devices = jax.devices()
        doc["device"] = devices[0].device_kind if devices else None
    except Exception:  # pragma: no cover - jax import is container-guaranteed
        doc.update({"jax": None, "jaxlib": None, "backend": None,
                    "device": None})
    return doc


def has_required_fields(doc: dict[str, Any]) -> bool:
    """True iff ``doc`` carries the full provenance schema (values may be
    None — the keys are the contract the manifest test pins)."""
    return all(k in doc for k in _SCHEMA_KEYS)
