"""repro.obs — observability: telemetry streams, provenance, profiling.

The engines (:mod:`repro.core.throughput`, :mod:`repro.faults.engine`,
:mod:`repro.serving.engine`) expose an optional ``telemetry=`` static flag
that threads extra per-round streams out of the SAME compiled computation
— estimator error vs. the genie's true p_good, allocated-load totals,
allocator prefix sizes, queue occupancy, admission decisions, fault-event
counts.  ``telemetry=False`` (the default) is literally the pre-existing
code path: bit-identical outputs, zero cost, and a telemetry-on batch
still compiles exactly once per sweep family (asserted through the
unified compile counter below).  This package owns everything that sits
on top of those streams:

  * :mod:`~repro.obs.counters`   — the ONE compile-event counter registry
    behind ``sweeps.compile_cache_size`` /
    ``faults.fault_compile_cache_size`` /
    ``serving.serving_compile_cache_size`` (all three are now thin
    aliases over :func:`compile_events`);
  * :mod:`~repro.obs.telemetry`  — :class:`TelemetryFrame` /
    :class:`FaultTelemetry` / :class:`ServingTelemetry` pytrees plus
    host-side exporters: flat metric tables (:func:`metric_streams`,
    :func:`metric_table`) and Chrome trace-event JSON
    (:func:`serving_trace`, viewable in Perfetto / ``chrome://tracing``);
  * :mod:`~repro.obs.provenance` — :func:`provenance`: git sha + dirty
    flag, jax/jaxlib versions, backend/device, caller-supplied timestamp
    — stamped into every ``BENCH_*.json`` by
    :func:`repro.sweeps.results.write_manifest`;
  * :mod:`~repro.obs.profiling`  — ``jax.named_scope`` phase spans inside
    the engines (trajectory sample -> policy replay -> allocate -> score
    -> decode), host-side ``jax.profiler.TraceAnnotation`` spans, and a
    ``REPRO_PROFILE=<dir>``-gated profiler-trace context manager.

``benchmarks/run.py obs_report`` is the consumer: it aggregates every
committed ``BENCH_*.json`` into one provenance-stamped regression summary
(metric deltas vs. the committed baselines, softgate warnings collected)
and renders a serving run as a request-timeline trace.
"""

from .counters import compile_events, counter_names, register_compiled
from .profiling import (PROFILE_ENV, annotate, phase, profile_dir,
                        profile_trace)
from .provenance import provenance
from .telemetry import (FaultTelemetry, ServingTelemetry, TelemetryFrame,
                        metric_streams, metric_table, serving_trace,
                        validate_trace, write_trace)

__all__ = [
    "FaultTelemetry", "PROFILE_ENV", "ServingTelemetry", "TelemetryFrame",
    "annotate", "compile_events", "counter_names", "metric_streams",
    "metric_table", "phase", "profile_dir", "profile_trace", "provenance",
    "register_compiled", "serving_trace", "validate_trace", "write_trace",
]
