"""repro.obs — observability: telemetry streams, provenance, profiling.

The engines (:mod:`repro.core.throughput`, :mod:`repro.faults.engine`,
:mod:`repro.serving.engine`) expose an optional ``telemetry=`` static flag
that threads extra per-round streams out of the SAME compiled computation
— estimator error vs. the genie's true p_good, allocated-load totals,
allocator prefix sizes, queue occupancy, admission decisions, fault-event
counts.  ``telemetry=False`` (the default) is literally the pre-existing
code path: bit-identical outputs, zero cost, and a telemetry-on batch
still compiles exactly once per sweep family (asserted through the
unified compile counter below).  This package owns everything that sits
on top of those streams:

  * :mod:`~repro.obs.counters`   — the ONE compile-event counter registry
    behind ``sweeps.compile_cache_size`` /
    ``faults.fault_compile_cache_size`` /
    ``serving.serving_compile_cache_size`` (all three are now thin
    aliases over :func:`compile_events`);
  * :mod:`~repro.obs.telemetry`  — :class:`TelemetryFrame` /
    :class:`FaultTelemetry` / :class:`ServingTelemetry` pytrees plus
    host-side exporters: flat metric tables (:func:`metric_streams`,
    :func:`metric_table`) and Chrome trace-event JSON
    (:func:`serving_trace`, viewable in Perfetto / ``chrome://tracing``);
  * :mod:`~repro.obs.provenance` — :func:`provenance`: git sha + dirty
    flag, jax/jaxlib versions, backend/device, caller-supplied timestamp
    — stamped into every ``BENCH_*.json`` by
    :func:`repro.sweeps.results.write_manifest`;
  * :mod:`~repro.obs.profiling`  — ``jax.named_scope`` phase spans inside
    the engines (trajectory sample -> policy replay -> allocate -> score
    -> decode), host-side ``jax.profiler.TraceAnnotation`` spans, and a
    ``REPRO_PROFILE=<dir>``-gated profiler-trace context manager.

The LIVE tier (PR 9) sits next to the post-hoc ``telemetry=`` streams:

  * :mod:`~repro.obs.taps`       — the ``tap=`` static engine flag's host
    side: ``io_callback``-backed block-aggregate events streamed DURING
    compiled scans, handler registry (:func:`add_tap` /
    :func:`capture_taps`), event schema validation; tap-off is
    bit-identical and zero-callback, tap-on still compiles once per
    family signature (same contract as ``telemetry=``);
  * :mod:`~repro.obs.metrics`    — host metrics registry (named counters /
    gauges / histograms under a strict naming convention) with JSONL,
    Prometheus-exposition and stderr progress-line sinks, plus per-phase
    wall-clock / compile-time attribution (:func:`timed`,
    :func:`record_compile`);
  * :mod:`~repro.obs.history`    — ``BENCH_history.jsonl``: every
    :func:`repro.sweeps.results.write_manifest` appends a compact
    provenance-stamped record, and :func:`~repro.obs.history.trend_report`
    flags robust (median-vs-MAD-envelope) slowdowns across the trajectory
    — the softgate's "vs HEAD" widened to "vs trajectory"
    (``benchmarks/run.py --check`` gates on it).

``benchmarks/run.py obs_report`` is the consumer: it aggregates every
committed ``BENCH_*.json`` into one provenance-stamped regression summary
(metric deltas vs. the committed baselines, softgate warnings collected,
trend section over the history) and renders a serving run as a
request-timeline trace.
"""

from .counters import compile_events, counter_names, register_compiled
from .history import (HISTORY_BASENAME, HISTORY_ENV, append_record,
                      history_path, read_history, record_from_manifest,
                      trend_report)
from .metrics import (DEFAULT as default_metrics, JsonlSink, MetricsRegistry,
                      ProgressLine, record_compile, tap_to_registry, timed)
from .profiling import (PROFILE_ENV, annotate, phase, profile_dir,
                        profile_trace)
from .provenance import provenance
from .taps import (EVENT_STREAMS, TAP_ENGINES, add_tap, capture_taps,
                   remove_tap, tap_names, validate_event)
from .telemetry import (FaultTelemetry, ServingTelemetry, TelemetryFrame,
                        metric_streams, metric_table, serving_trace,
                        validate_trace, write_trace)

__all__ = [
    "EVENT_STREAMS", "FaultTelemetry", "HISTORY_BASENAME", "HISTORY_ENV",
    "JsonlSink", "MetricsRegistry", "PROFILE_ENV", "ProgressLine",
    "ServingTelemetry", "TAP_ENGINES", "TelemetryFrame", "add_tap",
    "annotate", "append_record", "capture_taps", "compile_events",
    "counter_names", "default_metrics", "history_path", "metric_streams",
    "metric_table", "phase", "profile_dir", "profile_trace", "provenance",
    "read_history", "record_compile", "record_from_manifest",
    "register_compiled", "remove_tap", "serving_trace", "tap_names",
    "tap_to_registry", "timed", "trend_report", "validate_event",
    "validate_trace", "write_trace",
]
