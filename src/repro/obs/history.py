"""Benchmark history: an append-only trajectory behind every manifest.

``BENCH_*.json`` files are single snapshots: the softgate can only diff
against the ONE committed baseline (``git show HEAD:``), so a slow
regression spread over several PRs — each within tolerance of its
immediate predecessor — is invisible.  This module turns the baseline
into a trajectory:

  * every :func:`repro.sweeps.results.write_manifest` call appends a
    compact, provenance-stamped record to ``BENCH_history.jsonl``
    (co-located with the manifest; ``REPRO_BENCH_HISTORY`` overrides the
    path, which is how tests and CI redirect it);
  * :func:`trend_report` computes per-(bench, metric) time series across
    the history and flags robust changepoints: the median of the
    ``recent`` newest points is compared against a
    median ± max(tolerance·|median|, z·1.4826·MAD) envelope of the older
    committed points — single noisy runs cannot move the reference, and
    the detector needs several points before it says anything;
  * ``benchmarks/run.py --check`` exits non-zero on any hard regression
    record, and ``obs_report`` embeds the full report as the manifest's
    ``trend`` section.

Only PERF-ish metrics are trended (``*_per_sec``, ``speedup_*``,
``*_s`` wall-clocks, ``us_per_*`` latencies — see :func:`metric_direction`);
deterministic result metrics are snapshot-diffed by the softgate already
and would only add noise here.

Append/read never raise (the ``repro.obs`` convention): a read-only
checkout or a full disk degrades to an empty history, not a dead bench.
"""

from __future__ import annotations

import json
import os
import statistics
import time
from typing import Any, Iterable

HISTORY_ENV = "REPRO_BENCH_HISTORY"
HISTORY_BASENAME = "BENCH_history.jsonl"

SCHEMA_VERSION = 1

# keys every history record must carry (the hygiene test's contract)
RECORD_KEYS = ("schema", "bench", "manifest", "written_at", "provenance",
               "metrics", "warnings")

# provenance fields carried per record (a compact subset of the full stamp)
_PROV_KEYS = ("git_sha", "git_dirty", "jax", "backend", "device", "timestamp")

# robust-envelope constant: 1.4826 * MAD estimates sigma for normal data
_MAD_TO_SIGMA = 1.4826


def history_path(manifest_path: str | os.PathLike) -> str:
    """Where the history lives for a manifest at ``manifest_path``.

    Default: ``BENCH_history.jsonl`` next to the manifest (so repo-root
    manifests share the committed history and tmp-dir test manifests write
    to tmp).  ``REPRO_BENCH_HISTORY`` overrides everything — the hook CI
    and the ``--check`` tests use to redirect or doctor the trajectory.
    """
    env = os.environ.get(HISTORY_ENV)
    if env:
        return env
    return os.path.join(
        os.path.dirname(os.path.abspath(os.fspath(manifest_path))),
        HISTORY_BASENAME,
    )


def record_from_manifest(
    manifest_path: str | os.PathLike, doc: dict[str, Any]
) -> dict[str, Any]:
    """The compact history record for one just-written manifest.

    ``metrics`` keeps every numeric non-bool TOP-LEVEL field of the
    manifest (the same flat surface ``obs_report`` diffs); per-row results
    stay in the manifest — history is a trajectory of summaries, not a
    second copy of the data.
    """
    prov = doc.get("provenance") or {}
    metrics = {
        k: float(v) for k, v in doc.items()
        if isinstance(v, (int, float)) and not isinstance(v, bool)
    }
    return {
        "schema": SCHEMA_VERSION,
        "bench": doc.get("bench"),
        "manifest": os.path.basename(os.fspath(manifest_path)),
        "written_at": float(time.time()),
        "provenance": {k: prov.get(k) for k in _PROV_KEYS},
        "metrics": metrics,
        "warnings": len(doc.get("warnings") or []),
    }


def append_record(path: str | os.PathLike, record: dict[str, Any]) -> bool:
    """Append one record (one JSON line); False (never an exception) on
    failure — history must never be the reason a manifest write dies."""
    try:
        line = json.dumps(record, allow_nan=False)
        with open(path, "a") as f:
            f.write(line + "\n")
        return True
    except Exception:
        return False


def read_history(path: str | os.PathLike) -> list[dict[str, Any]]:
    """Every well-formed record at ``path``, in file order.

    Malformed lines are skipped (a torn concurrent append must not poison
    the whole trajectory); a missing file is an empty history.
    """
    records: list[dict[str, Any]] = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(rec, dict) and rec.get("bench"):
                    records.append(rec)
    except OSError:
        pass
    return records


def valid_record(rec: dict[str, Any]) -> bool:
    """Does ``rec`` carry the full history-record schema?"""
    return (
        all(k in rec for k in RECORD_KEYS)
        and isinstance(rec.get("metrics"), dict)
        and isinstance(rec.get("provenance"), dict)
        and all(k in rec["provenance"] for k in _PROV_KEYS)
    )


def metric_direction(metric: str) -> str | None:
    """Which way is better for ``metric``: "higher", "lower", or None.

    None means "not trended": deterministic result metrics (counts, flags,
    thresholds) are the softgate's job; only perf-ish metrics carry
    machine-noise trajectories worth a robust envelope.
    """
    m = metric.lower()
    if "per_sec" in m or m.startswith("speedup"):
        return "higher"
    if m.endswith(("_s", "_seconds")) or "us_per" in m or m.endswith("_us"):
        return "lower"
    return None


def _series(records: Iterable[dict[str, Any]]) -> dict[str, dict[str, list[float]]]:
    """{bench: {metric: [values in history order]}} for trended metrics."""
    out: dict[str, dict[str, list[float]]] = {}
    for rec in records:
        bench = rec.get("bench")
        metrics = rec.get("metrics")
        if not bench or not isinstance(metrics, dict):
            continue
        for k, v in metrics.items():
            if metric_direction(k) is None:
                continue
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out.setdefault(bench, {}).setdefault(k, []).append(float(v))
    return out


def trend_report(
    records: list[dict[str, Any]],
    *,
    recent: int = 2,
    tolerance: float = 0.30,
    z: float = 3.0,
    min_points: int = 5,
) -> dict[str, Any]:
    """Per-metric trajectories + robust slowdown/changepoint records.

    For each (bench, metric) series with at least ``min_points`` points:
    baseline = the points BEFORE the ``recent`` newest; the envelope half-
    width is ``max(tolerance * |median|, z * 1.4826 * MAD)``; a regression
    record (kind="trend", severity="hard") fires when the median of the
    recent points leaves the envelope on the WORSE side for the metric's
    direction.  Improvements are reported as severity="info" (visible, not
    gating).  Returns ``{"entries", "benches", "series", "regressions"}``
    — ``regressions`` is what ``run.py --check`` gates on.
    """
    if recent < 1:
        raise ValueError(f"recent must be >= 1, got {recent}")
    if min_points < recent + 2:
        raise ValueError(
            f"min_points must be >= recent + 2 (a baseline needs >= 2 "
            f"points), got {min_points} with recent={recent}"
        )
    series = _series(records)
    regressions: list[dict[str, Any]] = []
    summary: dict[str, Any] = {}
    for bench, metrics in sorted(series.items()):
        bench_summary = {}
        for metric, values in sorted(metrics.items()):
            info: dict[str, Any] = {"points": len(values), "last": values[-1]}
            if len(values) >= min_points:
                base = values[:-recent]
                med = statistics.median(base)
                mad = statistics.median(abs(v - med) for v in base)
                half = max(tolerance * abs(med), z * _MAD_TO_SIGMA * mad)
                recent_med = statistics.median(values[-recent:])
                info.update(baseline_median=med, envelope=half,
                            recent_median=recent_med)
                direction = metric_direction(metric)
                worse = (recent_med > med + half if direction == "lower"
                         else recent_med < med - half)
                better = (recent_med < med - half if direction == "lower"
                          else recent_med > med + half)
                if worse or better:
                    regressions.append({
                        "kind": "trend",
                        "severity": "hard" if worse else "info",
                        "bench": bench,
                        "metric": metric,
                        "value": recent_med,
                        "baseline": med,
                        "envelope": half,
                        "direction": direction,
                        "points": len(values),
                        "message": (
                            f"{bench} {metric} trend "
                            f"{'regressed' if worse else 'improved'}: "
                            f"median of last {recent} runs {recent_med:.4g} "
                            f"vs committed envelope {med:.4g} ± {half:.4g} "
                            f"over {len(base)} runs"
                        ),
                    })
            bench_summary[metric] = info
        summary[bench] = bench_summary
    return {
        "entries": len(records),
        "benches": sorted(series),
        "series": summary,
        "regressions": regressions,
    }


def hard_regressions(report: dict[str, Any]) -> list[dict[str, Any]]:
    """The gating subset of a :func:`trend_report`'s regression records."""
    return [r for r in report.get("regressions", [])
            if r.get("severity") == "hard"]
