"""Telemetry frames + host-side exporters (tables, Chrome traces).

The engines emit these pytrees from the SAME compiled computation as
their primary streams when called with ``telemetry=True``:

  * :class:`TelemetryFrame`   — :func:`repro.core.throughput
    .simulate_strategies_pool` (and ``sweep_pool`` / the sweeps executor
    with a leading batch axis): per-round estimator error vs. the genie's
    true p_good, allocator prefix sizes, allocated-load totals, received
    evaluations, feasibility;
  * :class:`FaultTelemetry`   — :func:`repro.faults.engine.sweep_faults`:
    per-round fault-event counts (preempted workers, dropped packets) and
    the binding per-packet received counts of both decode modes;
  * :class:`ServingTelemetry` — :func:`repro.serving.engine
    .sweep_serving`: per-round arrivals, queue occupancy and admission
    decisions.

Axis convention: ``M`` = rounds, ``S`` = strategies (request order), ``A``
= allocator (policy) strategies only, in
:func:`repro.core.throughput.allocator_strategies` order.  Batched sweeps
prepend a ``(B,)`` axis to every leaf; the exporters below take ONE row —
select it with ``jax.tree.map(lambda x: x[i], frame)``.

Exporters:

  * :func:`metric_streams` / :func:`metric_table` — flat metric names
    (``"est_err/lea"``) to per-round vectors / summary rows;
  * :func:`serving_trace` — a serving run as Chrome trace-event JSON
    (one process per strategy, one thread per queue slot, one complete
    event per request residency), viewable in Perfetto or
    ``chrome://tracing``.  Timestamps are DETERMINISTIC (round index x
    ``round_us``), so the trace is a committable artifact;
  * :func:`validate_trace` — structural validation + disposition counts
    (the conservation side of the exporter round-trip tests).
"""

from __future__ import annotations

import json
import os
from typing import Any, NamedTuple, Sequence

import numpy as np

# mirrors repro.serving.engine EVENT_* (kept literal: obs must not import
# the engines; the serving tests cross-check the two stay in sync)
_EVENT_NAMES = {1: "on_time", 2: "late", 3: "expired"}


class TelemetryFrame(NamedTuple):
    """Offline-engine telemetry, one leaf per stream (axes: see module doc).

    ``est_err`` (M, A) float32  — mean |predicted - true| p_good per policy
    (the genie's one-step conditional is the truth; the ``oracle`` policy's
    column is exactly zero);
    ``prefix_size`` (M, A) int32 — the allocator's chosen prefix i* per
    policy (how many workers receive load);
    ``load_total`` (M, S) int32 — total allocated load per strategy;
    ``received`` (M, S) int32   — on-time evaluations received;
    ``feasible`` (M, S) bool    — the engine's explicit feasibility flag.
    """

    est_err: Any
    prefix_size: Any
    load_total: Any
    received: Any
    feasible: Any


class FaultTelemetry(NamedTuple):
    """Fault-engine telemetry (axes: see module doc).

    ``preempted`` (M,) int32   — workers whose compute window was cut short
    (``t_cut < deadline``) this round;
    ``packets_lost`` (M,) int32 — packet deliveries erased by the channel;
    ``received_aon`` / ``received_conserve`` (M, S) int32 — the BINDING
    (min-over-packet-index) received count per decode mode — the margin to
    K* that decides full decode.
    """

    preempted: Any
    packets_lost: Any
    received_aon: Any
    received_conserve: Any


class ServingTelemetry(NamedTuple):
    """Serving-engine telemetry (axes: see module doc; Q = queue slots).

    ``arrivals_t`` (M,) int32 — requests arriving each round (shared across
    strategies: one arrival stream per simulation);
    ``occupancy`` (S, M) int32 — queue slots still occupied AFTER the
    round's departures;
    ``admitted_t`` / ``rejected_t`` (S, M) int32 — the round's admission
    decisions (``admitted_t + rejected_t == arrivals_t`` pointwise —
    conservation, property-tested).
    """

    arrivals_t: Any
    occupancy: Any
    admitted_t: Any
    rejected_t: Any


def _np(x) -> np.ndarray:
    return np.asarray(x)


def _strategy_names(n: int, names: Sequence[str] | None, kind: str) -> list[str]:
    if names is None:
        return [f"{kind}{j}" for j in range(n)]
    if len(names) != n:
        raise ValueError(
            f"{kind} axis has {n} columns but {len(names)} names: {names!r}"
        )
    return list(names)


def metric_streams(
    frame: TelemetryFrame | FaultTelemetry | ServingTelemetry,
    *,
    strategies: Sequence[str] | None = None,
    alloc_strategies: Sequence[str] | None = None,
) -> dict[str, np.ndarray]:
    """Flatten ONE frame (no batch axis) to ``{"stream/strategy": (M,)}``.

    Strategy-resolved leaves fan out per column (``"est_err/lea"``);
    per-round scalars keep their leaf name (``"preempted"``).  ``frame``
    may be any of the three telemetry classes; pass the matching name
    lists to label columns (defaults to positional ``s0``/``a0`` labels).
    """
    per_alloc = {"est_err", "prefix_size"}
    strategy_major = {"occupancy", "admitted_t", "rejected_t"}
    out: dict[str, np.ndarray] = {}
    for name, leaf in frame._asdict().items():
        arr = _np(leaf)
        if arr.ndim == 1:
            out[name] = arr
            continue
        if arr.ndim != 2:
            raise ValueError(
                f"leaf {name!r} has rank {arr.ndim}; exporters take ONE "
                "frame — select a batch row first (jax.tree.map(lambda x: "
                "x[i], frame))"
            )
        if name in strategy_major:
            arr = arr.T                               # (S, M) -> (M, S)
        names = _strategy_names(
            arr.shape[1],
            alloc_strategies if name in per_alloc else strategies,
            "a" if name in per_alloc else "s",
        )
        for j, s in enumerate(names):
            out[f"{name}/{s}"] = arr[:, j]
    return out


def metric_table(
    frame,
    *,
    strategies: Sequence[str] | None = None,
    alloc_strategies: Sequence[str] | None = None,
) -> list[dict[str, Any]]:
    """Summary rows (one per stream): mean / min / max / final value.

    The flat-table shape ``obs_report`` embeds in ``BENCH_obs.json`` —
    floats only, JSON-safe.
    """
    rows = []
    for name, vec in metric_streams(
        frame, strategies=strategies, alloc_strategies=alloc_strategies
    ).items():
        v = vec.astype(np.float64)
        rows.append({
            "metric": name,
            "rounds": int(v.size),
            "mean": float(v.mean()) if v.size else 0.0,
            "min": float(v.min()) if v.size else 0.0,
            "max": float(v.max()) if v.size else 0.0,
            "last": float(v[-1]) if v.size else 0.0,
        })
    return rows


def serving_trace(
    events,
    sojourn,
    *,
    strategies: Sequence[str] | None = None,
    telemetry: ServingTelemetry | None = None,
    round_us: float = 1000.0,
) -> dict[str, Any]:
    """One serving run as a Chrome trace-event document.

    ``events`` / ``sojourn`` are the (S, M, Q) per-slot streams of ONE
    :class:`repro.serving.engine.ServingOutcomes` row.  Each request
    residency becomes a complete ("X") event on (pid=strategy,
    tid=queue slot) spanning its sojourn, named by its disposition;
    with ``telemetry`` the queue-occupancy stream rides along as counter
    ("C") events.  Timestamps are round-deterministic (``round_us``
    microseconds per engine round), so identical runs produce identical
    traces.
    """
    ev = _np(events)
    so = _np(sojourn)
    if ev.ndim != 3 or ev.shape != so.shape:
        raise ValueError(
            f"expected matching (S, rounds, Q) events/sojourn, got "
            f"{ev.shape} / {so.shape}"
        )
    n_s, rounds, q = ev.shape
    names = _strategy_names(n_s, strategies, "s")
    out: list[dict[str, Any]] = []
    for s in range(n_s):
        out.append({
            "name": "process_name", "ph": "M", "pid": s, "tid": 0,
            "args": {"name": f"strategy:{names[s]}"},
        })
        for slot in range(q):
            out.append({
                "name": "thread_name", "ph": "M", "pid": s, "tid": slot,
                "args": {"name": f"slot{slot}"},
            })
        for t, slot in zip(*np.nonzero(ev[s])):
            code = int(ev[s, t, slot])
            dur = max(int(so[s, t, slot]), 1)
            out.append({
                "name": _EVENT_NAMES.get(code, f"event{code}"),
                "ph": "X", "pid": s, "tid": int(slot),
                "ts": float((int(t) - dur + 1) * round_us),
                "dur": float(dur * round_us),
                "args": {"round": int(t), "sojourn_rounds": dur,
                         "disposition": _EVENT_NAMES.get(code, str(code))},
            })
        if telemetry is not None:
            occ = _np(telemetry.occupancy)[s]
            for t in range(min(rounds, occ.shape[0])):
                out.append({
                    "name": "queue_occupancy", "ph": "C", "pid": s, "tid": 0,
                    "ts": float(t * round_us),
                    "args": {"occupied": int(occ[t])},
                })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def validate_trace(doc: dict[str, Any]) -> dict[str, Any]:
    """Structurally validate a trace document; returns disposition counts.

    Raises ``ValueError`` on malformation; on success returns
    ``{"events", "complete", "dispositions": {name: count}}`` — the counts
    the conservation tests reconcile against ``ServingOutcomes``.
    """
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError("trace document must be a dict with a traceEvents list")
    dispositions: dict[str, int] = {}
    complete = 0
    for i, e in enumerate(doc["traceEvents"]):
        for k in ("name", "ph", "pid", "tid"):
            if k not in e:
                raise ValueError(f"traceEvents[{i}] missing {k!r}: {e!r}")
        if e["ph"] == "X":
            if "ts" not in e or "dur" not in e or e["dur"] <= 0:
                raise ValueError(f"traceEvents[{i}] malformed X event: {e!r}")
            complete += 1
            d = e.get("args", {}).get("disposition", e["name"])
            dispositions[d] = dispositions.get(d, 0) + 1
        elif e["ph"] not in ("M", "C", "B", "E", "i"):
            raise ValueError(f"traceEvents[{i}] unknown phase {e['ph']!r}")
    json.dumps(doc, allow_nan=False)     # must round-trip as strict JSON
    return {"events": len(doc["traceEvents"]), "complete": complete,
            "dispositions": dispositions}


def write_trace(path: str | os.PathLike, doc: dict[str, Any]) -> None:
    """Validate + write a trace document (strict JSON, trailing newline)."""
    validate_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, allow_nan=False)
        f.write("\n")
