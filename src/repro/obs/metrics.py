"""Host metrics registry: named counters/gauges/histograms + sinks.

The host-side half of the live tier (:mod:`repro.obs.taps` is the device
half): a process-local registry of named metrics fed by tap events and by
host-side phases (compile time, block wall-clock — the per-phase
attribution hooks next to the engines' ``jax.named_scope`` spans), with
three sinks:

  * :class:`JsonlSink`      — append-only JSONL event log (one tap event
    or metric snapshot per line; the never-raise convention);
  * :meth:`MetricsRegistry.exposition` — Prometheus-style text exposition
    snapshot (``# TYPE`` lines, dot-separated names flattened to
    underscores);
  * :class:`ProgressLine`   — periodic stderr progress line (rounds/sec,
    ETA) driven by tap events or host ``update()`` calls; used by
    ``benchmarks/run.py`` and ``repro.launch.serve``.

Naming convention (enforced): ``<component>.<subject>[.<detail>...]`` —
lower-case, digits and underscores per segment, at least two dot-separated
segments (``tap.engine_pool.events``, ``phase.sweeps_run_group.seconds``,
``compile.serving_sweep.events``).  The convention keeps exposition names
collision-free after the dot->underscore flattening.

Everything here is plain host Python: no jax import at module scope, no
effect on traced computations, safe to call from io_callback threads
(mutations take the registry lock).
"""

from __future__ import annotations

import contextlib
import json
import math
import re
import sys
import threading
import time
from typing import Any, Iterator

import numpy as np

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)+$")

_KINDS = ("counter", "gauge", "histogram")


def valid_name(name: str) -> bool:
    """Does ``name`` follow the metric naming convention?"""
    return bool(_NAME_RE.match(name))


def _check_name(name: str) -> str:
    if not valid_name(name):
        raise ValueError(
            f"metric name {name!r} violates the convention "
            "<component>.<subject>[.<detail>...] (lower-case segments, "
            ">= 2 dot-separated)"
        )
    return name


class Metric:
    """One named metric; ``kind`` selects the update semantics.

    counter   — monotone float accumulator (``inc``);
    gauge     — last-value wins (``set``);
    histogram — running count/sum/min/max over ``observe`` values (no
                buckets: the sinks need summaries, not quantile sketches).
    """

    __slots__ = ("name", "kind", "help", "value", "count", "total",
                 "vmin", "vmax")

    def __init__(self, name: str, kind: str, help: str = ""):
        self.name = _check_name(name)
        if kind not in _KINDS:
            raise ValueError(f"metric kind must be one of {_KINDS}: {kind!r}")
        self.kind = kind
        self.help = help
        self.value = 0.0           # counter / gauge current value
        self.count = 0             # histogram observations
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def snapshot(self) -> dict[str, Any]:
        if self.kind == "histogram":
            return {
                "kind": self.kind, "count": self.count, "sum": self.total,
                "min": self.vmin if self.count else None,
                "max": self.vmax if self.count else None,
            }
        return {"kind": self.kind, "value": self.value}


class MetricsRegistry:
    """Get-or-create registry of named metrics (kind conflicts are errors)."""

    def __init__(self) -> None:
        self._metrics: dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Metric(name, kind, help)
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}"
                )
            return m

    def counter(self, name: str, inc: float = 1.0, *, help: str = "") -> float:
        """Increment (and create if needed) a counter; returns its value."""
        if inc < 0:
            raise ValueError(f"counter {name!r}: negative increment {inc}")
        m = self._get(name, "counter", help)
        with self._lock:
            m.value += float(inc)
            return m.value

    def gauge(self, name: str, value: float, *, help: str = "") -> float:
        """Set (and create if needed) a gauge; returns the new value."""
        m = self._get(name, "gauge", help)
        with self._lock:
            m.value = float(value)
            return m.value

    def histogram(self, name: str, value: float, *, help: str = "") -> None:
        """Observe one value into a histogram (create if needed)."""
        m = self._get(name, "histogram", help)
        v = float(value)
        with self._lock:
            m.count += 1
            m.total += v
            m.vmin = min(m.vmin, v)
            m.vmax = max(m.vmax, v)

    def names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._metrics))

    def get(self, name: str) -> dict[str, Any]:
        with self._lock:
            if name not in self._metrics:
                raise KeyError(f"no metric {name!r}; registered: "
                               f"{tuple(sorted(self._metrics))}")
            return self._metrics[name].snapshot()

    def snapshot(self) -> dict[str, dict[str, Any]]:
        """JSON-able {name: snapshot} of every registered metric."""
        with self._lock:
            return {n: m.snapshot() for n, m in sorted(self._metrics.items())}

    def exposition(self) -> str:
        """Prometheus-style text exposition of the current snapshot.

        Dots flatten to underscores; histograms render the summary series
        ``_count``/``_sum``/``_min``/``_max``.  Ends with a newline (the
        text-format convention).
        """
        lines: list[str] = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            flat = name.replace(".", "_")
            if m.help:
                lines.append(f"# HELP {flat} {m.help}")
            if m.kind == "histogram":
                lines.append(f"# TYPE {flat} summary")
                lines.append(f"{flat}_count {m.count}")
                lines.append(f"{flat}_sum {m.total}")
                if m.count:
                    lines.append(f"{flat}_min {m.vmin}")
                    lines.append(f"{flat}_max {m.vmax}")
            else:
                lines.append(f"# TYPE {flat} {m.kind}")
                lines.append(f"{flat} {m.value}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        """Drop every metric (test isolation)."""
        with self._lock:
            self._metrics.clear()


#: the process-default registry (benchmarks/run.py, the executors)
DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT


class JsonlSink:
    """Append-only JSONL event log; usable directly as a tap handler.

    Each call appends one line.  Numpy scalars/arrays are converted to
    JSON-able python values; writes never raise (a full disk must not kill
    a run) — ``errors`` counts the drops instead.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.written = 0
        self.errors = 0
        self._lock = threading.Lock()

    def __call__(self, event: dict) -> None:
        try:
            # allow_nan=False: drop (count) the event rather than emit
            # non-RFC JSON into a log other tooling will parse
            line = json.dumps({k: _jsonable(v) for k, v in event.items()},
                              allow_nan=False)
            with self._lock, open(self.path, "a") as f:
                f.write(line + "\n")
            self.written += 1
        except Exception:
            self.errors += 1


def _jsonable(v: Any) -> Any:
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return v


class ProgressLine:
    """Periodic stderr progress line: rounds/sec and ETA.

    Drive it as a tap handler (it reads ``rounds_done`` from events) or
    host-side via :meth:`update`.  Lines are rewritten in place (``\\r``)
    at most every ``min_interval`` seconds; :meth:`close` ends the line.
    ``enabled=False`` (the ``--quiet`` path) makes every call a no-op.

    Out-of-order folding: async pipelined executors complete blocks out of
    order ACROSS rows (row 3's block 2 can land before row 0's block 1), so
    a single max-watermark over ``rounds_done`` would jump to the fastest
    row and report a finished-looking ETA while most rows still run.  Tap
    events are instead folded as a per-``row`` watermark — max
    ``rounds_done`` per row, immune to event reordering within a row — and
    the line reports the MEAN across rows seen, which matches the true
    per-row progress when rows advance together and degrades gracefully
    when they don't.  Host-side :meth:`update` (no row structure) keeps the
    plain single-watermark semantics.
    """

    def __init__(self, total: int | None = None, *, stream=None,
                 min_interval: float = 0.25, enabled: bool = True,
                 label: str = "progress"):
        self.total = total
        self.stream = stream if stream is not None else sys.stderr
        self.min_interval = float(min_interval)
        self.enabled = enabled
        self.label = label
        self.rounds_done = 0
        self.events = 0
        self._row_rounds: dict[int, int] = {}
        self._t0: float | None = None
        self._last_write = 0.0
        self._lock = threading.Lock()

    def __call__(self, event: dict) -> None:
        rd = event.get("rounds_done")
        if rd is None:
            return
        row = event.get("row")
        if row is None:
            self.update(int(np.asarray(rd)))
            return
        if not self.enabled:
            return
        now = time.perf_counter()
        with self._lock:
            row, rd = int(np.asarray(row)), int(np.asarray(rd))
            self._row_rounds[row] = max(self._row_rounds.get(row, 0), rd)
            self.rounds_done = int(
                sum(self._row_rounds.values()) / len(self._row_rounds)
            )
            if not self._tick(now):
                return
            line = self._render(now)
        self._write(line)

    def update(self, rounds_done: int) -> None:
        if not self.enabled:
            return
        now = time.perf_counter()
        with self._lock:
            self.rounds_done = max(self.rounds_done, int(rounds_done))
            if not self._tick(now):
                return
            line = self._render(now)
        self._write(line)

    def _tick(self, now: float) -> bool:
        """Event bookkeeping under the lock; True when a line is due."""
        self.events += 1
        if self._t0 is None:
            self._t0 = now
        if now - self._last_write < self.min_interval:
            return False
        self._last_write = now
        return True

    def _write(self, line: str) -> None:
        try:
            self.stream.write("\r" + line)
            self.stream.flush()
        except Exception:
            pass

    def _render(self, now: float) -> str:
        elapsed = max(now - (self._t0 or now), 1e-9)
        rate = self.rounds_done / elapsed
        msg = f"[{self.label}] {self.rounds_done} rounds, {rate:.0f} rounds/s"
        if self.total:
            remaining = max(self.total - self.rounds_done, 0)
            eta = remaining / rate if rate > 0 else float("inf")
            msg += f", ETA {eta:.1f}s ({self.rounds_done}/{self.total})"
        return msg

    def close(self) -> None:
        if not self.enabled or self._t0 is None:
            return
        try:
            self.stream.write("\r" + self._render(time.perf_counter()) + "\n")
            self.stream.flush()
        except Exception:
            pass


def tap_to_registry(registry: MetricsRegistry | None = None):
    """A tap handler that folds every event into ``registry``.

    Per engine (ids sanitized dot->underscore to stay one name segment):
    ``tap.<engine>.events`` counter, ``tap.<engine>.rounds_done`` gauge
    (max so far), scalar numeric streams as gauges
    (``tap.<engine>.<stream>``), and ``tap.<engine>.block_seconds`` — a
    histogram of inter-event host-time deltas, the block wall-clock
    attribution alongside the ``named_scope`` phases.
    """
    reg = registry or DEFAULT
    last_time: dict[str, float] = {}
    lock = threading.Lock()

    def handler(event: dict) -> None:
        engine = str(event.get("engine", "unknown")).replace(".", "_")
        prefix = f"tap.{engine}"
        reg.counter(f"{prefix}.events")
        rd = event.get("rounds_done")
        if rd is not None:
            prev = 0.0
            try:
                prev = reg.get(f"{prefix}.rounds_done")["value"]
            except KeyError:
                pass
            reg.gauge(f"{prefix}.rounds_done",
                      max(prev, float(np.asarray(rd))))
        for k, v in event.items():
            if k in ("engine", "host_time", "rounds_done"):
                continue
            a = np.asarray(v)
            if a.ndim == 0 and np.issubdtype(a.dtype, np.number):
                reg.gauge(f"{prefix}.{k}", float(a))
        ht = event.get("host_time")
        if ht is not None:
            with lock:
                prev_t = last_time.get(engine)
                last_time[engine] = float(ht)
            if prev_t is not None and float(ht) > prev_t:
                reg.histogram(f"{prefix}.block_seconds", float(ht) - prev_t)

    return handler


@contextlib.contextmanager
def timed(name: str, registry: MetricsRegistry | None = None) -> Iterator[None]:
    """Observe the block's wall-clock into histogram ``<name>.seconds``.

    The host-side phase-attribution hook: ``with timed("phase.sweeps_run_group")``
    around a jitted call records its wall-clock next to the compile counters
    (see ``repro.sweeps.executor``).
    """
    reg = registry or DEFAULT
    t0 = time.perf_counter()
    try:
        yield
    finally:
        reg.histogram(f"{name}.seconds", time.perf_counter() - t0)


def record_compile(family: str, compiles: int, seconds: float,
                   registry: MetricsRegistry | None = None) -> None:
    """Attribute a jitted call's compile events + wall-clock to ``family``.

    Called by the executors around their group entry points: the compile
    counter delta goes to ``compile.<family>.events`` and — only when the
    call actually compiled — the wall-clock to ``compile.<family>.seconds``
    (warm calls land in ``phase.<family>.seconds`` via :func:`timed`).
    """
    reg = registry or DEFAULT
    fam = family.replace(".", "_")
    if compiles > 0:
        reg.counter(f"compile.{fam}.events", compiles)
        reg.histogram(f"compile.{fam}.seconds", seconds)
