"""The unified compile-event counter.

Before ``repro.obs`` each engine carried its own copy-pasted hook over its
jitted group entry point (``sweeps.compile_cache_size``,
``faults.fault_compile_cache_size``, ``serving.serving_compile_cache_size``
— all three were ``<jitted>._cache_size()`` one-liners).  They now register
here once at import time and the old names are thin aliases over
:func:`compile_events`, so "did this sweep add a compile?" is a single
question with a single answer no matter which engine ran:

    before = obs.compile_events()
    ... run any mix of sweep families ...
    assert obs.compile_events() - before == expected_new_computations

Counters are monotonic per process (they read jit caches, which only
grow); deltas, not absolutes, are the meaningful quantity.  Registration
is idempotent by name — re-importing an engine module re-registers the
same hook.
"""

from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable[[], int]] = {}


def register_compiled(name: str, jitted) -> None:
    """Register a jitted entry point's compile-cache counter under ``name``.

    ``jitted`` is anything with a ``_cache_size()`` hook (a ``jax.jit``
    wrapper) or a plain zero-arg callable returning an int.
    """
    hook = getattr(jitted, "_cache_size", jitted)
    if not callable(hook):
        raise TypeError(f"{name!r}: {jitted!r} has no _cache_size and is not callable")
    _REGISTRY[name] = hook


def counter_names() -> tuple[str, ...]:
    """Registered counter names, sorted (only imported engines appear)."""
    return tuple(sorted(_REGISTRY))


def compile_events(name: str | None = None) -> int:
    """Compiled computations so far: one named counter, or the sum of all.

    With ``name=None`` the value sums every registered engine — the number
    the acceptance tests diff around a sweep to assert "this run added
    exactly N compiles" (N=1 per new family signature, and telemetry=on
    must add zero beyond that).
    """
    if name is None:
        return sum(int(hook()) for hook in _REGISTRY.values())
    if name not in _REGISTRY:
        raise KeyError(
            f"no compile counter {name!r}; registered: {counter_names()}"
        )
    return int(_REGISTRY[name]())
