"""The unified compile-event counter.

Before ``repro.obs`` each engine carried its own copy-pasted hook over its
jitted group entry point (``sweeps.compile_cache_size``,
``faults.fault_compile_cache_size``, ``serving.serving_compile_cache_size``
— all three were ``<jitted>._cache_size()`` one-liners).  They now register
here once at import time and the old names are thin aliases over
:func:`compile_events`, so "did this sweep add a compile?" is a single
question with a single answer no matter which engine ran:

    before = obs.compile_events()
    ... run any mix of sweep families ...
    assert obs.compile_events() - before == expected_new_computations

Counters are monotonic per process (they read jit caches, which only
grow); deltas, not absolutes, are the meaningful quantity.  Registration
is idempotent by name — re-importing an engine module re-registers the
same hook.

Persistent-compilation-cache awareness: a jit trace-cache entry appears
whether XLA actually compiled or the persistent cache
(``REPRO_COMPILE_CACHE``; see :mod:`repro.launch.cache`) served the
executable — so a warm-restart process would otherwise look like it
recompiled everything.  :func:`note_persistent_cache_hits` is fed by the
``jax.monitoring`` listener the cache layer installs; executors subtract
the hit delta from the trace-cache delta
(``max(trace_delta - hit_delta, 0)``) before attributing compile events,
so "0 new compile events on a warm restart" is a real, measurable claim.
With the cache disabled (the default) the hit counter stays 0 and every
delta reduces to the plain trace-cache delta.
"""

from __future__ import annotations

import threading
from typing import Callable

_REGISTRY: dict[str, Callable[[], int]] = {}

_PERSISTENT_LOCK = threading.Lock()
_PERSISTENT_HITS = 0


def note_persistent_cache_hits(n: int = 1) -> None:
    """Record ``n`` persistent-compilation-cache hits (listener callback)."""
    global _PERSISTENT_HITS
    if n < 0:
        raise ValueError(f"persistent cache hits increment must be >= 0: {n}")
    with _PERSISTENT_LOCK:
        _PERSISTENT_HITS += int(n)


def persistent_cache_hits() -> int:
    """Monotonic count of persistent-compilation-cache hits this process."""
    with _PERSISTENT_LOCK:
        return _PERSISTENT_HITS


def backend_compile_events(name: str | None = None) -> int:
    """:func:`compile_events` minus process-wide persistent-cache hits.

    The "did XLA actually compile?" view: clamped at 0 because hits are
    counted process-wide (op-by-op dispatches hit the cache too) while the
    trace-cache counters are per entry point.  Meaningful as a delta
    around a call window, exactly like :func:`compile_events`.
    """
    return max(compile_events(name) - persistent_cache_hits(), 0)


def register_compiled(name: str, jitted) -> None:
    """Register a jitted entry point's compile-cache counter under ``name``.

    ``jitted`` is anything with a ``_cache_size()`` hook (a ``jax.jit``
    wrapper) or a plain zero-arg callable returning an int.
    """
    hook = getattr(jitted, "_cache_size", jitted)
    if not callable(hook):
        raise TypeError(f"{name!r}: {jitted!r} has no _cache_size and is not callable")
    _REGISTRY[name] = hook


def counter_names() -> tuple[str, ...]:
    """Registered counter names, sorted (only imported engines appear)."""
    return tuple(sorted(_REGISTRY))


def compile_events(name: str | None = None) -> int:
    """Compiled computations so far: one named counter, or the sum of all.

    With ``name=None`` the value sums every registered engine — the number
    the acceptance tests diff around a sweep to assert "this run added
    exactly N compiles" (N=1 per new family signature, and telemetry=on
    must add zero beyond that).
    """
    if name is None:
        return sum(int(hook()) for hook in _REGISTRY.values())
    if name not in _REGISTRY:
        raise KeyError(
            f"no compile counter {name!r}; registered: {counter_names()}"
        )
    return int(_REGISTRY[name]())
