from .pipeline import DataPipeline, PipelineState  # noqa: F401
