"""Deterministic synthetic token pipeline with a restorable cursor.

Production shape: each host materializes only its shard of the global batch
(host-sharded loading); the cursor (epoch, step) lives in the checkpoint so
restarts are sample-exact.  Synthetic corpus = seeded Zipf-ish integer stream
(offline container: no external datasets), but the sharding/cursor logic is
the real thing.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(step=int(d["step"]), seed=int(d["seed"]))


class DataPipeline:
    """Yields {"tokens": (global_batch, seq)} int32 batches, deterministically.

    ``host_id``/``host_count`` carve the global batch so each host only
    touches its rows — the pattern multi-host TPU input pipelines use.
    """

    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 *, seed: int = 0, host_id: int = 0, host_count: int = 1,
                 extra_specs: dict | None = None):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.seq = seq_len
        self.host_id = host_id
        self.host_count = host_count
        self.state = PipelineState(seed=seed)
        self.extra_specs = extra_specs or {}

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.host_count

    def _batch_at(self, step: int) -> dict[str, np.ndarray]:
        rows = []
        base = step * self.global_batch + self.host_id * self.host_batch
        for r in range(self.host_batch):
            rng = np.random.default_rng(self.state.seed * 1_000_003 + base + r)
            # Zipf-ish marginal over the vocab: realistic embedding access skew
            z = rng.zipf(1.3, size=self.seq).astype(np.int64)
            rows.append((z % self.vocab).astype(np.int32))
        out = {"tokens": np.stack(rows)}
        for name, sd in self.extra_specs.items():
            rng = np.random.default_rng(self.state.seed * 7_000_003 + base + hash(name) % 1000)
            shape = (self.host_batch,) + tuple(sd.shape[1:])
            out[name] = rng.standard_normal(shape).astype(np.float32)
        return out

    def next(self) -> dict[str, np.ndarray]:
        batch = self._batch_at(self.state.step)
        self.state.step += 1
        return batch

    def restore(self, state: PipelineState | dict) -> None:
        self.state = state if isinstance(state, PipelineState) else PipelineState.from_dict(state)
