"""Core library: the paper's contribution (LCC encoding + LEA scheduling)."""

from .lagrange import (  # noqa: F401
    FIELD_P,
    CodeSpec,
    alpha_points,
    beta_points,
    decode,
    decode_matrix,
    decode_matrix_jax,
    decode_matrix_modp,
    encode,
    generator_matrix,
    generator_matrix_modp,
    matmul_modp,
    recovery_threshold,
)
from .lea import (  # noqa: F401
    EstimatorState,
    LoadParams,
    allocate,
    estimated_transitions,
    init_estimator,
    predicted_good_prob,
    prefix_thresholds,
    round_success,
    success_prob_all_prefixes,
    update_estimator,
)
from .markov import (  # noqa: F401
    initial_states,
    sample_trajectory,
    speeds_from_states,
    stationary_good_prob,
    step_states,
    t_step_transitions,
)
from .throughput import (  # noqa: F401
    STATIC_STRATEGIES,
    STRATEGIES,
    allocator_strategies,
    compare,
    simulate,
    simulate_strategies,
    strategy_known,
    sweep,
    timely_throughput,
)
from .coded_ops import (  # noqa: F401
    CodedDataset,
    DecodeCache,
    chunk_gradient,
    coded_linear_gradient,
    coded_linear_gradient_device,
    coded_matmul,
    coded_matmul_device,
    encode_dataset,
    received_indices,
    uncoded_linear_gradient,
)
