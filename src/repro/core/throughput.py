"""Timely-computation-throughput simulator (Defn. 2.1, Sec. 6.1).

Simulates M rounds of deadline-constrained coded computation over n two-state
Markov workers and measures R(d, eta) = (1/M) * sum_m N_m(d) for a strategy:

  * ``lea``          — the paper's LEA (estimator + optimal allocator)
  * ``static``       — paper's simulation benchmark: iid allocation from the
                       *true stationary distribution*, resampled until the
                       total load >= K* (Sec. 6.1)
  * ``static_equal`` — paper's EC2 benchmark: ell_g/ell_b with prob 1/2 each
  * ``oracle``       — genie-aided optimum of Thm. 4.6 (knows the Markov model
                       and the previous state) — the upper bound R*(d)

The whole M-round loop is a single ``lax.scan`` (fast enough for M=1e5 on CPU).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import lea as lea_mod
from . import markov
from .lea import EstimatorState, LoadParams

STRATEGIES = ("lea", "static", "static_equal", "oracle")


class _OraclePrev(NamedTuple):
    """Scan carry for the genie strategy: last round's true states."""

    state: jnp.ndarray
    seen: jnp.ndarray


def _static_loads(key: jax.Array, pi_g: jnp.ndarray, lp: LoadParams) -> jnp.ndarray:
    """iid two-level loads from worker-wise good-probability ``pi_g``,
    rejection-resampled (bounded) until total >= K* (paper Sec. 6.1)."""

    def cond(carry):
        i, _, loads = carry
        return (jnp.sum(loads) < lp.kstar) & (i < 128)

    def body(carry):
        i, k, _ = carry
        k, sub = jax.random.split(k)
        draw = jax.random.uniform(sub, pi_g.shape) < pi_g
        loads = jnp.where(draw, lp.ell_g, lp.ell_b).astype(jnp.int32)
        return (i + 1, k, loads)

    init = (jnp.int32(0), key, jnp.zeros(pi_g.shape, jnp.int32))
    _, _, loads = jax.lax.while_loop(cond, body, init)
    return loads


@partial(jax.jit, static_argnames=("strategy", "lp", "rounds"))
def simulate(
    key: jax.Array,
    strategy: str,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: float,
    mu_b: float,
    deadline: float,
    rounds: int,
) -> jnp.ndarray:
    """Run M rounds; returns (rounds,) bool success indicators N_m(d)."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    k_traj, k_rounds = jax.random.split(key)
    states = markov.sample_trajectory(k_traj, p_gg, p_bb, rounds)  # (M, n)
    pi_g = markov.stationary_good_prob(p_gg, p_bb)
    round_keys = jax.random.split(k_rounds, rounds)

    def lea_round(est: EstimatorState, xs):
        _, s_m = xs
        p_good = jnp.where(
            est.seen_prev, lea_mod.predicted_good_prob(est), jnp.full_like(pi_g, 0.5)
        )
        loads, _ = lea_mod.allocate(p_good, lp)
        ok = lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)
        est = lea_mod.update_estimator(est, s_m)
        return est, ok

    def static_round(carry, xs):
        k, s_m = xs
        loads = _static_loads(k, pi_g, lp)
        return carry, lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)

    def static_equal_round(carry, xs):
        k, s_m = xs
        loads = _static_loads(k, jnp.full_like(pi_g, 0.5), lp)
        return carry, lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)

    def oracle_round(prev, xs):
        _, s_m = xs
        # genie: exact conditional good-probability given last round's state
        p_good = jnp.where(prev.seen, jnp.where(prev.state == 1, p_gg, 1.0 - p_bb), pi_g)
        loads, _ = lea_mod.allocate(p_good, lp)
        ok = lea_mod.round_success(loads, s_m, lp, mu_g, mu_b, deadline)
        return _OraclePrev(state=s_m, seen=jnp.asarray(True)), ok

    xs = (round_keys, states)
    if strategy == "lea":
        _, succ = jax.lax.scan(lea_round, lea_mod.init_estimator(lp.n), xs)
    elif strategy == "static":
        _, succ = jax.lax.scan(static_round, jnp.int32(0), xs)
    elif strategy == "static_equal":
        _, succ = jax.lax.scan(static_equal_round, jnp.int32(0), xs)
    else:
        init = _OraclePrev(state=jnp.zeros_like(p_gg, dtype=jnp.int32), seen=jnp.asarray(False))
        _, succ = jax.lax.scan(oracle_round, init, xs)
    return succ


def timely_throughput(successes: jnp.ndarray) -> float:
    """R(d, eta) — eq. (2)."""
    return float(jnp.mean(successes.astype(jnp.float32)))


def compare(
    key: jax.Array,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: float,
    mu_b: float,
    deadline: float,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
) -> dict[str, float]:
    """Throughput for several strategies on a *shared* worker trajectory."""
    out = {}
    for s in strategies:
        succ = simulate(key, s, lp, p_gg, p_bb, mu_g, mu_b, deadline, rounds)
        out[s] = timely_throughput(succ)
    return out
