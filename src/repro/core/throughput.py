"""Timely-computation-throughput simulator (Defn. 2.1, Sec. 6.1) — batched engine.

Simulates M rounds of deadline-constrained coded computation over n two-state
Markov workers and measures R(d, eta) = (1/M) * sum_m N_m(d) for a strategy:

  * ``lea``           — the paper's LEA (estimator + optimal allocator)
  * ``static``        — paper's simulation benchmark: iid allocation from the
                        *true stationary distribution*, resampled until the
                        total load >= K* (Sec. 6.1)
  * ``static_equal``  — like ``static`` but with prob 1/2 each (resampled)
  * ``static_single`` — paper's EC2 benchmark: ONE ell_g/ell_b draw with prob
                        1/2 each, no resampling (used by the Fig. 4 replay)
  * ``oracle``        — genie-aided optimum of Thm. 4.6 (knows the Markov model
                        and the previous state) — the upper bound R*(d)

Batched-engine design
---------------------
The seed ran one ``lax.scan`` per (strategy, scenario, seed) whose body did a
fresh O(n^2) allocator DP per round — M sequential DPs per simulation.  The
engine instead vectorises over *rounds*: nothing in a round's allocation
depends on the previous round's allocation, only on the worker-state
trajectory, so

  * the LEA estimator state is a running count of Markov transitions — an
    exact ``cumsum`` over the trajectory (integer counts in float32, so
    bit-identical to the sequential updates), giving every round's predicted
    p_good at once;
  * the genie's p_good is a one-round lag of the trajectory;
  * ALL rounds x allocator-strategies then go through ONE batched
    :func:`repro.core.lea.allocate` call — a single (A*M, n) Poisson-binomial
    DP (the ``repro.kernels.poisson_binomial`` dispatcher: Pallas kernel on
    TPU, batched ``lax.scan`` DP elsewhere);
  * static strategies draw every round in a vectorised rejection-resampling
    ``while_loop`` over the (M, n) batch, preserving each round's per-key
    draw chain bit-for-bit;
  * round scoring is one vectorised comparison over (S, M, n).

Nothing sequential remains: the Markov trajectory itself is a parallel
prefix over composed transition draws (``markov.sample_trajectory``,
``lax.associative_scan``).  :func:`sweep` vmaps the whole engine over
leading axes of (key, p_gg, p_bb, mu_g, mu_b, deadline), so a scenarios x
seeds Monte-Carlo grid compiles to one XLA computation; ``round_chunk``
bounds peak memory at paper-scale M by blocking the per-round work
(``lax.map``), bit-identically.  The ``repro.sweeps`` subsystem layers
scenario registries, heterogeneous-K* grouping and mesh sharding on top.

Failed static draws: the resampling cap (128 tries) can exhaust with total
load < K*; such rounds are *explicitly* failed via the ``feasible`` flag
returned by :func:`_static_loads_batch` (they could never succeed — total
load < K* — but the accounting no longer relies on that implicit property).

:func:`simulate` (single strategy) and :func:`compare` keep the seed call
signatures; both wrap :func:`simulate_strategies` with identical key
splitting, so results match the sequential seed path on the same key.

Pluggable policies (``repro.policies``)
---------------------------------------
Every non-static strategy name is resolved through the policy registry
(:func:`repro.policies.resolve`) at trace time: a policy supplies the
(M, n) predicted-p_good trajectory (its estimator-state replay in closed
form) and the engine feeds all rounds x policies through the one batched
allocator call as before.  ``"lea"`` and ``"oracle"`` are themselves
registry entries whose trajectory functions are the verbatim PR-1 closed
forms, so resolving them through the registry is bit-identical to the
pre-registry engine on the same PRNG keys (asserted in
tests/policies/).  The static draw strategies (``static``,
``static_equal``, ``static_single``) stay engine-native — they never
allocate from predictions.

Non-stationary chains: ``p_gg``/``p_bb`` may be (rounds, n) instead of
(n,) — row t governs the transition into round t, row 0 the initial
distribution (``markov.sample_trajectory`` composes per-round maps, so
time-varying chains cost nothing extra).  Static strategies keep drawing
from the round-0 chain's stationary distribution (there is no global one
under drift); the genie tracks the true current chain.  Stationary inputs
take the exact pre-existing code paths, bit-for-bit.

Shape-polymorphic engine (traced K*/ell + mask-padded pools)
------------------------------------------------------------
:func:`simulate_strategies_pool` / :func:`sweep_pool` are the traced twins
of :func:`simulate_strategies` / :func:`sweep`: the load parameters arrive
as a :class:`repro.core.lea.PoolLoad` — traced ``kstar``/``ell_g``/
``ell_b`` scalars plus an (n,) worker-validity mask — so ONE compiled
computation serves a whole batch of heterogeneous-K*, heterogeneous-load,
heterogeneous-pool-size rows (the ``repro.sweeps`` executor's grouping
signature shrinks to ``(rounds, strategies)``).  Masked workers are frozen
in the good state by the trajectory sampler, demoted below every real
worker by the masked allocator, assigned load 0 and thereby excluded from
the received-evaluations count; rows whose valid pool can never reach K*
(``kstar > n_valid * ell_g``) carry an explicit False feasibility flag.
The load-bearing invariant: a full-width row (all-True mask) takes
value-preserving selects only, so its results are bit-identical to the
static-``LoadParams`` path on the same PRNG key (property-tested per
layer).  Scope: the invariant is exact wherever both paths run the ``ref``
Poisson-binomial DP — the CPU/GPU default and the CI configuration; on TPU
the static and traced paths lower to different Pallas kernels that agree
to float32 round-off only (see ``repro.kernels.poisson_binomial``).  A row
padded from a NARROWER pool keeps the padded width's PRNG stream — pool
width has always been part of the stream geometry.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.obs.profiling import phase as _phase
from repro.obs.telemetry import TelemetryFrame

from . import lea as lea_mod
from . import markov
from .lea import LoadParams

# The classic closed strategy tuple, kept for back-compat with seed-era
# callers; the engine itself now accepts any registered policy name too
# (see strategy_known / repro.policies).
STRATEGIES = ("lea", "static", "static_equal", "static_single", "oracle")
STATIC_STRATEGIES = ("static", "static_equal", "static_single")
_ALLOCATOR_STRATEGIES = ("lea", "oracle")   # legacy alias (pre-policies order)

# fold_in tag separating policy-private PRNG streams from the trajectory /
# round-key streams derived by jax.random.split(key)
_POLICY_KEY_TAG = 0x9E3779B9 % (2**31)


def _policy_registry():
    # local import: repro.policies imports repro.core.{lea,markov}; resolving
    # lazily keeps the package import graph acyclic
    from repro.policies import registry as policy_registry

    return policy_registry


def strategy_known(name: str) -> bool:
    """Is ``name`` a legal strategy: a static draw or a registered policy?"""
    return name in STATIC_STRATEGIES or _policy_registry().is_registered(name)


def allocator_strategies(strategies: tuple[str, ...]) -> tuple[str, ...]:
    """The policy (allocator-driven) strategies, deduped, in appearance order."""
    seen: list[str] = []
    for s in strategies:
        if s not in STATIC_STRATEGIES and s not in seen:
            seen.append(s)
    return tuple(seen)


def _lea_p_good_trajectory(states: jnp.ndarray) -> jnp.ndarray:
    """Vanilla LEA's (M, n) closed-form estimator replay.

    Lives in :mod:`repro.policies.estimators` now (it IS the ``"lea"``
    policy); this alias keeps the engine-internal name the PR-1 tests and
    docs refer to.
    """
    from repro.policies.estimators import lea_p_good

    return lea_p_good(states)


def _oracle_p_good_trajectory(
    states: jnp.ndarray, p_gg: jnp.ndarray, p_bb: jnp.ndarray, pi_g: jnp.ndarray
) -> jnp.ndarray:
    """Genie p_good per round (the ``"oracle"`` policy's trajectory)."""
    from repro.policies.estimators import oracle_p_good

    return oracle_p_good(states, p_gg, p_bb, pi_g)


def _load_fields(load):
    """(kstar, ell_g, ell_b, mask-or-None) of a LoadParams OR PoolLoad."""
    if isinstance(load, lea_mod.PoolLoad):
        return load.kstar, load.ell_g, load.ell_b, load.mask
    return load.kstar, load.ell_g, load.ell_b, None


def _static_loads_batch(
    keys: jnp.ndarray, pi_g: jnp.ndarray, kstar, ell_g, ell_b, mask=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorised rejection resampling: one iid two-level draw chain per round.

    ``keys`` is (M, ...) round keys; every round redraws from its own key
    chain until its total load reaches K* (at most 128 tries), exactly the
    per-round semantics of the seed's scalar while_loop — rounds that finish
    early simply ignore later (masked) draws, so per-round results are
    bit-identical.  ``kstar``/``ell_g``/``ell_b`` may be static ints or
    traced scalars; ``mask`` (n,) bool excludes padded workers (their loads
    are zeroed and never count toward K*).  Returns ``(loads (M, n),
    feasible (M,))``; ``feasible`` is False iff a round exhausted the cap
    with total load < K* and must be scored as an explicit failure.
    """

    def draw_one(k):
        k2, sub = jax.random.split(k)
        return k2, jax.random.uniform(sub, pi_g.shape)

    def masked(loads):
        return loads if mask is None else jnp.where(mask, loads, 0)

    def unfinished(loads):
        return jnp.sum(masked(loads), axis=-1) < kstar

    def cond(carry):
        i, _, loads = carry
        return jnp.any(unfinished(loads)) & (i < 128)

    def body(carry):
        i, ks, loads = carry
        ks2, us = jax.vmap(draw_one)(ks)
        new = jnp.where(us < pi_g, ell_g, ell_b).astype(jnp.int32)
        redo = unfinished(loads)[:, None]
        return (i + 1, ks2, jnp.where(redo, new, loads))

    rounds = keys.shape[0]
    init = (jnp.int32(0), keys, jnp.zeros((rounds,) + pi_g.shape, jnp.int32))
    _, _, loads = jax.lax.while_loop(cond, body, init)
    loads = masked(loads)
    return loads, jnp.sum(loads, axis=-1) >= kstar


def _p_good_rows(
    states: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    alloc_names: tuple[str, ...],
    key: jax.Array,
) -> jnp.ndarray:
    """(A, M, n) predicted p_good per policy strategy (cheap: O(A*M*n)).

    Each name resolves through the policy registry; randomised policies get
    a private key stream (``fold_in`` of the simulation key, disjoint from
    the trajectory/round streams), which deterministic policies never
    consume — so ``lea``/``oracle`` results are unchanged by its existence.
    """
    from repro.policies.api import PolicyContext

    registry = _policy_registry()
    pi_g = markov.stationary_good_prob(*_chain_row0(p_gg, p_bb))
    pkey = jax.random.fold_in(key, _POLICY_KEY_TAG)
    p_rows = []
    for j, s in enumerate(alloc_names):
        ctx = PolicyContext(
            states=states, p_gg=p_gg, p_bb=p_bb, pi_g=pi_g,
            key=jax.random.fold_in(pkey, j),
        )
        p_rows.append(registry.resolve(s).p_good_trajectory(ctx))
    return jnp.stack(p_rows)


def _chain_row0(p_gg: jnp.ndarray, p_bb: jnp.ndarray):
    """The chain in force at round 0 ((n,) rows from a (rounds, n) schedule)."""
    if p_gg.ndim == 2:
        return p_gg[0], p_bb[0]
    return p_gg, p_bb


def _rollout_block(
    states: jnp.ndarray,       # (m, n) — a block of rounds
    round_keys: jnp.ndarray,   # (m, 2)
    p_alloc: jnp.ndarray,      # (A, m, n) predicted p_good per allocator strat
    pi_g: jnp.ndarray,         # (n,)
    load,                      # LoadParams (static) or lea.PoolLoad (traced)
    strategies: tuple[str, ...],
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Loads + feasibility + prefixes for one block: (S, m, n), (S, m), (A, m).

    Per-round work only (allocator DP rows, static draw chains, scoring are
    all row-independent), so any partition of the M rounds into blocks yields
    bit-identical results — this is what makes the ``round_chunk`` path exact.

    ``load`` selects the engine flavour: a static :class:`LoadParams` takes
    the classic paths verbatim; a traced :class:`~repro.core.lea.PoolLoad`
    routes allocator strategies through :func:`lea.allocate_masked` (per-row
    thresholds, masked pool, explicit feasibility) and zeroes masked
    workers' static-draw loads.
    """
    m = states.shape[0]
    kstar, ell_g, ell_b, mask = _load_fields(load)
    alloc_names = allocator_strategies(strategies)
    loads_by: dict[str, tuple[jnp.ndarray, jnp.ndarray]] = {}
    prefix = jnp.zeros((len(alloc_names), m), jnp.int32)       # allocator i*
    if alloc_names:
        with _phase("allocate"):
            if isinstance(load, lea_mod.PoolLoad):
                loads_all, i_star, feas = lea_mod.allocate_masked(p_alloc, load)
                feas_rows = jnp.broadcast_to(feas, loads_all.shape[:2])  # (A, m)
                for j, s in enumerate(alloc_names):
                    loads_by[s] = (loads_all[j], feas_rows[j])
            else:
                loads_all, i_star = lea_mod.allocate(p_alloc, load)  # one (A*m, n) DP
                always = jnp.ones((m,), bool)
                for j, s in enumerate(alloc_names):
                    loads_by[s] = (loads_all[j], always)
            prefix = i_star.astype(jnp.int32)                  # (A, m)

    # -- static draws (same round key per strategy, as in the seed) --
    if "static" in strategies:
        loads_by["static"] = _static_loads_batch(
            round_keys, pi_g, kstar, ell_g, ell_b, mask
        )
    if "static_equal" in strategies:
        loads_by["static_equal"] = _static_loads_batch(
            round_keys, jnp.full_like(pi_g, 0.5), kstar, ell_g, ell_b, mask
        )
    if "static_single" in strategies:
        draw = jax.vmap(lambda k: jax.random.uniform(k, pi_g.shape))(round_keys)
        single = jnp.where(draw < 0.5, ell_g, ell_b).astype(jnp.int32)
        if mask is not None:
            single = jnp.where(mask, single, 0)
        loads_by["static_single"] = (single, jnp.ones((m,), bool))

    loads_mat = jnp.stack([loads_by[s][0] for s in strategies])    # (S, m, n)
    feasible = jnp.stack([loads_by[s][1] for s in strategies])     # (S, m)
    return loads_mat, feasible, prefix


def _score_block_stats(
    loads_mat: jnp.ndarray, feasible: jnp.ndarray, states: jnp.ndarray,
    mu_g, mu_b, deadline, kstar: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(m, S) success indicators + (S, m) received counts for one block.

    ``received`` is an intermediate of the success rule; surfacing it is
    free (XLA dead-code-eliminates it when the caller discards it, so the
    telemetry=off computation is unchanged)."""
    with _phase("score"):
        speeds = jnp.where(states == 1, mu_g, mu_b)                # (m, n)
        on_time = loads_mat.astype(jnp.float32) / speeds <= deadline + 1e-9
        received = jnp.sum(jnp.where(on_time, loads_mat, 0), axis=-1)  # (S, m)
        succ = (received >= kstar) & feasible
    return jnp.moveaxis(succ, 0, 1), received                      # (m, S), _


def _score_block(
    loads_mat: jnp.ndarray, feasible: jnp.ndarray, states: jnp.ndarray,
    mu_g, mu_b, deadline, kstar: int,
) -> jnp.ndarray:
    """(m, S) success indicators from one block's loads + trajectory."""
    return _score_block_stats(
        loads_mat, feasible, states, mu_g, mu_b, deadline, kstar
    )[0]


def _check_strategies(strategies: tuple[str, ...]) -> None:
    if not strategies:
        raise ValueError("strategies must be non-empty")
    for s in strategies:
        if not strategy_known(s):
            raise ValueError(
                f"unknown strategy {s!r}: not a static draw "
                f"{STATIC_STRATEGIES} and not a registered policy "
                f"({', '.join(_policy_registry().names())})"
            )


def _check_chain_shapes(p_gg: jnp.ndarray, p_bb: jnp.ndarray, rounds: int) -> None:
    if p_gg.ndim != p_bb.ndim or p_gg.shape != p_bb.shape:
        raise ValueError(f"p_gg/p_bb shapes differ: {p_gg.shape} vs {p_bb.shape}")
    if p_gg.ndim == 2 and p_gg.shape[0] != rounds:
        raise ValueError(
            f"time-varying chain must have one row per round: got "
            f"{p_gg.shape[0]} rows for rounds={rounds}"
        )


def engine_preamble(
    key: jax.Array,
    load,                      # LoadParams (static) or lea.PoolLoad (traced)
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    strategies: tuple[str, ...],
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The per-simulation preamble every engine flavour shares.

    ``(states (M, n), round_keys (M, 2), p_alloc (A, M, n), pi_g (n,))`` on
    EXACTLY the PRNG discipline of :func:`simulate_strategies` — the same
    ``split(key)``, the same masked trajectory, the same policy-stream
    ``fold_in`` — so a caller that re-blocks the per-round work itself (the
    ``repro.sweeps`` pipelined executor) consumes bit-identical inputs.
    ``p_alloc`` has a zero-size leading axis when no allocator strategy is
    requested (the uniform-signature convention of the block body).
    """
    masked = isinstance(load, lea_mod.PoolLoad)
    k_traj, k_rounds = jax.random.split(key)
    with _phase("trajectory"):
        states = markov.sample_trajectory(
            k_traj, p_gg, p_bb, rounds,
            worker_mask=load.mask if masked else None,
        )                                                          # (M, n)
    pi_g = markov.stationary_good_prob(*_chain_row0(p_gg, p_bb))
    round_keys = jax.random.split(k_rounds, rounds)
    alloc_names = allocator_strategies(strategies)
    if alloc_names:
        with _phase("policy_replay"):
            p_alloc = _p_good_rows(states, p_gg, p_bb, alloc_names, key)  # (A, M, n)
    else:  # keep the block signature uniform; zero-size axis costs nothing
        p_alloc = jnp.zeros((0,) + states.shape, jnp.float32)
    return states, round_keys, p_alloc, pi_g


def engine_block(
    states_b: jnp.ndarray,     # (m, n) — a block of rounds
    keys_b: jnp.ndarray,       # (m, 2)
    p_alloc_b: jnp.ndarray,    # (A, m, n)
    pi_g: jnp.ndarray,         # (n,)
    load,                      # LoadParams (static) or lea.PoolLoad (traced)
    strategies: tuple[str, ...],
    mu_g,
    mu_b,
    deadline,
) -> jnp.ndarray:
    """One round block scored: (m, S) success indicators.

    Pure per-round work (:func:`_rollout_block` + :func:`_score_block_stats`
    — the body the chunked ``lax.map`` runs), so any partition of the M
    rounds into blocks, in any dispatch order, yields bit-identical rows.
    This is the unit the pipelined executor dispatches asynchronously.
    """
    loads_mat, feasible, _prefix = _rollout_block(
        states_b, keys_b, p_alloc_b, pi_g, load, strategies
    )
    return _score_block(
        loads_mat, feasible, states_b, mu_g, mu_b, deadline, load.kstar
    )


def estimator_error_rounds(
    states: jnp.ndarray,
    p_alloc: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    pi_g: jnp.ndarray,
    mask: jnp.ndarray | None,
) -> jnp.ndarray:
    """(M, A) mean |p_alloc - genie p_good| per round, masked workers excluded.

    The estimator-error stream shared by the telemetry frame and the tap
    aggregates — one definition so every consumer folds the same floats.
    """
    from repro.policies.estimators import oracle_p_good

    p_true = oracle_p_good(states, p_gg, p_bb, pi_g)           # (M, n)
    err = jnp.abs(p_alloc - p_true[None])                      # (A, M, n)
    if mask is not None:
        w = mask.astype(jnp.float32)
        est = jnp.sum(err * w, axis=-1) / jnp.maximum(jnp.sum(w), 1.0)
    else:
        est = jnp.mean(err, axis=-1)                           # (A, M)
    return jnp.moveaxis(est, 0, 1)                             # (M, A)


def _simulate_impl(
    key: jax.Array,
    load,                      # LoadParams (static) or lea.PoolLoad (traced)
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    rounds: int,
    strategies: tuple[str, ...],
    round_chunk: int | None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
    tap_row=None,
):
    """Shared engine body behind :func:`simulate_strategies` (static
    ``LoadParams``) and :func:`simulate_strategies_pool` (traced
    ``PoolLoad``).  The two flavours differ only in the value-preserving
    masking constructs the PoolLoad branch threads through the layers.

    ``telemetry`` (static): False returns the (rounds, S) success stream
    on literally the pre-existing code path; True additionally returns a
    :class:`repro.obs.telemetry.TelemetryFrame` of per-round streams —
    pure extra outputs of the same traced computation (the success stream
    is built from the identical intermediate values, so it is
    bit-identical either way; property-tested in tests/obs/).

    ``tap`` (static): True streams block aggregates (rounds done, success
    counts + timely throughput so far, mean estimator error so far) to the
    host DURING the computation via :func:`repro.obs.taps.emit` — at every
    ``round_chunk`` block boundary on the chunked path (which swaps the
    ``lax.map`` for an equivalent ``lax.scan`` carrying the cumulative
    aggregates; the per-round ys are untouched, so outputs stay
    bit-identical) and at ``tap_stride`` boundaries on the unchunked path.
    ``tap_row`` is an optional traced batch index stamped into the events
    (-1 when absent)."""
    _check_strategies(strategies)
    _check_chain_shapes(p_gg, p_bb, rounds)
    masked = isinstance(load, lea_mod.PoolLoad)
    states, round_keys, p_alloc, pi_g = engine_preamble(
        key, load, p_gg, p_bb, rounds, strategies
    )
    alloc_names = allocator_strategies(strategies)
    kstar = load.kstar

    def block(states_b, keys_b, p_alloc_b):
        loads_mat, feasible, prefix = _rollout_block(
            states_b, keys_b, p_alloc_b, pi_g, load, strategies
        )
        succ, received = _score_block_stats(
            loads_mat, feasible, states_b, mu_g, mu_b, deadline, kstar
        )
        if not telemetry:
            return succ
        # time-major extra streams (m leading) so the chunked path can
        # unblock them exactly like succ
        return succ, (
            jnp.moveaxis(prefix, 0, 1),                            # (m, A)
            jnp.moveaxis(jnp.sum(loads_mat, axis=-1), 0, 1),       # (m, S)
            jnp.moveaxis(received, 0, 1),                          # (m, S)
            jnp.moveaxis(feasible, 0, 1),                          # (m, S)
        )

    def est_err_rounds():
        # estimator error vs. the genie's true conditional p_good — O(A*M*n),
        # computed once outside the blocks; shared by the telemetry frame and
        # the tap aggregates (same traced values either way)
        return estimator_error_rounds(
            states, p_alloc, p_gg, p_bb, pi_g, load.mask if masked else None
        )

    def with_frame(succ, tel):
        prefix_t, load_total_t, received_t, feasible_t = tel
        return succ, TelemetryFrame(
            est_err=est_err_rounds(),
            prefix_size=prefix_t,
            load_total=load_total_t,
            received=received_t,
            feasible=feasible_t,
        )

    row = jnp.int32(-1) if tap_row is None else jnp.asarray(tap_row, jnp.int32)

    def tap_emit(token, block_i, rounds_done, succ_cum, err_cum):
        # block aggregates: cumulative success counts per strategy, timely
        # throughput so far, mean estimator error so far (A may be 0)
        from repro.obs import taps as _taps

        done_f = jnp.maximum(rounds_done.astype(jnp.float32), 1.0)
        return _taps.emit(
            "engine.pool", token=token,
            block=jnp.asarray(block_i, jnp.int32),
            row=row,
            rounds_done=jnp.asarray(rounds_done, jnp.int32),
            succ_so_far=succ_cum,
            throughput_so_far=succ_cum.astype(jnp.float32) / done_f,
            est_err_so_far=err_cum / done_f,
        )

    if round_chunk is None or round_chunk >= rounds:
        out = block(states, round_keys, p_alloc)
        if tap:
            succ_all = out[0] if telemetry else out                # (M, S)
            from repro.obs import taps as _taps

            stride = _taps.resolve_stride(rounds, tap_stride)
            succ_cum = jnp.cumsum(succ_all.astype(jnp.int32), axis=0)
            err_cum = jnp.cumsum(est_err_rounds(), axis=0)         # (M, A)
            token = None
            for bi, bound in enumerate(_taps.stride_boundaries(rounds, stride)):
                token = tap_emit(token, bi, jnp.int32(bound),
                                 succ_cum[bound - 1], err_cum[bound - 1])
        return with_frame(*out) if telemetry else out

    if round_chunk <= 0:
        raise ValueError("round_chunk must be positive")
    pad = (-rounds) % round_chunk
    n_blocks = (rounds + pad) // round_chunk
    # pad with edge rounds: real rows are untouched (blocks are independent)
    # and the pad rows behave like ordinary rounds, so no masked-lane hazards.
    states_p = jnp.concatenate([states, states[-pad:]]) if pad else states
    keys_p = jnp.concatenate([round_keys, round_keys[-pad:]]) if pad else round_keys
    p_alloc_p = (
        jnp.concatenate([p_alloc, p_alloc[:, -pad:]], axis=1) if pad else p_alloc
    )
    xs = (
        states_p.reshape((n_blocks, round_chunk) + states.shape[1:]),
        keys_p.reshape((n_blocks, round_chunk) + round_keys.shape[1:]),
        jnp.moveaxis(
            p_alloc_p.reshape(
                (p_alloc.shape[0], n_blocks, round_chunk, states.shape[1])
            ),
            0, 1,
        ),
    )
    if not tap:
        out = jax.lax.map(lambda b_xs: block(*b_xs), xs)
        # leaves: (n_blocks, round_chunk, ...)
    else:
        # lax.map IS lax.scan with an unused carry: carrying the cumulative
        # aggregates (and emitting them at every block boundary) leaves the
        # per-round ys — and therefore the unblocked outputs — bit-identical
        est_full = est_err_rounds()                                # (M, A)
        est_p = (
            jnp.concatenate([est_full, est_full[-pad:]]) if pad else est_full
        ).reshape((n_blocks, round_chunk, len(alloc_names)))
        in_round = jnp.arange(round_chunk, dtype=jnp.int32)

        def scan_body(carry, b_xs):
            block_i, succ_cum, err_cum, token = carry
            *block_xs, est_b = b_xs
            ys = block(*block_xs)
            succ_b = ys[0] if telemetry else ys                    # (m, S)
            # mask the edge-pad rows out of the aggregates (the ys keep
            # them; unblock slices them off exactly as before)
            valid = (block_i * round_chunk + in_round) < rounds    # (m,)
            succ_cum = succ_cum + jnp.sum(
                jnp.where(valid[:, None], succ_b.astype(jnp.int32), 0), axis=0
            )
            err_cum = err_cum + jnp.sum(
                jnp.where(valid[:, None], est_b, 0.0), axis=0
            )
            rounds_done = jnp.minimum((block_i + 1) * round_chunk, rounds)
            token = tap_emit(token, block_i, rounds_done, succ_cum, err_cum)
            return (block_i + 1, succ_cum, err_cum, token), ys

        carry0 = (
            jnp.int32(0),
            jnp.zeros((len(strategies),), jnp.int32),
            jnp.zeros((len(alloc_names),), jnp.float32),
            jnp.int32(0),
        )
        _, out = jax.lax.scan(scan_body, carry0, (*xs, est_p))

    def unblock(x):
        return x.reshape((n_blocks * round_chunk,) + x.shape[2:])[:rounds]

    if not telemetry:
        return unblock(out)
    succ, tel = out
    return with_frame(unblock(succ), jax.tree.map(unblock, tel))


@partial(jax.jit, static_argnames=("strategies", "lp", "rounds", "round_chunk"))
def simulate_strategies(
    key: jax.Array,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
    round_chunk: int | None = None,
) -> jnp.ndarray:
    """Run M rounds of ALL ``strategies`` over one shared worker trajectory.

    Returns (rounds, len(strategies)) bool success indicators, one column per
    strategy in the given order.  ``mu_g``/``mu_b``/``deadline`` may be traced
    scalars (they are vmapped over by :func:`sweep`).  ``strategies`` may mix
    static draws with any registered policy name (``repro.policies``).
    ``p_gg``/``p_bb`` of shape (rounds, n) run a non-stationary chain (row t
    governs the transition into round t).

    ``round_chunk``: with the default ``None`` the whole (S, M, n) round block
    is materialised at once; a positive value instead runs a ``lax.map`` over
    ceil(M / round_chunk) blocks of rounds so peak memory is bounded by the
    O(A * round_chunk * n^2)-ish allocator intermediates of ONE block — the
    knob that fits paper-scale M = 1e5 sweeps (with large scenario batches on
    top) in memory.  Only the cheap O(M*n) trajectory/estimator arrays span
    all rounds.  Every quantity in a block depends on its own rounds only, so
    chunked results are bit-identical to the unchunked path.
    """
    return _simulate_impl(
        key, lp, p_gg, p_bb, mu_g, mu_b, deadline, rounds, strategies,
        round_chunk,
    )


@partial(jax.jit,
         static_argnames=("strategies", "rounds", "round_chunk", "telemetry",
                          "tap", "tap_stride"))
def simulate_strategies_pool(
    key: jax.Array,
    pool,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
    round_chunk: int | None = None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
    tap_row=None,
):
    """:func:`simulate_strategies` with TRACED load parameters.

    ``pool`` is a :class:`repro.core.lea.PoolLoad`: kstar/ell_g/ell_b are
    traced scalars and ``pool.mask`` (n,) marks real workers in a pool
    padded to width n — so one compile serves every (K*, ell, pool-size)
    combination at a given width (the whole point of the shape-polymorphic
    engine).  A full-width pool (all-True mask) is bit-identical to
    :func:`simulate_strategies` with the equivalent static ``LoadParams``
    on the same key (exact on the ref-DP path — see the module docstring
    for the TPU-kernel caveat, the padded-row PRNG convention and the
    explicit infeasibility flag).

    ``telemetry`` (static): False returns the (rounds, S) success stream
    unchanged; True returns ``(succ, TelemetryFrame)`` — extra per-round
    streams out of the SAME traced computation (see
    :mod:`repro.obs.telemetry`; bit-identity and the zero-extra-compile
    property are asserted in tests/obs/).

    ``tap`` (static): True streams block-aggregated telemetry to the host
    DURING the computation (see :mod:`repro.obs.taps`) at ``round_chunk``
    block boundaries (or ``tap_stride`` boundaries when unchunked) —
    outputs stay bit-identical, one compile per signature, and
    ``tap=False`` traces zero callbacks.  ``tap_row`` (traced int) labels
    events with a batch index under :func:`sweep_pool`.
    """
    return _simulate_impl(
        key, pool, p_gg, p_bb, mu_g, mu_b, deadline, rounds, strategies,
        round_chunk, telemetry, tap, tap_stride, tap_row,
    )


def _rollout_impl(
    key: jax.Array,
    load,                      # LoadParams (static) or lea.PoolLoad (traced)
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    strategies: tuple[str, ...],
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared body of :func:`rollout` / :func:`rollout_pool`."""
    _check_strategies(strategies)
    _check_chain_shapes(p_gg, p_bb, rounds)
    states, round_keys, p_alloc, pi_g = engine_preamble(
        key, load, p_gg, p_bb, rounds, strategies
    )
    loads_mat, feasible, _prefix = _rollout_block(
        states, round_keys, p_alloc, pi_g, load, strategies
    )
    return states, loads_mat, feasible


@partial(jax.jit, static_argnames=("strategies", "lp", "rounds"))
def rollout(
    key: jax.Array,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static"),
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Trajectory + per-round loads without scoring — the engine's rollout.

    Returns ``(states (M, n), loads (S, M, n), feasible (S, M))`` on exactly
    the code path :func:`simulate_strategies` scores, so driving an
    application round-by-round (examples/coded_regression.py) replays the
    batched engine's allocations bit-for-bit instead of re-implementing the
    seed-era per-round estimator/allocate loop.
    """
    return _rollout_impl(key, lp, p_gg, p_bb, rounds, strategies)


@partial(jax.jit, static_argnames=("strategies", "rounds"))
def rollout_pool(
    key: jax.Array,
    pool,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static"),
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """:func:`rollout` with TRACED load parameters (a ``lea.PoolLoad``).

    The shape-polymorphic twin: traced kstar/ell and a mask-padded pool, so
    consumers that post-process the loads themselves (the fault engine's
    packet-level scoring in :mod:`repro.faults.engine`) fuse a whole
    heterogeneous batch into one compile exactly like
    :func:`simulate_strategies_pool`.  Full-width rows are bit-identical
    to :func:`rollout` with the equivalent static ``LoadParams`` on the
    same key (same invariant, same ref-DP scope).
    """
    return _rollout_impl(key, pool, p_gg, p_bb, rounds, strategies)


@partial(jax.jit, static_argnames=("strategies", "rounds"))
def serve_rollout(
    key: jax.Array,
    mask: jnp.ndarray,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    strategies: tuple[str, ...] = ("lea",),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Trajectory + per-policy predicted p_good rows for ``repro.serving``.

    The serving layer allocates per QUEUE SLOT (its own traced K*/ell per
    request), so unlike :func:`rollout_pool` there is no single pool-wide
    load allocation to return — just the engine preamble: ``(states (M, n),
    p_alloc (A, M, n))`` on exactly the PRNG discipline of the offline
    engine (same ``split(key)``, same masked trajectory, same policy-stream
    ``fold_in``), so a degenerate one-job-per-round serving run replays
    :func:`simulate_strategies_pool` bit-for-bit.

    ``strategies`` must be registered POLICY names, unique: the serving
    loop allocates from predictions every round, so the static draw
    strategies (which never produce a p_good trajectory) are rejected
    explicitly rather than silently served a default.
    """
    _check_strategies(strategies)
    if tuple(strategies) != allocator_strategies(strategies):
        raise ValueError(
            f"serve_rollout strategies must be unique policy names (no "
            f"static draws {STATIC_STRATEGIES}): got {strategies!r}"
        )
    _check_chain_shapes(p_gg, p_bb, rounds)
    # split exactly like _simulate_impl: k_rounds feeds the static-draw
    # chains there and is deliberately unused here, which keeps k_traj (and
    # therefore the trajectory) identical to the offline engine's
    k_traj, _k_rounds = jax.random.split(key)
    states = markov.sample_trajectory(
        k_traj, p_gg, p_bb, rounds, worker_mask=mask
    )
    p_alloc = _p_good_rows(states, p_gg, p_bb, tuple(strategies), key)
    return states, p_alloc


def score_rollout(
    states: jnp.ndarray,
    loads: jnp.ndarray,
    feasible: jnp.ndarray,
    lp: LoadParams,
    mu_g,
    mu_b,
    deadline,
) -> jnp.ndarray:
    """Score a :func:`rollout`: (M, S) success indicators.

    ``score_rollout(*rollout(...))`` equals :func:`simulate_strategies` on
    the same key — it IS the engine's scoring stage, exposed for drivers that
    need the per-round loads too (examples/coded_regression.py).
    """
    return _score_block(loads, feasible, states, mu_g, mu_b, deadline, lp.kstar)


def simulate(
    key: jax.Array,
    strategy: str,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: float,
    mu_b: float,
    deadline: float,
    rounds: int,
) -> jnp.ndarray:
    """Run M rounds of one strategy; returns (rounds,) bool indicators N_m(d).

    Thin wrapper over :func:`simulate_strategies`; kept for the sequential
    seed API (and as the old-path baseline in benchmarks/bench_allocator.py).
    """
    if not strategy_known(strategy):
        raise ValueError(f"unknown strategy {strategy!r}")
    succ = simulate_strategies(
        key, lp, p_gg, p_bb, mu_g, mu_b, deadline, rounds, strategies=(strategy,)
    )
    return succ[:, 0]


def sweep(
    keys: jax.Array,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
    round_chunk: int | None = None,
) -> jnp.ndarray:
    """Batched Monte-Carlo sweep: vmap the whole engine over leading axes.

    Args:
      keys: (B,) PRNG keys (one independent trajectory per row).
      p_gg/p_bb: (B, n) per-row transition probabilities, or (B, rounds, n)
        for non-stationary chains (row t governs the transition into round t).
      mu_g/mu_b/deadline: scalars or (B,) per-row values.
      lp/rounds/strategies: static, shared across the batch (group sweep calls
        by LoadParams when K* differs across scenarios — or use
        ``repro.sweeps``, which does the grouping, sharding and chunking).
      round_chunk: see :func:`simulate_strategies` — bounds peak memory by
        processing rounds in blocks, bit-identically.

    Returns (B, rounds, len(strategies)) bool success indicators.
    """
    strategies = tuple(strategies)   # lists would fail jit's static-arg hashing
    b = p_gg.shape[0]
    mu_g = jnp.broadcast_to(jnp.asarray(mu_g, jnp.float32), (b,))
    mu_b = jnp.broadcast_to(jnp.asarray(mu_b, jnp.float32), (b,))
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float32), (b,))
    fn = partial(simulate_strategies, lp=lp, rounds=rounds, strategies=strategies,
                 round_chunk=round_chunk)
    return jax.vmap(
        lambda k, pg, pb, mg, mb, d: fn(k, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d)
    )(keys, p_gg, p_bb, mu_g, mu_b, deadline)


def sweep_pool(
    keys: jax.Array,
    pool,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g,
    mu_b,
    deadline,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
    round_chunk: int | None = None,
    telemetry: bool = False,
    tap: bool = False,
    tap_stride: int | None = None,
):
    """:func:`sweep` with TRACED per-row load parameters.

    ``pool`` is a :class:`repro.core.lea.PoolLoad` whose leaves carry a
    leading (B,) batch axis (``mask`` is (B, n)): every row may have its own
    K*, loads and valid pool size, and the whole heterogeneous batch still
    compiles to ONE XLA computation — the fused path the ``repro.sweeps``
    executor runs.  Full-width rows are bit-identical to :func:`sweep` with
    the equivalent static ``LoadParams`` on the same keys.

    ``telemetry=True`` returns ``(succ, TelemetryFrame)`` with a leading
    (B,) axis on every frame leaf (same compile-fusion contract).
    ``tap=True`` streams per-row block aggregates to the host mid-run
    (events carry the batch ``row`` index; see :mod:`repro.obs.taps`) —
    same one-compile contract, outputs bit-identical.
    """
    strategies = tuple(strategies)   # lists would fail jit's static-arg hashing
    b = p_gg.shape[0]
    mu_g = jnp.broadcast_to(jnp.asarray(mu_g, jnp.float32), (b,))
    mu_b = jnp.broadcast_to(jnp.asarray(mu_b, jnp.float32), (b,))
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float32), (b,))
    fn = partial(simulate_strategies_pool, rounds=rounds, strategies=strategies,
                 round_chunk=round_chunk, telemetry=telemetry, tap=tap,
                 tap_stride=tap_stride)
    if tap:
        rows = jnp.arange(b, dtype=jnp.int32)
        return jax.vmap(
            lambda k, pl, pg, pb, mg, mb, d, ri: fn(
                k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d,
                tap_row=ri,
            )
        )(keys, pool, p_gg, p_bb, mu_g, mu_b, deadline, rows)
    return jax.vmap(
        lambda k, pl, pg, pb, mg, mb, d: fn(
            k, pool=pl, p_gg=pg, p_bb=pb, mu_g=mg, mu_b=mb, deadline=d
        )
    )(keys, pool, p_gg, p_bb, mu_g, mu_b, deadline)


def timely_throughput(successes: jnp.ndarray) -> float:
    """R(d, eta) — eq. (2)."""
    return float(jnp.mean(successes.astype(jnp.float32)))


def compare(
    key: jax.Array,
    lp: LoadParams,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    mu_g: float,
    mu_b: float,
    deadline: float,
    rounds: int,
    strategies: tuple[str, ...] = ("lea", "static", "oracle"),
) -> dict[str, float]:
    """Throughput for several strategies on a *shared* worker trajectory.

    All strategies now run in ONE compiled computation (the seed looped a
    separate per-round ``lax.scan`` per strategy over the same trajectory).
    """
    succ = simulate_strategies(
        key, lp, p_gg, p_bb, mu_g, mu_b, deadline, rounds, strategies=tuple(strategies)
    )
    return {s: timely_throughput(succ[:, j]) for j, s in enumerate(strategies)}


# the engine's jitted entry points feed the unified obs compile counter
# (repro.obs.counters) — one registry instead of per-module cache hooks
from repro.obs import counters as _obs_counters  # noqa: E402

_obs_counters.register_compiled("engine.simulate_strategies", simulate_strategies)
_obs_counters.register_compiled(
    "engine.simulate_strategies_pool", simulate_strategies_pool
)
_obs_counters.register_compiled("engine.rollout", rollout)
_obs_counters.register_compiled("engine.rollout_pool", rollout_pool)
_obs_counters.register_compiled("engine.serve_rollout", serve_rollout)
