"""Coded computation ops: encode-once, evaluate-per-round, decode-on-K*.

These are the ML-facing operations the paper's system executes each round:

  * :func:`coded_matmul`          — f(X_j) = X_j @ w           (deg f = 1)
  * :func:`coded_linear_gradient` — f(X_j,y_j) = X_jᵀ(X_j w−y) (deg f = 2)

Both follow the paper's protocol: the dataset is Lagrange-encoded once
(`encode_dataset`), each round every worker evaluates f on (a prefix of) its
r stored encoded chunks, and the master decodes from the K* fastest results.
On-time-ness is injected as a boolean mask (produced by the scheduler /
simulator), keeping shapes static for XLA.

The Pallas kernels in ``repro.kernels`` accelerate the two hot spots
(`lagrange_encode` GEMM and the fused degree-2 gradient); these jnp versions
are the oracles they are tested against.

Device-resident decode path
---------------------------
The seed rebuilt the decode matrix on the host every round
(``np.nonzero(on_time)`` -> ``decode_matrix``), forcing a host round-trip in
the middle of each training/serving step.  Two replacements:

  * :class:`DecodeCache` — a host-side memo keyed on the received chunk set.
    Worker states are discrete, so on-time patterns recur heavily across
    rounds; after warm-up a round's decode matrix is a dict hit instead of an
    O(K*^2 k) rebuild.  Used by the eager :func:`coded_matmul` /
    :func:`coded_linear_gradient` via their ``cache=`` argument.
  * :func:`coded_matmul_device` / :func:`coded_linear_gradient_device` — fully
    jittable: the received set is a static-shape masked gather
    (:func:`received_indices`) and the decode matrix is built on device by
    ``lagrange.decode_matrix_jax``, so round-over-round iteration compiles
    into one XLA computation with no host sync.  They return ``(out, ok)``
    instead of raising ``TimeoutError`` (jit cannot raise data-dependently);
    ``ok`` is False when fewer than K* results were on time and ``out`` is
    then meaningless.

Exact GF(p) path
----------------
:func:`encode_dataset_modp` / :func:`coded_matmul_exact` /
:class:`ModpDecodeCache` are the finite-field twins of the float path: the
whole encode -> worker-shard matmul -> erasure-aware decode round runs on
device in exact Mersenne-31 arithmetic (``repro.kernels.gf``), bit-identical
to the numpy ``lagrange.*_modp`` oracle, with on-time masks produced from
engine trajectories by :func:`chunk_on_time`.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .lagrange import (CodeSpec, _gf, decode_matrix, decode_matrix_jax,
                       decode_matrix_modp_device, encode, generator_matrix,
                       generator_matrix_modp_device)


@dataclasses.dataclass
class CodedDataset:
    """Encoded dataset as stored across workers: chunk v lives on worker v//r."""

    spec: CodeSpec
    x_tilde: jnp.ndarray            # (nr, rows, cols)
    y_tilde: jnp.ndarray | None     # (nr, rows) or None

    @property
    def nr(self) -> int:
        return self.spec.nr


def encode_dataset(
    spec: CodeSpec,
    x_chunks: jnp.ndarray,
    y_chunks: jnp.ndarray | None = None,
    encode_fn=encode,
) -> CodedDataset:
    """Encode (k, rows, cols) data chunks (and optionally (k, rows) targets).

    ``encode_fn`` lets callers swap in the Pallas kernel
    (``repro.kernels.lagrange_encode.ops.encode``).
    """
    if x_chunks.shape[0] != spec.k:
        raise ValueError(f"expected {spec.k} chunks, got {x_chunks.shape[0]}")
    g = generator_matrix(spec, x_chunks.dtype)
    x_t = encode_fn(g, x_chunks)
    y_t = encode_fn(g, y_chunks) if y_chunks is not None else None
    return CodedDataset(spec=spec, x_tilde=x_t, y_tilde=y_t)


def received_indices(on_time: jnp.ndarray, kstar: int) -> jnp.ndarray:
    """Indices of the K* lexicographically-first on-time chunks (static shape).

    The master only needs *any* K* on-time results (Defn. 4.1); we take the
    first K* in chunk order.  Caller must guarantee >= K* are on time.
    Jittable (argsort-based masked gather, no data-dependent shapes).
    """
    order = jnp.argsort(~on_time, stable=True)  # on-time chunks first
    return order[:kstar]


# seed-era private name, kept for external callers
_first_kstar_mask = received_indices


def _received_or_raise(spec: CodeSpec, on_time: np.ndarray) -> np.ndarray:
    """First-K* received indices, or ``TimeoutError`` on a short pattern."""
    on_time = np.asarray(on_time)
    got = int(np.count_nonzero(on_time))
    if got < spec.recovery_threshold:
        raise TimeoutError(
            f"round failed: {got} < K*={spec.recovery_threshold} on-time results"
        )
    return np.nonzero(on_time)[0][: spec.recovery_threshold]


class DecodeCache:
    """Host-side memo of decode matrices keyed on the received chunk set.

    On-time patterns recur across rounds (worker states are discrete), so the
    O(K*^2 k) decode-matrix build is paid once per distinct received set.
    Not thread-safe; one cache per CodedDataset/spec.
    """

    def __init__(self, spec: CodeSpec):
        self.spec = spec
        self._mats: dict[tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._mats)

    def matrix(self, received: np.ndarray, dtype=jnp.float32) -> jnp.ndarray:
        # dtype is part of the key: a hit must not hand back a matrix built
        # at a different precision than the caller's results
        key = (jnp.dtype(dtype).name, *(int(v) for v in received))
        mat = self._mats.get(key)
        if mat is None:
            self.misses += 1
            mat = decode_matrix(self.spec, received, dtype)
            self._mats[key] = mat
        else:
            self.hits += 1
        return mat

    def from_on_time(self, on_time: np.ndarray, dtype=jnp.float32):
        """(received indices, decode matrix) for the first-K* on-time chunks.

        Raises ``TimeoutError`` when fewer than K* chunks arrived (same
        convention as :func:`coded_matmul` and the modp twin) rather than
        building a decode matrix from a truncated received set.
        """
        received = _received_or_raise(self.spec, on_time)
        return received, self.matrix(received, dtype)


def coded_matmul(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray,
    cache: DecodeCache | None = None,
) -> jnp.ndarray:
    """Decode f(X_j) = X_j @ w from on-time encoded evaluations.

    ``on_time`` is a concrete (nr,) bool array from the scheduler (which chunk
    evaluations arrived before the deadline).  Returns (k, rows[, ...]).
    Pass a :class:`DecodeCache` to memoise the decode matrix across rounds;
    use :func:`coded_matmul_device` for the fully-jittable path.
    """
    spec = coded.spec
    # one shared short-pattern gate for every eager path (float eager, float
    # cache, modp cache): all raise the same TimeoutError before any compute
    received = _received_or_raise(spec, on_time)
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)
    if cache is not None:
        d = cache.matrix(received, results.dtype)
    else:
        d = decode_matrix(spec, received, results.dtype)
    return jnp.tensordot(d, results[jnp.asarray(received)], axes=1)


@partial(jax.jit, static_argnames=("spec",))
def _decode_on_time(
    spec: CodeSpec, results: jnp.ndarray, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Device decode: (nr, *dims) results + (nr,) bool -> ((k, *dims), ok)."""
    from repro.obs.profiling import phase as _phase

    with _phase("decode"):
        kstar = spec.recovery_threshold
        received = received_indices(on_time, kstar)
        d = decode_matrix_jax(spec, received)
        gathered = jnp.take(results, received, axis=0)        # (K*, *dims)
        ok = jnp.sum(on_time) >= kstar
        return jnp.tensordot(d.astype(results.dtype), gathered, axes=1), ok


def coded_matmul_device(
    coded: CodedDataset, w: jnp.ndarray, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-jittable :func:`coded_matmul`: traced ``on_time``, no host sync.

    Returns ``(decoded, ok)``; ``decoded`` is meaningful only where ``ok``.
    """
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)
    return _decode_on_time(coded.spec, results, jnp.asarray(on_time))


def chunk_gradient(x_tilde_v: jnp.ndarray, y_tilde_v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-chunk degree-2 evaluation f(X̃,ỹ) = X̃ᵀ(X̃ w − ỹ) — worker-side op."""
    resid = x_tilde_v @ w - y_tilde_v
    return x_tilde_v.T @ resid


def coded_linear_gradient(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray, gradient_fn=None,
    cache: DecodeCache | None = None,
) -> jnp.ndarray:
    """Full least-squares gradient sum_j X_jᵀ(X_j w − y_j) via LCC (deg f = 2).

    ``gradient_fn(x_tilde, y_tilde, w) -> (nr, cols)`` defaults to a vmapped
    :func:`chunk_gradient`; the Pallas fused kernel slots in here.  Pass a
    :class:`DecodeCache` to memoise decode matrices across rounds; use
    :func:`coded_linear_gradient_device` for the fully-jittable path.
    """
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    received = _received_or_raise(spec, on_time)   # shared TimeoutError gate
    if gradient_fn is None:
        gradient_fn = jax.vmap(chunk_gradient, in_axes=(0, 0, None))
    results = gradient_fn(coded.x_tilde, coded.y_tilde, w)       # (nr, cols)
    if cache is not None:
        d = cache.matrix(received, results.dtype)
    else:
        d = decode_matrix(spec, received, results.dtype)
    per_chunk = jnp.tensordot(d, results[jnp.asarray(received)], axes=1)  # (k, cols)
    return jnp.sum(per_chunk, axis=0)


def coded_linear_gradient_device(
    coded: CodedDataset, w: jnp.ndarray, on_time: jnp.ndarray, gradient_fn=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-jittable :func:`coded_linear_gradient`: traced ``on_time``.

    Returns ``(gradient, ok)``; ``gradient`` is meaningful only where ``ok``.
    """
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    if gradient_fn is None:
        gradient_fn = jax.vmap(chunk_gradient, in_axes=(0, 0, None))
    results = gradient_fn(coded.x_tilde, coded.y_tilde, w)       # (nr, cols)
    per_chunk, ok = _decode_on_time(spec, results, jnp.asarray(on_time))
    return jnp.sum(per_chunk, axis=0), ok


def uncoded_linear_gradient(x_chunks: jnp.ndarray, y_chunks: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Oracle: sum_j X_jᵀ(X_j w − y_j) computed directly on the raw data."""
    grads = jax.vmap(chunk_gradient, in_axes=(0, 0, None))(x_chunks, y_chunks, w)
    return jnp.sum(grads, axis=0)


# ---------------------------------------------------------------------------
# Exact GF(p) path: encode -> worker matmul -> decode, entirely on device
# ---------------------------------------------------------------------------
#
# The float path above is the ML adaptation; this is the paper's actual
# protocol — exact arithmetic over the finite field F = GF(2^31 - 1), where
# the MDS guarantee is bit-exact and conditioning does not exist.  The seed
# could only run it through the numpy ``lagrange.*_modp`` host oracle; the
# ``repro.kernels.gf`` subsystem (Mersenne-31 matmul, batched Lagrange basis,
# Fermat inversion) moves encode, worker-shard evaluation AND the
# erasure-pattern-aware decode onto the device, so the exact path now runs at
# engine speed with the on-time mask coming straight from ``rollout()``
# trajectories.  Residues are exact: every result is bit-identical to the
# numpy ``matmul_modp``/``decode_matrix_modp`` pipeline (asserted in tests).


@dataclasses.dataclass
class CodedDatasetModp:
    """Exact-path encoded dataset: int32 residues in [0, p), chunk v on
    worker v//r (same placement as the float :class:`CodedDataset`).
    ``y_tilde`` carries encoded targets for the exact degree-2 gradient."""

    spec: CodeSpec
    x_tilde: jnp.ndarray            # (nr, rows, cols) int32 residues
    y_tilde: jnp.ndarray | None = None   # (nr, rows) int32 residues, or None

    @property
    def nr(self) -> int:
        return self.spec.nr


def encode_dataset_modp(
    spec: CodeSpec, x_chunks, y_chunks=None
) -> CodedDatasetModp:
    """Exact device encode: (k, rows, cols) int residues -> (nr, rows, cols).

    The generator is built on device (:func:`generator_matrix_modp_device`)
    and applied with the GF(p) matmul kernel path — one exact GEMM, no host
    round-trip.  Inputs must be integers in (-2^31, 2^31); they are reduced
    into [0, p).  ``y_chunks`` (k, rows) targets are encoded alongside for
    the exact degree-2 gradient (:func:`coded_linear_gradient_modp`).
    """
    gf = _gf()
    x_chunks = jnp.asarray(x_chunks)
    if x_chunks.shape[0] != spec.k:
        raise ValueError(f"expected {spec.k} chunks, got {x_chunks.shape[0]}")
    g = generator_matrix_modp_device(spec)
    flat = x_chunks.reshape(spec.k, -1)
    x_t = gf.from_gf(gf.matmul_gf(g, flat)).reshape((spec.nr,) + x_chunks.shape[1:])
    y_t = None
    if y_chunks is not None:
        y_chunks = jnp.asarray(y_chunks)
        if y_chunks.shape[0] != spec.k:
            raise ValueError(f"expected {spec.k} target chunks, got {y_chunks.shape[0]}")
        y_t = gf.from_gf(
            gf.matmul_gf(g, y_chunks.reshape(spec.k, -1))
        ).reshape((spec.nr,) + y_chunks.shape[1:])
    return CodedDatasetModp(spec=spec, x_tilde=x_t, y_tilde=y_t)


class ModpDecodeCache:
    """Host-side memo of EXACT decode matrices keyed on the erasure pattern.

    The mod-p twin of :class:`DecodeCache`: worker states are discrete, so
    received sets recur across rounds and each distinct pattern pays the
    GF(p) basis build (gather + Fermat inversion) once.  Matrices are the
    device-built int32 residues of :func:`decode_matrix_modp_device` —
    bit-identical to the numpy ``decode_matrix_modp`` oracle.  No dtype in
    the key: the field has exactly one integer representation.
    """

    def __init__(self, spec: CodeSpec):
        self.spec = spec
        self._mats: dict[tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._mats)

    def matrix(self, received: np.ndarray) -> jnp.ndarray:
        key = tuple(int(v) for v in received)
        mat = self._mats.get(key)
        if mat is None:
            self.misses += 1
            mat = decode_matrix_modp_device(self.spec, jnp.asarray(received, jnp.int32))
            self._mats[key] = mat
        else:
            self.hits += 1
        return mat

    def from_on_time(self, on_time: np.ndarray):
        """(received indices, exact decode matrix) for the first K* on-time.

        Raises ``TimeoutError`` when fewer than K* chunks arrived, matching
        the eager float path (:func:`coded_matmul`) — a short pattern would
        otherwise feed a truncated gather into the device basis build.
        """
        received = _received_or_raise(self.spec, on_time)
        return received, self.matrix(received)


@partial(jax.jit, static_argnames=("spec",))
def _decode_on_time_modp(
    spec: CodeSpec, results: jnp.ndarray, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact device decode: (nr, *dims) residues + (nr,) bool -> ((k, *dims), ok)."""
    from repro.obs.profiling import phase as _phase

    with _phase("decode"):
        gf = _gf()
        kstar = spec.recovery_threshold
        received = received_indices(on_time, kstar)
        d = decode_matrix_modp_device(spec, received)
        gathered = jnp.take(results, received, axis=0)     # (K*, *dims)
        ok = jnp.sum(on_time) >= kstar
        out = gf.from_gf(gf.matmul_gf(d, gathered.reshape(kstar, -1)))
        return out.reshape((spec.k,) + results.shape[1:]), ok


def coded_matmul_exact(
    coded: CodedDatasetModp, w, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact f(X_j) = X_j @ w mod p from on-time evaluations — all on device.

    The paper's round, over its actual finite field: every worker evaluates
    its stored shards (one exact GF(p) GEMM across all chunks), the master
    gathers the K* lexicographically-first on-time results and decodes
    through the erasure-pattern decode matrix built on device.  ``on_time``
    is traced — feed it the chunk masks of an engine ``rollout()``
    (:func:`chunk_on_time`) and the whole round compiles into one XLA
    computation.  Returns ``(decoded (k, rows[, d]), ok)``: exact int
    equality with the numpy ``matmul_modp``/``decode_matrix_modp`` pipeline
    whenever ``ok`` (jit cannot raise data-dependently, so short rounds
    return ``ok=False`` instead of the eager path's ``TimeoutError``).
    """
    gf = _gf()
    w = jnp.asarray(w)
    squeeze = w.ndim == 1
    w2 = w[:, None] if squeeze else w                      # (cols, d)
    nr, rows = coded.x_tilde.shape[0], coded.x_tilde.shape[1]
    flat = coded.x_tilde.reshape(nr * rows, -1)            # (nr*rows, cols)
    results = gf.from_gf(gf.matmul_gf(flat, w2))           # (nr*rows, d)
    results = results.reshape(nr, rows, w2.shape[1])
    out, ok = _decode_on_time_modp(coded.spec, results, jnp.asarray(on_time))
    return (out[..., 0] if squeeze else out), ok


def coded_linear_gradient_modp(
    coded: CodedDatasetModp, w, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """EXACT least-squares gradient sum_j X_jᵀ(X_j w − y_j) over GF(p).

    The finite-field twin of :func:`coded_linear_gradient_device` — the
    degree-2 polynomial the paper's regression example actually evaluates,
    executed end to end in Mersenne-31 arithmetic on device:

      1. every worker shard evaluates its chunk gradient
         X̃_vᵀ(X̃_v w − ỹ_v) with the ``repro.kernels.gf`` matmuls (one
         batched GEMM over all nr chunks via ``bmm_gf``);
      2. the master gathers the K* lexicographically-first on-time results
         and decodes through the erasure-pattern decode matrix built on
         device;
      3. the per-chunk decoded gradients are summed mod p.

    ``w`` is (cols,) or (cols, d) int residues; ``on_time`` is traced (feed
    it :func:`chunk_on_time` masks from an engine rollout).  Returns
    ``(gradient, ok)`` with ``gradient`` (cols,[ d]) int32 residues that are
    bit-identical to the numpy ``matmul_modp``/``decode_matrix_modp``
    pipeline whenever ``ok`` (asserted in tests); short rounds return
    ``ok=False`` (jit cannot raise data-dependently).
    """
    gf = _gf()
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    w = jnp.asarray(w)
    squeeze = w.ndim == 1
    w2 = w[:, None] if squeeze else w                      # (cols, d)
    nr, rows, cols = coded.x_tilde.shape
    d = w2.shape[1]
    flat = coded.x_tilde.reshape(nr * rows, cols)
    xw = gf.matmul_gf(flat, w2).reshape(nr, rows, d)       # uint32 residues
    resid = gf.sub_gf(xw, gf.to_gf(coded.y_tilde)[..., None])   # (nr, rows, d)
    xt = jnp.swapaxes(coded.x_tilde, 1, 2)                 # (nr, cols, rows)
    grads = gf.from_gf(gf.bmm_gf(xt, gf.from_gf(resid)))   # (nr, cols, d)
    per_chunk, ok = _decode_on_time_modp(spec, grads, jnp.asarray(on_time))
    total = gf.to_gf(per_chunk[0])
    for j in range(1, spec.k):                             # k static, exact sum
        total = gf.add_gf(total, gf.to_gf(per_chunk[j]))
    total = gf.from_gf(total)                              # (cols, d)
    return (total[..., 0] if squeeze else total), ok


def chunk_on_time(
    states: jnp.ndarray, loads: jnp.ndarray, mu_g, mu_b, deadline, r: int
) -> jnp.ndarray:
    """Engine trajectory -> per-chunk on-time masks: (..., n) -> (..., n*r).

    Worker i evaluates a *prefix* of its r stored chunks (two-level loads),
    so when its whole load meets the deadline its first ``loads_i`` chunks
    arrive, else none — exactly the all-or-nothing rule
    ``throughput._score_block`` scores rounds with (same speed model, same
    deadline tolerance), which makes round success equivalent to
    ``sum(chunk mask) >= K*``.  Broadcasts over any leading axes: feed it
    ``rollout()``'s (M, n) states and (S, M, n) loads and get every round's
    erasure pattern in one call.
    """
    speeds = jnp.where(states == 1, mu_g, mu_b)
    done = jnp.where(
        loads.astype(jnp.float32) / speeds <= deadline + 1e-9, loads, 0
    )                                                      # (..., n)
    nr = done.shape[-1] * r
    return (jnp.arange(nr) % r) < jnp.repeat(done, r, axis=-1)
