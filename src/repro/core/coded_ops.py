"""Coded computation ops: encode-once, evaluate-per-round, decode-on-K*.

These are the ML-facing operations the paper's system executes each round:

  * :func:`coded_matmul`          — f(X_j) = X_j @ w           (deg f = 1)
  * :func:`coded_linear_gradient` — f(X_j,y_j) = X_jᵀ(X_j w−y) (deg f = 2)

Both follow the paper's protocol: the dataset is Lagrange-encoded once
(`encode_dataset`), each round every worker evaluates f on (a prefix of) its
r stored encoded chunks, and the master decodes from the K* fastest results.
On-time-ness is injected as a boolean mask (produced by the scheduler /
simulator), keeping shapes static for XLA.

The Pallas kernels in ``repro.kernels`` accelerate the two hot spots
(`lagrange_encode` GEMM and the fused degree-2 gradient); these jnp versions
are the oracles they are tested against.

Device-resident decode path
---------------------------
The seed rebuilt the decode matrix on the host every round
(``np.nonzero(on_time)`` -> ``decode_matrix``), forcing a host round-trip in
the middle of each training/serving step.  Two replacements:

  * :class:`DecodeCache` — a host-side memo keyed on the received chunk set.
    Worker states are discrete, so on-time patterns recur heavily across
    rounds; after warm-up a round's decode matrix is a dict hit instead of an
    O(K*^2 k) rebuild.  Used by the eager :func:`coded_matmul` /
    :func:`coded_linear_gradient` via their ``cache=`` argument.
  * :func:`coded_matmul_device` / :func:`coded_linear_gradient_device` — fully
    jittable: the received set is a static-shape masked gather
    (:func:`received_indices`) and the decode matrix is built on device by
    ``lagrange.decode_matrix_jax``, so round-over-round iteration compiles
    into one XLA computation with no host sync.  They return ``(out, ok)``
    instead of raising ``TimeoutError`` (jit cannot raise data-dependently);
    ``ok`` is False when fewer than K* results were on time and ``out`` is
    then meaningless.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .lagrange import (CodeSpec, decode_matrix, decode_matrix_jax, encode,
                       generator_matrix)


@dataclasses.dataclass
class CodedDataset:
    """Encoded dataset as stored across workers: chunk v lives on worker v//r."""

    spec: CodeSpec
    x_tilde: jnp.ndarray            # (nr, rows, cols)
    y_tilde: jnp.ndarray | None     # (nr, rows) or None

    @property
    def nr(self) -> int:
        return self.spec.nr


def encode_dataset(
    spec: CodeSpec,
    x_chunks: jnp.ndarray,
    y_chunks: jnp.ndarray | None = None,
    encode_fn=encode,
) -> CodedDataset:
    """Encode (k, rows, cols) data chunks (and optionally (k, rows) targets).

    ``encode_fn`` lets callers swap in the Pallas kernel
    (``repro.kernels.lagrange_encode.ops.encode``).
    """
    if x_chunks.shape[0] != spec.k:
        raise ValueError(f"expected {spec.k} chunks, got {x_chunks.shape[0]}")
    g = generator_matrix(spec, x_chunks.dtype)
    x_t = encode_fn(g, x_chunks)
    y_t = encode_fn(g, y_chunks) if y_chunks is not None else None
    return CodedDataset(spec=spec, x_tilde=x_t, y_tilde=y_t)


def received_indices(on_time: jnp.ndarray, kstar: int) -> jnp.ndarray:
    """Indices of the K* lexicographically-first on-time chunks (static shape).

    The master only needs *any* K* on-time results (Defn. 4.1); we take the
    first K* in chunk order.  Caller must guarantee >= K* are on time.
    Jittable (argsort-based masked gather, no data-dependent shapes).
    """
    order = jnp.argsort(~on_time, stable=True)  # on-time chunks first
    return order[:kstar]


# seed-era private name, kept for external callers
_first_kstar_mask = received_indices


class DecodeCache:
    """Host-side memo of decode matrices keyed on the received chunk set.

    On-time patterns recur across rounds (worker states are discrete), so the
    O(K*^2 k) decode-matrix build is paid once per distinct received set.
    Not thread-safe; one cache per CodedDataset/spec.
    """

    def __init__(self, spec: CodeSpec):
        self.spec = spec
        self._mats: dict[tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._mats)

    def matrix(self, received: np.ndarray, dtype=jnp.float32) -> jnp.ndarray:
        # dtype is part of the key: a hit must not hand back a matrix built
        # at a different precision than the caller's results
        key = (jnp.dtype(dtype).name, *(int(v) for v in received))
        mat = self._mats.get(key)
        if mat is None:
            self.misses += 1
            mat = decode_matrix(self.spec, received, dtype)
            self._mats[key] = mat
        else:
            self.hits += 1
        return mat

    def from_on_time(self, on_time: np.ndarray, dtype=jnp.float32):
        """(received indices, decode matrix) for the first-K* on-time chunks."""
        received = np.nonzero(np.asarray(on_time))[0][: self.spec.recovery_threshold]
        return received, self.matrix(received, dtype)


def coded_matmul(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray,
    cache: DecodeCache | None = None,
) -> jnp.ndarray:
    """Decode f(X_j) = X_j @ w from on-time encoded evaluations.

    ``on_time`` is a concrete (nr,) bool array from the scheduler (which chunk
    evaluations arrived before the deadline).  Returns (k, rows[, ...]).
    Pass a :class:`DecodeCache` to memoise the decode matrix across rounds;
    use :func:`coded_matmul_device` for the fully-jittable path.
    """
    spec = coded.spec
    on_time = np.asarray(on_time)
    if int(on_time.sum()) < spec.recovery_threshold:
        raise TimeoutError(
            f"round failed: {int(on_time.sum())} < K*={spec.recovery_threshold} on-time results"
        )
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)
    if cache is not None:
        received, d = cache.from_on_time(on_time, results.dtype)
    else:
        received = np.nonzero(on_time)[0][: spec.recovery_threshold]
        d = decode_matrix(spec, received, results.dtype)
    return jnp.tensordot(d, results[jnp.asarray(received)], axes=1)


@partial(jax.jit, static_argnames=("spec",))
def _decode_on_time(
    spec: CodeSpec, results: jnp.ndarray, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Device decode: (nr, *dims) results + (nr,) bool -> ((k, *dims), ok)."""
    kstar = spec.recovery_threshold
    received = received_indices(on_time, kstar)
    d = decode_matrix_jax(spec, received)
    gathered = jnp.take(results, received, axis=0)            # (K*, *dims)
    ok = jnp.sum(on_time) >= kstar
    return jnp.tensordot(d.astype(results.dtype), gathered, axes=1), ok


def coded_matmul_device(
    coded: CodedDataset, w: jnp.ndarray, on_time: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-jittable :func:`coded_matmul`: traced ``on_time``, no host sync.

    Returns ``(decoded, ok)``; ``decoded`` is meaningful only where ``ok``.
    """
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)
    return _decode_on_time(coded.spec, results, jnp.asarray(on_time))


def chunk_gradient(x_tilde_v: jnp.ndarray, y_tilde_v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-chunk degree-2 evaluation f(X̃,ỹ) = X̃ᵀ(X̃ w − ỹ) — worker-side op."""
    resid = x_tilde_v @ w - y_tilde_v
    return x_tilde_v.T @ resid


def coded_linear_gradient(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray, gradient_fn=None,
    cache: DecodeCache | None = None,
) -> jnp.ndarray:
    """Full least-squares gradient sum_j X_jᵀ(X_j w − y_j) via LCC (deg f = 2).

    ``gradient_fn(x_tilde, y_tilde, w) -> (nr, cols)`` defaults to a vmapped
    :func:`chunk_gradient`; the Pallas fused kernel slots in here.  Pass a
    :class:`DecodeCache` to memoise decode matrices across rounds; use
    :func:`coded_linear_gradient_device` for the fully-jittable path.
    """
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    on_time = np.asarray(on_time)
    if int(on_time.sum()) < spec.recovery_threshold:
        raise TimeoutError(
            f"round failed: {int(on_time.sum())} < K*={spec.recovery_threshold} on-time results"
        )
    if gradient_fn is None:
        gradient_fn = jax.vmap(chunk_gradient, in_axes=(0, 0, None))
    results = gradient_fn(coded.x_tilde, coded.y_tilde, w)       # (nr, cols)
    if cache is not None:
        received, d = cache.from_on_time(on_time, results.dtype)
    else:
        received = np.nonzero(on_time)[0][: spec.recovery_threshold]
        d = decode_matrix(spec, received, results.dtype)
    per_chunk = jnp.tensordot(d, results[jnp.asarray(received)], axes=1)  # (k, cols)
    return jnp.sum(per_chunk, axis=0)


def coded_linear_gradient_device(
    coded: CodedDataset, w: jnp.ndarray, on_time: jnp.ndarray, gradient_fn=None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-jittable :func:`coded_linear_gradient`: traced ``on_time``.

    Returns ``(gradient, ok)``; ``gradient`` is meaningful only where ``ok``.
    """
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    if gradient_fn is None:
        gradient_fn = jax.vmap(chunk_gradient, in_axes=(0, 0, None))
    results = gradient_fn(coded.x_tilde, coded.y_tilde, w)       # (nr, cols)
    per_chunk, ok = _decode_on_time(spec, results, jnp.asarray(on_time))
    return jnp.sum(per_chunk, axis=0), ok


def uncoded_linear_gradient(x_chunks: jnp.ndarray, y_chunks: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Oracle: sum_j X_jᵀ(X_j w − y_j) computed directly on the raw data."""
    grads = jax.vmap(chunk_gradient, in_axes=(0, 0, None))(x_chunks, y_chunks, w)
    return jnp.sum(grads, axis=0)
