"""Coded computation ops: encode-once, evaluate-per-round, decode-on-K*.

These are the ML-facing operations the paper's system executes each round:

  * :func:`coded_matmul`          — f(X_j) = X_j @ w           (deg f = 1)
  * :func:`coded_linear_gradient` — f(X_j,y_j) = X_jᵀ(X_j w−y) (deg f = 2)

Both follow the paper's protocol: the dataset is Lagrange-encoded once
(`encode_dataset`), each round every worker evaluates f on (a prefix of) its
r stored encoded chunks, and the master decodes from the K* fastest results.
On-time-ness is injected as a boolean mask (produced by the scheduler /
simulator), keeping shapes static for XLA.

The Pallas kernels in ``repro.kernels`` accelerate the two hot spots
(`lagrange_encode` GEMM and the fused degree-2 gradient); these jnp versions
are the oracles they are tested against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .lagrange import CodeSpec, decode_matrix, encode, generator_matrix


@dataclasses.dataclass
class CodedDataset:
    """Encoded dataset as stored across workers: chunk v lives on worker v//r."""

    spec: CodeSpec
    x_tilde: jnp.ndarray            # (nr, rows, cols)
    y_tilde: jnp.ndarray | None     # (nr, rows) or None

    @property
    def nr(self) -> int:
        return self.spec.nr


def encode_dataset(
    spec: CodeSpec,
    x_chunks: jnp.ndarray,
    y_chunks: jnp.ndarray | None = None,
    encode_fn=encode,
) -> CodedDataset:
    """Encode (k, rows, cols) data chunks (and optionally (k, rows) targets).

    ``encode_fn`` lets callers swap in the Pallas kernel
    (``repro.kernels.lagrange_encode.ops.encode``).
    """
    if x_chunks.shape[0] != spec.k:
        raise ValueError(f"expected {spec.k} chunks, got {x_chunks.shape[0]}")
    g = generator_matrix(spec, x_chunks.dtype)
    x_t = encode_fn(g, x_chunks)
    y_t = encode_fn(g, y_chunks) if y_chunks is not None else None
    return CodedDataset(spec=spec, x_tilde=x_t, y_tilde=y_t)


def _first_kstar_mask(on_time: jnp.ndarray, kstar: int) -> jnp.ndarray:
    """Indices of the K* lexicographically-first on-time chunks (static shape).

    The master only needs *any* K* on-time results (Defn. 4.1); we take the
    first K* in chunk order.  Caller must guarantee >= K* are on time.
    """
    order = jnp.argsort(~on_time, stable=True)  # on-time chunks first
    return order[:kstar]


def coded_matmul(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray
) -> jnp.ndarray:
    """Decode f(X_j) = X_j @ w from on-time encoded evaluations.

    ``on_time`` is a concrete (nr,) bool array from the scheduler (which chunk
    evaluations arrived before the deadline).  Returns (k, rows[, ...]).
    """
    spec = coded.spec
    on_time = np.asarray(on_time)
    if int(on_time.sum()) < spec.recovery_threshold:
        raise TimeoutError(
            f"round failed: {int(on_time.sum())} < K*={spec.recovery_threshold} on-time results"
        )
    results = jnp.einsum("vrc,c...->vr...", coded.x_tilde, w)
    received = np.nonzero(on_time)[0][: spec.recovery_threshold]
    d = decode_matrix(spec, received, results.dtype)
    return jnp.tensordot(d, results[jnp.asarray(received)], axes=1)


def chunk_gradient(x_tilde_v: jnp.ndarray, y_tilde_v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-chunk degree-2 evaluation f(X̃,ỹ) = X̃ᵀ(X̃ w − ỹ) — worker-side op."""
    resid = x_tilde_v @ w - y_tilde_v
    return x_tilde_v.T @ resid


def coded_linear_gradient(
    coded: CodedDataset, w: jnp.ndarray, on_time: np.ndarray, gradient_fn=None
) -> jnp.ndarray:
    """Full least-squares gradient sum_j X_jᵀ(X_j w − y_j) via LCC (deg f = 2).

    ``gradient_fn(x_tilde, y_tilde, w) -> (nr, cols)`` defaults to a vmapped
    :func:`chunk_gradient`; the Pallas fused kernel slots in here.
    """
    spec = coded.spec
    if coded.y_tilde is None:
        raise ValueError("dataset was encoded without targets")
    if spec.deg_f != 2:
        raise ValueError("linear-model gradient is a degree-2 polynomial; spec.deg_f must be 2")
    on_time = np.asarray(on_time)
    if int(on_time.sum()) < spec.recovery_threshold:
        raise TimeoutError(
            f"round failed: {int(on_time.sum())} < K*={spec.recovery_threshold} on-time results"
        )
    if gradient_fn is None:
        gradient_fn = jax.vmap(chunk_gradient, in_axes=(0, 0, None))
    results = gradient_fn(coded.x_tilde, coded.y_tilde, w)       # (nr, cols)
    received = np.nonzero(on_time)[0][: spec.recovery_threshold]
    d = decode_matrix(spec, received, results.dtype)
    per_chunk = jnp.tensordot(d, results[jnp.asarray(received)], axes=1)  # (k, cols)
    return jnp.sum(per_chunk, axis=0)


def uncoded_linear_gradient(x_chunks: jnp.ndarray, y_chunks: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Oracle: sum_j X_jᵀ(X_j w − y_j) computed directly on the raw data."""
    grads = jax.vmap(chunk_gradient, in_axes=(0, 0, None))(x_chunks, y_chunks, w)
    return jnp.sum(grads, axis=0)
