"""Two-state Markov worker-speed model (Sec. 2.2 of the paper).

State convention throughout the codebase: ``1 = good``, ``0 = bad``.
Each worker i has transition probs ``p_gg[i] = P[good -> good]`` and
``p_bb[i] = P[bad -> bad]``; chains are mutually independent and initialized
from their stationary distribution (as in the paper).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def stationary_good_prob(p_gg: jnp.ndarray, p_bb: jnp.ndarray) -> jnp.ndarray:
    """pi_g = (1 - p_bb) / (2 - p_gg - p_bb) for an irreducible 2-state chain."""
    return (1.0 - p_bb) / (2.0 - p_gg - p_bb)


def initial_states(key: jax.Array, p_gg: jnp.ndarray, p_bb: jnp.ndarray) -> jnp.ndarray:
    """Sample worker states (n,) int32 from the stationary distribution."""
    pi_g = stationary_good_prob(p_gg, p_bb)
    return (jax.random.uniform(key, p_gg.shape) < pi_g).astype(jnp.int32)


def step_states(
    key: jax.Array, states: jnp.ndarray, p_gg: jnp.ndarray, p_bb: jnp.ndarray
) -> jnp.ndarray:
    """One Markov transition for all n workers."""
    u = jax.random.uniform(key, states.shape)
    stay_good = u < p_gg
    leave_bad = u < (1.0 - p_bb)
    return jnp.where(states == 1, stay_good, leave_bad).astype(jnp.int32)


@partial(jax.jit, static_argnames=("rounds",))
def sample_trajectory(
    key: jax.Array, p_gg: jnp.ndarray, p_bb: jnp.ndarray, rounds: int
) -> jnp.ndarray:
    """(rounds, n) int32 state trajectory, initial state from stationary dist."""
    k0, k1 = jax.random.split(key)
    s0 = initial_states(k0, p_gg, p_bb)

    def body(carry, k):
        s = step_states(k, carry, p_gg, p_bb)
        return s, s

    keys = jax.random.split(k1, rounds - 1)
    _, tail = jax.lax.scan(body, s0, keys)
    return jnp.concatenate([s0[None], tail], axis=0)


def speeds_from_states(states: jnp.ndarray, mu_g: float, mu_b: float) -> jnp.ndarray:
    """Map 0/1 states to evaluations-per-second speeds."""
    return jnp.where(states == 1, mu_g, mu_b)


def t_step_transitions(p_gg, p_bb, t: int):
    """Effective (p_gg, p_bb) of the t-step chain: P^t in closed form.

    For a 2-state chain with eigenvalue lam = p_gg + p_bb - 1,
    ``P^t[g,g] = pi_g + (1 - pi_g) lam^t`` (and symmetrically for b).  Used by
    the Fig. 4 EC2 replay: applying ``t`` Markov transitions between requests
    is equivalent to one transition of the t-step chain, which lets the
    arrival-gap simulation run on the batched one-transition-per-round engine.
    """
    p_gg = jnp.asarray(p_gg, jnp.float32)
    p_bb = jnp.asarray(p_bb, jnp.float32)
    lam = p_gg + p_bb - 1.0
    pi_g = stationary_good_prob(p_gg, p_bb)
    lam_t = lam ** t
    return pi_g + (1.0 - pi_g) * lam_t, (1.0 - pi_g) + pi_g * lam_t
