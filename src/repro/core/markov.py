"""Two-state Markov worker-speed model (Sec. 2.2 of the paper).

State convention throughout the codebase: ``1 = good``, ``0 = bad``.
Each worker i has transition probs ``p_gg[i] = P[good -> good]`` and
``p_bb[i] = P[bad -> bad]``; chains are mutually independent and initialized
from their stationary distribution (as in the paper).

Non-stationary chains (beyond the paper): the trajectory samplers also
accept ``p_gg``/``p_bb`` of shape (rounds, n) — row t governs the
transition INTO round t (t >= 1) and row 0 the initial distribution.  The
associative-scan sampler composes per-round transition maps anyway, so a
time-varying chain is the same parallel prefix with per-row thresholds;
stationary (n,) inputs take the exact original code path, bit-for-bit.

Mask-padded pools (the shape-polymorphic engine): the samplers accept an
optional ``worker_mask`` (n,) bool.  Masked (padding) workers are FROZEN —
pinned to the good state every round — so a padded pool is simulated at its
padded width with deterministic, inert extras.  The mask does not change
the PRNG geometry: draws are shaped (n,) over the padded width, exactly as
an unpadded width-n pool draws (``worker_mask=None`` and an all-True mask
are value-identical; a row padded from a NARROWER pool keeps the padded
width's stream — pool width has always been part of the stream geometry).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def stationary_good_prob(p_gg: jnp.ndarray, p_bb: jnp.ndarray) -> jnp.ndarray:
    """pi_g = (1 - p_bb) / (2 - p_gg - p_bb) for an irreducible 2-state chain."""
    return (1.0 - p_bb) / (2.0 - p_gg - p_bb)


def initial_states(
    key: jax.Array,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    worker_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sample worker states (n,) int32 from the stationary distribution.

    A (rounds, n) schedule initializes from its round-0 chain.  Masked
    workers (``worker_mask`` False) are pinned to the good state.
    """
    if p_gg.ndim == 2:
        p_gg, p_bb = p_gg[0], p_bb[0]
    pi_g = stationary_good_prob(p_gg, p_bb)
    s0 = (jax.random.uniform(key, p_gg.shape) < pi_g).astype(jnp.int32)
    if worker_mask is None:
        return s0
    return jnp.where(worker_mask, s0, 1)


def step_states(
    key: jax.Array, states: jnp.ndarray, p_gg: jnp.ndarray, p_bb: jnp.ndarray
) -> jnp.ndarray:
    """One Markov transition for all n workers."""
    u = jax.random.uniform(key, states.shape)
    stay_good = u < p_gg
    leave_bad = u < (1.0 - p_bb)
    return jnp.where(states == 1, stay_good, leave_bad).astype(jnp.int32)


@partial(jax.jit, static_argnames=("rounds",))
def sample_trajectory_scan(
    key: jax.Array,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    worker_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sequential reference: (rounds, n) trajectory via ``lax.scan``.

    Kept as the oracle for :func:`sample_trajectory` (the associative-scan
    path), which must reproduce it bit-for-bit.  Accepts a (rounds, n)
    time-varying schedule like the parallel sampler, and an optional
    ``worker_mask`` freezing masked workers in the good state.
    """
    k0, k1 = jax.random.split(key)
    s0 = initial_states(k0, p_gg, p_bb)
    keys = jax.random.split(k1, rounds - 1)

    if p_gg.ndim == 2:
        def body_tv(carry, xs):
            k, pg, pb = xs
            s = step_states(k, carry, pg, pb)
            return s, s

        _, tail = jax.lax.scan(body_tv, s0, (keys, p_gg[1:], p_bb[1:]))
        traj = jnp.concatenate([s0[None], tail], axis=0)
    else:
        def body(carry, k):
            s = step_states(k, carry, p_gg, p_bb)
            return s, s

        _, tail = jax.lax.scan(body, s0, keys)
        traj = jnp.concatenate([s0[None], tail], axis=0)
    if worker_mask is None:
        return traj
    return jnp.where(worker_mask, traj, 1)


@partial(jax.jit, static_argnames=("rounds",))
def sample_trajectory(
    key: jax.Array,
    p_gg: jnp.ndarray,
    p_bb: jnp.ndarray,
    rounds: int,
    worker_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """(rounds, n) int32 state trajectory, initial state from stationary dist.

    ``worker_mask`` (n,) bool freezes masked workers in the good state
    (``None`` and an all-True mask are value-identical; the mask never
    changes the PRNG draw geometry — see the module docstring).

    Parallel-prefix formulation: round t's transition is a map {0,1} -> {0,1}
    fully determined by its uniform draw ``u_t`` —

        f_t(s) = [u_t < p_gg]  if s == 1  else  [u_t < 1 - p_bb]

    i.e. the pair ``(to1_if_bad, to1_if_good) = ([u_t < 1-p_bb], [u_t < p_gg])``
    (exactly :func:`step_states` on both possible inputs).  Function
    composition of such maps is associative, so the prefix compositions
    ``f_t ∘ ... ∘ f_1`` come from one ``lax.associative_scan`` (O(log M)
    depth instead of the M-step scan — the last sequential computation in the
    batched Monte-Carlo engine).  Applying prefix t to the stationary draw s0
    gives state t.  Every round consumes the same per-key uniform draw and the
    composition is pure boolean selection, so trajectories are bit-identical
    to :func:`sample_trajectory_scan` on the same key.
    """
    k0, k1 = jax.random.split(key)
    s0 = initial_states(k0, p_gg, p_bb)
    if rounds == 1:
        traj = s0[None]
        return traj if worker_mask is None else jnp.where(worker_mask, traj, 1)

    # per-step thresholds: a (rounds, n) schedule contributes rows 1..M-1
    # (row t is the chain in force for the transition into round t); the
    # stationary (n,) case broadcasts one row over all steps as before.
    n_shape = p_gg.shape[-1:]
    p_step_gg = p_gg[1:] if p_gg.ndim == 2 else p_gg
    p_step_bb = p_bb[1:] if p_bb.ndim == 2 else p_bb
    keys = jax.random.split(k1, rounds - 1)
    u = jax.vmap(lambda k: jax.random.uniform(k, n_shape))(keys)  # (M-1, n)
    # f_t as a value table: out1[t] = f_t(good), out0[t] = f_t(bad)
    out1 = (u < p_step_gg).astype(jnp.int32)
    out0 = (u < (1.0 - p_step_bb)).astype(jnp.int32)

    pref0, pref1 = jax.lax.associative_scan(_compose_maps, (out0, out1), axis=0)
    tail = jnp.where(s0[None] == 1, pref1, pref0)
    traj = jnp.concatenate([s0[None], tail], axis=0)
    if worker_mask is None:
        return traj
    return jnp.where(worker_mask, traj, 1)


def _compose_maps(f, g):
    """(g ∘ f) for {0,1} -> {0,1} maps as (f(0), f(1)) value tables."""
    f0, f1 = f
    g0, g1 = g
    return (jnp.where(f0 == 1, g1, g0), jnp.where(f1 == 1, g1, g0))


@partial(jax.jit, static_argnames=("rounds",))
def sample_trajectory_from(
    key: jax.Array,
    p_stay1: jnp.ndarray,
    p_stay0: jnp.ndarray,
    rounds: int,
    init: jnp.ndarray,
) -> jnp.ndarray:
    """(rounds, n) trajectory of a 2-state chain from an EXPLICIT initial state.

    The fault-process twin of :func:`sample_trajectory`: ``init`` (n,) int32
    IS round 0 (no stationary draw — a fleet starts alive, a channel starts
    clear), and ``p_stay1``/``p_stay0`` are the stay probabilities
    P[1 -> 1] / P[0 -> 0], broadcastable against ``init``.  Same
    parallel-prefix composition as :func:`sample_trajectory` (per-round
    transition maps composed with ``lax.associative_scan``), so it is
    equally batched-engine-friendly; the whole key feeds the transition
    draws (there is no initial-state draw to split it with).
    """
    init = jnp.asarray(init, jnp.int32)
    if rounds == 1:
        return init[None]
    keys = jax.random.split(key, rounds - 1)
    u = jax.vmap(lambda k: jax.random.uniform(k, init.shape))(keys)
    out1 = (u < p_stay1).astype(jnp.int32)
    out0 = (u < (1.0 - p_stay0)).astype(jnp.int32)
    pref0, pref1 = jax.lax.associative_scan(_compose_maps, (out0, out1), axis=0)
    tail = jnp.where(init[None] == 1, pref1, pref0)
    return jnp.concatenate([init[None], tail], axis=0)


def speeds_from_states(states: jnp.ndarray, mu_g: float, mu_b: float) -> jnp.ndarray:
    """Map 0/1 states to evaluations-per-second speeds."""
    return jnp.where(states == 1, mu_g, mu_b)


def t_step_transitions(p_gg, p_bb, t: int):
    """Effective (p_gg, p_bb) of the t-step chain: P^t in closed form.

    For a 2-state chain with eigenvalue lam = p_gg + p_bb - 1,
    ``P^t[g,g] = pi_g + (1 - pi_g) lam^t`` (and symmetrically for b).  Used by
    the Fig. 4 EC2 replay: applying ``t`` Markov transitions between requests
    is equivalent to one transition of the t-step chain, which lets the
    arrival-gap simulation run on the batched one-transition-per-round engine.
    """
    p_gg = jnp.asarray(p_gg, jnp.float32)
    p_bb = jnp.asarray(p_bb, jnp.float32)
    lam = p_gg + p_bb - 1.0
    pi_g = stationary_good_prob(p_gg, p_bb)
    lam_t = lam ** t
    return pi_g + (1.0 - pi_g) * lam_t, (1.0 - pi_g) + pi_g * lam_t
