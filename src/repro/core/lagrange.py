"""Lagrange Coded Computing (LCC) — the data-encoding layer of LEA.

Implements the coding scheme of Sec. 3.1 of the paper (following Yu et al. 2019):

* ``lagrange`` branch (``nr >= k*deg_f - 1``): the dataset ``X_1..X_k`` is
  interpolated by a degree-(k-1) polynomial ``u`` with ``u(beta_j) = X_j``; the
  encoded chunks are ``X~_v = u(alpha_v)``.  Because ``f`` is a polynomial of
  total degree ``deg_f``, ``h(z) = f(u(z))`` has degree ``(k-1)*deg_f`` and the
  master can interpolate ``h`` from any ``K* = (k-1)*deg_f + 1`` on-time worker
  results, then read off ``f(X_j) = h(beta_j)``.

* ``repetition`` branch (``nr < k*deg_f - 1``): every chunk is replicated
  ``floor(nr/k)`` or ``ceil(nr/k)`` times; ``K* = nr - floor(nr/k) + 1`` results
  always contain at least one copy of each chunk.  (This branch is valid for
  *arbitrary*, non-polynomial ``f`` — it is what the LM-training coded-DP mode
  uses; see DESIGN.md §3/§6.)

Two numeric paths:
  * float32/float64 with Chebyshev interpolation nodes (conditioning-bounded)
    — used by the ML-facing ops and the Pallas kernels;
  * exact arithmetic over the prime field GF(p), p = 2^31 - 1 — mirroring the
    finite field F of the paper.  The numpy ``*_modp`` functions are the host
    oracle; the ``*_modp_device`` functions build the same matrices on device
    through :mod:`repro.kernels.gf` (Mersenne-31 matmul + batched Lagrange
    basis), bit-identically — residues are exact, so host and device agree
    to the last bit.  ``coded_ops.coded_matmul_exact`` runs the whole
    encode -> worker matmul -> erasure-aware decode round on device.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Mersenne prime 2^31 - 1.  Products of two residues fit in int64 and sums of
# up to ~4e9 residues fit in int64, so exact mod-p linear algebra is safe.
FIELD_P = (1 << 31) - 1


@dataclasses.dataclass(frozen=True)
class CodeSpec:
    """Static description of one coded-computing instance."""

    n: int        # number of workers
    r: int        # encoded chunks stored per worker
    k: int        # number of data chunks
    deg_f: int    # total degree of the polynomial f evaluated each round

    @property
    def nr(self) -> int:
        return self.n * self.r

    @property
    def mode(self) -> str:
        return "lagrange" if self.nr >= self.k * self.deg_f - 1 else "repetition"

    @property
    def recovery_threshold(self) -> int:
        """K*, eq. (15)/(16) of the paper."""
        if self.mode == "lagrange":
            return (self.k - 1) * self.deg_f + 1
        return self.nr - self.nr // self.k + 1

    def chunk_owner(self, v: int) -> int:
        """Worker that stores encoded chunk v (worker i holds [i*r, (i+1)*r))."""
        return v // self.r

    def worker_chunks(self, i: int) -> range:
        return range(i * self.r, (i + 1) * self.r)


def recovery_threshold(n: int, r: int, k: int, deg_f: int) -> int:
    return CodeSpec(n, r, k, deg_f).recovery_threshold


# ---------------------------------------------------------------------------
# Interpolation nodes (float path)
# ---------------------------------------------------------------------------

def beta_points_np(k: int) -> np.ndarray:
    """Chebyshev nodes of the first kind on [-1, 1] — well-conditioned betas."""
    j = np.arange(k)
    return np.cos(np.pi * (2 * j + 1) / (2 * k))


def alpha_points_np(nr: int) -> np.ndarray:
    """nr mutually-distinct evaluation points (Chebyshev grid of size nr)."""
    v = np.arange(nr)
    return np.cos(np.pi * (2 * v + 1) / (2 * nr))


def beta_points(k: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(beta_points_np(k).astype(np.float32), dtype=dtype)


def alpha_points(nr: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(alpha_points_np(nr).astype(np.float32), dtype=dtype)


def _lagrange_basis(eval_pts: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """Matrix M[e, j] = prod_{l != j} (eval_e - nodes_l) / (nodes_j - nodes_l).

    Computed in float64 regardless of the target dtype (the matrices are tiny —
    (nr, k) / (k, K*) — the data they multiply is what is large).
    """
    eval_pts = np.asarray(eval_pts, dtype=np.float64)
    nodes = np.asarray(nodes, dtype=np.float64)
    e = eval_pts[:, None, None]                    # (E,1,1)
    nj = nodes[None, :, None]                      # (1,J,1)
    nl = nodes[None, None, :]                      # (1,1,J)
    num = e - nl                                   # (E,J,J) broadcast of (e - n_l)
    den = nj - nl                                  # (1,J,J)
    J = nodes.shape[0]
    eye = np.eye(J, dtype=bool)[None]
    num = np.where(eye, 1.0, np.broadcast_to(num, (eval_pts.shape[0], J, J)))
    den = np.where(eye, 1.0, np.broadcast_to(den, (1, J, J)))
    return np.prod(num / den, axis=-1)             # (E, J)


def chunk_alpha_indices(spec: CodeSpec) -> np.ndarray:
    """Chunk v -> index into the alpha grid, STRIDED across workers.

    Worker i stores chunks [i*r, (i+1)*r) and always evaluates a *prefix* of
    them (two-level loads, Lemma 4.4).  Mapping worker i's j-th chunk to grid
    position j*n + i spreads any union of per-worker prefixes uniformly over
    the Chebyshev grid, keeping the real-valued decode well-conditioned.
    (Irrelevant over the paper's finite field F; essential for the float
    adaptation — DESIGN §9.)
    """
    v = np.arange(spec.nr)
    worker, j = v // spec.r, v % spec.r
    return j * spec.n + worker


def generator_matrix(spec: CodeSpec, dtype=jnp.float32) -> jnp.ndarray:
    """(nr, k) encoding matrix G with X~ = G @ X (rows = encoded chunks).

    Lagrange branch: G[v, j] = Lagrange basis at alpha_{idx(v)}.
    Repetition branch: 0/1 replication matrix, chunk v holds X_{v mod k}.
    """
    if spec.mode == "lagrange":
        alphas = alpha_points_np(spec.nr)[chunk_alpha_indices(spec)]
        g = _lagrange_basis(alphas, beta_points_np(spec.k))
        return jnp.asarray(g.astype(np.float32), dtype=dtype)
    g = np.zeros((spec.nr, spec.k))
    g[np.arange(spec.nr), np.arange(spec.nr) % spec.k] = 1.0
    return jnp.asarray(g, dtype=dtype)


@partial(jax.jit, static_argnames=())
def encode(generator: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """Encode stacked data chunks: (k, *dims) -> (nr, *dims)."""
    return jnp.tensordot(generator, data, axes=1)


def decode_matrix(
    spec: CodeSpec, received: Sequence[int] | np.ndarray, dtype=jnp.float32
) -> jnp.ndarray:
    """(k, K*) decode matrix D for a given set of received chunk indices.

    Lagrange branch: interpolate h(z)=f(u(z)) (degree (k-1)*deg_f) through the
    received alphas and evaluate at the betas:  f(X) = D @ f(X~)[received].
    Requires len(received) == K* and h-degree + 1 <= K*.

    Repetition branch: 0/1 selection of the first on-time copy of each chunk.
    """
    received = np.asarray(received, dtype=np.int64)
    kstar = spec.recovery_threshold
    if received.shape[0] != kstar:
        raise ValueError(f"need exactly K*={kstar} received indices, got {received.shape[0]}")
    if len(np.unique(received)) != kstar:
        raise ValueError("received chunk indices must be distinct")
    if spec.mode == "lagrange":
        alphas = alpha_points_np(spec.nr)[chunk_alpha_indices(spec)[received]]
        betas = beta_points_np(spec.k)
        return jnp.asarray(_lagrange_basis(betas, alphas).astype(np.float32), dtype=dtype)
    d = np.zeros((spec.k, kstar))
    src = received % spec.k
    for j in range(spec.k):
        hits = np.nonzero(src == j)[0]
        if hits.size == 0:
            raise ValueError(
                f"received set misses every copy of chunk {j} — violates K* guarantee"
            )
        d[j, hits[0]] = 1.0
    return jnp.asarray(d, dtype=dtype)


@partial(jax.jit, static_argnames=())
def decode(decode_mat: jnp.ndarray, results: jnp.ndarray) -> jnp.ndarray:
    """Decode: (k, K*) @ (K*, *dims) -> (k, *dims)."""
    return jnp.tensordot(decode_mat, results, axes=1)


# ---------------------------------------------------------------------------
# Device-resident decode path (traced received set — no host round-trip)
# ---------------------------------------------------------------------------

def _lagrange_basis_jax(eval_pts: jnp.ndarray, nodes: jnp.ndarray) -> jnp.ndarray:
    """Traced counterpart of :func:`_lagrange_basis`: M[e, j] =
    prod_{l != j} (eval_e - nodes_l) / (nodes_j - nodes_l).

    ``nodes`` may be a traced gather of alpha points (the received set), so
    this runs fully on device in float32 (the host path uses float64; the
    Chebyshev grids keep the products conditioned — DESIGN §9).
    """
    eval_pts = jnp.asarray(eval_pts, jnp.float32)
    nodes = jnp.asarray(nodes, jnp.float32)
    j = nodes.shape[0]
    e = eval_pts[:, None, None]                    # (E,1,1)
    nj = nodes[None, :, None]                      # (1,J,1)
    nl = nodes[None, None, :]                      # (1,1,J)
    eye = jnp.eye(j, dtype=bool)[None]
    num = jnp.where(eye, 1.0, e - nl)              # (E,J,J)
    den = jnp.where(eye, 1.0, nj - nl)
    return jnp.prod(num / den, axis=-1)            # (E, J)


def decode_matrix_jax(spec: CodeSpec, received: jnp.ndarray) -> jnp.ndarray:
    """(k, K*) decode matrix from a TRACED (K*,) received-index vector.

    Fully jittable (``spec`` is static): a static-shape gather picks the
    received alpha points and the Lagrange basis is evaluated on device —
    no ``np.nonzero`` / host construction per round.  Validity (distinct
    indices, repetition coverage) is the caller's contract, exactly the K*
    guarantee of Defn. 4.1; rows that would be unrecoverable come back as
    zeros rather than raising (jit cannot raise data-dependently).
    """
    received = jnp.asarray(received, jnp.int32)
    kstar = spec.recovery_threshold
    assert received.shape == (kstar,), (received.shape, kstar)
    if spec.mode == "lagrange":
        alpha_grid = jnp.asarray(
            alpha_points_np(spec.nr)[chunk_alpha_indices(spec)], jnp.float32
        )
        alphas = jnp.take(alpha_grid, received)    # (K*,) traced gather
        betas = jnp.asarray(beta_points_np(spec.k), jnp.float32)
        return _lagrange_basis_jax(betas, alphas)
    # repetition: select the first received copy of each chunk j (j = v mod k)
    src = received % spec.k                        # (K*,)
    pos = jnp.arange(kstar)
    hit = src[None, :] == jnp.arange(spec.k)[:, None]          # (k, K*)
    first = jnp.min(jnp.where(hit, pos[None, :], kstar), axis=1)  # (k,)
    return (pos[None, :] == first[:, None]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Exact GF(p) path (mirrors the paper's finite field F; used by property tests)
# ---------------------------------------------------------------------------

def _mod_inv(a: np.ndarray, p: int = FIELD_P) -> np.ndarray:
    """Vectorized modular inverse via Fermat: a^(p-2) mod p."""
    a = np.asarray(a, dtype=np.int64) % p
    result = np.ones_like(a)
    base = a.copy()
    e = p - 2
    while e:
        if e & 1:
            result = (result * base) % p
        base = (base * base) % p
        e >>= 1
    return result


def _lagrange_basis_modp(eval_pts: np.ndarray, nodes: np.ndarray, p: int = FIELD_P) -> np.ndarray:
    eval_pts = np.asarray(eval_pts, dtype=np.int64) % p
    nodes = np.asarray(nodes, dtype=np.int64) % p
    E, J = eval_pts.shape[0], nodes.shape[0]
    out = np.ones((E, J), dtype=np.int64)
    for l in range(J):
        num = (eval_pts[:, None] - nodes[l]) % p          # (E,1)
        den = (nodes[None, :] - nodes[l]) % p             # (1,J)
        num = np.broadcast_to(num, (E, J)).copy()
        den = np.broadcast_to(den, (E, J)).copy()
        skip = np.zeros((E, J), dtype=bool)
        skip[:, l] = True
        num[skip] = 1
        den[skip] = 1
        out = (out * ((num * _mod_inv(den, p)) % p)) % p
    return out


def generator_matrix_modp(spec: CodeSpec, p: int = FIELD_P) -> np.ndarray:
    """Exact (nr, k) generator over GF(p); alphas/betas = 0..nr-1 / nr..nr+k-1."""
    if spec.mode != "lagrange":
        return np.asarray(generator_matrix(spec, jnp.float64), dtype=np.int64)
    alphas = np.arange(spec.nr, dtype=np.int64)[chunk_alpha_indices(spec)]
    betas = np.arange(spec.nr, spec.nr + spec.k, dtype=np.int64)
    return _lagrange_basis_modp(alphas, betas, p)


def decode_matrix_modp(
    spec: CodeSpec, received: Sequence[int] | np.ndarray, p: int = FIELD_P
) -> np.ndarray:
    received = np.asarray(received, dtype=np.int64)
    if spec.mode != "lagrange":
        return np.asarray(decode_matrix(spec, received, jnp.float64), dtype=np.int64)
    alphas = np.arange(spec.nr, dtype=np.int64)[chunk_alpha_indices(spec)[received]]
    betas = np.arange(spec.nr, spec.nr + spec.k, dtype=np.int64)
    return _lagrange_basis_modp(betas, alphas, p)


def matmul_modp(a: np.ndarray, b: np.ndarray, p: int = FIELD_P) -> np.ndarray:
    """Exact (m, c) @ (c, *dims) mod p.  Products of residues stay < 2^63."""
    a = np.asarray(a, dtype=np.int64) % p
    b = np.asarray(b, dtype=np.int64) % p
    trailing = b.shape[1:]
    b2 = b.reshape(b.shape[0], -1)
    # per-term product mod p (each < p), then sum over the contraction axis
    # (< 2^32 terms each < 2^31 fits int64), then one final mod.
    terms = (a[:, :, None] * b2[None, :, :]) % p      # (m, c, flat)
    out = np.sum(terms, axis=1) % p
    return out.reshape((a.shape[0],) + trailing)


# ---------------------------------------------------------------------------
# Device-resident exact GF(p) path (repro.kernels.gf) — no host round-trip
# ---------------------------------------------------------------------------

def _gf():
    # local import: repro.kernels.gf is a leaf package, but keeping the core
    # import graph lazy mirrors the policies/throughput convention
    from repro.kernels import gf as gf_mod

    return gf_mod


def _alpha_grid_modp(spec: CodeSpec) -> np.ndarray:
    """The strided integer alpha grid of the exact path: chunk v -> idx(v)."""
    return np.arange(spec.nr, dtype=np.int32)[chunk_alpha_indices(spec)]


def generator_matrix_modp_device(spec: CodeSpec) -> jnp.ndarray:
    """Device-built exact (nr, k) generator over GF(p) — int32 residues.

    Bit-identical (as integers) to the numpy :func:`generator_matrix_modp`:
    same integer alphas/betas (0..nr-1 strided / nr..nr+k-1), same field —
    residues are exact, so the only difference is where the matrix lives.
    """
    gf = _gf()
    if spec.mode != "lagrange":
        return jnp.asarray(generator_matrix_modp(spec), jnp.int32)
    alphas = jnp.asarray(_alpha_grid_modp(spec))
    betas = jnp.arange(spec.nr, spec.nr + spec.k, dtype=jnp.int32)
    return gf.from_gf(gf.lagrange_basis_gf(alphas, betas))


def decode_matrix_modp_device(spec: CodeSpec, received: jnp.ndarray) -> jnp.ndarray:
    """Exact (..., k, K*) decode matrices from TRACED (..., K*) received rows.

    The erasure-pattern-aware device decode: a static-shape gather picks the
    surviving alpha points and the GF(p) Lagrange basis is inverted on
    device (Fermat), so erasure patterns straight from the engine's Markov
    trajectories decode with no host sync.  Leading axes batch over
    patterns (one call builds a whole trajectory's decode matrices).
    Validity (distinct indices, repetition coverage) is the caller's
    contract, exactly as for :func:`decode_matrix_jax`.
    """
    gf = _gf()
    received = jnp.asarray(received, jnp.int32)
    kstar = spec.recovery_threshold
    assert received.shape[-1] == kstar, (received.shape, kstar)
    if spec.mode == "lagrange":
        alpha_grid = jnp.asarray(_alpha_grid_modp(spec))
        alphas = jnp.take(alpha_grid, received)            # (..., K*) gather
        betas = jnp.arange(spec.nr, spec.nr + spec.k, dtype=jnp.int32)
        return gf.from_gf(gf.lagrange_basis_gf(betas, alphas))
    # repetition: 0/1 selection of the first received copy of each chunk
    src = received % spec.k                                # (..., K*)
    pos = jnp.arange(kstar)
    hit = src[..., None, :] == jnp.arange(spec.k)[:, None]           # (..., k, K*)
    first = jnp.min(jnp.where(hit, pos, kstar), axis=-1)             # (..., k)
    return (pos == first[..., None]).astype(jnp.int32)
