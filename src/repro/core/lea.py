"""Estimate-and-Allocate (EA) — the load-allocation half of LEA (Sec. 3.2).

The paper's 4 phases map to:
  (1) Load Assignment  -> :func:`allocate`    (linear search over i~, eq. 7/8)
  (2) Local Computation-> simulated in core/throughput.py / executed by
                          runtime/fault_tolerance.py
  (3) Aggregation/Obs. -> the caller passes observed worker states
  (4) Update           -> :func:`update_estimator`

Efficiency note (beyond the paper's pseudocode): the estimated success
probability (8) is a Poisson-binomial tail.  Instead of the exponential
sum over subsets G ⊆ [i~], we evaluate all n prefixes with one O(n^2)
dynamic program (convolving one Bernoulli at a time), so one allocation
costs O(n^2) total rather than O(2^n) — the linear search of the paper
then reads the tails off the DP table.

Batched-engine API: :func:`success_prob_all_prefixes` and :func:`allocate`
accept any leading batch axes — ``p_good`` of shape (..., n) yields loads of
shape (..., n) and ``i_star`` of shape (...,).  One batched call costs one
DP pass over the whole batch (the ``repro.kernels.poisson_binomial``
dispatcher picks the Pallas kernel on TPU and the batched ``lax.scan`` DP
elsewhere), which is what lets the throughput engine allocate for every
(scenario x seed x strategy) row of a Monte-Carlo sweep simultaneously.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EstimatorState(NamedTuple):
    """Per-worker transition counts + last observed state.

    counts[:, 0] = C_{g->g}, counts[:, 1] = C_{g->b},
    counts[:, 2] = C_{b->g}, counts[:, 3] = C_{b->b}.
    """

    counts: jnp.ndarray      # (n, 4) float32
    prev_state: jnp.ndarray  # (n,) int32, 1=good 0=bad
    seen_prev: jnp.ndarray   # () bool — False before the first observation


def init_estimator(n: int) -> EstimatorState:
    return EstimatorState(
        counts=jnp.zeros((n, 4), jnp.float32),
        prev_state=jnp.zeros((n,), jnp.int32),
        seen_prev=jnp.asarray(False),
    )


def transition_onehot(prev: jnp.ndarray, cur: jnp.ndarray) -> jnp.ndarray:
    """One-hot (g->g, g->b, b->g, b->b) transition indicators, (..., 4) f32.

    Shared by the sequential estimator update and the engine's vectorised
    cumsum replay (`throughput._lea_p_good_trajectory`) — they must stay the
    same expression for the replay to be bit-identical.
    """
    return jnp.stack(
        [
            (prev == 1) & (cur == 1),
            (prev == 1) & (cur == 0),
            (prev == 0) & (cur == 1),
            (prev == 0) & (cur == 0),
        ],
        axis=-1,
    ).astype(jnp.float32)


def smoothed_transitions(counts: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(p̂_gg, p̂_bb) from (..., 4) transition counts with add-one smoothing
    (paper leaves t=0 behaviour open; Laplace smoothing avoids 0/0 and washes
    out as counts grow).  Shared with the engine's vectorised replay."""
    p_gg = (counts[..., 0] + 1.0) / (counts[..., 0] + counts[..., 1] + 2.0)
    p_bb = (counts[..., 3] + 1.0) / (counts[..., 2] + counts[..., 3] + 2.0)
    return p_gg, p_bb


def update_estimator(state: EstimatorState, observed: jnp.ndarray) -> EstimatorState:
    """Phase (4): fold one round's observed states (n,) into the counts.

    The first observation only sets ``prev_state`` (no transition yet).
    """
    prev, cur = state.prev_state, observed.astype(jnp.int32)
    inc = transition_onehot(prev, cur)
    counts = jnp.where(state.seen_prev, state.counts + inc, state.counts)
    return EstimatorState(counts=counts, prev_state=cur, seen_prev=jnp.asarray(True))


def estimated_transitions(state: EstimatorState) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(p̂_gg, p̂_bb) of this estimator state (see :func:`smoothed_transitions`)."""
    return smoothed_transitions(state.counts)


def predicted_good_prob(state: EstimatorState) -> jnp.ndarray:
    """p̂_{g,i}(m+1): p̂_gg if last seen good, else 1 - p̂_bb (Phase 4)."""
    p_gg, p_bb = estimated_transitions(state)
    return jnp.where(state.prev_state == 1, p_gg, 1.0 - p_bb)


# ---------------------------------------------------------------------------
# Success probability + allocation (Phase 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoadParams:
    """Static load-allocation parameters for one deployment."""

    n: int
    kstar: int      # optimal recovery threshold K*
    ell_g: int      # min(mu_g * d, r)  — good-state load
    ell_b: int      # mu_b * d          — bad-state load (always finishes)

    def __post_init__(self):
        if self.ell_g <= self.ell_b:
            raise ValueError("ell_g must exceed ell_b (otherwise allocation is trivial)")


class PoolLoad(NamedTuple):
    """TRACED load-allocation parameters + worker-pool validity mask.

    The shape-polymorphic twin of :class:`LoadParams`: every leaf is a JAX
    array, so one compiled computation serves a whole batch of heterogeneous
    (K*, ell_g, ell_b, pool-size) rows.  ``mask`` is (..., n) bool over a
    pool padded to a common width n — ``False`` workers are padding: they
    receive no load, contribute nothing to the success count, and their
    probability entries are ignored by :func:`allocate_masked`.

    Leading axes of the scalar leaves broadcast against the probability
    batch exactly like the static parameters did.
    """

    kstar: jnp.ndarray   # (...,) int32
    ell_g: jnp.ndarray   # (...,) int32
    ell_b: jnp.ndarray   # (...,) int32
    mask: jnp.ndarray    # (..., n) bool — True = real worker

    @property
    def n(self) -> int:
        """The PADDED pool width (static — it is a shape)."""
        return self.mask.shape[-1]


def pool_load(lp: LoadParams, n: int | None = None) -> PoolLoad:
    """Lift a static :class:`LoadParams` to a (possibly padded) PoolLoad.

    ``n`` >= lp.n pads the pool; the first lp.n slots are the real workers.
    """
    n = lp.n if n is None else n
    if n < lp.n:
        raise ValueError(f"cannot pad {lp.n} workers into width {n}")
    return PoolLoad(
        kstar=jnp.asarray(lp.kstar, jnp.int32),
        ell_g=jnp.asarray(lp.ell_g, jnp.int32),
        ell_b=jnp.asarray(lp.ell_b, jnp.int32),
        mask=jnp.arange(n) < lp.n,
    )


def prefix_thresholds(lp: LoadParams) -> np.ndarray:
    """w(i~) = ceil((K* - (n - i~) * ell_b) / ell_g) for i~ = 1..n  (eq. 7/8).

    Values <= 0 mean "always enough", > i~ mean "impossible".  Concrete
    (numpy) because ``lp`` is static — the Pallas kernel bakes these in as
    trace-time constants.
    """
    i_tilde = np.arange(1, lp.n + 1)
    return np.ceil((lp.kstar - (lp.n - i_tilde) * lp.ell_b) / lp.ell_g).astype(np.int32)


def prefix_thresholds_traced(
    kstar: jnp.ndarray,
    ell_g: jnp.ndarray,
    ell_b: jnp.ndarray,
    n_valid: jnp.ndarray,
    n: int,
) -> jnp.ndarray:
    """TRACED w(i~) for i~ = 1..n over a pool of n_valid real workers.

    The same eq. 7/8 formula as :func:`prefix_thresholds` with the VALID
    pool size in place of the static n, evaluated in exact int32 arithmetic
    (``ceil(a/g) = -((-a) // g)``, so it equals the numpy float64 ``ceil``
    for every reachable magnitude).  Prefixes past the valid pool
    (``i~ > n_valid`` — they would have to include padded workers) are set
    to the infeasible sentinel n + 1 > i~, so the DP scores them exactly 0.

    All of ``kstar``/``ell_g``/``ell_b``/``n_valid`` may carry leading batch
    axes (broadcast against each other); the result gains a trailing (n,).
    """
    kstar = jnp.asarray(kstar, jnp.int32)[..., None]
    ell_g = jnp.asarray(ell_g, jnp.int32)[..., None]
    ell_b = jnp.asarray(ell_b, jnp.int32)[..., None]
    n_valid = jnp.asarray(n_valid, jnp.int32)[..., None]
    i_tilde = jnp.arange(1, n + 1, dtype=jnp.int32)
    num = kstar - (n_valid - i_tilde) * ell_b
    w = -((-num) // ell_g)                          # exact integer ceil-div
    return jnp.where(i_tilde > n_valid, jnp.int32(n + 1), w)


def success_prob_all_prefixes(
    p_good_sorted: jnp.ndarray,
    lp: "LoadParams | PoolLoad",
    *,
    impl: str | None = None,
) -> jnp.ndarray:
    """P̂(i~) for every i~ in 1..n, given p_good sorted descending along the
    last axis.  (..., n) in -> (..., n) out (any leading batch axes).

    P̂(i~) = P[ Binom-mixture(top i~) >= w(i~) ]  with w from
    :func:`prefix_thresholds`.  One O(n^2) DP over the whole batch, routed
    through ``repro.kernels.poisson_binomial`` (``impl``: "pallas" / "ref" /
    None = auto — Pallas on TPU, batched ``lax.scan`` DP elsewhere).

    ``lp`` may be a TRACED :class:`PoolLoad` instead of a static
    :class:`LoadParams`: the thresholds then come from
    :func:`prefix_thresholds_traced` (per-row K*/ell, prefixes past the
    valid pool infeasible) and one compiled DP serves every row.  The
    caller is responsible for having sorted padded entries to the tail with
    probability 0 (:func:`allocate_masked` does).
    """
    from repro.kernels.poisson_binomial import success_tails

    if isinstance(lp, PoolLoad):
        n = p_good_sorted.shape[-1]
        n_valid = jnp.sum(lp.mask.astype(jnp.int32), axis=-1)
        w = prefix_thresholds_traced(lp.kstar, lp.ell_g, lp.ell_b, n_valid, n)
        return success_tails(p_good_sorted, w, impl=impl)
    return success_tails(p_good_sorted, prefix_thresholds(lp), impl=impl)


# Above this worker count, unrolling the O(n^2) pairwise rank loop bloats the
# program; fall back to XLA sorts (the batch sizes that matter are small-n).
_PAIRWISE_RANK_MAX_N = 64


def _ranks_descending(p: jnp.ndarray) -> jnp.ndarray:
    """Stable descending ranks: identical to argsort(argsort(-p)) per row.

    rank_i = #{j : p_j > p_i} + #{j < i : p_j == p_i} — n unrolled passes of
    element-wise compares over the batch, which XLA CPU runs ~20x faster than
    two variadic sorts at the (rounds x batch) sizes the engine produces.
    """
    n = p.shape[-1]
    idx = jnp.arange(n)
    acc = jnp.zeros(p.shape, jnp.int32)
    for j in range(n):
        pj = p[..., j : j + 1]
        acc = acc + (pj > p) + ((pj == p) & (idx > j))
    return acc


def _take_by_rank(p: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """Values in rank order: out[..., r] = p at the row position with rank r.

    Exact one-hot gather (the sum has a single non-zero term per slot), so it
    equals take_along_axis with the descending argsort bit-for-bit.
    """
    n = p.shape[-1]
    return jnp.stack(
        [jnp.sum(jnp.where(ranks == r, p, 0.0), axis=-1) for r in range(n)], axis=-1
    )


def allocate(
    p_good: jnp.ndarray, lp: LoadParams, *, impl: str | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Phase (1): the LEA load assignment, batched over leading axes.

    ``p_good`` has shape (..., n).  Returns ``(loads, i_star)`` where
    ``loads`` is the (..., n) int32 allocation in the *original worker order*
    (per row, the i* workers with the largest p_good get ell_g, the rest
    ell_b — Lemma 4.5), and ``i_star`` (...,) the argmax of P̂ per row.
    """
    if lp.n <= _PAIRWISE_RANK_MAX_N:
        ranks = _ranks_descending(p_good)
        p_sorted = _take_by_rank(p_good, ranks)
    else:
        order = jnp.argsort(-p_good, axis=-1)                   # descending
        p_sorted = jnp.take_along_axis(p_good, order, axis=-1)
        ranks = jnp.argsort(order, axis=-1)                     # rank per worker
    probs = success_prob_all_prefixes(p_sorted, lp, impl=impl)  # (..., n)
    i_star = jnp.argmax(probs, axis=-1) + 1                     # in 1..n
    loads = jnp.where(ranks < i_star[..., None], lp.ell_g, lp.ell_b).astype(jnp.int32)
    return loads, i_star


def allocate_masked(
    p_good: jnp.ndarray, pool: PoolLoad, *, impl: str | None = None
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shape-polymorphic LEA load assignment over a mask-padded pool.

    The traced twin of :func:`allocate`: ``pool`` carries per-row TRACED
    (K*, ell_g, ell_b) and a (..., n) validity mask, so ONE compiled call
    serves heterogeneous thresholds and pool sizes.  Masked (padding)
    workers are demoted below every real probability before the rank
    elimination, contribute an identity term (p = 0) to the prefix DP, and
    receive load 0 in the output.

    Returns ``(loads, i_star, feasible)``:

      * ``loads`` (..., n) int32 — the two-level assignment in original
        worker order; 0 at masked slots;
      * ``i_star`` (...,) — argmax prefix (1-based, over valid prefixes);
      * ``feasible`` — False where NO prefix of the valid pool can reach K*
        (``kstar > n_valid * ell_g``): such rows can never succeed and the
        flag makes the failure explicit rather than implicit in the scoring
        (an all-masked row is the degenerate case).  The flag broadcasts
        over the probability batch axes.

    On a full-width pool (all-True mask) every masking construct is a
    value-preserving select, so ``loads``/``i_star`` are bit-identical to
    :func:`allocate` with the equivalent static :class:`LoadParams`
    whenever both route through the ``ref`` DP — the CPU/GPU default, and
    the code path the property tests pin.  On TPU the two paths lower to
    different Pallas kernels (baked vs traced thresholds), which agree to
    float32 round-off only (see ``poisson_binomial.kernel``); an argmax
    within an ulp of a tie may then allocate differently.
    """
    mask = pool.mask
    n = p_good.shape[-1]
    if mask.shape[-1] != n:
        raise ValueError(f"mask width {mask.shape[-1]} != pool width {n}")
    n_valid = jnp.sum(mask.astype(jnp.int32), axis=-1)          # (...,)
    # demote padding below any real probability (p_good lives in [0, 1])
    p_eff = jnp.where(mask, p_good, -1.0)
    if n <= _PAIRWISE_RANK_MAX_N:
        ranks = _ranks_descending(p_eff)
        p_sorted = _take_by_rank(p_eff, ranks)
    else:
        order = jnp.argsort(-p_eff, axis=-1)                    # descending
        p_sorted = jnp.take_along_axis(p_eff, order, axis=-1)
        ranks = jnp.argsort(order, axis=-1)                     # rank per worker
    # padding sorted to the tail: replace its sentinel with the identity
    # Bernoulli p = 0 so the DP's pmf is untouched past the valid prefix
    pos = jnp.arange(n)
    p_dp = jnp.where(pos < n_valid[..., None], p_sorted, 0.0)
    w = prefix_thresholds_traced(
        pool.kstar, pool.ell_g, pool.ell_b, n_valid, n
    )                                                           # (..., n)
    from repro.kernels.poisson_binomial import success_tails

    probs = success_tails(p_dp, w, impl=impl)                   # (..., n)
    i_star = jnp.argmax(probs, axis=-1) + 1                     # in 1..n
    i_tilde = pos + 1
    feasible = jnp.any((w <= i_tilde) & (i_tilde <= n_valid[..., None]), axis=-1)
    loads = jnp.where(
        ranks < i_star[..., None], pool.ell_g[..., None], pool.ell_b[..., None]
    )
    loads = jnp.where(mask, loads, 0).astype(jnp.int32)
    return loads, i_star, jnp.broadcast_to(feasible, i_star.shape)


def allocate_queue(
    p_good: jnp.ndarray,
    pool_mask: jnp.ndarray,
    active: jnp.ndarray,
    kstar: jnp.ndarray,
    ell_g: jnp.ndarray,
    ell_b: jnp.ndarray,
    order: jnp.ndarray,
    *,
    impl: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Split ONE worker pool across the active slots of a request queue.

    The multi-job extension of :func:`allocate_masked` (repro.serving):
    greedy EDF water-filling over the pool's descending-p_good ranks.  Each
    active slot j has its own traced (kstar, ell_g, ell_b); ``order`` is a
    (Q,) slot permutation in priority (EDF) order.  Walking slots in that
    order, slot j is handed a contiguous SEGMENT of the rank-sorted pool:
    at least its minimal feasible worker count ``m_j = ceil(kstar_j /
    ell_g_j)``, plus every worker not reserved by the minimal demands of
    the lower-priority slots behind it — so the most urgent slot absorbs
    all surplus redundancy and each segment then gets its own
    :func:`allocate_masked` two-level assignment (ONE batched DP over the
    Q segments).

    Args:
      p_good: (n,) predicted good probabilities (raw, not demoted).
      pool_mask: (n,) bool — True = real worker (padding excluded).
      active: (Q,) bool — which queue slots hold a live request.
      kstar/ell_g/ell_b: (Q,) int32 per-slot traced load parameters.
      order: (Q,) int32 permutation of slots, highest priority first.
        Inactive slots may appear anywhere (they demand and receive
        nothing).

    Returns ``(loads, i_star, feasible)``, all in ORIGINAL slot order:

      * ``loads`` (Q, n) int32 — per-slot worker assignment; segments are
        disjoint, zero outside a slot's segment and for inactive slots;
      * ``i_star`` (Q,) — each segment's argmax prefix (1-based);
      * ``feasible`` (Q,) bool — False where a slot's segment cannot reach
        its kstar (``kstar > segment_size * ell_g``: the pool is
        oversubscribed and the shortfall is EXPLICIT, never silent).
        Inactive slots read False (their empty segment is the degenerate
        all-masked row).

    With ONE active slot the segment is the entire valid pool, so the
    result is bit-identical to :func:`allocate_masked` on the full pool —
    the degenerate case that reduces the serving engine to the single-job
    engine.
    """
    n = p_good.shape[-1]
    q = active.shape[-1]
    # worker ranks over the FULL pool, exactly allocate_masked's demotion
    p_eff = jnp.where(pool_mask, p_good, -1.0)
    if n <= _PAIRWISE_RANK_MAX_N:
        ranks = _ranks_descending(p_eff)
    else:
        ranks = jnp.argsort(jnp.argsort(-p_eff, axis=-1), axis=-1)
    n_valid = jnp.sum(pool_mask.astype(jnp.int32), axis=-1)

    # per-slot quantities in priority order
    act_e = jnp.take(active, order)
    ks_e = jnp.take(kstar, order).astype(jnp.int32)
    eg_e = jnp.take(ell_g, order).astype(jnp.int32)
    eb_e = jnp.take(ell_b, order).astype(jnp.int32)
    m_e = jnp.where(act_e, -((-ks_e) // jnp.maximum(eg_e, 1)), 0)  # ceil-div
    # minimal demand of the slots BEHIND priority position j
    reserve_after = jnp.flip(jnp.cumsum(jnp.flip(m_e))) - m_e

    starts, sizes = [], []
    remaining = n_valid
    for j in range(q):
        want = jnp.maximum(m_e[j], remaining - reserve_after[j])
        size = jnp.where(act_e[j], jnp.clip(want, 0, remaining), 0)
        starts.append(n_valid - remaining)
        sizes.append(size)
        remaining = remaining - size
    starts_e = jnp.stack(starts)                                   # (Q,)
    sizes_e = jnp.stack(sizes)

    seg = (
        (ranks[None, :] >= starts_e[:, None])
        & (ranks[None, :] < (starts_e + sizes_e)[:, None])
        & pool_mask[None, :]
        & act_e[:, None]
    )                                                              # (Q, n)
    loads_e, i_star_e, feas_e = allocate_masked(
        jnp.broadcast_to(p_good, (q, n)),
        PoolLoad(kstar=ks_e, ell_g=eg_e, ell_b=eb_e, mask=seg),
        impl=impl,
    )
    inv = jnp.argsort(order)                                       # unpermute
    return (
        jnp.take(loads_e, inv, axis=0),
        jnp.take(i_star_e, inv),
        jnp.take(feas_e, inv),
    )


def success_prob_bruteforce(p_good_sorted: jnp.ndarray, lp: LoadParams, i_tilde: int) -> float:
    """Reference implementation of eq. (8) by exponential enumeration (tests)."""
    import itertools

    import numpy as np

    p = np.asarray(p_good_sorted)[:i_tilde]
    w = math_ceil((lp.kstar - (lp.n - i_tilde) * lp.ell_b) / lp.ell_g)
    if w > i_tilde:
        return 0.0
    total = 0.0
    for mask in itertools.product([0, 1], repeat=i_tilde):
        if sum(mask) >= max(w, 0):
            prob = 1.0
            for i, m in enumerate(mask):
                prob *= p[i] if m else (1.0 - p[i])
            total += prob
    return float(total)


def math_ceil(x: float) -> int:
    import math

    return int(math.ceil(x))


def round_success(loads: jnp.ndarray, states: jnp.ndarray, lp: LoadParams,
                  mu_g: float, mu_b: float, deadline: float) -> jnp.ndarray:
    """Did the master receive >= K* evaluations by the deadline?

    Worker i returns all ``loads[i]`` results iff loads[i]/speed_i <= d
    (speeds are deterministic given the state — Sec. 2.2).
    """
    speeds = jnp.where(states == 1, mu_g, mu_b)
    on_time = loads.astype(jnp.float32) / speeds <= deadline + 1e-9
    received = jnp.sum(jnp.where(on_time, loads, 0))
    return received >= lp.kstar
