"""Estimate-and-Allocate (EA) — the load-allocation half of LEA (Sec. 3.2).

The paper's 4 phases map to:
  (1) Load Assignment  -> :func:`allocate`    (linear search over i~, eq. 7/8)
  (2) Local Computation-> simulated in core/throughput.py / executed by
                          runtime/fault_tolerance.py
  (3) Aggregation/Obs. -> the caller passes observed worker states
  (4) Update           -> :func:`update_estimator`

Efficiency note (beyond the paper's pseudocode): the estimated success
probability (8) is a Poisson-binomial tail.  Instead of the exponential
sum over subsets G ⊆ [i~], we evaluate all n prefixes with one O(n^2)
dynamic program (convolving one Bernoulli at a time), so one allocation
costs O(n^2) total rather than O(2^n) — the linear search of the paper
then reads the tails off the DP table.

Batched-engine API: :func:`success_prob_all_prefixes` and :func:`allocate`
accept any leading batch axes — ``p_good`` of shape (..., n) yields loads of
shape (..., n) and ``i_star`` of shape (...,).  One batched call costs one
DP pass over the whole batch (the ``repro.kernels.poisson_binomial``
dispatcher picks the Pallas kernel on TPU and the batched ``lax.scan`` DP
elsewhere), which is what lets the throughput engine allocate for every
(scenario x seed x strategy) row of a Monte-Carlo sweep simultaneously.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EstimatorState(NamedTuple):
    """Per-worker transition counts + last observed state.

    counts[:, 0] = C_{g->g}, counts[:, 1] = C_{g->b},
    counts[:, 2] = C_{b->g}, counts[:, 3] = C_{b->b}.
    """

    counts: jnp.ndarray      # (n, 4) float32
    prev_state: jnp.ndarray  # (n,) int32, 1=good 0=bad
    seen_prev: jnp.ndarray   # () bool — False before the first observation


def init_estimator(n: int) -> EstimatorState:
    return EstimatorState(
        counts=jnp.zeros((n, 4), jnp.float32),
        prev_state=jnp.zeros((n,), jnp.int32),
        seen_prev=jnp.asarray(False),
    )


def transition_onehot(prev: jnp.ndarray, cur: jnp.ndarray) -> jnp.ndarray:
    """One-hot (g->g, g->b, b->g, b->b) transition indicators, (..., 4) f32.

    Shared by the sequential estimator update and the engine's vectorised
    cumsum replay (`throughput._lea_p_good_trajectory`) — they must stay the
    same expression for the replay to be bit-identical.
    """
    return jnp.stack(
        [
            (prev == 1) & (cur == 1),
            (prev == 1) & (cur == 0),
            (prev == 0) & (cur == 1),
            (prev == 0) & (cur == 0),
        ],
        axis=-1,
    ).astype(jnp.float32)


def smoothed_transitions(counts: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(p̂_gg, p̂_bb) from (..., 4) transition counts with add-one smoothing
    (paper leaves t=0 behaviour open; Laplace smoothing avoids 0/0 and washes
    out as counts grow).  Shared with the engine's vectorised replay."""
    p_gg = (counts[..., 0] + 1.0) / (counts[..., 0] + counts[..., 1] + 2.0)
    p_bb = (counts[..., 3] + 1.0) / (counts[..., 2] + counts[..., 3] + 2.0)
    return p_gg, p_bb


def update_estimator(state: EstimatorState, observed: jnp.ndarray) -> EstimatorState:
    """Phase (4): fold one round's observed states (n,) into the counts.

    The first observation only sets ``prev_state`` (no transition yet).
    """
    prev, cur = state.prev_state, observed.astype(jnp.int32)
    inc = transition_onehot(prev, cur)
    counts = jnp.where(state.seen_prev, state.counts + inc, state.counts)
    return EstimatorState(counts=counts, prev_state=cur, seen_prev=jnp.asarray(True))


def estimated_transitions(state: EstimatorState) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(p̂_gg, p̂_bb) of this estimator state (see :func:`smoothed_transitions`)."""
    return smoothed_transitions(state.counts)


def predicted_good_prob(state: EstimatorState) -> jnp.ndarray:
    """p̂_{g,i}(m+1): p̂_gg if last seen good, else 1 - p̂_bb (Phase 4)."""
    p_gg, p_bb = estimated_transitions(state)
    return jnp.where(state.prev_state == 1, p_gg, 1.0 - p_bb)


# ---------------------------------------------------------------------------
# Success probability + allocation (Phase 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoadParams:
    """Static load-allocation parameters for one deployment."""

    n: int
    kstar: int      # optimal recovery threshold K*
    ell_g: int      # min(mu_g * d, r)  — good-state load
    ell_b: int      # mu_b * d          — bad-state load (always finishes)

    def __post_init__(self):
        if self.ell_g <= self.ell_b:
            raise ValueError("ell_g must exceed ell_b (otherwise allocation is trivial)")


def prefix_thresholds(lp: LoadParams) -> np.ndarray:
    """w(i~) = ceil((K* - (n - i~) * ell_b) / ell_g) for i~ = 1..n  (eq. 7/8).

    Values <= 0 mean "always enough", > i~ mean "impossible".  Concrete
    (numpy) because ``lp`` is static — the Pallas kernel bakes these in as
    trace-time constants.
    """
    i_tilde = np.arange(1, lp.n + 1)
    return np.ceil((lp.kstar - (lp.n - i_tilde) * lp.ell_b) / lp.ell_g).astype(np.int32)


def success_prob_all_prefixes(
    p_good_sorted: jnp.ndarray, lp: LoadParams, *, impl: str | None = None
) -> jnp.ndarray:
    """P̂(i~) for every i~ in 1..n, given p_good sorted descending along the
    last axis.  (..., n) in -> (..., n) out (any leading batch axes).

    P̂(i~) = P[ Binom-mixture(top i~) >= w(i~) ]  with w from
    :func:`prefix_thresholds`.  One O(n^2) DP over the whole batch, routed
    through ``repro.kernels.poisson_binomial`` (``impl``: "pallas" / "ref" /
    None = auto — Pallas on TPU, batched ``lax.scan`` DP elsewhere).
    """
    from repro.kernels.poisson_binomial import success_tails

    return success_tails(p_good_sorted, prefix_thresholds(lp), impl=impl)


# Above this worker count, unrolling the O(n^2) pairwise rank loop bloats the
# program; fall back to XLA sorts (the batch sizes that matter are small-n).
_PAIRWISE_RANK_MAX_N = 64


def _ranks_descending(p: jnp.ndarray) -> jnp.ndarray:
    """Stable descending ranks: identical to argsort(argsort(-p)) per row.

    rank_i = #{j : p_j > p_i} + #{j < i : p_j == p_i} — n unrolled passes of
    element-wise compares over the batch, which XLA CPU runs ~20x faster than
    two variadic sorts at the (rounds x batch) sizes the engine produces.
    """
    n = p.shape[-1]
    idx = jnp.arange(n)
    acc = jnp.zeros(p.shape, jnp.int32)
    for j in range(n):
        pj = p[..., j : j + 1]
        acc = acc + (pj > p) + ((pj == p) & (idx > j))
    return acc


def _take_by_rank(p: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """Values in rank order: out[..., r] = p at the row position with rank r.

    Exact one-hot gather (the sum has a single non-zero term per slot), so it
    equals take_along_axis with the descending argsort bit-for-bit.
    """
    n = p.shape[-1]
    return jnp.stack(
        [jnp.sum(jnp.where(ranks == r, p, 0.0), axis=-1) for r in range(n)], axis=-1
    )


def allocate(
    p_good: jnp.ndarray, lp: LoadParams, *, impl: str | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Phase (1): the LEA load assignment, batched over leading axes.

    ``p_good`` has shape (..., n).  Returns ``(loads, i_star)`` where
    ``loads`` is the (..., n) int32 allocation in the *original worker order*
    (per row, the i* workers with the largest p_good get ell_g, the rest
    ell_b — Lemma 4.5), and ``i_star`` (...,) the argmax of P̂ per row.
    """
    if lp.n <= _PAIRWISE_RANK_MAX_N:
        ranks = _ranks_descending(p_good)
        p_sorted = _take_by_rank(p_good, ranks)
    else:
        order = jnp.argsort(-p_good, axis=-1)                   # descending
        p_sorted = jnp.take_along_axis(p_good, order, axis=-1)
        ranks = jnp.argsort(order, axis=-1)                     # rank per worker
    probs = success_prob_all_prefixes(p_sorted, lp, impl=impl)  # (..., n)
    i_star = jnp.argmax(probs, axis=-1) + 1                     # in 1..n
    loads = jnp.where(ranks < i_star[..., None], lp.ell_g, lp.ell_b).astype(jnp.int32)
    return loads, i_star


def success_prob_bruteforce(p_good_sorted: jnp.ndarray, lp: LoadParams, i_tilde: int) -> float:
    """Reference implementation of eq. (8) by exponential enumeration (tests)."""
    import itertools

    import numpy as np

    p = np.asarray(p_good_sorted)[:i_tilde]
    w = math_ceil((lp.kstar - (lp.n - i_tilde) * lp.ell_b) / lp.ell_g)
    if w > i_tilde:
        return 0.0
    total = 0.0
    for mask in itertools.product([0, 1], repeat=i_tilde):
        if sum(mask) >= max(w, 0):
            prob = 1.0
            for i, m in enumerate(mask):
                prob *= p[i] if m else (1.0 - p[i])
            total += prob
    return float(total)


def math_ceil(x: float) -> int:
    import math

    return int(math.ceil(x))


def round_success(loads: jnp.ndarray, states: jnp.ndarray, lp: LoadParams,
                  mu_g: float, mu_b: float, deadline: float) -> jnp.ndarray:
    """Did the master receive >= K* evaluations by the deadline?

    Worker i returns all ``loads[i]`` results iff loads[i]/speed_i <= d
    (speeds are deterministic given the state — Sec. 2.2).
    """
    speeds = jnp.where(states == 1, mu_g, mu_b)
    on_time = loads.astype(jnp.float32) / speeds <= deadline + 1e-9
    received = jnp.sum(jnp.where(on_time, loads, 0))
    return received >= lp.kstar
