"""Estimate-and-Allocate (EA) — the load-allocation half of LEA (Sec. 3.2).

The paper's 4 phases map to:
  (1) Load Assignment  -> :func:`allocate`    (linear search over i~, eq. 7/8)
  (2) Local Computation-> simulated in core/throughput.py / executed by
                          runtime/fault_tolerance.py
  (3) Aggregation/Obs. -> the caller passes observed worker states
  (4) Update           -> :func:`update_estimator`

Efficiency note (beyond the paper's pseudocode): the estimated success
probability (8) is a Poisson-binomial tail.  Instead of the exponential
sum over subsets G ⊆ [i~], we evaluate all n prefixes with one O(n^2)
dynamic program (`lax.scan` convolving one Bernoulli at a time), so one
allocation costs O(n^2) total rather than O(2^n) — the linear search of the
paper then reads the tails off the DP table.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class EstimatorState(NamedTuple):
    """Per-worker transition counts + last observed state.

    counts[:, 0] = C_{g->g}, counts[:, 1] = C_{g->b},
    counts[:, 2] = C_{b->g}, counts[:, 3] = C_{b->b}.
    """

    counts: jnp.ndarray      # (n, 4) float32
    prev_state: jnp.ndarray  # (n,) int32, 1=good 0=bad
    seen_prev: jnp.ndarray   # () bool — False before the first observation


def init_estimator(n: int) -> EstimatorState:
    return EstimatorState(
        counts=jnp.zeros((n, 4), jnp.float32),
        prev_state=jnp.zeros((n,), jnp.int32),
        seen_prev=jnp.asarray(False),
    )


def update_estimator(state: EstimatorState, observed: jnp.ndarray) -> EstimatorState:
    """Phase (4): fold one round's observed states (n,) into the counts.

    The first observation only sets ``prev_state`` (no transition yet).
    """
    prev, cur = state.prev_state, observed.astype(jnp.int32)
    inc = jnp.stack(
        [
            (prev == 1) & (cur == 1),
            (prev == 1) & (cur == 0),
            (prev == 0) & (cur == 1),
            (prev == 0) & (cur == 0),
        ],
        axis=-1,
    ).astype(jnp.float32)
    counts = jnp.where(state.seen_prev, state.counts + inc, state.counts)
    return EstimatorState(counts=counts, prev_state=cur, seen_prev=jnp.asarray(True))


def estimated_transitions(state: EstimatorState) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(p̂_gg, p̂_bb) with add-one smoothing (paper leaves t=0 behaviour open;
    Laplace smoothing avoids 0/0 and washes out as counts grow)."""
    c = state.counts
    p_gg = (c[:, 0] + 1.0) / (c[:, 0] + c[:, 1] + 2.0)
    p_bb = (c[:, 3] + 1.0) / (c[:, 2] + c[:, 3] + 2.0)
    return p_gg, p_bb


def predicted_good_prob(state: EstimatorState) -> jnp.ndarray:
    """p̂_{g,i}(m+1): p̂_gg if last seen good, else 1 - p̂_bb (Phase 4)."""
    p_gg, p_bb = estimated_transitions(state)
    return jnp.where(state.prev_state == 1, p_gg, 1.0 - p_bb)


# ---------------------------------------------------------------------------
# Success probability + allocation (Phase 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoadParams:
    """Static load-allocation parameters for one deployment."""

    n: int
    kstar: int      # optimal recovery threshold K*
    ell_g: int      # min(mu_g * d, r)  — good-state load
    ell_b: int      # mu_b * d          — bad-state load (always finishes)

    def __post_init__(self):
        if self.ell_g <= self.ell_b:
            raise ValueError("ell_g must exceed ell_b (otherwise allocation is trivial)")


def success_prob_all_prefixes(p_good_sorted: jnp.ndarray, lp: LoadParams) -> jnp.ndarray:
    """P̂(i~) for every i~ in 1..n, given p_good sorted descending.  (n,) float.

    P̂(i~) = P[ Binom-mixture(top i~) >= w(i~) ],
    w(i~)  = ceil((K* - (n - i~) * ell_b) / ell_g)   (eq. 7/8).

    One O(n^2) DP: scan over workers, carry the Poisson-binomial pmf of the
    good-worker count among the first i~ workers; read the tail per prefix.
    """
    n = lp.n
    i_tilde = jnp.arange(1, n + 1)
    # w(i~); values <= 0 mean "always enough", > i~ mean "impossible".
    w = jnp.ceil((lp.kstar - (n - i_tilde) * lp.ell_b) / lp.ell_g).astype(jnp.int32)

    def body(pmf, p):
        # pmf over counts 0..n (length n+1); convolve one Bernoulli(p).
        shifted = jnp.concatenate([jnp.zeros((1,), pmf.dtype), pmf[:-1]])
        new = pmf * (1.0 - p) + shifted * p
        return new, new

    pmf0 = jnp.zeros((n + 1,), jnp.float32).at[0].set(1.0)
    _, pmfs = jax.lax.scan(body, pmf0, p_good_sorted.astype(jnp.float32))  # (n, n+1)

    counts = jnp.arange(n + 1)[None, :]
    tail_mask = counts >= jnp.maximum(w, 0)[:, None]
    tails = jnp.sum(pmfs * tail_mask, axis=-1)
    # w > i~  -> infeasible -> probability 0 (eq. 7).
    return jnp.where(w > i_tilde, 0.0, tails)


def allocate(p_good: jnp.ndarray, lp: LoadParams) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Phase (1): the LEA load assignment.

    Returns ``(loads, i_star)`` where ``loads`` is the (n,) int32 allocation in
    the *original worker order* (the i* workers with the largest p_good get
    ell_g, the rest ell_b — Lemma 4.5), and ``i_star`` the argmax of P̂.
    """
    order = jnp.argsort(-p_good)                      # descending
    p_sorted = p_good[order]
    probs = success_prob_all_prefixes(p_sorted, lp)   # (n,)
    i_star = jnp.argmax(probs) + 1                    # in 1..n
    ranks = jnp.argsort(order)                        # rank of each worker
    loads = jnp.where(ranks < i_star, lp.ell_g, lp.ell_b).astype(jnp.int32)
    return loads, i_star


def success_prob_bruteforce(p_good_sorted: jnp.ndarray, lp: LoadParams, i_tilde: int) -> float:
    """Reference implementation of eq. (8) by exponential enumeration (tests)."""
    import itertools

    import numpy as np

    p = np.asarray(p_good_sorted)[:i_tilde]
    w = math_ceil((lp.kstar - (lp.n - i_tilde) * lp.ell_b) / lp.ell_g)
    if w > i_tilde:
        return 0.0
    total = 0.0
    for mask in itertools.product([0, 1], repeat=i_tilde):
        if sum(mask) >= max(w, 0):
            prob = 1.0
            for i, m in enumerate(mask):
                prob *= p[i] if m else (1.0 - p[i])
            total += prob
    return float(total)


def math_ceil(x: float) -> int:
    import math

    return int(math.ceil(x))


def round_success(loads: jnp.ndarray, states: jnp.ndarray, lp: LoadParams,
                  mu_g: float, mu_b: float, deadline: float) -> jnp.ndarray:
    """Did the master receive >= K* evaluations by the deadline?

    Worker i returns all ``loads[i]`` results iff loads[i]/speed_i <= d
    (speeds are deterministic given the state — Sec. 2.2).
    """
    speeds = jnp.where(states == 1, mu_g, mu_b)
    on_time = loads.astype(jnp.float32) / speeds <= deadline + 1e-9
    received = jnp.sum(jnp.where(on_time, loads, 0))
    return received >= lp.kstar
