"""Atomic, async checkpointing with resume + elastic reshard-on-load.

Layout per step:  <dir>/step_<n>.tmp/ -> (atomic rename) -> <dir>/step_<n>/
  arrays.npz      flattened arrays (keyed by pytree path)
  meta.json       treedef repr, pipeline cursor, LEA estimator counts, step

Fault-tolerance contract (DESIGN §7):
  * writer never leaves a half-written visible checkpoint (tmp + rename);
  * ``latest_step`` ignores tmp/corrupt dirs, so a crash mid-write simply
    falls back to the previous checkpoint;
  * the async thread is joined before the next save (one in flight).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(directory: str, step: int, tree, *, extra_meta: dict | None = None) -> str:
    """Blocking atomic save.  Returns the final path."""
    names, leaves, _ = _flatten_with_names(tree)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.view(np.uint16)            # npz-safe storage for bf16
        arrays[f"a{i}"] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "names": names,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            p = os.path.join(directory, name, "meta.json")
            if os.path.exists(p):
                try:
                    s = int(name.split("_", 1)[1])
                except ValueError:
                    continue
                best = s if best is None else max(best, s)
    return best


def restore(directory: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings`` (same-structure pytree of NamedSharding) triggers
    device_put per leaf — this is the elastic path: a checkpoint written on
    one mesh reshards onto another (runtime/elastic.py).
    Returns (tree, meta).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_names(like_tree)
    if names != meta["names"]:
        raise ValueError(
            "checkpoint structure mismatch: "
            f"{set(names) ^ set(meta['names'])}"
        )
    import ml_dtypes

    out_leaves = []
    flat_sh = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    for i, (like, sh) in enumerate(zip(leaves, flat_sh)):
        arr = data[f"a{i}"]
        saved_dtype = meta["dtypes"][i]
        if saved_dtype == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        want = np.dtype(like.dtype) if hasattr(like, "dtype") else arr.dtype
        if arr.dtype != want:
            arr = arr.astype(want)
        if sh is not None:
            out_leaves.append(jax.device_put(arr, sh))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), meta


class CheckpointManager:
    """Async save + retention + auto-resume."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree, *, extra_meta: dict | None = None) -> None:
        self.wait()
        # materialize on host BEFORE backgrounding (donated buffers may die)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.dir, step, host_tree, extra_meta=extra_meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_", 1)[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.dir, n, "meta.json"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def restore_latest(self, like_tree, *, shardings=None):
        self.wait()
        s = latest_step(self.dir)
        if s is None:
            return None, None, None
        tree, meta = restore(self.dir, s, like_tree, shardings=shardings)
        return s, tree, meta
