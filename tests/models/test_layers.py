"""Layer-level correctness: chunked/parallel forms vs naive recurrences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _ssm_cfg(**kw):
    base = dict(
        name="t", family="hybrid", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
        dtype="float32", remat=False,
    )
    base.update(kw)
    return ArchConfig(**base)


def _mamba_params(key, cfg):
    d = cfg.d_model
    d_in, nh, ds, hd = L.mamba2_dims(cfg)
    ks = jax.random.split(key, 3)
    proj_out = 2 * d_in + 2 * ds + nh
    conv_ch = d_in + 2 * ds
    return {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), jnp.float32) * 0.2,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.3,
        "conv_b": jnp.zeros((conv_ch,)),
        "dt_bias": jnp.zeros((nh,)),
        "a_log": jnp.zeros((nh,)),
        "d_skip": jnp.ones((nh,)),
        "norm": jnp.ones((d_in,)),
        "out_proj": jax.random.normal(ks[2], (d_in, d), jnp.float32) * 0.2,
    }


@pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (12, 12)])
def test_mamba2_chunked_scan_matches_stepwise_decode(s, chunk):
    """The chunk-parallel SSD must equal the exact one-token recurrence."""
    cfg = _ssm_cfg()
    key = jax.random.PRNGKey(0)
    p = _mamba_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model)) * 0.5

    y_scan, (h_fin, conv_state) = L.mamba2_scan(x, p, cfg, chunk=chunk, return_state=True)

    d_in, nh, ds, hd = L.mamba2_dims(cfg)
    h = jnp.zeros((2, nh, hd, ds))
    conv = jnp.zeros((2, cfg.ssm_conv - 1, d_in + 2 * ds))
    outs = []
    for t in range(s):
        y_t, h, conv = L.mamba2_decode(x[:, t: t + 1], p, cfg, h, conv)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h), rtol=2e-4, atol=2e-4)


def test_mamba2_state_continuation():
    """prefill(state) then decode continues exactly."""
    cfg = _ssm_cfg()
    p = _mamba_params(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 12, cfg.d_model)) * 0.5
    y_full = L.mamba2_scan(x, p, cfg, chunk=4)
    y_pre, (h, conv) = L.mamba2_scan(x[:, :8], p, cfg, chunk=4, return_state=True)
    y9, h, conv = L.mamba2_decode(x[:, 8:9], p, cfg, h, conv)
    np.testing.assert_allclose(np.asarray(y9[:, 0]), np.asarray(y_full[:, 8]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s,chunk", [(16, 4), (20, 5), (8, 8)])
def test_mlstm_chunked_matches_stepwise(s, chunk):
    b, h, d = 2, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    i_pre = jax.random.normal(ks[3], (b, s, h))
    f_pre = jax.random.normal(ks[4], (b, s, h)) + 1.0

    out_chunk, (c_f, n_f, m_f) = L.mlstm_chunked(
        q, k, v, i_pre, f_pre, chunk=chunk, return_state=True)

    c = jnp.zeros((b, h, d, d)); n = jnp.zeros((b, h, d)); m = jnp.full((b, h), -jnp.inf)
    outs = []
    for t in range(s):
        o, (c, n, m) = L.mlstm_decode(q[:, t], k[:, t], v[:, t],
                                      i_pre[:, t], f_pre[:, t], (c, n, m))
        outs.append(o[:, None])
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(c_f), np.asarray(c), rtol=3e-4, atol=3e-4)


def test_mlstm_chunk_boundary_invariance():
    """Same result regardless of chunk size (state passing is exact)."""
    b, s, h, d = 1, 24, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    args = [jax.random.normal(ks[i], (b, s, h, d)) for i in range(3)]
    gates = [jax.random.normal(ks[3], (b, s, h)), jax.random.normal(ks[4], (b, s, h))]
    o1 = L.mlstm_chunked(*args, *gates, chunk=4)
    o2 = L.mlstm_chunked(*args, *gates, chunk=24)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-4, atol=3e-4)


def test_slstm_state_continuation():
    b, s, h, d = 2, 10, 2, 4
    gates = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, 4, d)) * 0.5
    r = jax.random.normal(jax.random.PRNGKey(1), (h, 4, d, d)) * 0.2
    full = L.slstm_scan(gates, r)
    first, st = L.slstm_scan(gates[:, :6], r, return_state=True)
    rest = L.slstm_scan(gates[:, 6:], r, initial=st)
    np.testing.assert_allclose(np.asarray(full[:, 6:]), np.asarray(rest),
                               rtol=1e-5, atol=1e-6)


def test_blockwise_attention_equals_dense():
    b, hq, hkv, s, d = 1, 4, 2, 96, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    for window in (None, 24):
        dense = L._dense_attention(q, k, v, causal=True, window=window)
        block = L._blockwise_attention(q, k, v, causal=True, window=window, block=32)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                                   rtol=2e-5, atol=2e-5)


def test_gqa_reduces_to_mha_when_heads_equal():
    from repro.kernels.flash_attention.ref import attention_ref

    b, h, s, d = 1, 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    ours = L._dense_attention(q, k, v, causal=True, window=None)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relative_angles():
    s, h, d = 16, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (1, s, h, d))
    pos = jnp.arange(s)
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
    def score(i, j):
        qq = L.rope(q, jnp.asarray([i]), 10_000.0)
        kk = L.rope(k, jnp.asarray([j]), 10_000.0)
        return float(jnp.sum(qq * kk))
    assert abs(score(3, 1) - score(7, 5)) < 1e-4


def test_moe_matches_per_token_reference():
    cfg = _ssm_cfg(family="moe", n_experts=4, top_k=2, d_ff=16,
                   capacity_factor=100.0)  # ample capacity: no drops
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.5,
        "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.2,
        "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.2,
        "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.2,
    }
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, d))
    got = L.moe(x, p, cfg)

    # reference: per-token explicit top-k mixture
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    want = np.zeros(x.shape, np.float32)
    xn = np.asarray(x)
    for b in range(2):
        for t in range(6):
            pr = np.asarray(probs[b, t])
            top = np.argsort(-pr)[: cfg.top_k]
            gsum = pr[top].sum()
            for ei in top:
                h = L.silu(xn[b, t] @ np.asarray(p["w_gate"][ei])) * (
                    xn[b, t] @ np.asarray(p["w_up"][ei]))
                want[b, t] += (pr[ei] / gsum) * np.asarray(h @ np.asarray(p["w_down"][ei]))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_overflow_tokens():
    cfg = _ssm_cfg(family="moe", n_experts=2, top_k=1, d_ff=8, capacity_factor=0.5)
    d = cfg.d_model
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = {
        "router": jnp.zeros((d, 2)).at[:, 0].set(1.0),   # everyone wants expert 0
        "w_gate": jax.random.normal(ks[1], (2, d, 8)) * 0.2,
        "w_up": jax.random.normal(ks[2], (2, d, 8)) * 0.2,
        "w_down": jax.random.normal(ks[3], (2, 8, d)) * 0.2,
    }
    x = jnp.ones((1, 8, d))
    out = np.asarray(L.moe(x, p, cfg))
    # capacity = ceil(8*1*0.5/2) = 2 -> tokens beyond the 2nd drop to zero
    assert np.allclose(out[0, 4:], 0.0)
    assert not np.allclose(out[0, :2], 0.0)
