"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions; prefill->decode consistency for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCell, get_smoke_config, list_configs
from repro.models import api

SMOKE_CELL = ShapeCell("smoke", seq_len=32, global_batch=2, kind="train")


def _smoke(name):
    return get_smoke_config(name)


@pytest.mark.parametrize("arch", list_configs())
def test_train_step_runs_and_is_finite(arch):
    cfg = _smoke(arch)
    key = jax.random.PRNGKey(0)
    state = api.init_state(cfg, key)
    batch = api.make_batch(cfg, SMOKE_CELL, key)
    step = jax.jit(api.make_train_step(cfg, peak_lr=1e-3, warmup=1))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) > 0
    assert int(new_state.step) == 1
    # params actually changed (bitwise) somewhere in the tree
    changed = any(
        not np.array_equal(np.asarray(b), np.asarray(a))
        for b, a in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert changed, arch


@pytest.mark.parametrize("arch", list_configs())
def test_loss_decreases_over_steps(arch):
    cfg = _smoke(arch)
    key = jax.random.PRNGKey(1)
    state = api.init_state(cfg, key)
    batch = api.make_batch(cfg, SMOKE_CELL, key)
    step = jax.jit(api.make_train_step(cfg, peak_lr=1e-3, warmup=1, total_steps=50))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), (arch, losses)
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", list_configs())
def test_prefill_then_decode_shapes(arch):
    cfg = _smoke(arch)
    key = jax.random.PRNGKey(2)
    params = api.get_model(cfg).init_params(key, cfg)
    cell = ShapeCell("smoke_prefill", seq_len=16, global_batch=2, kind="prefill")
    batch = api.make_batch(cfg, cell, key)
    max_len = 24
    prefill = jax.jit(api.make_prefill_step(cfg, max_len=max_len))
    logits, cache = prefill(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    serve = jax.jit(api.make_serve_step(cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = serve(params, cache, {"next_token": tok})
        assert logits.shape == (2, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "zamba2_7b", "xlstm_125m", "whisper_tiny"])
def test_decode_matches_prefill_logits(arch):
    """Teacher-forcing consistency: decoding token t with a cache built from
    tokens [0,t) must reproduce the prefill logits at position t."""
    cfg = _smoke(arch)
    key = jax.random.PRNGKey(3)
    params = api.get_model(cfg).init_params(key, cfg)
    s = 12
    cell = ShapeCell("c", seq_len=s, global_batch=2, kind="prefill")
    batch = api.make_batch(cfg, cell, key)

    # full prefill logits at the last position
    full_logits, _ = jax.jit(api.make_prefill_step(cfg, max_len=s + 4))(params, batch)

    # prefill on the first s-1 tokens, then decode the last token
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, : s - 1]
    logits0, cache = jax.jit(api.make_prefill_step(cfg, max_len=s + 4))(params, short)
    step_logits, _ = jax.jit(api.make_serve_step(cfg))(
        params, cache, {"next_token": batch["tokens"][:, s - 1]}
    )
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_vocab_padding_masks_padded_logits():
    cfg = dataclasses.replace(_smoke("qwen3_0_6b"), vocab_size=500, vocab_pad_to=128)
    assert cfg.padded_vocab == 512
    key = jax.random.PRNGKey(4)
    params = api.get_model(cfg).init_params(key, cfg)
    cell = ShapeCell("c", seq_len=8, global_batch=1, kind="prefill")
    batch = api.make_batch(cfg, cell, key)
    logits, _ = jax.jit(api.make_prefill_step(cfg))(params, batch)
    assert np.all(np.asarray(logits)[:, 500:] < -1e29)


def test_param_counts_match_analytic():
    for arch in ("qwen3_0_6b", "yi_9b"):
        cfg = _smoke(arch)
        params = api.get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        approx = cfg.n_params()
        assert abs(n - approx) / max(n, 1) < 0.05, (arch, n, approx)
