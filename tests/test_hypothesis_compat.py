"""The hypothesis real-vs-stub contract (conftest + tests/_hypothesis_stub.py):
the REAL package must win whenever it is importable; the stub registers only
when it is absent, and then must honour the API subset the suite uses.
"""

import random
import sys

import _hypothesis_stub


def test_real_hypothesis_preferred_when_installed():
    mod = sys.modules["hypothesis"]  # conftest already ran install_if_missing
    # probe the import path directly (find_spec would just echo sys.modules)
    from importlib.machinery import PathFinder

    real_installed = PathFinder.find_spec("hypothesis", sys.path) is not None
    if real_installed:
        # a real install must never be shadowed by the stub
        assert not getattr(mod, "__stub__", False)
    else:
        assert getattr(mod, "__stub__", False)
    # idempotent: re-installing returns the active module, no replacement
    assert _hypothesis_stub.install_if_missing() is mod


def test_stub_surface_matches_suite_usage():
    """The stub implements exactly the names the test-suite imports, with
    real-hypothesis keyword spellings (min_value/max_value), so switching
    between real and stub needs no test changes."""
    mod = _hypothesis_stub._as_module()
    assert callable(mod.given) and callable(mod.settings) and callable(mod.assume)
    for name in ("integers", "floats", "booleans", "sampled_from"):
        assert callable(getattr(mod.strategies, name))
    # keyword spellings match the real package
    s = mod.strategies.integers(min_value=3, max_value=3)
    assert s.example(random.Random(0)) == 3
    f = mod.strategies.floats(min_value=0.25, max_value=0.5)
    assert 0.25 <= f.example(random.Random(0)) <= 0.5


def test_stub_given_runs_max_examples_and_is_deterministic():
    calls = []

    @_hypothesis_stub.settings(max_examples=7)
    @_hypothesis_stub.given(x=_hypothesis_stub.strategies.integers(0, 10**6))
    def prop(x):
        calls.append(x)

    prop()
    prop()
    assert len(calls) == 14
    assert calls[:7] == calls[7:]          # seeded off the qualname -> same draws


def test_stub_given_hides_strategy_params_from_signature():
    """pytest must not see strategy-drawn params as fixtures."""
    import inspect

    @_hypothesis_stub.given(x=_hypothesis_stub.strategies.integers(0, 1))
    def prop(tmp_path_like, x):
        pass

    assert list(inspect.signature(prop).parameters) == ["tmp_path_like"]
