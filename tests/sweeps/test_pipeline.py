"""Async pipelined executor: bit-identity vs the sync path, donated carries,
shard-once cache, pipeline-aware chunk suggestion.

The pipelined path (``run_group(pipeline=True)``) re-expresses the engine's
round-block loop as host-dispatched donated-carry steps; these tests pin
the two contracts everything else rests on — the success stream is
BIT-identical to the sync executor in every (mesh, chunking) combination,
and the donation actually happened (runtime buffer deletion + the
``input_output_alias`` entries in the compiled HLO, not just the
``donate_argnums`` request).
"""

import numpy as np
import pytest

from repro.launch.mesh import make_sweep_mesh
from repro.sweeps import executor
from repro.sweeps.registry import build_groups, expand

ROUNDS = 64


@pytest.fixture(scope="module")
def kstar_group():
    scens = expand("hetero_kstar", ks=(50, 99), lams=(0.2,), rounds=ROUNDS)
    groups = build_groups(scens, seeds=2)
    assert len(groups) == 1
    return groups[0]


@pytest.fixture(scope="module")
def arrival_group():
    scens = expand("arrival_grid", rates=(0.6, 2.4), deadline_rels=(1,),
                   rounds=ROUNDS)
    groups = build_groups(scens, seeds=2)
    assert len(groups) == 1
    return groups[0]


@pytest.fixture(scope="module")
def mesh():
    return make_sweep_mesh()


@pytest.mark.parametrize("use_mesh", [False, True])
@pytest.mark.parametrize("round_chunk", [None, 16])
def test_pipeline_bit_identical_hetero_kstar(kstar_group, mesh, use_mesh,
                                             round_chunk):
    m = mesh if use_mesh else None
    ref = executor.run_group(kstar_group, mesh=m, round_chunk=round_chunk)
    out = executor.run_group(kstar_group, mesh=m, round_chunk=round_chunk,
                             pipeline=True)
    assert out.dtype == ref.dtype and out.shape == ref.shape
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("round_chunk", [None, 16])
def test_pipeline_bit_identical_arrival_grid(arrival_group, mesh, round_chunk):
    ref = executor.run_group(arrival_group, mesh=mesh, round_chunk=round_chunk)
    out = executor.run_group(arrival_group, mesh=mesh, round_chunk=round_chunk,
                             pipeline=True)
    assert np.array_equal(out, ref)


def test_block_step_carries_are_donated(kstar_group, mesh):
    # compiled-executable proof: XLA aliased the donated carries
    hlo = executor.pipeline_block_hlo(kstar_group, mesh=mesh, round_chunk=16)
    assert "input_output_alias" in hlo
    # runtime proof: the previous carry buffer was consumed by the step
    executor.run_group(kstar_group, mesh=mesh, round_chunk=16, pipeline=True)
    stats = executor.last_pipeline_stats()
    assert stats["donated"] is True
    assert stats["blocks"] == ROUNDS // 16


def test_shard_cache_hits_on_second_call(kstar_group, mesh):
    executor.run_group(kstar_group, mesh=mesh, round_chunk=16, pipeline=True)
    executor.run_group(kstar_group, mesh=mesh, round_chunk=16, pipeline=True)
    assert executor.last_pipeline_stats()["shard_cached"] is True


def test_pipeline_rejects_telemetry(kstar_group):
    with pytest.raises(ValueError, match="telemetry"):
        executor.run_group(kstar_group, pipeline=True, telemetry=True)


def test_pipeline_tap_streams_block_events(kstar_group):
    from repro.obs import taps

    with taps.capture_taps() as events:
        out = executor.run_group(kstar_group, round_chunk=16, pipeline=True,
                                 tap=True)
    ref = executor.run_group(kstar_group, round_chunk=16)
    assert np.array_equal(out, ref)          # tap on != bits changed
    rows = kstar_group.batch.rows
    assert len(events) == rows * (ROUNDS // 16)
    last_by_row = {}
    for e in events:
        assert e["engine"] == "engine.pool"
        r = int(e["row"])
        if (r not in last_by_row
                or int(e["rounds_done"]) > int(last_by_row[r]["rounds_done"])):
            last_by_row[r] = e
    for e in last_by_row.values():
        assert int(e["rounds_done"]) == ROUNDS
        np.testing.assert_allclose(
            np.asarray(e["throughput_so_far"]),
            np.asarray(e["succ_so_far"], np.float32) / ROUNDS, rtol=1e-6)


def test_suggest_round_chunk_halves_budget_for_pipeline(kstar_group):
    # smallest budget whose whole run fits the sync path (bisection)
    lo, hi = 1 << 10, 1 << 40
    while lo < hi:
        mid = (lo + hi) // 2
        if executor.suggest_round_chunk(kstar_group, budget_bytes=mid) is None:
            hi = mid
        else:
            lo = mid + 1
    fits = lo
    # boundary: exactly at the fit threshold the sync path needs no
    # chunking, but the double-buffered pipeline (2 live blocks) does
    assert executor.suggest_round_chunk(kstar_group, budget_bytes=fits) is None
    assert executor.suggest_round_chunk(
        kstar_group, budget_bytes=fits, pipeline=True) is not None
    # under the threshold both chunk, and the pipeline chunk is the halved
    # budget's: floor-division composition makes it exactly base // 2
    budget = fits // 2
    base = executor.suggest_round_chunk(kstar_group, budget_bytes=budget)
    piped = executor.suggest_round_chunk(kstar_group, budget_bytes=budget,
                                         pipeline=True)
    assert base is not None and piped is not None
    assert piped == max(base // 2, 1)
