"""Tests for the repro.sweeps subsystem: registry expansion and grouping,
executor equivalences (registry path == engine path, chunked == unchunked),
per-group compilation, and the results layer.  Multi-device sharding is
covered by tests/distributed/_sweeps_sharded.py (own subprocess, forced
8-device CPU)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sweeps
from repro.core import throughput
from repro.sweeps.registry import RowMeta


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_families_expand_with_unique_names_and_catalogue():
    names = sweeps.family_names()
    assert {"fig3", "fig4", "kstar_table", "deadline_sweep", "bursty_chains",
            "hetero_kstar", "elastic_pool", "straggler_slack"} <= set(names)
    cat = sweeps.catalogue()
    for fam in names:
        scs = sweeps.expand(fam)
        assert scs, fam
        assert len({sc.name for sc in scs}) == len(scs), fam
        assert all(sc.family == fam for sc in scs)
        assert fam in cat


def test_expand_unknown_family_raises():
    with pytest.raises(KeyError):
        sweeps.expand("no_such_family")


def test_scenario_validation():
    sc = sweeps.expand("fig3", rounds=10)[0]
    import dataclasses
    with pytest.raises(ValueError):
        dataclasses.replace(sc, p_gg=(0.5,))            # wrong length
    with pytest.raises(ValueError):
        dataclasses.replace(sc, strategies=("nope",))   # unknown strategy
    with pytest.raises(ValueError):
        dataclasses.replace(sc, baseline="static_single")  # not in strategies


def test_build_groups_by_static_signature_and_row_layout():
    scs = sweeps.expand("fig4", rounds=16)
    groups = sweeps.build_groups(scs, seeds=3)
    # 6 scenarios over K* in {120, 100, 50}: K* is a traced batch leaf, so
    # the whole family is ONE group (the signature is (rounds, strategies))
    assert len(groups) == 1
    (g,) = groups
    assert len(g.scenarios) == 6
    assert g.batch.rows == len(g.rows) == 6 * 3
    assert g.rows == tuple(
        RowMeta(si, s) for si in range(6) for s in range(3)
    )
    assert g.batch.p_gg.shape == (18, g.n_max)
    assert g.batch.keys.shape[0] == 18
    # per-row traced load params follow the scenario layout
    kstars = np.asarray(g.batch.kstar).reshape(6, 3)
    assert [int(v) for v in kstars[:, 0]] == [sc.lp.kstar for sc in g.scenarios]
    assert sorted(set(int(v) for v in kstars[:, 0])) == [50, 100, 120]
    assert bool(np.all(np.asarray(g.batch.worker_mask)))   # all full-width


def test_row_keys_replicate_paper_seed_then_fold_in():
    scs = sweeps.expand("fig3", rounds=8)
    (group,) = sweeps.build_groups(scs, seeds=2)
    for si, sc in enumerate(group.scenarios):
        base = jax.random.PRNGKey(sc.seed)
        rows = [ri for ri, rm in enumerate(group.rows) if rm.scenario_index == si]
        np.testing.assert_array_equal(np.asarray(group.batch.keys[rows[0]]),
                                      np.asarray(base))
        np.testing.assert_array_equal(np.asarray(group.batch.keys[rows[1]]),
                                      np.asarray(jax.random.fold_in(base, 1)))


def test_hetero_kstar_grid_fuses_into_one_group():
    scs = sweeps.expand("hetero_kstar", ks=(50, 80, 99), lams=(0.1, 0.5), rounds=8)
    groups = sweeps.build_groups(scs)
    assert len(groups) == 1 and len(groups[0].scenarios) == 6
    assert sorted(set(int(v) for v in np.asarray(groups[0].batch.kstar))) == [50, 80, 99]


def test_elastic_pool_pads_to_widest_scenario():
    scs = sweeps.expand("elastic_pool", ns=(10, 15, 30), rounds=8)
    (g,) = sweeps.build_groups(scs)
    assert g.n_max == 30
    mask = np.asarray(g.batch.worker_mask)
    assert list(mask.sum(axis=1)) == [sc.lp.n for sc in g.scenarios]
    # prefix-valid convention: padding is a suffix of frozen always-good chains
    for row, sc in zip(mask, g.scenarios):
        assert row[: sc.lp.n].all() and not row[sc.lp.n:].any()
    p_gg = np.asarray(g.batch.p_gg)
    p_bb = np.asarray(g.batch.p_bb)
    for ri, sc in enumerate(g.scenarios):
        assert (p_gg[ri, sc.lp.n:] == 1.0).all() and (p_bb[ri, sc.lp.n:] == 0.0).all()


# ---------------------------------------------------------------------------
# executor: registry path == engine path, chunked == unchunked, compiles
# ---------------------------------------------------------------------------

ROUNDS = 160


def test_fig3_through_sweeps_bit_identical_to_compare():
    """The acceptance criterion: registry-path Fig. 3 values == PR-1 engine
    values on the same PRNG keys."""
    scs = sweeps.expand("fig3", rounds=ROUNDS)
    res = sweeps.run(scs)
    for sc, r in zip(scs, res):
        old = throughput.compare(
            jax.random.PRNGKey(sc.seed), sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, ROUNDS, strategies=sc.strategies,
        )
        assert old == r.throughput, sc.name


def test_fig4_through_sweeps_bit_identical_to_compare():
    scs = sweeps.expand("fig4", rounds=ROUNDS)
    res = sweeps.run(scs)
    for sc, r in zip(scs, res):
        old = throughput.compare(
            jax.random.PRNGKey(sc.seed), sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, ROUNDS, strategies=sc.strategies,
        )
        assert old == r.throughput, sc.name


def test_executor_chunked_matches_unchunked():
    scs = sweeps.expand("straggler_slack", speed_ratios=(2.0, 5.0),
                        deadlines=(1.0,), rounds=ROUNDS)
    groups = sweeps.build_groups(scs, seeds=2)
    plain = sweeps.run_groups(groups)
    for chunk in (1, 23, ROUNDS, 10 * ROUNDS):
        chunked = sweeps.run_groups(groups, round_chunk=chunk)
        for a, b in zip(plain, chunked):
            np.testing.assert_array_equal(a, b)


def test_executor_matches_core_sweep():
    scs = sweeps.expand("bursty_chains", lams=(0.2, 0.8), rounds=ROUNDS)
    (group,) = sweeps.build_groups(scs, seeds=2)
    got = sweeps.run_group(group)
    # all bursty scenarios share one LoadParams -> the static engine path is
    # an exact reference for the executor's traced full-width path
    ref = throughput.sweep(
        group.batch.keys, group.scenarios[0].lp, group.batch.p_gg,
        group.batch.p_bb, group.batch.mu_g, group.batch.mu_b,
        group.batch.deadline, group.rounds, strategies=group.strategies,
    )
    np.testing.assert_array_equal(got, np.asarray(ref))


def test_one_compile_for_whole_hetero_kstar_grid():
    # fresh static signature (unique rounds) so cached entries don't mask it
    scs = sweeps.expand("hetero_kstar", ks=(50, 80, 99), lams=(0.15, 0.55, 0.85),
                        rounds=96)
    groups = sweeps.build_groups(scs, seeds=2)
    assert len(groups) == 1        # 9 scenarios, 3 K*s, ONE fused computation
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups)
    assert sweeps.compile_cache_size() - before == 1
    # re-running the same grid compiles nothing new
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups)
    assert sweeps.compile_cache_size() == before


# ---------------------------------------------------------------------------
# acceptance: every sweep family below = ONE compiled computation, and the
# fused traced-K*/ell results replicate the static-LoadParams engine exactly
# ---------------------------------------------------------------------------

def _assert_rows_match_static_engine(group, succ):
    """Every fused row == the static-LoadParams engine on the same key."""
    for ri, rm in enumerate(group.rows):
        sc = group.scenarios[rm.scenario_index]
        if sc.lp.n != group.n_max:
            continue       # padded rows define their stream at padded width
        ref = throughput.simulate_strategies(
            group.batch.keys[ri], sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, group.rounds,
            strategies=group.strategies,
        )
        np.testing.assert_array_equal(succ[ri], np.asarray(ref))


@pytest.mark.parametrize("family,params,full_width", [
    ("fig4", {"rounds": 88}, True),
    ("hetero_kstar", {"ks": (50, 80, 120), "lams": (0.3, 0.6), "rounds": 88}, True),
    ("deadline_sweep", {"deadlines": (0.7, 1.0, 1.5), "rounds": 88}, True),
    ("elastic_pool", {"ns": (10, 15, 20), "rounds": 88}, False),
])
def test_family_runs_as_one_compile_bit_identical_to_static_engine(
    family, params, full_width
):
    scs = sweeps.expand(family, **params)
    assert len(scs) > 1, family
    groups = sweeps.build_groups(scs, seeds=2)
    assert len(groups) == 1, (family, len(groups))
    before = sweeps.compile_cache_size()
    (succ,) = sweeps.run_groups(groups)
    compiled = sweeps.compile_cache_size() - before
    assert compiled <= 1, (family, compiled)   # <=: an earlier test may have cached it
    if full_width:
        assert all(sc.lp.n == groups[0].n_max for sc in scs)
    _assert_rows_match_static_engine(groups[0], succ)


def test_padded_elastic_rows_match_masked_engine_at_padded_width():
    """A padded row's semantics: the same scenario run through the masked
    engine at the group's padded width, bit for bit."""
    from repro.core import lea as lea_mod

    scs = sweeps.expand("elastic_pool", ns=(10, 20), rounds=72)
    (group,) = sweeps.build_groups(scs)
    (succ,) = sweeps.run_groups([group])
    n_max = group.n_max
    for ri, rm in enumerate(group.rows):
        sc = group.scenarios[rm.scenario_index]
        pool = lea_mod.pool_load(sc.lp, n=n_max)
        ref = throughput.simulate_strategies_pool(
            group.batch.keys[ri], pool,
            group.batch.p_gg[ri], group.batch.p_bb[ri],
            sc.mu_g, sc.mu_b, sc.deadline, group.rounds,
            strategies=group.strategies,
        )
        np.testing.assert_array_equal(succ[ri], np.asarray(ref))


def test_suggest_round_chunk_scales_with_budget():
    scs = sweeps.expand("fig3", rounds=100_000)
    (group,) = sweeps.build_groups(scs, seeds=4)
    chunk = sweeps.suggest_round_chunk(group, budget_bytes=64 << 20)
    assert chunk is not None and 0 < chunk < 100_000
    assert sweeps.suggest_round_chunk(group, budget_bytes=1 << 50) is None


def test_suggest_round_chunk_rounds_smaller_than_chunk_is_none():
    """When the whole run fits the budget the chooser must decline to chunk —
    including the degenerate single-round group."""
    scs = sweeps.expand("fig3", rounds=48)
    (group,) = sweeps.build_groups(scs)
    assert sweeps.suggest_round_chunk(group, budget_bytes=1 << 30) is None
    one = sweeps.expand("fig3", rounds=1)
    (g1,) = sweeps.build_groups(one)
    # even a 1-byte budget cannot produce a chunk smaller than one round,
    # and chunk == rounds means "don't chunk"
    assert sweeps.suggest_round_chunk(g1, budget_bytes=1) is None


def test_suggest_round_chunk_floor_is_one_round():
    """An impossibly small budget clamps to chunk=1 (never 0, never None)."""
    scs = sweeps.expand("fig3", rounds=64)
    (group,) = sweeps.build_groups(scs, seeds=2)
    chunk = sweeps.suggest_round_chunk(group, budget_bytes=1)
    assert chunk == 1
    # and the engine accepts the floor, bit-identically
    (ref,) = sweeps.run_groups([group])
    (chunked,) = sweeps.run_groups([group], round_chunk=chunk)
    np.testing.assert_array_equal(ref, chunked)


def test_suggest_round_chunk_non_dividing_chunk_is_valid():
    """The chooser does not round to divisors; a non-dividing suggestion must
    execute bit-identically (the engine pads the final block)."""
    scs = sweeps.expand("fig3", rounds=100)
    (group,) = sweeps.build_groups(scs)
    budget = None
    for shift in range(14, 32):
        c = sweeps.suggest_round_chunk(group, budget_bytes=1 << shift)
        if c is not None and 1 < c < 100 and 100 % c != 0:
            budget = c
            break
    assert budget is not None, "no non-dividing chunk found in budget scan"
    (ref,) = sweeps.run_groups([group])
    (chunked,) = sweeps.run_groups([group], round_chunk=budget)
    np.testing.assert_array_equal(ref, chunked)


def test_kstar_table_expands_to_simulatable_scenarios_with_rounds():
    """Satellite: the catalogue-only family becomes genuinely runnable when
    expanded with rounds > 0 (default stays display-only, see
    test_catalogue_only_family_raises_clear_error)."""
    scs = sweeps.expand("kstar_table", rounds=16)
    assert all(sc.rounds == 16 for sc in scs)
    res = sweeps.run(scs)
    assert len(res) == len(scs)
    for r in res:
        assert 0.0 <= r.throughput["lea"] <= 1.0
    # paper-expected K* values still ride along in meta
    assert all(r.scenario.meta_dict()["expect_kstar"] >= 1 for r in res)


# ---------------------------------------------------------------------------
# results layer
# ---------------------------------------------------------------------------

def test_results_seeds_ratio_and_ci():
    scs = sweeps.expand("bursty_chains", lams=(0.3,), rounds=ROUNDS)
    res = sweeps.run(scs, seeds=4)
    (r,) = res
    assert r.seeds == 4
    for s in r.scenario.strategies:
        assert len(r.per_seed[s]) == 4
        assert abs(np.mean(r.per_seed[s]) - r.throughput[s]) < 1e-12
        lo, hi = r.ci95[s]
        assert 0.0 <= lo <= r.throughput[s] <= hi <= 1.0
    base = r.scenario.baseline
    assert r.ratio[base] == 1.0
    assert r.ratio["lea"] == r.throughput["lea"] / r.throughput[base]
    assert r.baseline_ratio >= 1.0  # lea/oracle should not lose to static here


def test_manifest_json_roundtrip():
    res = sweeps.run("elastic_pool", ns=(10, 15), rounds=64)
    doc = sweeps.manifest(res, bench="unit_test", extra={"devices": 1})
    blob = json.dumps(doc)
    back = json.loads(blob)
    assert back["bench"] == "unit_test" and back["scenarios"] == len(res)
    assert back["devices"] == 1
    for row in back["results"]:
        assert {"scenario", "family", "kstar", "baseline"} <= set(row)


def test_name_colliding_scenarios_keep_their_own_results():
    """The same family expanded twice (different rounds -> same names, different
    groups) must not alias: each scenario gets the result of ITS simulation."""
    a = sweeps.expand("deadline_sweep", deadlines=(1.0,), rounds=16)
    b = sweeps.expand("deadline_sweep", deadlines=(1.0,), rounds=32)
    res = sweeps.run(a + b)
    assert res[0].scenario.rounds == 16 and res[1].scenario.rounds == 32
    assert res[0].scenario is not res[1].scenario


def test_catalogue_only_family_raises_clear_error():
    with pytest.raises(ValueError, match="catalogue-only"):
        sweeps.run("kstar_table")


def test_seedless_streams_disjoint_from_explicit_paper_keys():
    """Mixing a seedless family with fig3 must not alias PRNG streams: the
    seedless fallback keys are fold_ins, never raw PRNGKey(i)."""
    scs = sweeps.expand("fig3", rounds=8) + sweeps.expand(
        "bursty_chains", lams=(0.2, 0.5), rounds=8
    )
    groups = sweeps.build_groups(scs)
    explicit = {tuple(np.asarray(jax.random.PRNGKey(i))) for i in range(len(scs))}
    seedless_keys = []
    for g in groups:
        for rm, sc in ((rm, g.scenarios[rm.scenario_index]) for rm in g.rows):
            k = tuple(np.asarray(g.batch.keys[g.rows.index(rm)]))
            if sc.seed is None:
                seedless_keys.append(k)
                assert k not in explicit
    # distinct seedless scenarios get distinct streams
    assert len(set(seedless_keys)) == len(seedless_keys)


def test_manifest_zero_baseline_ratio_is_rfc_json():
    """A baseline that never succeeds must serialize as null, not Infinity."""
    import dataclasses
    res = sweeps.run("bursty_chains", lams=(0.3,), rounds=32)
    (r,) = res
    rigged = dataclasses.replace(
        r,
        throughput={**r.throughput, r.scenario.baseline: 0.0},
        ratio={**r.ratio, "lea": float("inf")},
    )
    doc = sweeps.manifest([rigged], bench="inf_test")
    blob = json.dumps(doc, allow_nan=False)   # must not raise
    assert json.loads(blob)["results"][0]["ratio_lea"] is None


def test_summarize_rejects_row_mismatch():
    scs = sweeps.expand("fig3", rounds=8)
    (group,) = sweeps.build_groups(scs)
    with pytest.raises(ValueError):
        sweeps.summarize_group(group, np.zeros((1, 8, 3), bool))


# ---------------------------------------------------------------------------
# dense chain schedules (PR-4 satellite) + regret CIs
# ---------------------------------------------------------------------------

def test_dense_schedule_matches_piecewise_step1_bit_for_bit():
    """A dense spec built from a step-1 piecewise schedule materialises the
    SAME chain arrays and simulates bit-identically (same group shape)."""
    sc_p = sweeps.expand("drifting_chains", periods=(120,), rounds=96, step=1,
                         strategies=("lea", "static", "oracle"))[0]
    gg, bb = sc_p.chain_arrays()
    dense = sweeps.as_dense_schedule(gg, bb)
    import dataclasses
    # round-0 rows must match the dense spec's float32 materialisation
    sc_d = dataclasses.replace(sc_p, name="dense_twin", schedule=(),
                               p_gg=dense[0][0], p_bb=dense[1][0],
                               dense_schedule=dense, seed=3)
    sc_p = dataclasses.replace(sc_p, seed=3)
    np.testing.assert_array_equal(sc_d.chain_arrays()[0], gg)
    np.testing.assert_array_equal(sc_d.chain_arrays()[1], bb)
    assert sc_d.group_signature == sc_p.group_signature  # same compile group
    (g_p,) = sweeps.build_groups([sc_p])
    (g_d,) = sweeps.build_groups([sc_d])
    np.testing.assert_array_equal(
        sweeps.run_groups([g_p])[0], sweeps.run_groups([g_d])[0])


def test_dense_schedule_validation():
    import dataclasses
    sc = sweeps.expand("computed_drift", periods=(50,), rounds=40)[0]
    gg, bb = sc.chain_arrays()
    with pytest.raises(ValueError):      # schedule and dense are exclusive
        dataclasses.replace(
            sc, schedule=((0, sc.p_gg, sc.p_bb),))
    with pytest.raises(ValueError):      # wrong number of rows
        dataclasses.replace(sc, dense_schedule=sweeps.as_dense_schedule(
            gg[:-1], bb[:-1]))
    with pytest.raises(ValueError):      # round-0 row must match p_gg
        bad = gg.copy(); bad[0, 0] += 0.25
        dataclasses.replace(sc, dense_schedule=sweeps.as_dense_schedule(bad, bb))
    with pytest.raises(ValueError):      # mismatched array shapes
        sweeps.as_dense_schedule(gg, bb[:, :-1])


def test_computed_drift_family_runs_with_regret_ci_columns():
    res = sweeps.run("computed_drift", periods=(60,), rounds=80, seeds=2)
    assert [r.name for r in res] == ["cdrift_T60"]
    row = res[0].row()
    for s in ("lea", "lea_window64", "static"):
        assert f"regret_{s}" in row and f"regret_ci95_{s}" in row
        lo, hi = row[f"regret_ci95_{s}"]
        assert lo <= row[f"regret_{s}"] <= hi
    assert "regret_oracle" not in row
    json.dumps(row, allow_nan=False)     # manifest rows stay RFC JSON


def test_regret_ci_single_seed_uses_paired_per_round_width():
    res = sweeps.run("regime_switch", dwells=(40,), rounds=80, seeds=1)
    row = res[0].row()
    lo, hi = row["regret_ci95_lea"]
    assert hi > lo                       # CLT width from per-round diffs
    assert lo <= row["regret_lea"] <= hi


def test_regret_ci_multi_seed_shrinks_with_more_seeds():
    """Across-seed CI machinery: the half width is the z*s/sqrt(n) of the
    per-seed finals (checked against a direct recomputation)."""
    res = sweeps.run("regime_switch", dwells=(40,), rounds=60, seeds=4)
    r = res[0]
    from repro.sweeps.results import _Z95
    import math
    lo, hi = r.regret_ci95["lea"]
    assert abs((lo + hi) / 2 - r.regret["lea"]) < 1e-9
    # reconstruct the finals from the paired engine run
    (group,) = sweeps.build_groups(
        sweeps.expand("regime_switch", dwells=(40,), rounds=60), seeds=4)
    succ = sweeps.run_groups([group])[0]
    from repro.policies import regret as regret_mod
    finals = regret_mod.final_regret(succ, group.strategies)["lea"]
    want_half = _Z95 * finals.std(ddof=1) / math.sqrt(finals.size)
    assert abs((hi - lo) / 2 - want_half) < 1e-6
