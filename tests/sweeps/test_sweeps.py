"""Tests for the repro.sweeps subsystem: registry expansion and grouping,
executor equivalences (registry path == engine path, chunked == unchunked),
per-group compilation, and the results layer.  Multi-device sharding is
covered by tests/distributed/_sweeps_sharded.py (own subprocess, forced
8-device CPU)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sweeps
from repro.core import throughput
from repro.sweeps.registry import RowMeta


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_families_expand_with_unique_names_and_catalogue():
    names = sweeps.family_names()
    assert {"fig3", "fig4", "kstar_table", "deadline_sweep", "bursty_chains",
            "hetero_kstar", "elastic_pool", "straggler_slack"} <= set(names)
    cat = sweeps.catalogue()
    for fam in names:
        scs = sweeps.expand(fam)
        assert scs, fam
        assert len({sc.name for sc in scs}) == len(scs), fam
        assert all(sc.family == fam for sc in scs)
        assert fam in cat


def test_expand_unknown_family_raises():
    with pytest.raises(KeyError):
        sweeps.expand("no_such_family")


def test_scenario_validation():
    sc = sweeps.expand("fig3", rounds=10)[0]
    import dataclasses
    with pytest.raises(ValueError):
        dataclasses.replace(sc, p_gg=(0.5,))            # wrong length
    with pytest.raises(ValueError):
        dataclasses.replace(sc, strategies=("nope",))   # unknown strategy
    with pytest.raises(ValueError):
        dataclasses.replace(sc, baseline="static_single")  # not in strategies


def test_build_groups_by_static_signature_and_row_layout():
    scs = sweeps.expand("fig4", rounds=16)
    groups = sweeps.build_groups(scs, seeds=3)
    # 6 scenarios over K* in {120, 100, 50} -> 3 groups of 2 scenarios
    assert len(groups) == 3
    assert sorted(g.lp.kstar for g in groups) == [50, 100, 120]
    for g in groups:
        assert len(g.scenarios) == 2
        assert g.batch.rows == len(g.rows) == 2 * 3
        assert g.rows == tuple(
            RowMeta(si, s) for si in range(2) for s in range(3)
        )
        assert g.batch.p_gg.shape == (6, g.lp.n)
        assert g.batch.keys.shape[0] == 6


def test_row_keys_replicate_paper_seed_then_fold_in():
    scs = sweeps.expand("fig3", rounds=8)
    (group,) = sweeps.build_groups(scs, seeds=2)
    for si, sc in enumerate(group.scenarios):
        base = jax.random.PRNGKey(sc.seed)
        rows = [ri for ri, rm in enumerate(group.rows) if rm.scenario_index == si]
        np.testing.assert_array_equal(np.asarray(group.batch.keys[rows[0]]),
                                      np.asarray(base))
        np.testing.assert_array_equal(np.asarray(group.batch.keys[rows[1]]),
                                      np.asarray(jax.random.fold_in(base, 1)))


def test_hetero_kstar_group_count_matches_ks():
    scs = sweeps.expand("hetero_kstar", ks=(50, 80, 99), lams=(0.1, 0.5), rounds=8)
    groups = sweeps.build_groups(scs)
    assert len(groups) == 3 and all(len(g.scenarios) == 2 for g in groups)


# ---------------------------------------------------------------------------
# executor: registry path == engine path, chunked == unchunked, compiles
# ---------------------------------------------------------------------------

ROUNDS = 160


def test_fig3_through_sweeps_bit_identical_to_compare():
    """The acceptance criterion: registry-path Fig. 3 values == PR-1 engine
    values on the same PRNG keys."""
    scs = sweeps.expand("fig3", rounds=ROUNDS)
    res = sweeps.run(scs)
    for sc, r in zip(scs, res):
        old = throughput.compare(
            jax.random.PRNGKey(sc.seed), sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, ROUNDS, strategies=sc.strategies,
        )
        assert old == r.throughput, sc.name


def test_fig4_through_sweeps_bit_identical_to_compare():
    scs = sweeps.expand("fig4", rounds=ROUNDS)
    res = sweeps.run(scs)
    for sc, r in zip(scs, res):
        old = throughput.compare(
            jax.random.PRNGKey(sc.seed), sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, ROUNDS, strategies=sc.strategies,
        )
        assert old == r.throughput, sc.name


def test_executor_chunked_matches_unchunked():
    scs = sweeps.expand("straggler_slack", speed_ratios=(2.0, 5.0),
                        deadlines=(1.0,), rounds=ROUNDS)
    groups = sweeps.build_groups(scs, seeds=2)
    plain = sweeps.run_groups(groups)
    for chunk in (1, 23, ROUNDS, 10 * ROUNDS):
        chunked = sweeps.run_groups(groups, round_chunk=chunk)
        for a, b in zip(plain, chunked):
            np.testing.assert_array_equal(a, b)


def test_executor_matches_core_sweep():
    scs = sweeps.expand("bursty_chains", lams=(0.2, 0.8), rounds=ROUNDS)
    (group,) = sweeps.build_groups(scs, seeds=2)
    got = sweeps.run_group(group)
    ref = throughput.sweep(
        group.batch.keys, group.lp, group.batch.p_gg, group.batch.p_bb,
        group.batch.mu_g, group.batch.mu_b, group.batch.deadline,
        group.rounds, strategies=group.strategies,
    )
    np.testing.assert_array_equal(got, np.asarray(ref))


def test_one_compile_per_group_for_hetero_kstar_grid():
    # fresh static signature (unique rounds) so cached entries don't mask it
    scs = sweeps.expand("hetero_kstar", ks=(50, 80, 99), lams=(0.15, 0.55, 0.85),
                        rounds=96)
    groups = sweeps.build_groups(scs, seeds=2)
    assert len(groups) == 3
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups)
    assert sweeps.compile_cache_size() - before == len(groups)
    # re-running the same grid compiles nothing new
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups)
    assert sweeps.compile_cache_size() == before


def test_suggest_round_chunk_scales_with_budget():
    scs = sweeps.expand("fig3", rounds=100_000)
    (group,) = sweeps.build_groups(scs, seeds=4)
    chunk = sweeps.suggest_round_chunk(group, budget_bytes=64 << 20)
    assert chunk is not None and 0 < chunk < 100_000
    assert sweeps.suggest_round_chunk(group, budget_bytes=1 << 50) is None


def test_suggest_round_chunk_rounds_smaller_than_chunk_is_none():
    """When the whole run fits the budget the chooser must decline to chunk —
    including the degenerate single-round group."""
    scs = sweeps.expand("fig3", rounds=48)
    (group,) = sweeps.build_groups(scs)
    assert sweeps.suggest_round_chunk(group, budget_bytes=1 << 30) is None
    one = sweeps.expand("fig3", rounds=1)
    (g1,) = sweeps.build_groups(one)
    # even a 1-byte budget cannot produce a chunk smaller than one round,
    # and chunk == rounds means "don't chunk"
    assert sweeps.suggest_round_chunk(g1, budget_bytes=1) is None


def test_suggest_round_chunk_floor_is_one_round():
    """An impossibly small budget clamps to chunk=1 (never 0, never None)."""
    scs = sweeps.expand("fig3", rounds=64)
    (group,) = sweeps.build_groups(scs, seeds=2)
    chunk = sweeps.suggest_round_chunk(group, budget_bytes=1)
    assert chunk == 1
    # and the engine accepts the floor, bit-identically
    (ref,) = sweeps.run_groups([group])
    (chunked,) = sweeps.run_groups([group], round_chunk=chunk)
    np.testing.assert_array_equal(ref, chunked)


def test_suggest_round_chunk_non_dividing_chunk_is_valid():
    """The chooser does not round to divisors; a non-dividing suggestion must
    execute bit-identically (the engine pads the final block)."""
    scs = sweeps.expand("fig3", rounds=100)
    (group,) = sweeps.build_groups(scs)
    budget = None
    for shift in range(14, 32):
        c = sweeps.suggest_round_chunk(group, budget_bytes=1 << shift)
        if c is not None and 1 < c < 100 and 100 % c != 0:
            budget = c
            break
    assert budget is not None, "no non-dividing chunk found in budget scan"
    (ref,) = sweeps.run_groups([group])
    (chunked,) = sweeps.run_groups([group], round_chunk=budget)
    np.testing.assert_array_equal(ref, chunked)


def test_kstar_table_expands_to_simulatable_scenarios_with_rounds():
    """Satellite: the catalogue-only family becomes genuinely runnable when
    expanded with rounds > 0 (default stays display-only, see
    test_catalogue_only_family_raises_clear_error)."""
    scs = sweeps.expand("kstar_table", rounds=16)
    assert all(sc.rounds == 16 for sc in scs)
    res = sweeps.run(scs)
    assert len(res) == len(scs)
    for r in res:
        assert 0.0 <= r.throughput["lea"] <= 1.0
    # paper-expected K* values still ride along in meta
    assert all(r.scenario.meta_dict()["expect_kstar"] >= 1 for r in res)


# ---------------------------------------------------------------------------
# results layer
# ---------------------------------------------------------------------------

def test_results_seeds_ratio_and_ci():
    scs = sweeps.expand("bursty_chains", lams=(0.3,), rounds=ROUNDS)
    res = sweeps.run(scs, seeds=4)
    (r,) = res
    assert r.seeds == 4
    for s in r.scenario.strategies:
        assert len(r.per_seed[s]) == 4
        assert abs(np.mean(r.per_seed[s]) - r.throughput[s]) < 1e-12
        lo, hi = r.ci95[s]
        assert 0.0 <= lo <= r.throughput[s] <= hi <= 1.0
    base = r.scenario.baseline
    assert r.ratio[base] == 1.0
    assert r.ratio["lea"] == r.throughput["lea"] / r.throughput[base]
    assert r.baseline_ratio >= 1.0  # lea/oracle should not lose to static here


def test_manifest_json_roundtrip():
    res = sweeps.run("elastic_pool", ns=(10, 15), rounds=64)
    doc = sweeps.manifest(res, bench="unit_test", extra={"devices": 1})
    blob = json.dumps(doc)
    back = json.loads(blob)
    assert back["bench"] == "unit_test" and back["scenarios"] == len(res)
    assert back["devices"] == 1
    for row in back["results"]:
        assert {"scenario", "family", "kstar", "baseline"} <= set(row)


def test_name_colliding_scenarios_keep_their_own_results():
    """The same family expanded twice (different rounds -> same names, different
    groups) must not alias: each scenario gets the result of ITS simulation."""
    a = sweeps.expand("deadline_sweep", deadlines=(1.0,), rounds=16)
    b = sweeps.expand("deadline_sweep", deadlines=(1.0,), rounds=32)
    res = sweeps.run(a + b)
    assert res[0].scenario.rounds == 16 and res[1].scenario.rounds == 32
    assert res[0].scenario is not res[1].scenario


def test_catalogue_only_family_raises_clear_error():
    with pytest.raises(ValueError, match="catalogue-only"):
        sweeps.run("kstar_table")


def test_seedless_streams_disjoint_from_explicit_paper_keys():
    """Mixing a seedless family with fig3 must not alias PRNG streams: the
    seedless fallback keys are fold_ins, never raw PRNGKey(i)."""
    scs = sweeps.expand("fig3", rounds=8) + sweeps.expand(
        "bursty_chains", lams=(0.2, 0.5), rounds=8
    )
    groups = sweeps.build_groups(scs)
    explicit = {tuple(np.asarray(jax.random.PRNGKey(i))) for i in range(len(scs))}
    seedless_keys = []
    for g in groups:
        for rm, sc in ((rm, g.scenarios[rm.scenario_index]) for rm in g.rows):
            k = tuple(np.asarray(g.batch.keys[g.rows.index(rm)]))
            if sc.seed is None:
                seedless_keys.append(k)
                assert k not in explicit
    # distinct seedless scenarios get distinct streams
    assert len(set(seedless_keys)) == len(seedless_keys)


def test_manifest_zero_baseline_ratio_is_rfc_json():
    """A baseline that never succeeds must serialize as null, not Infinity."""
    import dataclasses
    res = sweeps.run("bursty_chains", lams=(0.3,), rounds=32)
    (r,) = res
    rigged = dataclasses.replace(
        r,
        throughput={**r.throughput, r.scenario.baseline: 0.0},
        ratio={**r.ratio, "lea": float("inf")},
    )
    doc = sweeps.manifest([rigged], bench="inf_test")
    blob = json.dumps(doc, allow_nan=False)   # must not raise
    assert json.loads(blob)["results"][0]["ratio_lea"] is None


def test_summarize_rejects_row_mismatch():
    scs = sweeps.expand("fig3", rounds=8)
    (group,) = sweeps.build_groups(scs)
    with pytest.raises(ValueError):
        sweeps.summarize_group(group, np.zeros((1, 8, 3), bool))


# ---------------------------------------------------------------------------
# dense chain schedules (PR-4 satellite) + regret CIs
# ---------------------------------------------------------------------------

def test_dense_schedule_matches_piecewise_step1_bit_for_bit():
    """A dense spec built from a step-1 piecewise schedule materialises the
    SAME chain arrays and simulates bit-identically (same group shape)."""
    sc_p = sweeps.expand("drifting_chains", periods=(120,), rounds=96, step=1,
                         strategies=("lea", "static", "oracle"))[0]
    gg, bb = sc_p.chain_arrays()
    dense = sweeps.as_dense_schedule(gg, bb)
    import dataclasses
    # round-0 rows must match the dense spec's float32 materialisation
    sc_d = dataclasses.replace(sc_p, name="dense_twin", schedule=(),
                               p_gg=dense[0][0], p_bb=dense[1][0],
                               dense_schedule=dense, seed=3)
    sc_p = dataclasses.replace(sc_p, seed=3)
    np.testing.assert_array_equal(sc_d.chain_arrays()[0], gg)
    np.testing.assert_array_equal(sc_d.chain_arrays()[1], bb)
    assert sc_d.group_signature == sc_p.group_signature  # same compile group
    (g_p,) = sweeps.build_groups([sc_p])
    (g_d,) = sweeps.build_groups([sc_d])
    np.testing.assert_array_equal(
        sweeps.run_groups([g_p])[0], sweeps.run_groups([g_d])[0])


def test_dense_schedule_validation():
    import dataclasses
    sc = sweeps.expand("computed_drift", periods=(50,), rounds=40)[0]
    gg, bb = sc.chain_arrays()
    with pytest.raises(ValueError):      # schedule and dense are exclusive
        dataclasses.replace(
            sc, schedule=((0, sc.p_gg, sc.p_bb),))
    with pytest.raises(ValueError):      # wrong number of rows
        dataclasses.replace(sc, dense_schedule=sweeps.as_dense_schedule(
            gg[:-1], bb[:-1]))
    with pytest.raises(ValueError):      # round-0 row must match p_gg
        bad = gg.copy(); bad[0, 0] += 0.25
        dataclasses.replace(sc, dense_schedule=sweeps.as_dense_schedule(bad, bb))
    with pytest.raises(ValueError):      # mismatched array shapes
        sweeps.as_dense_schedule(gg, bb[:, :-1])


def test_computed_drift_family_runs_with_regret_ci_columns():
    res = sweeps.run("computed_drift", periods=(60,), rounds=80, seeds=2)
    assert [r.name for r in res] == ["cdrift_T60"]
    row = res[0].row()
    for s in ("lea", "lea_window64", "static"):
        assert f"regret_{s}" in row and f"regret_ci95_{s}" in row
        lo, hi = row[f"regret_ci95_{s}"]
        assert lo <= row[f"regret_{s}"] <= hi
    assert "regret_oracle" not in row
    json.dumps(row, allow_nan=False)     # manifest rows stay RFC JSON


def test_regret_ci_single_seed_uses_paired_per_round_width():
    res = sweeps.run("regime_switch", dwells=(40,), rounds=80, seeds=1)
    row = res[0].row()
    lo, hi = row["regret_ci95_lea"]
    assert hi > lo                       # CLT width from per-round diffs
    assert lo <= row["regret_lea"] <= hi


def test_regret_ci_multi_seed_shrinks_with_more_seeds():
    """Across-seed CI machinery: the half width is the z*s/sqrt(n) of the
    per-seed finals (checked against a direct recomputation)."""
    res = sweeps.run("regime_switch", dwells=(40,), rounds=60, seeds=4)
    r = res[0]
    from repro.sweeps.results import _Z95
    import math
    lo, hi = r.regret_ci95["lea"]
    assert abs((lo + hi) / 2 - r.regret["lea"]) < 1e-9
    # reconstruct the finals from the paired engine run
    (group,) = sweeps.build_groups(
        sweeps.expand("regime_switch", dwells=(40,), rounds=60), seeds=4)
    succ = sweeps.run_groups([group])[0]
    from repro.policies import regret as regret_mod
    finals = regret_mod.final_regret(succ, group.strategies)["lea"]
    want_half = _Z95 * finals.std(ddof=1) / math.sqrt(finals.size)
    assert abs((hi - lo) / 2 - want_half) < 1e-6
