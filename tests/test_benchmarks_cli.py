"""The benchmarks/run.py CLI: --list target discovery and target selection."""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cli(*args: str, timeout: int = 120):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT]
        + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=_ROOT,
    )


def test_list_prints_every_registered_target_with_description():
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    lines = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    from benchmarks.run import SUITES

    assert len(lines) == len(SUITES)
    for (name, _, desc), line in zip(SUITES, lines):
        assert line.startswith(name) and desc in line
    # --list must not print the CSV header (it runs nothing)
    assert "us_per_call" not in proc.stdout


def test_unknown_target_fails_with_target_listing():
    proc = _run_cli("no_such_bench")
    assert proc.returncode != 0
    assert "no_such_bench" in proc.stderr
    assert "bench_policies" in proc.stderr      # the listing helps recovery


def test_bench_policies_is_a_registered_target():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_policies" in names and "sweep_smoke" in names


def test_bench_gf_is_a_registered_target_and_listed():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_gf" in names
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    assert "bench_gf" in proc.stdout and "BENCH_gf.json" in proc.stdout


def test_suite_blurbs_name_exactly_the_manifests_they_write():
    """The SUITES table is the manifest contract: a blurb names a
    BENCH_*.json iff the target writes it, and every named file is
    committed at the repo root."""
    import re

    from benchmarks.run import SUITES

    writers = {
        "fig3_sim": "BENCH_fig3.json",
        "sweep_smoke": "BENCH_sweep.json",
        "bench_speed": "BENCH_speed.json",
        "bench_policies": "BENCH_policies.json",
        "bench_gf": "BENCH_gf.json",
        "bench_faults": "BENCH_faults.json",
        "bench_serving": "BENCH_serving.json",
        "obs_report": "BENCH_obs.json",
    }
    for name, _, desc in SUITES:
        named = re.findall(r"BENCH_\w+\.json", desc)
        if name in writers:
            assert named == [writers[name]], (name, desc)
            assert os.path.exists(os.path.join(_ROOT, writers[name])), name
        else:
            assert not named, f"{name} blurb names a manifest it never writes"


def test_every_committed_manifest_is_provenance_stamped():
    """The manifest contract: every BENCH_*.json writer stamps run
    provenance (repro.obs.provenance via results.manifest/write_manifest)
    and carries the structured ``warnings`` list the softgate records
    append to."""
    import glob
    import json

    from repro.obs.provenance import has_required_fields

    paths = sorted(glob.glob(os.path.join(_ROOT, "BENCH_*.json")))
    assert len(paths) >= 8, paths        # all eight writers are committed
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        name = os.path.basename(path)
        assert "provenance" in doc, f"{name} missing provenance stamp"
        assert has_required_fields(doc["provenance"]), name
        assert doc["provenance"]["git_sha"], name
        assert isinstance(doc.get("warnings"), list), name
        for w in doc["warnings"]:
            assert {"kind", "bench", "metric", "message"} <= set(w), (name, w)


def test_bench_speed_is_a_registered_target_and_listed():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_speed" in names
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    assert "bench_speed" in proc.stdout and "BENCH_speed.json" in proc.stdout


def test_committed_bench_speed_manifest_shape_and_invariants():
    """BENCH_speed.json is a committed artifact: the bit-identity, donation
    and warm-restart-0-compiles acceptance results must hold in the
    committed numbers.  rows/sec and the speedup itself are
    machine-dependent and follow the soft-gate convention (a miss is a
    recorded warning, never a hidden one), so only their presence, the
    honest before/after pairing and the structural flags are pinned."""
    import json

    with open(os.path.join(_ROOT, "BENCH_speed.json")) as f:
        doc = json.load(f)
    assert doc["bench"] == "bench_speed"
    assert doc["family"] == "hetero_kstar"
    # hard in-run gates, recorded
    assert doc["bitexact_async_vs_sync"] is True
    assert doc["donated_runtime"] is True
    assert doc["donation_hlo_alias"] is True
    # warm restart of the cached family attributed ZERO backend compiles
    assert doc["cache_warm_backend_compiles"] == 0
    assert doc["cache_cold_backend_compiles"] >= 1
    assert doc["cache_warm_persistent_hits"] >= 1
    # before/after measured in one process: both sides present and positive
    assert doc["sync_rows_per_sec"] > 0 and doc["async_rows_per_sec"] > 0
    assert doc["speedup_async_vs_sync"] == (
        doc["async_rows_per_sec"] / doc["sync_rows_per_sec"])
    assert doc["speedup_bar"] == 1.3
    # a below-bar committed run must carry the structured warning
    if doc["speedup_below_bar"]:
        assert any(w["kind"] == "speedup_bar" for w in doc["warnings"])
    # tap overlap accounting rode along (count > 0 iff events streamed)
    assert doc["tap_block_seconds_count"] > 0
    assert doc["pipeline_stats"]["blocks"] >= 1


def test_bench_faults_is_a_registered_target_and_listed():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_faults" in names
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    assert "bench_faults" in proc.stdout and "BENCH_faults.json" in proc.stdout


def test_committed_bench_faults_manifest_shape_and_invariants():
    """BENCH_faults.json is a committed artifact: the decode-mode dominance
    and executor-accounting acceptance results must hold in the committed
    numbers, not just in a fresh run.  rows/sec is machine-dependent and
    follows the soft-gate convention, so only its presence is pinned."""
    import json

    with open(os.path.join(_ROOT, "BENCH_faults.json")) as f:
        doc = json.load(f)
    assert doc["bench"] == "bench_faults"
    assert doc["family"] == "packet_erasure"
    assert doc["conserve_contains_aon"] is True
    assert doc["conserve_gain_rounds"] > 0
    # the whole fault grid fuses into one compiled computation
    assert doc["family_compiles"] == {"packet_erasure": 1}
    assert doc["rows_per_sec"] > 0
    # executor accounting: every round in exactly one disposition
    outcomes = doc["executor_outcomes"]
    assert set(outcomes) == {"on_time", "late", "partial", "dropped"}
    assert sum(outcomes.values()) == doc["executor_rounds"]
    assert doc["executor_outcomes_sum_ok"] is True
    for cell in doc["results"]:
        # containment, cell by cell, in the committed rates
        assert cell["recovered_conserve"] >= cell["recovered_aon"]
        assert 0.0 <= cell["served_any"] <= 1.0


def test_bench_serving_is_a_registered_target_and_listed():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_serving" in names
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    assert "bench_serving" in proc.stdout
    assert "BENCH_serving.json" in proc.stdout


def test_committed_bench_serving_manifest_shape_and_invariants():
    """BENCH_serving.json is a committed artifact: the admission-beats-
    admit-all acceptance result, the one-compile contract and the
    conservation flag must hold in the committed numbers, not just in a
    fresh run.  rows/sec is machine-dependent and follows the soft-gate
    convention, so only its presence is pinned."""
    import json

    with open(os.path.join(_ROOT, "BENCH_serving.json")) as f:
        doc = json.load(f)
    assert doc["bench"] == "bench_serving"
    assert doc["family"] == "arrival_grid"
    assert doc["conservation_ok"] is True
    # the acceptance criterion: controlled admission strictly beats
    # admit-all timely throughput on the overloaded cells
    assert doc["admission_beats_admit_all"] is True
    assert doc["admission_gain_requests"] > 0
    # the whole grid, admit-all AND controlled, is one compiled computation
    assert doc["family_compiles"] == {"arrival_grid": 1}
    assert doc["rows_per_sec"] > 0
    rates = set()
    overloaded_gain = 0
    for cell in doc["results"]:
        rates.add(cell["rate"])
        assert cell["served_on_time_controlled"] > 0
        assert cell["served_req_per_sec"] > 0
        # percentiles are real and ordered
        assert (cell["latency_p50_rounds"] <= cell["latency_p95_rounds"]
                <= cell["latency_p99_rounds"])
        assert cell["latency_p50_rounds"] >= 1.0
        if cell["overloaded"]:
            overloaded_gain += (cell["served_on_time_controlled"]
                                - cell["served_on_time_admit_all"])
    # latency + req/sec at >= 3 arrival rates, at least one overloaded
    assert len(rates) >= 3
    assert overloaded_gain == doc["admission_gain_requests"]


def test_obs_report_is_a_registered_target_and_listed():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "obs_report" in names
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    assert "obs_report" in proc.stdout and "BENCH_obs.json" in proc.stdout


def test_committed_obs_report_manifest_and_trace():
    """BENCH_obs.json is a committed artifact: the telemetry run compiled
    exactly once, the committed Chrome trace is structurally valid and its
    request dispositions reconcile (the flag the bench hard-gates in-run),
    and the cost model covers every hlo_cost entry point."""
    import json

    from repro.launch import hlo_cost
    from repro.obs import validate_trace

    with open(os.path.join(_ROOT, "BENCH_obs.json")) as f:
        doc = json.load(f)
    assert doc["bench"] == "obs_report"
    assert doc["telemetry_compiles"] == 1
    assert doc["trace_dispositions_ok"] is True
    assert doc["trace_complete"] > 0
    targets = {row["target"] for row in doc["cost_model"]}
    assert targets == set(hlo_cost.entry_point_names())
    for row in doc["cost_model"]:
        assert row["flops"] > 0 and row["hbm_bytes"] > 0, row["target"]
    # every sibling manifest was aggregated
    assert set(doc["manifests"]) >= {
        "BENCH_fig3.json", "BENCH_sweep.json", "BENCH_policies.json",
        "BENCH_gf.json", "BENCH_faults.json", "BENCH_serving.json",
    }
    assert doc["missing_provenance"] == []
    # the live tier rode along: tap events streamed during the demo run and
    # the trend section spans a real history trajectory
    assert doc["tap_events"] > 0
    assert doc["trend"]["entries"] >= 2
    assert "regressions" in doc["trend"] and "series" in doc["trend"]
    # the committed trace itself must be a valid trace-event document, and
    # it lives under benchmarks/artifacts/ (the root stays manifest-only)
    assert doc["trace_path"].replace(os.sep, "/").startswith(
        "benchmarks/artifacts/"
    )
    with open(os.path.join(_ROOT, doc["trace_path"])) as f:
        trace = json.load(f)
    stats = validate_trace(trace)
    assert stats["complete"] == doc["trace_complete"]
    assert stats["dispositions"] == doc["trace_dispositions"]


def test_committed_bench_gf_manifest_shape_and_flags():
    """BENCH_gf.json is a committed artifact: it must carry the exact-path
    speedup fields and the bit-exactness flag.  Speedups themselves follow
    the repo's soft-perf convention (sweep_smoke): the bench WARNS below
    the 5x bar and records ``speedup_below_bar``, but wall-clock numbers
    are machine-dependent so the unit test only pins the structure and the
    algorithmic floor (device beats numpy at all)."""
    import json

    with open(os.path.join(_ROOT, "BENCH_gf.json")) as f:
        doc = json.load(f)
    assert doc["bench"] == "bench_gf"
    assert doc["bit_exact_vs_numpy"] is True
    assert doc["field_p"] == (1 << 31) - 1
    assert doc["speedup_bar"] == 5.0
    for key in ("speedup_encode_gemm", "speedup_decode_matrix",
                "speedup_exact_round"):
        assert doc[key] > 1.0, key
    # the committed manifest (this container, idle) must meet the bar
    assert doc["speedup_below_bar"] is False
    names = [r["name"] for r in doc["results"]]
    assert names == ["gf_encode_gemm", "gf_decode_matrix", "gf_exact_round"]
