"""The benchmarks/run.py CLI: --list target discovery and target selection."""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cli(*args: str, timeout: int = 120):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT]
        + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=_ROOT,
    )


def test_list_prints_every_registered_target_with_description():
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    lines = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    from benchmarks.run import SUITES

    assert len(lines) == len(SUITES)
    for (name, _, desc), line in zip(SUITES, lines):
        assert line.startswith(name) and desc in line
    # --list must not print the CSV header (it runs nothing)
    assert "us_per_call" not in proc.stdout


def test_unknown_target_fails_with_target_listing():
    proc = _run_cli("no_such_bench")
    assert proc.returncode != 0
    assert "no_such_bench" in proc.stderr
    assert "bench_policies" in proc.stderr      # the listing helps recovery


def test_bench_policies_is_a_registered_target():
    from benchmarks.run import SUITES

    names = [name for name, _, _ in SUITES]
    assert "bench_policies" in names and "sweep_smoke" in names
