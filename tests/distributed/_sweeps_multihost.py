"""2-process ``jax.distributed`` sweep: multi-host manifest == single-host.

The parent (no argv) computes the single-host reference manifest, then
spawns itself twice with ``--worker <pid>`` (coordinator on a localhost
port, 2 processes).  Each worker joins the grid via
``repro.launch.mesh.init_distributed``, runs
``repro.sweeps.run_multihost`` — interleaved row shards through the
ordinary executor on LOCAL devices, spool-file merge on process 0 — and
process 0 writes its manifest.  The parent asserts the merged multi-host
document is BIT-identical (same JSON, fixed timestamp) to the single-host
one, prints the marker.

World=1 degeneration is also pinned here: ``run_multihost`` outside any
grid must return byte-identical results to plain ``run``.
"""

import json
import os
import socket
import subprocess
import sys

FAMILY_KW = dict(ks=(50, 99), lams=(0.2, 0.7), rounds=96)
SEEDS = 2
MARKER = "SWEEPS_MULTIHOST_OK"


def _manifest_doc(results):
    from repro.sweeps import results as results_mod

    doc = results_mod.manifest(results, bench="multihost_test", timestamp=0.0)
    # provenance is host/process state, not simulation output — the
    # bit-identity claim is about every computed row
    doc.pop("provenance", None)
    return doc


def _run_single():
    from repro.sweeps import run

    return run("hetero_kstar", seeds=SEEDS, **FAMILY_KW)


def worker(pid: int, coord: str, spool: str, out_path: str) -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    from repro.launch.mesh import init_distributed, make_sweep_mesh
    from repro.sweeps import run_multihost

    wpid, nprocs = init_distributed(coordinator=coord, num_processes=2,
                                    process_id=pid)
    assert (wpid, nprocs) == (pid, 2), (wpid, nprocs)
    results = run_multihost("hetero_kstar", seeds=SEEDS, spool_dir=spool,
                            mesh=make_sweep_mesh(), round_chunk=24,
                            pipeline=True, **FAMILY_KW)
    if pid == 0:
        assert results is not None
        with open(out_path, "w") as f:
            json.dump(_manifest_doc(results), f, indent=2)
    else:
        assert results is None
    import jax

    jax.distributed.shutdown()


def main() -> None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        # single-host reference, same executor knobs
        from repro.launch.mesh import make_sweep_mesh, world
        from repro.sweeps import run, run_multihost

        assert world() == (0, 1)
        ref = run("hetero_kstar", seeds=SEEDS, mesh=make_sweep_mesh(),
                  round_chunk=24, pipeline=True, **FAMILY_KW)
        ref_doc = _manifest_doc(ref)

        # world=1 degeneration: run_multihost IS run outside any grid
        deg = run_multihost("hetero_kstar", seeds=SEEDS,
                            spool_dir=os.path.join(tmp, "unused"),
                            mesh=make_sweep_mesh(), round_chunk=24,
                            pipeline=True, **FAMILY_KW)
        assert json.dumps(_manifest_doc(deg), sort_keys=True) == \
            json.dumps(ref_doc, sort_keys=True), "world=1 degeneration broke"

        # 2-process grid: same manifest, bit for bit
        with socket.socket() as s:
            s.bind(("localhost", 0))
            coord = f"localhost:{s.getsockname()[1]}"
        spool = os.path.join(tmp, "spool")
        out_path = os.path.join(tmp, "multihost.json")
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)   # workers set their own
        procs = [
            subprocess.Popen(
                [sys.executable, os.path.abspath(__file__), "--worker",
                 str(pid), coord, spool, out_path],
                env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True,
            )
            for pid in range(2)
        ]
        logs = [p.communicate(timeout=540)[0] for p in procs]
        for p, log in zip(procs, logs):
            assert p.returncode == 0, f"worker failed:\n{log}"
        with open(out_path) as f:
            multi_doc = json.load(f)
        assert json.dumps(multi_doc, sort_keys=True) == \
            json.dumps(ref_doc, sort_keys=True), (
            "multi-host manifest != single-host")
        print(MARKER)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--worker":
        worker(int(sys.argv[2]), sys.argv[3], sys.argv[4], sys.argv[5])
    else:
        main()
