"""Multi-device tests, each in its own subprocess so XLA_FLAGS device-count
overrides never leak into the main test process (see conftest note)."""

import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))


def _run(script: str, marker: str, timeout: int = 600):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # script sets its own
    proc = subprocess.run(
        [sys.executable, os.path.join(_HERE, script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"{script} failed:\n{proc.stdout}\n{proc.stderr}"
    assert marker in proc.stdout, proc.stdout


def test_sharded_train_step_matches_single_device():
    _run("_sharded_train.py", "SHARDED_TRAIN_OK")


def test_pipeline_parallel_matches_reference():
    _run("_pp_forward.py", "PP_OK")


def test_elastic_reshard_roundtrip():
    _run("_elastic_reshard.py", "ELASTIC_OK")


def test_dryrun_cli_single_cell():
    """The dry-run entrypoint itself (512 fake devices) on the cheapest cell."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_HERE, "..", "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper_tiny", "--shape", "decode_32k",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all cells ok" in proc.stdout


def test_moe_expert_parallel_matches_dense():
    _run("_moe_ep.py", "MOE_EP_OK")


def test_sweeps_sharded_executor_matches_unsharded():
    _run("_sweeps_sharded.py", "SWEEPS_SHARDED_OK")


def test_sweeps_multihost_merge_matches_single_host():
    """2-process jax.distributed grid: spool-merged manifest bit-identical
    to the single-host run (plus the world=1 degeneration)."""
    _run("_sweeps_multihost.py", "SWEEPS_MULTIHOST_OK")
