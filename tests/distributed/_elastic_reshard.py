"""Subprocess body: checkpoint written from an 8-device mesh restores onto a
4-device mesh (elastic shrink) with identical values."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.checkpoint import restore, save
from repro.configs.base import get_smoke_config
from repro.models import api
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.runtime.elastic import remap_estimator
from repro.core import lea


def main():
    cfg = get_smoke_config("qwen3_0_6b")
    state = api.init_state(cfg, jax.random.PRNGKey(0))

    mesh8 = make_host_mesh((2, 4), ("data", "model"))
    with mesh8, use_mesh(mesh8):
        sh8 = api.state_shardings(cfg, mesh8, state)
        state8 = jax.device_put(state, sh8)
    d = tempfile.mkdtemp()
    save(d, 3, state8)

    # "shrink" to a 4-device submesh (1 data x 4 model)
    import numpy as _np
    devs = _np.asarray(jax.devices()[:4]).reshape(1, 4)
    from jax.sharding import Mesh
    mesh4 = Mesh(devs, ("data", "model"))
    with mesh4, use_mesh(mesh4):
        sh4 = api.state_shardings(cfg, mesh4, state)
        restored, _ = restore(d, 3, state, shardings=sh4)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    # LEA estimator remap across worker-pool resize
    est = lea.init_estimator(8)
    import jax.numpy as jnp
    est = lea.update_estimator(est, jnp.ones((8,), jnp.int32))
    est = lea.update_estimator(est, jnp.zeros((8,), jnp.int32))
    shrunk = remap_estimator(est, 8, 4, survivors=[0, 2, 4, 6])
    assert shrunk.counts.shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(shrunk.counts[1]), np.asarray(est.counts[2]))
    grown = remap_estimator(est, 8, 10)
    assert grown.counts.shape == (10, 4)
    print("ELASTIC_OK")


if __name__ == "__main__":
    main()
