"""Subprocess body: expert-parallel MoE equals the dense-dispatch MoE on a
4-way model mesh (E=8 experts, 2 per shard)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh


def main():
    cfg = ArchConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=16, vocab_size=128, n_experts=8, top_k=2,
        capacity_factor=100.0, dtype="float32", remat=False,
    )
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    p = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.5,
        "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.2,
        "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.2,
        "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.2,
    }
    x = jax.random.normal(ks[4], (2, 6, d))
    want = L.moe(x, p, cfg)                          # dense dispatch, no mesh

    mesh = make_host_mesh((1, 4), ("data", "model"))
    cfg_ep = dataclasses.replace(cfg, moe_impl="ep")
    with mesh, use_mesh(mesh):
        got = jax.jit(lambda xx, pp: L.moe(xx, pp, cfg_ep))(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    print("MOE_EP_OK")


if __name__ == "__main__":
    main()
