"""Subprocess body: GPipe pipeline over a 4-stage axis matches the sequential
oracle."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch.mesh import make_host_mesh
from repro.runtime.pipeline_parallel import pipeline_forward, reference_forward


def main():
    mesh = make_host_mesh((4,), ("pod",))
    s_count, m_count, mb, d = 4, 6, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {
        "w": jax.random.normal(ks[0], (s_count, d, d)) * 0.3,
        "b": jax.random.normal(ks[1], (s_count, d)) * 0.1,
    }
    x = jax.random.normal(ks[2], (m_count, mb, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    got = pipeline_forward(stage_fn, params, x, mesh, axis="pod")
    want = reference_forward(stage_fn, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    print("PP_OK")


if __name__ == "__main__":
    main()
