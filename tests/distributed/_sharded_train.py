"""Subprocess body: sharded train step on an 8-device host mesh must match the
single-device result bit-for-reasonable-tolerance.  Run by test_multidevice."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs.base import ShapeCell, get_smoke_config
from repro.models import api
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh


def main():
    cfg = get_smoke_config("qwen3_0_6b")
    cell = ShapeCell("t", seq_len=32, global_batch=4, kind="train")
    key = jax.random.PRNGKey(0)
    state = api.init_state(cfg, key)
    batch = api.make_batch(cfg, cell, key)

    # single-device reference
    step = api.make_train_step(cfg, peak_lr=1e-3, warmup=1)
    ref_state, ref_metrics = jax.jit(step)(state, batch)
    ref_loss = float(ref_metrics["loss"])

    # sharded (2 data x 4 model)
    mesh = make_host_mesh((2, 4), ("data", "model"))
    with mesh, use_mesh(mesh):
        sh_state = api.state_shardings(cfg, mesh, state)
        sh_batch = api.batch_shardings(cfg, mesh, api.input_specs(cfg, cell))
        state_d = jax.device_put(state, sh_state)
        batch_d = jax.device_put(batch, sh_batch)
        jitted = jax.jit(step, in_shardings=(sh_state, sh_batch),
                         out_shardings=(sh_state, None))
        new_state, metrics = jitted(state_d, batch_d)
    loss = float(metrics["loss"])
    assert abs(loss - ref_loss) < 2e-3, (loss, ref_loss)

    # updated params equal too
    for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(new_state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
    print("SHARDED_TRAIN_OK", loss)


if __name__ == "__main__":
    main()
