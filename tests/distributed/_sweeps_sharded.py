"""Subprocess body: the repro.sweeps executor on a forced 8-device CPU host.

Asserts, on a heterogeneous-K* registry grid:
  * the traced-K* engine fuses the WHOLE grid into one group and one
    executor compile;
  * sharded executor output == per-row static-``LoadParams``
    ``core.throughput.simulate_strategies``, bit-exact (the full-width
    invariant of the shape-polymorphic engine; the 18-row batch does NOT
    divide the device count -> exercises mesh padding too);
  * sharded + round-chunked == sharded unchunked, bit-exact.
Run by tests/distributed/test_multidevice.py.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro import sweeps
from repro.core import throughput
from repro.launch.mesh import make_sweep_mesh

ROUNDS = 128


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_sweep_mesh()

    # 3 K*s x 2 chains x 3 seeds = 18 rows, ONE fused group (pads 18 -> 24)
    scenarios = sweeps.expand(
        "hetero_kstar", ks=(50, 80, 99), lams=(0.25, 0.65), rounds=ROUNDS
    )
    groups = sweeps.build_groups(scenarios, seeds=3)
    assert len(groups) == 1, len(groups)
    (group,) = groups
    assert group.batch.rows == 18                   # forces pad to 24
    assert sorted(set(int(k) for k in np.asarray(group.batch.kstar))) == [50, 80, 99]

    before = sweeps.compile_cache_size()
    sharded = sweeps.run_groups(groups, mesh=mesh)
    compiles = sweeps.compile_cache_size() - before
    assert compiles == len(groups) == 1, (compiles, len(groups))

    # sharded fused == per-row static-LoadParams engine, bit-identical
    (succ,) = sharded
    for ri, rm in enumerate(group.rows):
        sc = group.scenarios[rm.scenario_index]
        ref = throughput.simulate_strategies(
            group.batch.keys[ri], sc.lp,
            jnp.asarray(sc.p_gg), jnp.asarray(sc.p_bb),
            sc.mu_g, sc.mu_b, sc.deadline, group.rounds,
            strategies=group.strategies,
        )
        np.testing.assert_array_equal(succ[ri], np.asarray(ref))

    # sharded + chunked == sharded unchunked, bit-identical (37 does not
    # divide 128, exercising the round-padding path too)
    chunked = sweeps.run_groups(groups, mesh=mesh, round_chunk=37)
    for a, b in zip(sharded, chunked):
        np.testing.assert_array_equal(a, b)

    # re-running an already-compiled grid adds no compiles
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups, mesh=mesh)
    assert sweeps.compile_cache_size() == before

    # results fold correctly on the sharded output
    results = sweeps.summarize(groups, sharded, scenario_order=scenarios)
    assert [r.name for r in results] == [sc.name for sc in scenarios]
    print("SWEEPS_SHARDED_OK", f"groups={len(groups)}", f"compiles={compiles}")


if __name__ == "__main__":
    main()
