"""Subprocess body: the repro.sweeps executor on a forced 8-device CPU host.

Asserts, on a heterogeneous-K* registry grid:
  * sharded executor output == unsharded ``core.throughput.sweep``, bit-exact
    (including a batch size that does NOT divide the device count -> padding);
  * sharded + round-chunked == sharded unchunked, bit-exact;
  * exactly one executor compile per LoadParams group.
Run by tests/distributed/test_multidevice.py.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro import sweeps
from repro.core import throughput
from repro.launch.mesh import make_sweep_mesh

ROUNDS = 128


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_sweep_mesh()

    # 3 K* groups x 2 chains x 3 seeds = 6 rows per group (pads 6 -> 8)
    scenarios = sweeps.expand(
        "hetero_kstar", ks=(50, 80, 99), lams=(0.25, 0.65), rounds=ROUNDS
    )
    groups = sweeps.build_groups(scenarios, seeds=3)
    assert len(groups) == 3
    assert all(g.batch.rows == 6 for g in groups)   # forces pad to 8

    before = sweeps.compile_cache_size()
    sharded = sweeps.run_groups(groups, mesh=mesh)
    compiles = sweeps.compile_cache_size() - before
    assert compiles == len(groups), (compiles, len(groups))

    # sharded == unsharded core.throughput.sweep, bit-identical
    for g, s in zip(groups, sharded):
        ref = throughput.sweep(
            g.batch.keys, g.lp, g.batch.p_gg, g.batch.p_bb,
            g.batch.mu_g, g.batch.mu_b, g.batch.deadline,
            g.rounds, strategies=g.strategies,
        )
        np.testing.assert_array_equal(s, np.asarray(ref))

    # sharded + chunked == sharded unchunked, bit-identical (chunk pads 128->?
    # no: 37 does not divide 128, exercising the round-padding path too)
    chunked = sweeps.run_groups(groups, mesh=mesh, round_chunk=37)
    for a, b in zip(sharded, chunked):
        np.testing.assert_array_equal(a, b)

    # re-running an already-compiled grid adds no compiles
    before = sweeps.compile_cache_size()
    sweeps.run_groups(groups, mesh=mesh)
    assert sweeps.compile_cache_size() == before

    # results fold correctly on the sharded output
    results = sweeps.summarize(groups, sharded, scenario_order=scenarios)
    assert [r.name for r in results] == [sc.name for sc in scenarios]
    print("SWEEPS_SHARDED_OK", f"groups={len(groups)}", f"compiles={compiles}")


if __name__ == "__main__":
    main()
