"""Fault-injector registry, monotonicity and determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.faults.channels import _FAULT_KEY_TAG

ROUNDS, N, R, P = 16, 5, 3, 4
DEADLINE = 1.0

ALL_CHANNELS = [
    ("crash_restart", {"p_crash": 0.3, "p_restart": 0.5}),
    ("preempt", {"p_preempt": 0.5, "min_frac": 0.2}),
    ("packet_bernoulli", {"p_drop": 0.3}),
    ("gilbert_elliott", {"p_gb": 0.3, "p_bg": 0.4, "drop_bad": 0.8}),
    ("burst", {"p_event": 0.4, "frac": 0.5}),
]


def _base():
    return faults.base_trace(ROUNDS, N, R, P, DEADLINE)


def test_registry_lists_all_builtin_injectors():
    assert faults.injector_names() == (
        "burst", "crash_restart", "gilbert_elliott", "packet_bernoulli",
        "preempt",
    )


def test_make_injector_unknown_name_lists_available():
    with pytest.raises(KeyError, match="packet_bernoulli"):
        faults.make_injector("no_such_fault")


def test_make_channel_builds_named_injectors_in_order():
    ch = faults.make_channel(ALL_CHANNELS)
    assert [inj.injector_name for inj in ch] == [n for n, _ in ALL_CHANNELS]


def test_base_trace_is_no_fault():
    tr = _base()
    assert tr.rounds == ROUNDS
    np.testing.assert_array_equal(np.asarray(tr.t_cut),
                                  np.full((ROUNDS, N), DEADLINE, np.float32))
    assert bool(jnp.all(tr.keep))


@pytest.mark.parametrize("name,params", ALL_CHANNELS)
def test_every_injector_is_monotone(name, params):
    """t_cut only decreases, keep only loses packets — injectors can never
    manufacture work, on any key."""
    inj = faults.make_injector(name, **params)
    tr = _base()
    for seed in range(3):
        out = inj.apply(jax.random.PRNGKey(seed), tr)
        assert out.t_cut.shape == tr.t_cut.shape
        assert out.keep.shape == tr.keep.shape
        assert bool(jnp.all(out.t_cut <= tr.t_cut))
        assert bool(jnp.all(out.keep <= tr.keep))


@pytest.mark.parametrize("name,params", ALL_CHANNELS)
def test_every_injector_actually_degrades(name, params):
    """At these rates, some fault fires within 16 rounds (not a no-op)."""
    inj = faults.make_injector(name, **params)
    out = inj.apply(jax.random.PRNGKey(0), _base())
    degraded = (not bool(jnp.all(out.t_cut == DEADLINE))) or (
        not bool(jnp.all(out.keep))
    )
    assert degraded


def test_apply_channel_is_deterministic_in_key():
    ch = faults.make_channel(ALL_CHANNELS)
    key = jax.random.PRNGKey(7)
    a = faults.apply_channel(key, ch, _base())
    b = faults.apply_channel(key, ch, _base())
    np.testing.assert_array_equal(np.asarray(a.t_cut), np.asarray(b.t_cut))
    np.testing.assert_array_equal(np.asarray(a.keep), np.asarray(b.keep))
    c = faults.apply_channel(jax.random.PRNGKey(8), ch, _base())
    assert not (np.array_equal(np.asarray(a.t_cut), np.asarray(c.t_cut))
                and np.array_equal(np.asarray(a.keep), np.asarray(c.keep)))


def test_channel_prefix_shares_faults_exactly():
    """Per-injector subkeys are fold_in(key, position): two channels sharing
    a prefix realise that prefix's faults identically."""
    key = jax.random.PRNGKey(3)
    short = faults.make_channel(ALL_CHANNELS[:2])
    long = faults.make_channel(ALL_CHANNELS)
    a = faults.apply_channel(key, short, _base())
    b = faults.apply_channel(key, long, _base())
    # the long channel's extra injectors only REMOVE work from the prefix
    assert bool(jnp.all(b.t_cut <= a.t_cut))
    assert bool(jnp.all(b.keep <= a.keep))
    # and the t_cut-only prefix (crash+preempt) is bit-identical: the keep
    # injectors that follow never touch t_cut
    np.testing.assert_array_equal(np.asarray(a.t_cut), np.asarray(b.t_cut))


def test_fault_key_is_a_distinct_stream():
    key = jax.random.PRNGKey(0)
    fk = faults.fault_key(key)
    assert not np.array_equal(np.asarray(fk), np.asarray(key))
    np.testing.assert_array_equal(
        np.asarray(fk), np.asarray(jax.random.fold_in(key, _FAULT_KEY_TAG))
    )


def test_crash_restart_zeroes_crashed_rounds():
    inj = faults.make_injector("crash_restart", p_crash=0.5, p_restart=0.3)
    out = inj.apply(jax.random.PRNGKey(1), _base())
    t = np.asarray(out.t_cut)
    # a crashed round contributes nothing; an alive one keeps the deadline
    assert set(np.unique(t)).issubset({0.0, np.float32(DEADLINE)})
    assert (t == 0.0).any()
    # round 0 starts alive for every worker
    np.testing.assert_array_equal(t[0], np.full(N, DEADLINE, np.float32))


def test_burst_wipes_packet_tail_fleet_wide():
    inj = faults.make_injector("burst", p_event=1.0, frac=0.5)
    out = inj.apply(jax.random.PRNGKey(0), _base())
    keep = np.asarray(out.keep)
    # every round is hit: last half of packet indices gone everywhere,
    # first half untouched
    assert not keep[..., P // 2:].any()
    assert keep[..., : P // 2].all()
