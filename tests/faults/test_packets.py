"""Packet-level scoring and decode: bit-identity, containment, dominance.

The acceptance properties of the partial-work-conservation tentpole:

  * at ``packets=1`` with no faults, the packet path IS the existing
    all-or-nothing path bit-for-bit — masks vs ``chunk_on_time``, float
    decode vs ``coded_matmul_device``, exact GF(p) decode vs
    ``coded_matmul_exact`` (property-tested over random instances);
  * AON ⊆ conserve pointwise on ANY trace, so a conserving decode never
    loses a round the all-or-nothing decode recovers;
  * under injected preemption the conserving/hierarchical decode recovers
    STRICTLY more rounds than all-or-nothing on the same PRNG keys;
  * the batched fault engine compiles ONCE per static signature across a
    whole channel-parameter grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.core import lea
from repro.core.coded_ops import (CodeSpec, chunk_on_time, coded_matmul_device,
                                  coded_matmul_exact, encode_dataset,
                                  encode_dataset_modp)
from repro.faults.packets import (coded_matmul_exact_packets,
                                  coded_matmul_packets, layer1_recovery,
                                  packet_counts, packet_on_time)

MU_G, MU_B, DEADLINE = 10.0, 3.0, 1.0


def _states_loads(seed, m, n, r):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    states = jax.random.bernoulli(k0, 0.6, (m, n)).astype(jnp.int32)
    loads = jax.random.randint(k1, (m, n), 0, r + 1)
    return states, loads


# ---------------------------------------------------------------------------
# bit-identity at packets=1, no faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_p1_aon_mask_is_chunk_on_time_bitwise(seed):
    n, r = 7, 4
    states, loads = _states_loads(seed, 6, n, r)
    ref = chunk_on_time(states, loads, MU_G, MU_B, DEADLINE, r)
    m = packet_on_time(states, loads, MU_G, MU_B, DEADLINE, r, 1,
                       trace=None, conserve=False)
    assert m.shape == (6, n * r, 1)
    np.testing.assert_array_equal(np.asarray(m[..., 0]), np.asarray(ref))
    # conserve=True at packets=1 is chunk-level work conservation: a strict
    # SUPERSET of the all-or-nothing mask (chunks that individually meet the
    # deadline count even when the worker's whole load does not)
    con = packet_on_time(states, loads, MU_G, MU_B, DEADLINE, r, 1,
                         trace=None, conserve=True)
    assert bool(jnp.all(~m | con))


@pytest.mark.parametrize("seed", range(3))
def test_p1_float_decode_is_coded_matmul_device_bitwise(seed):
    rng = np.random.default_rng(seed)
    spec = CodeSpec(n=6, r=2, k=4, deg_f=1)
    coded = encode_dataset(
        spec, rng.normal(size=(4, 8, 3)).astype(np.float32)
    )
    w = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    on_time = jnp.asarray(rng.random(spec.n * spec.r) < 0.75)
    ref, ok_ref = coded_matmul_device(coded, w, on_time)
    out, ok = coded_matmul_packets(coded, w, on_time[:, None])
    assert ok.shape == (1,)
    assert bool(ok[0]) == bool(ok_ref)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("seed", range(3))
def test_p1_exact_gf_decode_is_coded_matmul_exact_bitwise(seed):
    rng = np.random.default_rng(seed)
    spec = CodeSpec(n=6, r=2, k=4, deg_f=1)
    coded = encode_dataset_modp(
        spec, rng.integers(0, 997, size=(4, 8, 3)).astype(np.int64)
    )
    w = rng.integers(0, 997, size=(3,)).astype(np.int64)
    on_time = jnp.asarray(rng.random(spec.n * spec.r) < 0.75)
    ref, ok_ref = coded_matmul_exact(coded, w, on_time)
    out, ok = coded_matmul_exact_packets(coded, w, on_time[:, None])
    assert bool(ok[0]) == bool(ok_ref)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_per_packet_blocks_match_single_mask_decodes():
    """Each decodable packet block equals the same rows of a full decode run
    with that packet's mask — packets decouple row-wise."""
    rng = np.random.default_rng(0)
    spec = CodeSpec(n=6, r=2, k=4, deg_f=1)
    rows, P = 8, 4
    coded = encode_dataset(
        spec, rng.normal(size=(4, rows, 3)).astype(np.float32)
    )
    w = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    pm = jnp.asarray(rng.random((spec.n * spec.r, P)) < 0.8)
    out, ok = coded_matmul_packets(coded, w, pm)
    rp = rows // P
    for q in range(P):
        ref_q, ok_q = coded_matmul_device(coded, w, pm[:, q])
        assert bool(ok[q]) == bool(ok_q)
        if bool(ok_q):
            np.testing.assert_array_equal(
                np.asarray(out[:, q * rp:(q + 1) * rp]),
                np.asarray(ref_q[:, q * rp:(q + 1) * rp]),
            )


def test_rows_must_divide_into_packets():
    rng = np.random.default_rng(0)
    spec = CodeSpec(n=6, r=2, k=4, deg_f=1)
    coded = encode_dataset(
        spec, rng.normal(size=(4, 8, 3)).astype(np.float32)
    )
    w = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    pm = jnp.ones((spec.n * spec.r, 3), bool)
    with pytest.raises(ValueError, match="divide"):
        coded_matmul_packets(coded, w, pm)


# ---------------------------------------------------------------------------
# containment + dominance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_aon_mask_subset_of_conserve_on_any_trace(seed):
    n, r, P = 7, 4, 4
    states, loads = _states_loads(seed, 10, n, r)
    trace = faults.base_trace(10, n, r, P, DEADLINE)
    trace = faults.apply_channel(
        jax.random.PRNGKey(seed),
        faults.make_channel([
            ("preempt", {"p_preempt": 0.5}),
            ("packet_bernoulli", {"p_drop": 0.2}),
        ]),
        trace,
    )
    for tr in (None, trace):
        aon = packet_on_time(states, loads, MU_G, MU_B, DEADLINE, r, P,
                             trace=tr, conserve=False)
        con = packet_on_time(states, loads, MU_G, MU_B, DEADLINE, r, P,
                             trace=tr, conserve=True)
        assert bool(jnp.all(~aon | con)), "AON packet missing from conserve"


def test_preempted_work_counts_only_under_conserve():
    """One worker, load 4, preempted at half its round: AON loses everything,
    conserve keeps the packets finished before the cut."""
    n, r, P = 1, 4, 4
    states = jnp.ones((1, n), jnp.int32)
    loads = jnp.full((1, n), 4)
    mu = 4.0  # exactly clears 4 chunks by the deadline
    trace = faults.base_trace(1, n, r, P, DEADLINE)
    trace = trace._replace(t_cut=jnp.full((1, n), 0.5, jnp.float32))
    aon = packet_on_time(states, loads, mu, mu, DEADLINE, r, P,
                         trace=trace, conserve=False)
    con = packet_on_time(states, loads, mu, mu, DEADLINE, r, P,
                         trace=trace, conserve=True)
    assert int(aon.sum()) == 0
    # chunks 0 and 1 finish by t=0.5: 8 packets survive the preemption
    assert int(con.sum()) == 8


def test_counts_and_layer1():
    masks = jnp.asarray([[True, False], [True, True], [False, False]])
    np.testing.assert_array_equal(np.asarray(packet_counts(masks)), [2, 1])
    counts = jnp.asarray([[3, 1], [2, 2], [1, 0]])
    np.testing.assert_array_equal(
        np.asarray(layer1_recovery(counts, 2, 1)), [True, True, False]
    )
    np.testing.assert_array_equal(
        np.asarray(layer1_recovery(counts, 2, 2)), [False, True, False]
    )


# ---------------------------------------------------------------------------
# engine: dominance under preemption + one compile per signature
# ---------------------------------------------------------------------------

def _pool(b, n, kstar, ell_g, ell_b):
    return lea.PoolLoad(
        kstar=jnp.full((b,), kstar, jnp.int32),
        ell_g=jnp.full((b,), ell_g, jnp.int32),
        ell_b=jnp.full((b,), ell_b, jnp.int32),
        mask=jnp.ones((b, n), bool),
    )


def test_conserve_recovers_strictly_more_rounds_under_preemption():
    n, r, P, b = 8, 6, 4, 4
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    channel = faults.make_channel([
        ("preempt", {"p_preempt": jnp.asarray([0.2, 0.3, 0.4, 0.5])}),
    ])
    out = faults.sweep_faults(
        keys, _pool(b, n, 30, 6, 2),
        jnp.full((b, n), 0.8), jnp.full((b, n), 0.7),
        MU_G, MU_B, DEADLINE, channel, 15,
        rounds=128, strategies=("lea", "static"), r=r, packets=P, p1=1,
    )
    aon = np.asarray(out.full_aon)
    con = np.asarray(out.full_conserve)
    part = np.asarray(out.partial)
    assert not (aon & ~con).any()
    assert not (part & con).any()
    # strict dominance on the same keys, the same traces
    assert con.sum() > aon.sum()
    # the hierarchical layer serves additional rounds beyond full decode
    assert part.sum() > 0


def test_fault_grid_compiles_once_per_signature():
    n, r, P, b = 8, 6, 4, 3
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    kwargs = dict(rounds=32, strategies=("lea",), r=r, packets=P, p1=1)

    def go(p_pre, p_drop, kstar):
        channel = faults.make_channel([
            ("preempt", {"p_preempt": jnp.asarray(p_pre)}),
            ("packet_bernoulli", {"p_drop": jnp.asarray(p_drop)}),
        ])
        return faults.sweep_faults(
            keys, _pool(b, n, kstar, 6, 2),
            jnp.full((b, n), 0.8), jnp.full((b, n), 0.7),
            MU_G, MU_B, DEADLINE, channel, 15, **kwargs,
        )

    c0 = faults.fault_compile_cache_size()
    go([0.1, 0.2, 0.3], [0.0, 0.1, 0.2], 30)
    after_first = faults.fault_compile_cache_size() - c0
    # different channel params, different traced K*: same compile
    go([0.5, 0.6, 0.7], [0.3, 0.0, 0.4], 25)
    assert faults.fault_compile_cache_size() - c0 == after_first == 1


def test_empty_channel_packets1_aon_matches_throughput_engine():
    """The fault engine's AON column degenerates to the existing batched
    engine's success indicators: same loads, same on-time rule."""
    from repro.core import throughput

    n, r, b = 8, 6, 3
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    pool = _pool(b, n, 30, 6, 2)
    p_gg = jnp.full((b, n), 0.8)
    p_bb = jnp.full((b, n), 0.7)
    out = faults.sweep_faults(
        keys, pool, p_gg, p_bb, MU_G, MU_B, DEADLINE, (), 15,
        rounds=64, strategies=("lea", "static"), r=r, packets=1, p1=1,
    )
    ref = jax.vmap(
        lambda k, pl, pg, pb: throughput.simulate_strategies_pool(
            k, pl, pg, pb, MU_G, MU_B, DEADLINE, 64,
            strategies=("lea", "static"),
        )
    )(keys, pool, p_gg, p_bb)
    np.testing.assert_array_equal(
        np.asarray(out.full_aon), np.asarray(ref).astype(bool)
    )
