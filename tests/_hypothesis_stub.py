"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The container this repo targets does not ship ``hypothesis`` and we cannot
install packages, so ``tests/conftest.py`` registers this module under
``sys.modules["hypothesis"]`` as a fallback.  It implements exactly the
surface the test-suite uses — ``@settings``, ``@given`` and the
``strategies.integers`` / ``strategies.floats`` strategies — by running each
property over a fixed number of deterministically-seeded random examples.

This is NOT a shrinking property-based tester; it is a seeded fuzz loop.  If
the real hypothesis is installed it always wins (conftest only installs this
stub on ImportError).
"""

from __future__ import annotations

import functools
import inspect
import random
import types

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator: records max_examples on the (given-wrapped) function."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    """Decorator: run the test over seeded random draws of each strategy."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            # seed from the test name so runs are deterministic but distinct
            rng = random.Random(f"hypothesis-stub:{fn.__module__}.{fn.__qualname__}")
            for _ in range(max_examples):
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # Hide the strategy-drawn parameters from pytest's fixture resolution:
        # drop __wrapped__ (inspect.signature would follow it) and expose only
        # the parameters NOT supplied by a strategy (e.g. real fixtures).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=kept)
        wrapper.hypothesis_stub = True
        return wrapper

    return deco


def assume(condition) -> bool:
    """Stub assume: silently tolerate (no rejection machinery) — callers in
    this suite only use it for cheap constraints that rarely fire."""
    return bool(condition)


def _as_module() -> types.ModuleType:
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st_mod, name, getattr(strategies, name))
    mod.strategies = st_mod
    mod.__stub__ = True
    return mod


def install_if_missing() -> types.ModuleType:
    """Make ``import hypothesis`` work: REAL package if installed, else stub.

    The real hypothesis always wins — dev environments that have it get
    genuine shrinking and example databases; only when the import machinery
    cannot find it at all (the pinned container) is the stub registered
    under ``sys.modules``.  Idempotent: repeated calls return whatever is
    already active, so conftest re-imports and direct script runs agree.
    """
    import importlib.util
    import sys

    existing = sys.modules.get("hypothesis")
    if existing is not None:
        return existing
    if importlib.util.find_spec("hypothesis") is not None:
        import hypothesis  # the real package

        return hypothesis
    mod = _as_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
    return mod
