"""End-to-end behaviour tests for the paper's system: the full trainer with
LEA-coded data parallelism, checkpoint/restart, and the serving driver."""

import jax
import numpy as np

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_trainer_end_to_end_with_coded_dp(tmp_path):
    """Train a reduced LM with the paper's scheduling layer in the loop:
    deadline misses cost rounds (not correctness), loss decreases, the
    timely-throughput metric is reported."""
    out = train_mod.main([
        "--arch", "qwen3_0_6b", "--smoke",
        "--steps", "14", "--batch", "8", "--seq", "32", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        "--coded-dp", "--dp-workers", "8", "--dp-r", "4", "--dp-shards", "8",
    ])
    losses = [h["loss"] for h in out["history"] if "loss" in h]
    assert len(losses) >= 5                      # most rounds hit the deadline
    assert losses[-1] < losses[0]
    assert 0.0 < out["timely_throughput"] <= 1.0


def test_trainer_resume_from_checkpoint(tmp_path):
    """Restart mid-run: step counter, data cursor and LEA estimator resume."""
    train_mod.main([
        "--arch", "qwen3_0_6b", "--smoke", "--steps", "10",
        "--batch", "8", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5", "--coded-dp",
    ])
    out = train_mod.main([
        "--arch", "qwen3_0_6b", "--smoke", "--steps", "14",
        "--batch", "8", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5", "--coded-dp",
    ])
    steps = [h["step"] for h in out["history"]]
    assert steps and min(steps) >= 10            # resumed, did not restart at 0


def test_serving_driver_reports_timely_throughput():
    out = serve_mod.main([
        "--smoke", "--rounds", "32", "--process", "constant",
        "--per-round", "1", "--deadline-rel", "5", "--capacity", "8",
        "--admit-threshold", "0.0", "--reserve-cap", "1e6",
    ])
    lea = out["lea"]
    assert lea["arrivals"] == 32
    assert lea["rejected"] == 0                  # admit-all
    # generous per-request deadline: (nearly) everything is served on time
    assert lea["timely_throughput"] >= 0.9
    assert lea["served_on_time"] == round(lea["timely_throughput"] * 32)
    assert lea["latency_p50"] >= 1.0
