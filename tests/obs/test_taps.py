"""Tap property tests: ``tap=on`` streams block aggregates DURING the
compiled scans while leaving every engine output bit-identical, tracing
zero callbacks when off, and adding zero compiles beyond the family's one
computation (the same contract ``telemetry=`` keeps)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, serving, sweeps
from repro.core import throughput
from repro.core.lea import PoolLoad
from repro.obs import (EVENT_STREAMS, TAP_ENGINES, capture_taps,
                       compile_events, validate_event)
from repro.obs.taps import resolve_stride, stride_boundaries

N = 8
ROUNDS = 48
STRATEGIES = ("lea", "static", "oracle")
KSTAR, ELL_G, ELL_B = 20, 5, 1
MU_G, MU_B, DEADLINE = 5.0, 1.0, 1.0
P_GG, P_BB = 0.8, 0.7


def _pool(n=N):
    return PoolLoad(
        kstar=jnp.int32(KSTAR), ell_g=jnp.int32(ELL_G), ell_b=jnp.int32(ELL_B),
        mask=jnp.ones((n,), bool),
    )


def _engine(key, *, tap=False, tap_stride=None, round_chunk=None):
    return throughput.simulate_strategies_pool(
        key, _pool(),
        jnp.full((N,), P_GG, jnp.float32), jnp.full((N,), P_BB, jnp.float32),
        MU_G, MU_B, DEADLINE, rounds=ROUNDS, strategies=STRATEGIES,
        round_chunk=round_chunk, tap=tap, tap_stride=tap_stride,
    )


# ---------------------------------------------------------------- helpers


def test_stride_helpers():
    assert resolve_stride(48, None) == 48
    assert resolve_stride(48, 16) == 16
    assert resolve_stride(8, 100) == 8        # clamped to the horizon
    with pytest.raises(ValueError):
        resolve_stride(48, 0)
    assert stride_boundaries(48, 16) == (16, 32, 48)
    assert stride_boundaries(48, 20) == (20, 40, 48)  # always ends at rounds
    assert stride_boundaries(48, 48) == (48,)


def test_event_streams_catalogue_matches_engines():
    assert set(EVENT_STREAMS) == set(TAP_ENGINES)


# ------------------------------------------------------------ core engine


@pytest.mark.parametrize("round_chunk", [None, 16, 20])
def test_engine_tap_bit_identical_and_off_is_silent(round_chunk):
    key = jax.random.PRNGKey(0)
    with capture_taps() as off_events:
        off = _engine(key, round_chunk=round_chunk)
        jax.block_until_ready(off)
    assert off_events == []                    # tap=off traces NO callbacks
    with capture_taps() as events:
        on = _engine(key, tap=True, tap_stride=16, round_chunk=round_chunk)
        jax.block_until_ready(on)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    assert len(events) > 0
    for e in events:
        validate_event(e)
        assert e["engine"] == "engine.pool"


def test_engine_tap_one_compile_per_signature():
    c0 = compile_events("engine.simulate_strategies_pool")
    with capture_taps():
        _engine(jax.random.PRNGKey(3), tap=True, tap_stride=16)
        c_on = compile_events("engine.simulate_strategies_pool") - c0
        _engine(jax.random.PRNGKey(4), tap=True, tap_stride=16)  # warm
    assert c_on <= 1
    assert compile_events("engine.simulate_strategies_pool") == c0 + c_on


def test_engine_tap_monotone_and_consistent_with_outputs():
    key = jax.random.PRNGKey(5)
    with capture_taps() as events:
        succ = _engine(key, tap=True, tap_stride=16)
        jax.block_until_ready(succ)
    events.sort(key=lambda e: int(e["block"]))
    done = [int(e["rounds_done"]) for e in events]
    assert done == [16, 32, 48]
    succ_cum = [np.asarray(e["succ_so_far"]) for e in events]
    for prev, cur in zip(succ_cum, succ_cum[1:]):
        assert (cur >= prev).all()             # cumulative successes grow
    thr = [np.asarray(e["throughput_so_far"]) for e in events]
    for t in thr:
        assert (t >= 0).all() and (t <= 1).all()
    # the final block aggregate IS the run total
    np.testing.assert_array_equal(
        succ_cum[-1], np.asarray(succ).astype(np.int64).sum(axis=0)
    )
    np.testing.assert_allclose(
        thr[-1], np.asarray(succ).mean(axis=0), rtol=1e-6
    )


def test_sweep_pool_tap_labels_rows():
    b = 3
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    pool = PoolLoad(
        kstar=jnp.full((b,), KSTAR, jnp.int32),
        ell_g=jnp.full((b,), ELL_G, jnp.int32),
        ell_b=jnp.full((b,), ELL_B, jnp.int32),
        mask=jnp.ones((b, N), bool),
    )
    args = (keys, pool,
            jnp.full((b, N), P_GG, jnp.float32),
            jnp.full((b, N), P_BB, jnp.float32),
            MU_G, MU_B, DEADLINE)
    kw = dict(rounds=32, strategies=("lea", "static"))
    off = throughput.sweep_pool(*args, **kw)
    with capture_taps() as events:
        on = throughput.sweep_pool(*args, tap=True, tap_stride=16, **kw)
        jax.block_until_ready(on)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    per_row = {}
    for e in events:
        validate_event(e)
        per_row.setdefault(int(e["row"]), []).append(e)
    assert sorted(per_row) == list(range(b))
    for es in per_row.values():
        es.sort(key=lambda e: int(e["block"]))
        assert [int(e["rounds_done"]) for e in es] == [16, 32]


# ---------------------------------------------------------------- faults


def _fault_args(b=3):
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    pool = PoolLoad(
        kstar=jnp.full((b,), KSTAR, jnp.int32),
        ell_g=jnp.full((b,), ELL_G, jnp.int32),
        ell_b=jnp.full((b,), ELL_B, jnp.int32),
        mask=jnp.ones((b, N), bool),
    )
    channel = faults.make_channel([
        ("preempt", {"p_preempt": jnp.full((b,), 0.3, jnp.float32)}),
        ("packet_bernoulli", {"p_drop": jnp.full((b,), 0.1, jnp.float32)}),
    ])
    return (keys, pool, jnp.full((b, N), P_GG, jnp.float32),
            jnp.full((b, N), P_BB, jnp.float32), MU_G, MU_B, DEADLINE,
            channel, 10)


def test_faults_tap_bit_identical_monotone_rows():
    args = _fault_args()
    kw = dict(rounds=32, strategies=("lea", "static"), r=2, packets=2, p1=1)
    off = faults.sweep_faults(*args, **kw)
    c0 = compile_events("faults.sweep")
    with capture_taps() as events:
        on = faults.sweep_faults(*args, tap=True, tap_stride=8, **kw)
        jax.block_until_ready(on)
    c_on = compile_events("faults.sweep") - c0
    on2 = faults.sweep_faults(*args, tap=True, tap_stride=8, **kw)
    jax.block_until_ready(on2)
    assert c_on <= 1
    assert compile_events("faults.sweep") == c0 + c_on    # warm repeat
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    per_row = {}
    for e in events:
        validate_event(e)
        assert e["engine"] == "faults.sweep"
        per_row.setdefault(int(e["row"]), []).append(e)
    assert sorted(per_row) == [0, 1, 2]
    for r, es in per_row.items():
        es.sort(key=lambda e: int(e["block"]))
        for key in ("recovered_aon_so_far", "recovered_conserve_so_far",
                    "partial_so_far", "preempted_so_far",
                    "packets_lost_so_far"):
            vals = [np.asarray(e[key]) for e in es]
            for prev, cur in zip(vals, vals[1:]):
                assert (cur >= prev).all(), (r, key)
        # final aggregates reconcile with the outcome streams
        last = es[-1]
        np.testing.assert_array_equal(
            np.asarray(last["recovered_aon_so_far"]),
            np.asarray(off.full_aon)[r].astype(np.int64).sum(axis=0),
        )
        # AON <= conserve pointwise, so the aggregates inherit the order
        assert (np.asarray(last["recovered_aon_so_far"])
                <= np.asarray(last["recovered_conserve_so_far"])).all()


# --------------------------------------------------------------- serving


def _serving_args(b=2):
    keys = jax.vmap(lambda i: jax.random.PRNGKey(100 + i))(jnp.arange(b))
    spec = serving.RequestSpec(
        kstar=jnp.full((b,), 50, jnp.int32),
        ell_g=jnp.full((b,), 10, jnp.int32),
        ell_b=jnp.full((b,), 3, jnp.int32),
        deadline_rel=jnp.full((b,), 3, jnp.int32),
        admit_threshold=jnp.zeros((b,), jnp.float32),
        reserve_cap=jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32),
    )
    process = serving.make_process(
        "poisson", rate=jnp.full((b,), 0.6, jnp.float32)
    )
    n = 15
    return (keys, jnp.ones((b, n), bool),
            jnp.full((b, n), P_GG, jnp.float32),
            jnp.full((b, n), P_BB, jnp.float32),
            10.0, 3.0, 1.0, spec, process)


def test_serving_tap_bit_identical_strategy_rows_one_compile():
    args = _serving_args()
    kw = dict(rounds=40, strategies=("lea",), capacity=2)
    off = serving.sweep_serving(*args, **kw)
    c0 = compile_events("serving.sweep")
    with capture_taps() as events:
        on = serving.sweep_serving(*args, tap=True, tap_stride=10, **kw)
        jax.block_until_ready(on)
    c_on = compile_events("serving.sweep") - c0
    on2 = serving.sweep_serving(*args, tap=True, tap_stride=10, **kw)
    jax.block_until_ready(on2)
    assert c_on <= 1
    assert compile_events("serving.sweep") == c0 + c_on
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    per = {}
    for e in events:
        validate_event(e)
        assert e["engine"] == "serving"
        per.setdefault((int(e["row"]), int(e["strategy"])), []).append(e)
    assert sorted(per) == [(0, 0), (1, 0)]
    for (r, s), es in per.items():
        es.sort(key=lambda e: int(e["block"]))
        assert [int(e["rounds_done"]) for e in es] == [10, 20, 30, 40]
        adm = [int(e["admitted_so_far"]) for e in es]
        srv = [int(e["served_on_time_so_far"]) for e in es]
        assert adm == sorted(adm) and srv == sorted(srv)
        # the final block aggregate IS the outcome counter
        assert adm[-1] == int(np.asarray(off.admitted)[r, s])
        assert srv[-1] == int(np.asarray(off.served_on_time)[r, s])
        # occupancy is bounded by the queue capacity
        assert all(0 <= int(e["occupancy"]) <= kw["capacity"] for e in es)


def test_serving_tap_streams_during_scan():
    """The acceptance gate: tap events land on the host strictly BEFORE the
    compiled scan completes — live streaming, not post-hoc replay."""
    args = _serving_args(b=1)
    with capture_taps() as events:
        out = serving.sweep_serving(
            *args, rounds=40, strategies=("lea",), capacity=2,
            tap=True, tap_stride=10,
        )
        jax.block_until_ready(out)
        done_t = time.perf_counter()
    assert len(events) == 4
    assert all(e["host_time"] < done_t for e in events)
    # block order is preserved per (row, strategy): the token chain
    # serializes the unordered callbacks
    times = [e["host_time"] for e in sorted(events,
                                            key=lambda e: int(e["block"]))]
    assert times == sorted(times)


# ------------------------------------------------------------- executor


def test_sweeps_executor_tap_threads_through():
    res_off = sweeps.run("deadline_sweep", seeds=1)
    with capture_taps() as events:
        res_on = sweeps.run("deadline_sweep", seeds=1, tap=True,
                            tap_stride=32)
    for a, b in zip(res_off, res_on):
        assert a.throughput == b.throughput
    assert len(events) > 0
    for e in events:
        validate_event(e)
        assert e["engine"] == "engine.pool"
        assert int(e["row"]) >= 0              # executor labels batch rows
