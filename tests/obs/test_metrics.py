"""Host metrics registry tests: naming convention, update semantics, the
three sinks (JSONL / exposition / progress line) and the phase-attribution
hooks the executors call."""

import io
import json
import time

import pytest

from repro.obs.metrics import (DEFAULT, JsonlSink, MetricsRegistry,
                               ProgressLine, record_compile, tap_to_registry,
                               timed, valid_name)


def test_naming_convention():
    assert valid_name("tap.engine_pool.events")
    assert valid_name("phase.sweeps_run_group.seconds")
    assert not valid_name("noseparator")          # needs >= 2 segments
    assert not valid_name("Upper.case")
    assert not valid_name("tap..events")
    assert not valid_name("tap.1digitfirst")
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("bad name")


def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    assert reg.counter("a.count") == 1.0
    assert reg.counter("a.count", 2.5) == 3.5
    with pytest.raises(ValueError):
        reg.counter("a.count", -1.0)              # counters are monotone
    reg.gauge("a.level", 7.0)
    assert reg.gauge("a.level", 3.0) == 3.0       # last value wins
    for v in (1.0, 5.0, 3.0):
        reg.histogram("a.lat", v)
    snap = reg.get("a.lat")
    assert snap == {"kind": "histogram", "count": 3, "sum": 9.0,
                    "min": 1.0, "max": 5.0}
    with pytest.raises(ValueError):
        reg.gauge("a.count", 1.0)                 # kind conflicts are errors
    with pytest.raises(KeyError):
        reg.get("a.missing")
    assert reg.names() == ("a.count", "a.lat", "a.level")


def test_exposition_format():
    reg = MetricsRegistry()
    reg.counter("tap.pool.events", 4)
    reg.histogram("phase.run.seconds", 0.5)
    text = reg.exposition()
    assert text.endswith("\n")
    assert "# TYPE tap_pool_events counter" in text
    assert "tap_pool_events 4.0" in text
    assert "# TYPE phase_run_seconds summary" in text
    assert "phase_run_seconds_count 1" in text
    assert "phase_run_seconds_sum 0.5" in text


def test_jsonl_sink_writes_and_never_raises(tmp_path):
    path = tmp_path / "events.jsonl"
    sink = JsonlSink(str(path))
    import numpy as np
    sink({"engine": "serving", "rounds_done": np.int32(8),
          "vec": np.arange(2)})
    sink({"bad": float("nan")})                   # allow_nan=False -> dropped
    assert sink.written == 1 and sink.errors == 1
    rec = json.loads(path.read_text().strip())
    assert rec == {"engine": "serving", "rounds_done": 8, "vec": [0, 1]}
    # unwritable path: every call counts an error, none raises
    bad = JsonlSink(str(tmp_path / "no" / "dir" / "x.jsonl"))
    bad({"engine": "x"})
    assert bad.errors == 1


def test_progress_line_renders_and_quiet_is_noop():
    buf = io.StringIO()
    p = ProgressLine(total=100, stream=buf, min_interval=0.0, label="t")
    p({"rounds_done": 50})
    p.update(100)
    p.close()
    out = buf.getvalue()
    assert "rounds/s" in out and "ETA" in out and "100/100" in out
    quiet = ProgressLine(total=100, stream=buf, enabled=False)
    before = buf.getvalue()
    quiet.update(10)
    quiet.close()
    assert buf.getvalue() == before               # --quiet writes nothing


def test_progress_line_eta_under_out_of_order_blocks():
    """Async pipelining delivers block events out of order across rows; the
    line must fold a per-(row, block) watermark, not a global max."""
    import random

    buf = io.StringIO()
    p = ProgressLine(total=100, stream=buf, min_interval=0.0)
    # 4 rows x 4 blocks of 25 rounds, shuffled delivery
    events = [{"row": r, "block": b, "rounds_done": (b + 1) * 25}
              for r in range(4) for b in range(4)]
    random.Random(0).shuffle(events)
    partial_done = []
    for e in events[:8]:
        p(e)
        partial_done.append(p.rounds_done)
    # a single max-watermark would already claim 100 after any one row's
    # final block; the per-row fold reports mean progress across rows seen
    first_final = next(i for i, e in enumerate(events) if e["rounds_done"] == 100)
    assert first_final < 8                       # shuffle really is adversarial
    assert any(d < 100 for d in partial_done[first_final:])
    for e in events[8:]:
        p(e)
    assert p.rounds_done == 100                  # all rows done -> exact
    # duplicate/late re-delivery of an old block cannot move progress back
    p({"row": 2, "block": 0, "rounds_done": 25})
    assert p.rounds_done == 100
    p.close()
    assert "100/100" in buf.getvalue()
    # host-side update() keeps the plain single-watermark semantics
    q = ProgressLine(total=10, stream=io.StringIO(), min_interval=0.0)
    q.update(7)
    q.update(3)
    assert q.rounds_done == 7


def test_tap_to_registry_folds_events():
    reg = MetricsRegistry()
    handler = tap_to_registry(reg)
    handler({"engine": "engine.pool", "block": 0, "row": 0,
             "rounds_done": 16, "host_time": 1.0})
    handler({"engine": "engine.pool", "block": 1, "row": 0,
             "rounds_done": 32, "host_time": 1.5})
    assert reg.get("tap.engine_pool.events")["value"] == 2.0
    assert reg.get("tap.engine_pool.rounds_done")["value"] == 32.0
    blk = reg.get("tap.engine_pool.block_seconds")
    assert blk["count"] == 1 and abs(blk["sum"] - 0.5) < 1e-9


def test_timed_and_record_compile():
    reg = MetricsRegistry()
    with timed("phase.demo", reg):
        time.sleep(0.01)
    snap = reg.get("phase.demo.seconds")
    assert snap["count"] == 1 and snap["sum"] >= 0.01
    record_compile("sweeps.run_group", 0, 1.0, reg)   # warm call: no metric
    assert "compile.sweeps_run_group.events" not in reg.names()
    record_compile("sweeps.run_group", 1, 2.0, reg)
    assert reg.get("compile.sweeps_run_group.events")["value"] == 1.0
    assert reg.get("compile.sweeps_run_group.seconds")["sum"] == 2.0


def test_default_registry_is_shared():
    name = "test.metrics_shared.probe"
    base = 0.0
    try:
        base = DEFAULT.get(name)["value"]
    except KeyError:
        pass
    DEFAULT.counter(name)
    assert DEFAULT.get(name)["value"] == base + 1.0
