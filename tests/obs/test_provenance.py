"""Run provenance, manifest stamping, compile-counter registry and the
REPRO_PROFILE gating of the profiling layer."""

import json
import os
import subprocess

import pytest

from repro.obs import (PROFILE_ENV, compile_events, counter_names, phase,
                       profile_dir, provenance, register_compiled)
from repro.obs.provenance import has_required_fields

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def test_provenance_has_every_schema_field_and_caller_timestamp():
    doc = provenance(1234.5)
    assert has_required_fields(doc)
    assert doc["timestamp"] == 1234.5
    assert doc["python"] and doc["platform"]
    # in-repo: the sha is the checkout's HEAD
    head = subprocess.run(
        ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
        cwd=_ROOT, timeout=30,
    ).stdout.strip()
    assert doc["git_sha"] == head
    assert isinstance(doc["git_dirty"], bool)
    # jax metadata is live in this environment
    assert doc["jax"] and doc["jaxlib"] and doc["backend"]
    json.dumps(doc, allow_nan=False)


def test_provenance_never_raises_outside_a_checkout(tmp_path):
    doc = provenance(0.0, root=str(tmp_path))
    assert doc["git_sha"] is None and doc["git_dirty"] is None
    assert has_required_fields(doc)


def test_manifest_and_write_manifest_stamp_provenance(tmp_path):
    from repro.sweeps import results as rmod

    doc = rmod.manifest([], bench="t", timestamp=99.0)
    assert doc["provenance"]["timestamp"] == 99.0
    assert doc["warnings"] == []
    # hand-assembled docs are stamped by the writer backstop
    path = tmp_path / "BENCH_x.json"
    rmod.write_manifest(path, {"bench": "x"})
    back = json.loads(path.read_text())
    assert has_required_fields(back["provenance"])
    assert back["warnings"] == []
    # an existing stamp is never overwritten
    rmod.write_manifest(path, {"bench": "x", "provenance": {"timestamp": 7.0}})
    assert json.loads(path.read_text())["provenance"] == {"timestamp": 7.0}


def test_counter_registry_names_and_totals():
    names = counter_names()
    for expected in ("engine.simulate_strategies_pool", "sweeps.run_group",
                     "faults.sweep", "serving.sweep"):
        assert expected in names, names
    assert names == tuple(sorted(names))
    total = compile_events()
    assert total == sum(compile_events(n) for n in names)
    # counters are monotonic within a process
    assert total >= 0


def test_register_compiled_rejects_uncallable_hooks():
    with pytest.raises(TypeError):
        register_compiled("bad.hook", object())


def test_profile_gating_and_phase_scope():
    import jax.numpy as jnp

    old = os.environ.pop(PROFILE_ENV, None)
    try:
        assert profile_dir() is None
        # the named scope is trace-time metadata: values are untouched
        with phase("allocate"):
            x = jnp.arange(3) * 2
        assert list(x) == [0, 2, 4]
    finally:
        if old is not None:
            os.environ[PROFILE_ENV] = old


def test_profile_trace_writes_a_trace_when_enabled(tmp_path):
    from repro.obs import annotate, profile_trace

    old = os.environ.get(PROFILE_ENV)
    os.environ[PROFILE_ENV] = str(tmp_path)
    try:
        import jax.numpy as jnp

        with profile_trace("test") as out:
            assert out == str(tmp_path)
            with annotate("span"):
                jnp.arange(4).sum().block_until_ready()
    finally:
        if old is None:
            os.environ.pop(PROFILE_ENV, None)
        else:
            os.environ[PROFILE_ENV] = old
    # jax.profiler drops its dump under plugins/profile/<run>/
    dumped = [p for p, _, files in os.walk(tmp_path) if files]
    assert dumped, "REPRO_PROFILE produced no trace files"
