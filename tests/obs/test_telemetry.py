"""Telemetry property tests: ``telemetry=on`` leaves every pre-existing
engine stream bit-identical and adds zero compiles beyond the family's one
computation (asserted via the unified ``repro.obs`` compile counter)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, serving, sweeps
from repro.core import throughput
from repro.core.lea import PoolLoad
from repro.obs import FaultTelemetry, ServingTelemetry, TelemetryFrame, compile_events

N = 8
ROUNDS = 48
STRATEGIES = ("lea", "static", "oracle")
KSTAR, ELL_G, ELL_B = 20, 5, 1
MU_G, MU_B, DEADLINE = 5.0, 1.0, 1.0
P_GG, P_BB = 0.8, 0.7


def _pool(n=N, mask=None):
    return PoolLoad(
        kstar=jnp.int32(KSTAR), ell_g=jnp.int32(ELL_G), ell_b=jnp.int32(ELL_B),
        mask=jnp.ones((n,), bool) if mask is None else mask,
    )


def _engine(key, telemetry, round_chunk=None):
    return throughput.simulate_strategies_pool(
        key, _pool(),
        jnp.full((N,), P_GG, jnp.float32), jnp.full((N,), P_BB, jnp.float32),
        MU_G, MU_B, DEADLINE, rounds=ROUNDS, strategies=STRATEGIES,
        round_chunk=round_chunk, telemetry=telemetry,
    )


def test_engine_telemetry_bit_identical_one_compile_each():
    key = jax.random.PRNGKey(0)
    c0 = compile_events("engine.simulate_strategies_pool")
    off = _engine(key, telemetry=False)
    c_off = compile_events("engine.simulate_strategies_pool") - c0
    on, frame = _engine(key, telemetry=True)
    c_on = compile_events("engine.simulate_strategies_pool") - c0 - c_off
    # the pre-existing stream is untouched, bit for bit
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    # telemetry=on is ONE computation of its own (no compile fragmentation);
    # repeats of either variant hit the cache (<= because an earlier test
    # may already have populated this signature)
    assert c_off <= 1 and c_on == 1, (c_off, c_on)
    _engine(key, telemetry=True)
    assert compile_events("engine.simulate_strategies_pool") == c0 + c_off + c_on
    assert isinstance(frame, TelemetryFrame)
    n_a = len(throughput.allocator_strategies(STRATEGIES))
    assert np.asarray(frame.est_err).shape == (ROUNDS, n_a)
    assert np.asarray(frame.prefix_size).shape == (ROUNDS, n_a)
    for leaf in (frame.load_total, frame.received, frame.feasible):
        assert np.asarray(leaf).shape == (ROUNDS, len(STRATEGIES))


def test_engine_oracle_estimator_error_is_exactly_zero():
    _, frame = _engine(jax.random.PRNGKey(1), telemetry=True)
    alloc = throughput.allocator_strategies(STRATEGIES)
    err = np.asarray(frame.est_err)
    oi = alloc.index("oracle")
    # the genie predicts with the genie's own truth
    np.testing.assert_array_equal(err[:, oi], np.zeros(ROUNDS, np.float32))
    # a real estimator is not the genie
    assert err[:, alloc.index("lea")].max() > 0.0


def test_engine_chunked_telemetry_bit_identical_to_unchunked():
    key = jax.random.PRNGKey(2)
    succ, frame = _engine(key, telemetry=True)
    succ_c, frame_c = _engine(key, telemetry=True, round_chunk=16)
    np.testing.assert_array_equal(np.asarray(succ), np.asarray(succ_c))
    for a, b in zip(frame, frame_c):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _fault_args(b=3):
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    pool = PoolLoad(
        kstar=jnp.full((b,), KSTAR, jnp.int32),
        ell_g=jnp.full((b,), ELL_G, jnp.int32),
        ell_b=jnp.full((b,), ELL_B, jnp.int32),
        mask=jnp.ones((b, N), bool),
    )
    channel = faults.make_channel([
        ("preempt", {"p_preempt": jnp.full((b,), 0.3, jnp.float32)}),
        ("packet_bernoulli", {"p_drop": jnp.full((b,), 0.1, jnp.float32)}),
    ])
    return (keys, pool, jnp.full((b, N), P_GG, jnp.float32),
            jnp.full((b, N), P_BB, jnp.float32), MU_G, MU_B, DEADLINE,
            channel, 10)


def test_faults_telemetry_bit_identical_one_compile():
    args = _fault_args()
    kw = dict(rounds=32, strategies=("lea", "static"), r=2, packets=2, p1=1)
    c0 = compile_events("faults.sweep")
    off = faults.sweep_faults(*args, **kw)
    on, tel = faults.sweep_faults(*args, telemetry=True, **kw)
    compiles = compile_events("faults.sweep") - c0
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert compiles <= 2, compiles     # one per static variant, no more
    assert isinstance(tel, FaultTelemetry)
    b_rows = np.asarray(args[2]).shape[0]
    assert np.asarray(tel.preempted).shape == (b_rows, 32)
    assert np.asarray(tel.packets_lost).shape == (b_rows, 32)
    assert np.asarray(tel.received_aon).shape == (b_rows, 32, 2)
    # conserve counts at least the AON packets, pointwise
    assert (np.asarray(tel.received_conserve)
            >= np.asarray(tel.received_aon)).all()
    # the channel actually fires (the counters are live streams, not zeros)
    assert np.asarray(tel.preempted).sum() > 0
    assert np.asarray(tel.packets_lost).sum() > 0


def _serving_args(b=2):
    keys = jax.vmap(lambda i: jax.random.PRNGKey(100 + i))(jnp.arange(b))
    spec = serving.RequestSpec(
        kstar=jnp.full((b,), 50, jnp.int32),
        ell_g=jnp.full((b,), 10, jnp.int32),
        ell_b=jnp.full((b,), 3, jnp.int32),
        deadline_rel=jnp.full((b,), 3, jnp.int32),
        admit_threshold=jnp.zeros((b,), jnp.float32),
        reserve_cap=jnp.full((b,), serving.ADMIT_ALL_CAP, jnp.float32),
    )
    process = serving.make_process(
        "poisson", rate=jnp.full((b,), 0.6, jnp.float32)
    )
    n = 15
    return (keys, jnp.ones((b, n), bool),
            jnp.full((b, n), P_GG, jnp.float32),
            jnp.full((b, n), P_BB, jnp.float32),
            10.0, 3.0, 1.0, spec, process)


def test_serving_telemetry_bit_identical_and_conserving():
    args = _serving_args()
    kw = dict(rounds=40, strategies=("lea",), capacity=2)
    c0 = compile_events("serving.sweep")
    off = serving.sweep_serving(*args, **kw)
    on, tel = serving.sweep_serving(*args, telemetry=True, **kw)
    compiles = compile_events("serving.sweep") - c0
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert compiles <= 2, compiles     # one per static variant, no more
    assert isinstance(tel, ServingTelemetry)
    arrivals_t = np.asarray(tel.arrivals_t)        # (B, M)
    admitted_t = np.asarray(tel.admitted_t)        # (B, S, M)
    rejected_t = np.asarray(tel.rejected_t)
    occupancy = np.asarray(tel.occupancy)
    # per-round admission conservation: every arrival admitted or rejected
    np.testing.assert_array_equal(
        admitted_t + rejected_t,
        np.broadcast_to(arrivals_t[:, None, :], admitted_t.shape),
    )
    # the per-round streams sum to the run counters
    np.testing.assert_array_equal(admitted_t.sum(-1), np.asarray(on.admitted))
    np.testing.assert_array_equal(rejected_t.sum(-1), np.asarray(on.rejected))
    # final occupancy is exactly the engine's in-flight count
    np.testing.assert_array_equal(occupancy[..., -1], np.asarray(on.in_flight))
    # the arrival stream matches the outcomes' own arrival counter
    np.testing.assert_array_equal(
        np.broadcast_to(arrivals_t.sum(-1)[:, None],
                        np.asarray(on.arrivals).shape),
        np.asarray(on.arrivals),
    )


def test_sweeps_executor_threads_telemetry_and_slices_batch():
    scenarios = sweeps.expand("hetero_kstar", ks=(50, 80), lams=(0.2,),
                              rounds=24)
    (group,) = sweeps.build_groups(scenarios, seeds=1)
    succ = sweeps.run_group(group)
    succ_t, frame = sweeps.run_group(group, telemetry=True)
    np.testing.assert_array_equal(succ, succ_t)
    b = group.batch.rows
    assert succ_t.shape[0] == b
    for leaf in frame:
        assert np.asarray(leaf).shape[0] == b
        assert np.asarray(leaf).shape[1] == group.rounds


def test_legacy_compile_counter_aliases_track_the_obs_counter():
    assert sweeps.compile_cache_size() == compile_events("sweeps.run_group")
    assert faults.fault_compile_cache_size() == compile_events("faults.sweep")
    assert (serving.serving_compile_cache_size()
            == compile_events("serving.sweep"))
    # and the unified total covers every registered family
    assert compile_events() >= (
        compile_events("sweeps.run_group")
        + compile_events("faults.sweep")
        + compile_events("serving.sweep")
    )


def test_unknown_counter_name_raises():
    with pytest.raises(KeyError):
        compile_events("no.such.counter")
