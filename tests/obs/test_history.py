"""Benchmark-history tests: the write_manifest append hook, record schema,
the robust trend detector, and the ``benchmarks/run.py --check`` gate."""

import json
import os
import subprocess
import sys

import pytest

from repro.obs import history
from repro.sweeps import results as sweeps_results

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _record(bench="sweep_smoke", **metrics):
    return {
        "schema": history.SCHEMA_VERSION,
        "bench": bench,
        "manifest": f"BENCH_{bench}.json",
        "written_at": 0.0,
        "provenance": {k: None for k in
                       ("git_sha", "git_dirty", "jax", "backend", "device",
                        "timestamp")},
        "metrics": metrics,
        "warnings": 0,
    }


def test_history_path_env_override(tmp_path, monkeypatch):
    manifest = tmp_path / "BENCH_x.json"
    assert history.history_path(manifest) == str(
        tmp_path / history.HISTORY_BASENAME
    )
    monkeypatch.setenv(history.HISTORY_ENV, "/elsewhere/h.jsonl")
    assert history.history_path(manifest) == "/elsewhere/h.jsonl"


def test_append_read_roundtrip_and_malformed_lines(tmp_path):
    path = tmp_path / "h.jsonl"
    rec = _record(rows_per_sec=100.0)
    assert history.append_record(path, rec)
    with open(path, "a") as f:
        f.write("{torn line\n\n")
    assert history.append_record(path, _record(rows_per_sec=101.0))
    got = history.read_history(path)
    assert len(got) == 2                       # torn line skipped, not fatal
    assert all(history.valid_record(r) for r in got)
    # a missing file is an empty history; appends to bad paths return False
    assert history.read_history(tmp_path / "missing.jsonl") == []
    assert not history.append_record(tmp_path / "no" / "dir" / "h.jsonl", rec)
    # non-JSON-able records (NaN) are refused, never raised
    assert not history.append_record(path, _record(x=float("nan")))


def test_write_manifest_appends_history(tmp_path):
    manifest = tmp_path / "BENCH_demo.json"
    for i in range(2):
        sweeps_results.write_manifest(
            manifest, {"bench": "demo", "rows_per_sec": 100.0 + i,
                       "flag": True, "results": [{"x": 1}]},
        )
    recs = history.read_history(history.history_path(manifest))
    assert [r["bench"] for r in recs] == ["demo", "demo"]
    for r in recs:
        assert history.valid_record(r)
        assert r["manifest"] == "BENCH_demo.json"
        # numeric non-bool TOP-LEVEL fields only: the bool and the result
        # rows stay in the manifest
        assert set(r["metrics"]) == {"rows_per_sec"}
    assert recs[0]["metrics"]["rows_per_sec"] == 100.0
    assert recs[1]["metrics"]["rows_per_sec"] == 101.0


def test_metric_direction():
    assert history.metric_direction("rows_per_sec") == "higher"
    assert history.metric_direction("speedup_matmul") == "higher"
    assert history.metric_direction("run_s") == "lower"
    assert history.metric_direction("compile_seconds") == "lower"
    assert history.metric_direction("us_per_call") == "lower"
    assert history.metric_direction("telemetry_compiles") is None
    assert history.metric_direction("trace_events") is None


def test_trend_report_flags_synthetic_slowdown():
    vals = [100.0, 101.0, 99.0, 100.5, 100.0, 40.0, 39.0]
    recs = [_record(rows_per_sec=v) for v in vals]
    report = history.trend_report(recs)
    assert report["entries"] == len(vals)
    assert report["benches"] == ["sweep_smoke"]
    hard = history.hard_regressions(report)
    assert len(hard) == 1
    (r,) = hard
    assert r["kind"] == "trend" and r["severity"] == "hard"
    assert r["bench"] == "sweep_smoke" and r["metric"] == "rows_per_sec"
    assert r["value"] == pytest.approx(39.5)
    assert r["baseline"] == pytest.approx(100.0)
    assert r["direction"] == "higher"
    assert "regressed" in r["message"]


def test_trend_report_lower_better_and_improvements():
    # wall-clock DOUBLES -> hard; throughput improves -> info only
    slow = [_record(run_s=1.0) for _ in range(5)] + \
           [_record(run_s=2.5), _record(run_s=2.6)]
    hard = history.hard_regressions(history.trend_report(slow))
    assert len(hard) == 1 and hard[0]["direction"] == "lower"
    up = [_record(rows_per_sec=100.0) for _ in range(5)] + \
         [_record(rows_per_sec=200.0), _record(rows_per_sec=210.0)]
    report = history.trend_report(up)
    assert history.hard_regressions(report) == []
    infos = [r for r in report["regressions"] if r["severity"] == "info"]
    assert len(infos) == 1 and "improved" in infos[0]["message"]


def test_trend_report_robust_to_noise_and_short_series():
    # single outlier inside the recent window cannot fire the detector
    # (median of recent=2), nor can normal machine noise within tolerance
    noisy = [_record(rows_per_sec=v)
             for v in [100, 98, 103, 101, 99, 100, 75]]
    assert history.hard_regressions(history.trend_report(noisy)) == []
    # short series: below min_points nothing is trended
    short = [_record(rows_per_sec=v) for v in [100, 100, 10, 10]]
    report = history.trend_report(short)
    assert report["regressions"] == []
    assert report["series"]["sweep_smoke"]["rows_per_sec"]["points"] == 4
    # non-perf metrics never produce series
    flat = [_record(trace_events=100.0) for _ in range(10)]
    assert history.trend_report(flat)["series"] == {}
    with pytest.raises(ValueError):
        history.trend_report([], recent=0)
    with pytest.raises(ValueError):
        history.trend_report([], recent=3, min_points=4)


def _run_check(history_path, tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               REPRO_BENCH_HISTORY=str(history_path))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--check", "--quiet",
         "table_kstar"],
        capture_output=True, text=True, timeout=560, cwd=_ROOT, env=env,
    )


def test_run_check_gates_on_doctored_history(tmp_path):
    doctored = tmp_path / "doctored.jsonl"
    vals = [100.0, 101.0, 99.0, 100.5, 100.0, 40.0, 39.0]
    with open(doctored, "w") as f:
        for v in vals:
            f.write(json.dumps(_record(rows_per_sec=v)) + "\n")
    proc = _run_check(doctored, tmp_path)
    assert proc.returncode == 2, proc.stderr
    assert "TREND REGRESSION" in proc.stderr
    assert "rows_per_sec" in proc.stderr


def test_run_check_passes_on_stable_history(tmp_path):
    stable = tmp_path / "stable.jsonl"
    with open(stable, "w") as f:
        for v in [100.0, 101.0, 99.0, 100.5, 100.0, 100.2, 99.8]:
            f.write(json.dumps(_record(rows_per_sec=v)) + "\n")
    proc = _run_check(stable, tmp_path)
    assert proc.returncode == 0, proc.stderr + proc.stdout
    assert "TREND REGRESSION" not in proc.stderr
