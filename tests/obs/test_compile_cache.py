"""Persistent compile cache (repro.launch.cache): warm restarts compile 0.

Two child processes share one ``REPRO_COMPILE_CACHE`` directory and run the
SAME sweep family.  The cold child populates the cache (real backend
compiles, 0 hits); the warm child must serve every computation from the
persistent cache — 0 backend compile events through the unified counter
(``counters.backend_compile_events``), and ``record_compile`` attributes
nothing to the metrics registry.
"""

import json
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))


def _child(cache_dir: str) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_HERE, "..", "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(_HERE, "_cache_child.py"), cache_dir],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_warm_process_records_zero_compile_events(tmp_path):
    cache_dir = str(tmp_path / "xla_cache")

    cold = _child(cache_dir)
    assert cold["trace_entries"] >= 1
    assert cold["persistent_hits"] == 0
    assert cold["persistent_misses"] >= 1          # cache was really on
    assert cold["backend_compiles"] == cold["trace_entries"]
    assert cold["recorded_compile_metric"] == cold["trace_entries"]
    assert os.listdir(cache_dir)                   # entries persisted

    warm = _child(cache_dir)
    assert warm["trace_entries"] == cold["trace_entries"]  # same tracing
    assert warm["persistent_hits"] >= warm["trace_entries"]
    assert warm["backend_compiles"] == 0           # THE warm-restart contract
    assert warm["recorded_compile_metric"] is None  # nothing attributed


def test_enable_is_idempotent_but_rejects_redirect(tmp_path, monkeypatch):
    from repro.launch import cache as cache_mod

    monkeypatch.setattr(cache_mod, "_STATE",
                        {"enabled_dir": None, "listener": True, "misses": 0})
    d1 = str(tmp_path / "a")
    try:
        assert cache_mod.enable_compile_cache(d1) == os.path.abspath(d1)
        assert cache_mod.enable_compile_cache(d1) == os.path.abspath(d1)
        with pytest.raises(RuntimeError, match="already enabled"):
            cache_mod.enable_compile_cache(str(tmp_path / "b"))
    finally:
        # tmp_path dies with the test; leaving the global cache dir pointed
        # at it would make every later compile in this process write there
        import jax

        jax.config.update("jax_compilation_cache_dir", None)


def test_disabled_when_env_unset(monkeypatch):
    from repro.launch import cache as cache_mod

    monkeypatch.delenv(cache_mod.CACHE_ENV, raising=False)
    monkeypatch.setattr(cache_mod, "_STATE",
                        {"enabled_dir": None, "listener": True, "misses": 0})
    assert cache_mod.enable_compile_cache() is None
    assert cache_mod.cache_dir() is None
