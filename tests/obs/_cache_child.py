"""Child process for the persistent compile-cache warm-restart test.

Usage: ``python _cache_child.py <cache_dir>``.  Enables the cache at
``cache_dir``, runs one sweep group (the same family/shapes every
invocation), and prints one JSON line with the unified compile accounting:
trace-cache entries, persistent-cache hits, and the backend compile events
(trace entries minus hits — what ``record_compile`` attributes).
"""

import json
import sys


def main() -> None:
    from repro.launch.cache import enable_compile_cache, persistent_cache_misses

    assert enable_compile_cache(sys.argv[1]) is not None

    from repro.obs import counters
    from repro.obs.metrics import DEFAULT as registry
    from repro.sweeps import executor
    from repro.sweeps.registry import build_groups, expand

    scens = expand("hetero_kstar", ks=(50, 99), lams=(0.2,), rounds=32)
    (group,) = build_groups(scens, seeds=1)
    executor.run_group(group, round_chunk=16)

    try:  # absent on a warm restart: record_compile skips 0-event calls
        snap = registry.get("compile.sweeps_run_group.events")
    except KeyError:
        snap = None
    print(json.dumps({
        "trace_entries": counters.compile_events("sweeps.run_group"),
        "persistent_hits": counters.persistent_cache_hits(),
        "persistent_misses": persistent_cache_misses(),
        "backend_compiles": counters.backend_compile_events("sweeps.run_group"),
        "recorded_compile_metric": None if snap is None else snap["value"],
    }))


if __name__ == "__main__":
    main()
