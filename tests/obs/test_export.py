"""Exporter round-trip tests: metric tables, Chrome trace-event JSON,
validation and disposition conservation — all on synthetic frames, no
engine required."""

import json

import numpy as np
import pytest

from repro.obs import (ServingTelemetry, TelemetryFrame, metric_streams,
                       metric_table, serving_trace, validate_trace,
                       write_trace)

M = 6


def _frame():
    return TelemetryFrame(
        est_err=np.arange(M * 2, dtype=np.float32).reshape(M, 2),
        prefix_size=np.full((M, 2), 5, np.int32),
        load_total=np.full((M, 3), 40, np.int32),
        received=np.full((M, 3), 20, np.int32),
        feasible=np.ones((M, 3), bool),
    )


def test_metric_streams_names_and_axes():
    streams = metric_streams(
        _frame(), strategies=("lea", "static", "oracle"),
        alloc_strategies=("lea", "oracle"),
    )
    assert set(streams) == {
        "est_err/lea", "est_err/oracle", "prefix_size/lea",
        "prefix_size/oracle",
        "load_total/lea", "load_total/static", "load_total/oracle",
        "received/lea", "received/static", "received/oracle",
        "feasible/lea", "feasible/static", "feasible/oracle",
    }
    for vec in streams.values():
        assert vec.shape == (M,)
    np.testing.assert_array_equal(
        streams["est_err/oracle"], np.arange(M * 2).reshape(M, 2)[:, 1]
    )


def test_metric_streams_strategy_major_leaves_are_transposed():
    tel = ServingTelemetry(
        arrivals_t=np.arange(M, dtype=np.int32),
        occupancy=np.arange(2 * M, dtype=np.int32).reshape(2, M),
        admitted_t=np.zeros((2, M), np.int32),
        rejected_t=np.zeros((2, M), np.int32),
    )
    streams = metric_streams(tel, strategies=("lea", "greedy"))
    np.testing.assert_array_equal(streams["arrivals_t"], np.arange(M))
    # (S, M) leaves come out per-strategy along rounds
    np.testing.assert_array_equal(streams["occupancy/greedy"],
                                  np.arange(M, 2 * M))


def test_metric_streams_rejects_batched_frames():
    batched = TelemetryFrame(*[np.zeros((2, M, 3))] * 5)
    with pytest.raises(ValueError, match="batch row"):
        metric_streams(batched)


def test_metric_streams_rejects_wrong_name_count():
    with pytest.raises(ValueError):
        metric_streams(_frame(), strategies=("lea",))


def test_metric_table_rows_are_json_safe_summaries():
    rows = metric_table(_frame(), strategies=("a", "b", "c"),
                        alloc_strategies=("a", "c"))
    by_name = {r["metric"]: r for r in rows}
    r = by_name["est_err/a"]
    assert r["rounds"] == M
    assert r["min"] == 0.0 and r["last"] == float((M - 1) * 2)
    json.dumps(rows, allow_nan=False)


def _events_sojourn():
    # (S=1, M, Q=2): codes 1/2/3 at chosen (round, slot) cells
    ev = np.zeros((1, M, 2), np.int32)
    so = np.zeros((1, M, 2), np.int32)
    ev[0, 2, 0], so[0, 2, 0] = 1, 2      # on_time, 2-round sojourn
    ev[0, 4, 1], so[0, 4, 1] = 2, 3      # late
    ev[0, 5, 0], so[0, 5, 0] = 3, 4      # expired
    return ev, so


def test_serving_trace_round_trips_and_conserves_dispositions(tmp_path):
    ev, so = _events_sojourn()
    tel = ServingTelemetry(
        arrivals_t=np.ones(M, np.int32),
        occupancy=np.ones((1, M), np.int32),
        admitted_t=np.ones((1, M), np.int32),
        rejected_t=np.zeros((1, M), np.int32),
    )
    doc = serving_trace(ev, so, strategies=("lea",), telemetry=tel)
    stats = validate_trace(doc)
    assert stats["complete"] == 3
    assert stats["dispositions"] == {"on_time": 1, "late": 1, "expired": 1}
    # deterministic timestamps: round index x round_us
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    on_time = next(e for e in x if e["name"] == "on_time")
    assert on_time["ts"] == (2 - 2 + 1) * 1000.0
    assert on_time["dur"] == 2 * 1000.0
    # occupancy counters ride along
    assert sum(e["ph"] == "C" for e in doc["traceEvents"]) == M
    # file round-trip through the strict writer
    path = tmp_path / "trace.json"
    write_trace(path, doc)
    back = json.loads(path.read_text())
    assert back == json.loads(json.dumps(doc))
    assert validate_trace(back) == stats


def test_serving_trace_is_deterministic():
    ev, so = _events_sojourn()
    assert serving_trace(ev, so) == serving_trace(ev, so)


def test_serving_trace_rejects_mismatched_shapes():
    ev, so = _events_sojourn()
    with pytest.raises(ValueError):
        serving_trace(ev, so[:, :-1])
    with pytest.raises(ValueError):
        serving_trace(ev[0], so[0])     # batch row already selected twice


def test_event_names_mirror_the_serving_engine_constants():
    # obs keeps the code->name map literal (it must not import the engines);
    # this is the cross-check that the two stay in sync
    from repro import serving
    from repro.obs import telemetry as tmod

    assert tmod._EVENT_NAMES == {
        serving.EVENT_ON_TIME: "on_time",
        serving.EVENT_LATE: "late",
        serving.EVENT_EXPIRED: "expired",
    }


def test_validate_trace_rejects_malformed_documents():
    with pytest.raises(ValueError):
        validate_trace({"no": "traceEvents"})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "name": "x"}]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "x", "pid": 0, "tid": 0, "ts": 0.0, "dur": 0.0}
        ]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "Z", "name": "x", "pid": 0, "tid": 0}
        ]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "C", "name": "x", "pid": 0, "tid": 0,
             "args": {"v": float("nan")}}
        ]})
